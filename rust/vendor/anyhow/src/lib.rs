//! Offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the anyhow 1.x API this repository uses —
//! `anyhow!`, `bail!`, `ensure!`, `Result<T>`, `Error`, and the `Context`
//! extension trait on `Result` and `Option` — with no dependencies, so the
//! workspace builds with no network and no registry. The error value is a
//! chain of display frames (outermost context first); `{e}` prints the
//! outermost frame and `{e:#}` prints the full chain joined by `": "`,
//! matching anyhow's Display behaviour.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: a chain of context frames, outermost first.
pub struct Error {
    frames: Vec<String>,
}

/// `Result<T>` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap this error in an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context/cause frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }

    /// The innermost (root-cause) frame.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn macros_and_display() {
        let name = "x";
        let e = anyhow!("missing tensor {name:?}");
        assert_eq!(e.to_string(), "missing tensor \"x\"");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e = io_fail().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.with_context(|| "empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<i32> {
            ensure!(ok, "must be ok");
            if !ok {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "must be ok");
    }
}
