//! Offline host stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate wraps the XLA C API: PJRT client, compiled executables,
//! and device buffers. This vendored stand-in keeps the crate buildable
//! and testable on machines without the XLA runtime:
//!
//! - [`Literal`] is a **fully functional** host tensor (f32/i32 payload +
//!   dims): `vec1`, `reshape`, `to_vec`, `shape`, `element_count` behave
//!   like the real crate, so checkpoint/tensor round-trips and every unit
//!   test that stays on the host work unchanged.
//! - [`PjRtClient::cpu`] succeeds (so `Runtime::open` works and manifest
//!   driven code paths run), but `compile`/`execute` return a clear
//!   "stub" error. Integration tests already self-skip when `artifacts/`
//!   is missing, and the serving/engine layers never touch PJRT.
//!
//! Swap this path dependency for the real `xla` crate to run the HLO
//! train/eval artifacts.

use std::fmt;

/// Error type mirroring `xla::Error` well enough for `?` conversions.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "the vendored xla stub cannot compile or execute HLO; \
     link the real xla runtime (see rust/vendor/xla/src/lib.rs) to run artifacts";

// ----------------------------------------------------------------------
// host literals
// ----------------------------------------------------------------------

/// Internal payload storage — public only because the [`NativeType`]
/// trait mentions it; not part of the mirrored xla API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Sized + Copy {
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<f32>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<i32>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

/// Array shape (dims only; the stub carries no layout/element-type info
/// beyond the payload tag).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal: a dense array or a tuple.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: T::wrap(data.to_vec()),
        }
    }

    /// Same payload under new dims (element counts must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.payload.len() {
            return Err(Error::new(format!(
                "reshape to {:?} wants {} elements, literal has {}",
                dims,
                n,
                self.payload.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            payload: self.payload.clone(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone() }))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error::new("literal payload has a different element type"))
    }

    /// The stub never materializes tuple literals (only `execute` would).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new(UNAVAILABLE))
    }
}

// ----------------------------------------------------------------------
// PJRT stubs
// ----------------------------------------------------------------------

/// HLO module handle; the stub keeps only the source path for messages.
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error::new(format!("no such HLO text file: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// Computation handle produced from an [`HloModuleProto`].
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// Device buffer: in the stub, a host literal.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A compiled executable (never constructible through the stub client).
pub struct PjRtLoadedExecutable {
    path: String,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!("execute({}): {UNAVAILABLE}", self.path)))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!("execute_b({}): {UNAVAILABLE}", self.path)))
    }
}

/// PJRT client. `cpu()` succeeds so manifest-driven host code paths run;
/// compilation is where the stub draws the line.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(format!("compile({}): {UNAVAILABLE}", computation.path)))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let d: Vec<i64> = dims.iter().map(|&v| v as i64).collect();
        let lit = Literal::vec1(data).reshape(&d)?;
        Ok(PjRtBuffer { lit })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        match r.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            other => panic!("unexpected shape {other:?}"),
        }
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[5i32]).reshape(&[]).unwrap();
        assert_eq!(l.element_count(), 1);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5]);
    }

    #[test]
    fn client_is_host_only() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let b = c
            .buffer_from_host_buffer(&[1.0f32, 2.0], &[2], None)
            .unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }
}
