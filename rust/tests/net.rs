//! Network front-end integration: the overload-hardening (chaos) gate
//! and the wire-determinism contract, end to end over real TCP sockets
//! on a synthetic (manifest-free) model spec — no artifacts needed, so
//! these always run.
//!
//! - The chaos gate drives the server with open-loop multi-connection
//!   bursts far past saturation, with seeded fault injection corrupting
//!   frames, delaying reads, stalling accepts, and killing connections
//!   mid-stream. The server must come out of it with the books balanced
//!   (`submitted == completed + rejected + expired + canceled`), having
//!   shed rather than queued unboundedly, with every surviving
//!   connection holding only well-formed frames — and it must shut down
//!   cleanly (a hang here IS the failure).
//! - The determinism test pins that the bytes a TCP client reads in a
//!   `done` frame are exactly [`terminal_frame`] of the in-process
//!   [`Server`]'s response for the same requests, across every ternary
//!   kernel generation and thread count — the network layer adds
//!   transport, never drift.

// Test crate roots sit outside src/lib.rs, so the Cargo.toml clippy
// deny-list is re-allowed here (clippy.toml only exempts #[test] fns,
// not the shared helpers): panicking is how a test fails.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use bitnet_distill::engine::{Engine, KernelKind};
use bitnet_distill::params::ParamStore;
use bitnet_distill::runtime::ModelSpec;
use bitnet_distill::serve::net::terminal_frame;
use bitnet_distill::serve::{
    FaultPlan, NetCfg, NetServer, Request, Server, ServerCfg,
};
use bitnet_distill::substrate::{Json, Rng};

fn engine() -> Engine {
    let spec = ModelSpec::synthetic("tiny").unwrap();
    let mut rng = Rng::new(11);
    let params = ParamStore::init(&spec, &mut rng);
    Engine::from_params(&spec, &params, true).unwrap()
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

/// The chaos gate: ~4x-saturation open-loop load (clients push bursts
/// without waiting for responses, queue capacity 8) over several
/// connections, under the full seeded fault mix. Passing means: the run
/// drains and returns (no deadlock, no panic escaped containment), the
/// stats invariant balances exactly, overload was shed rather than
/// buffered, and the surviving clients saw only well-formed frames with
/// bounded completed-request latency.
#[test]
fn chaos_gate_overload_with_fault_injection_sheds_and_balances() {
    let e = engine();
    let cfg = NetCfg {
        // writer gives up fast on killed clients; reader tick stays at
        // the default so shutdown latency is bounded
        write_timeout: Duration::from_millis(500),
        ..NetCfg::default()
    };
    let net = NetServer::bind(cfg).unwrap();
    let addr = net.local_addr().unwrap();
    let scfg = ServerCfg { max_batch: 2, max_queue: 8, ..ServerCfg::default() };

    let n_clients = 4usize;
    let per_client = 25usize;
    let (report, client_results) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            handles.push(s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                let mut rng = Rng::new(1000 + c as u64);
                for i in 0..per_client {
                    let line = format!(
                        r#"{{"op":"generate","prompt":[{},4,6],"max_new":16,"deadline_ms":250}}"#,
                        1 + (c + i) % 5
                    );
                    stream.write_all(line.as_bytes()).ok();
                    stream.write_all(b"\n").ok();
                    // open-loop-ish jittered arrivals: never wait for a
                    // response before sending the next request
                    std::thread::sleep(Duration::from_micros(
                        200 + (rng.f64() * 800.0) as u64,
                    ));
                }
                if c < 2 {
                    // these two vanish mid-stream with responses unread:
                    // the abortive close must cancel their outstanding
                    // requests, not leak their KV slots
                    return (c, Vec::new(), true);
                }
                // well-behaved clients half-close (EOF) and drain
                let reader = stream.try_clone().unwrap();
                stream.shutdown(std::net::Shutdown::Write).ok();
                let mut lines = Vec::new();
                for l in BufReader::new(reader).lines() {
                    let Ok(l) = l else { break };
                    lines.push(l);
                }
                (c, lines, false)
            }));
        }
        let shutdown_handle = s.spawn(move || {
            // wait for the load to finish, then ask for a clean drain
            std::thread::sleep(Duration::from_millis(300));
            if let Ok(mut stream) = TcpStream::connect(addr) {
                send_line(&mut stream, r#"{"op":"shutdown"}"#);
            }
        });
        let report = net.run(&e, scfg, FaultPlan::chaos(42));
        shutdown_handle.join().unwrap();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (report, results)
    });

    // the books balance exactly — nothing was lost to a panic, a killed
    // connection, or a corrupted frame
    assert_eq!(
        report.stats.accounted(),
        report.stats.submitted,
        "stats must balance: {:?}",
        report.stats
    );
    // overload + faults must have produced *some* shedding: scheduler
    // rejects (queue full), deadline expiry, disconnect cancels, or
    // wire-level rejects from corrupted frames
    let shed = report.stats.rejected
        + report.stats.expired
        + report.stats.canceled
        + report.wire_rejects as usize;
    assert!(
        shed > 0,
        "4x-saturation chaos load produced zero shedding: {:?} wire_rejects={}",
        report.stats,
        report.wire_rejects
    );
    assert!(report.conns_accepted >= n_clients as u64);

    // surviving clients: every line is a well-formed frame of a known
    // kind, and completed-request latency stayed bounded (deadline
    // shedding caps queue sojourn; nothing waited unboundedly)
    let mut timing_totals = Vec::new();
    for (c, lines, dropped) in &client_results {
        if *dropped {
            continue;
        }
        assert!(
            !lines.is_empty(),
            "surviving client {c} got no frames at all"
        );
        for l in lines {
            let j = Json::parse(l).unwrap_or_else(|e| panic!("client {c} bad frame {l:?}: {e}"));
            let kind = j.get("frame").and_then(Json::as_str).unwrap();
            assert!(
                ["token", "done", "timing", "reject", "canceled"].contains(&kind),
                "client {c} unknown frame kind {kind:?}"
            );
            if kind == "timing" {
                if let Some(t) = j.get("total_ms").and_then(Json::as_f64) {
                    timing_totals.push(t);
                }
            }
        }
    }
    timing_totals.sort_by(f64::total_cmp);
    if let Some(&worst) = timing_totals.last() {
        assert!(
            worst < 10_000.0,
            "completed-request latency unbounded under overload: {worst}ms"
        );
    }
}

/// Wire determinism: for the same requests, the `done` frame bytes a TCP
/// client reads are exactly `terminal_frame()` of the in-process
/// server's responses — across every ternary kernel generation and
/// thread count. (The `timing` frame carries the wall-clock numbers; the
/// `done` frame is deliberately timing-free so it can be byte-pinned.)
#[test]
fn tcp_done_frames_are_byte_identical_to_in_process_responses() {
    let e = engine();
    let frames_in = [
        r#"{"op":"generate","prompt":[1,4,6],"max_new":8}"#,
        r#"{"op":"generate","prompt":[9,2],"max_new":5,"eos":3}"#,
        r#"{"op":"classify","prompt":[2,3,5],"labels":[7,8,9]}"#,
        r#"{"op":"generate","prompt":[5,5,5,5],"max_new":3}"#,
    ];
    // the same requests, built the way frame::parse_frame builds them
    let reqs = [
        Request::generate(vec![1, 4, 6], 8),
        {
            let mut r = Request::generate(vec![9, 2], 5);
            r.eos = 3;
            r
        },
        Request::classify(vec![2, 3, 5], vec![7, 8, 9]),
        Request::generate(vec![5, 5, 5, 5], 3),
    ];

    for kernel in KernelKind::ALL {
        for threads in [1usize, 2] {
            let scfg = ServerCfg { kernel, threads, ..ServerCfg::default() };

            // in-process ground truth: id -> terminal frame bytes
            let mut srv = Server::new(&e, scfg);
            for r in &reqs {
                srv.submit(r.clone());
            }
            let expect: BTreeMap<u64, String> = srv
                .run_to_completion()
                .iter()
                .map(|r| (r.id, terminal_frame(r)))
                .collect();
            assert_eq!(expect.len(), reqs.len());

            // the same requests over TCP
            let net = NetServer::bind(NetCfg::default()).unwrap();
            let addr = net.local_addr().unwrap();
            let lines = std::thread::scope(|s| {
                let h = s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                    for f in frames_in {
                        send_line(&mut stream, f);
                    }
                    send_line(&mut stream, r#"{"op":"shutdown"}"#);
                    let mut lines = Vec::new();
                    for l in BufReader::new(stream).lines() {
                        let Ok(l) = l else { break };
                        lines.push(l);
                    }
                    lines
                });
                let report = net.run(&e, scfg, FaultPlan::off());
                assert_eq!(report.stats.completed, reqs.len());
                h.join().unwrap()
            });

            let mut seen = 0usize;
            for l in &lines {
                let j = Json::parse(l).unwrap();
                if j.get("frame").and_then(Json::as_str) != Some("done") {
                    continue;
                }
                let id = j.get("id").and_then(Json::as_f64).unwrap() as u64;
                assert_eq!(
                    l,
                    expect.get(&id).unwrap(),
                    "kernel={} threads={threads} id={id}: TCP bytes drifted from \
                     the in-process response",
                    kernel.name()
                );
                seen += 1;
            }
            assert_eq!(
                seen,
                reqs.len(),
                "kernel={} threads={threads}: expected one done frame per request",
                kernel.name()
            );
        }
    }
}
