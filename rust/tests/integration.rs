//! Integration tests over the real AOT artifacts: the L1<->L2<->L3
//! composition proofs. These require `make artifacts` to have run; they
//! self-skip (with a loud message) when artifacts/ is missing so plain
//! `cargo test` works in a fresh checkout.

// Test crate roots sit outside src/lib.rs, so the Cargo.toml clippy
// deny-list is re-allowed here (clippy.toml only exempts #[test] fns,
// not the shared helpers): panicking is how a test fails.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench;
use bitnet_distill::data::{CorpusBatcher, CorpusStream, Task, TaskGen, Tokenizer};
use bitnet_distill::engine::{act_quant_i8, Engine, TernaryMatrix};
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::{self, stages, Ctx, StudentOpts, Trainer};
use bitnet_distill::runtime::Runtime;
use bitnet_distill::substrate::Rng;
use bitnet_distill::tensor::{TensorF32, TensorI32};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open("artifacts").expect("open runtime"))
}

#[test]
fn manifest_covers_every_size_and_variant() {
    let Some(rt) = runtime() else { return };
    for size in ["tiny", "small", "base", "gemmaish", "qwenish"] {
        rt.manifest.model(&stages::teacher_key(size)).unwrap();
        rt.manifest.model(&stages::model_key(size, true, "absmean")).unwrap();
        rt.manifest.artifact(&format!("{size}_lm_train")).unwrap();
        rt.manifest.artifact(&format!("{size}_bitnet_train")).unwrap();
        rt.manifest.artifact(&format!("{size}_distill_train")).unwrap();
    }
    for q in ["block", "gptq", "awq"] {
        rt.manifest
            .artifact(&format!("tiny_distill_train_{q}"))
            .unwrap();
    }
    rt.manifest.artifact("bitlinear_pallas").unwrap();
}

#[test]
fn lm_train_step_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.model("tiny-nosubln-none").unwrap();
    let mut rng = Rng::new(1);
    let params = ParamStore::init(spec, &mut rng);
    let tok = Tokenizer::new(rt.manifest.vocab);
    let stream = CorpusStream::new(&tok, rt.manifest.seq, 3);
    let mut batches = CorpusBatcher::new(stream, rt.manifest.batch, rt.manifest.seq);
    let mut tr = Trainer::new(&rt, "tiny_lm_train", params);
    let mut first = 0.0;
    let mut last = 0.0;
    for s in 0..20 {
        let b = batches.next_batch();
        last = tr.train_step(&b, 2e-3).unwrap();
        if s == 0 {
            first = last;
        }
    }
    assert!(
        last < first - 1.0,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn distill_step_composes_losses() {
    let Some(rt) = runtime() else { return };
    let scfg = rt.manifest.model("tiny-subln-absmean").unwrap();
    let tcfg = rt.manifest.model("tiny-nosubln-none").unwrap();
    let mut rng = Rng::new(2);
    let sp = ParamStore::init(scfg, &mut rng);
    let tp = ParamStore::init(tcfg, &mut rng);
    let tok = Tokenizer::new(rt.manifest.vocab);
    let gen = TaskGen::new(Task::Mnli, &tok, rt.manifest.seq);
    let ds = gen.dataset(16, 5);
    let mut batches =
        bitnet_distill::data::Batcher::new(&ds, rt.manifest.batch, rt.manifest.seq, 1);
    let mut tr = Trainer::new(&rt, "tiny_distill_train", sp);
    let b = batches.next_batch();
    let l = tr.distill_step(&tp, &b, 1e-3, 10.0, 1e5, 2).unwrap();
    assert!(l.total.is_finite() && l.ce > 0.0 && l.ld >= 0.0 && l.ad >= 0.0);
    let recomposed = l.ce + 10.0 * l.ld + 1e5 * l.ad;
    assert!(
        (l.total - recomposed).abs() < 0.01 * l.total.max(1.0),
        "eq. 13 decomposition broken: {l:?}"
    );
    // zero coefficients reduce to plain CE (+AD/LD reported but unweighted)
    let l0 = tr.distill_step(&tp, &b, 1e-3, 0.0, 0.0, 2).unwrap();
    assert!((l0.total - l0.ce).abs() < 1e-4 * l0.ce.max(1.0));
}

#[test]
fn engine_matches_hlo_fwd() {
    let Some(rt) = runtime() else { return };
    let (tern, f) = bench::parity_check(&rt, "tiny").unwrap();
    // f32 engine must match the teacher HLO almost exactly; the ternary
    // path tolerates rounding-boundary trit flips (different f32
    // reduction orders for Delta/gamma), which bound at ~5e-2 relative.
    assert!(f < 1e-4, "teacher parity broke: {f}");
    assert!(tern < 8e-2, "ternary parity broke: {tern}");
}

#[test]
fn pallas_kernel_artifact_matches_rust_ternary_path() {
    // The L1 composition proof: execute the *actual pallas kernel* HLO
    // from rust and compare against the engine's packed-ternary GEMV.
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    let (m, k, n) = (64usize, 128usize, 256usize);
    let mut x = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut w, 0.05);
    let xt = TensorF32::from_vec(&[m, k], x.clone()).unwrap();
    let wt = TensorF32::from_vec(&[k, n], w.clone()).unwrap();
    let outs = rt
        .run_f32(
            "bitlinear_pallas",
            &[xt.to_literal().unwrap(), wt.to_literal().unwrap()],
        )
        .unwrap();
    let y_hlo = &outs[0];
    assert_eq!(y_hlo.shape, vec![m, n]);

    let tm = TernaryMatrix::from_xw_f32(&w, k, n);
    let mut q = vec![0i8; k];
    let mut y_rust = vec![0.0f32; n];
    let mut worst = 0.0f32;
    for row in 0..m {
        let gamma = act_quant_i8(&x[row * k..(row + 1) * k], &mut q);
        bitnet_distill::engine::gemv::gemv_ternary(&tm, &q, gamma, &mut y_rust);
        for c in 0..n {
            let hv = y_hlo.data[row * n + c];
            worst = worst.max((y_rust[c] - hv).abs() / (1.0 + hv.abs()));
        }
    }
    assert!(worst < 5e-2, "pallas kernel vs rust ternary path: {worst}");
}

#[test]
fn classification_eval_runs_at_chance_on_random_params() {
    let Some(rt) = runtime() else { return };
    let ctx = Ctx::new(&rt, std::env::temp_dir().join("bd_eval_test"));
    let spec = rt.manifest.model("tiny-subln-absmean").unwrap();
    let mut rng = Rng::new(11);
    let params = ParamStore::init(spec, &mut rng);
    let ds = pipeline::eval_set(&ctx, Task::Mnli, 48);
    let acc = pipeline::eval_classification(
        &rt,
        "tiny_student_fwd",
        &params,
        &ds,
        &ctx.tok,
        Task::Mnli,
    )
    .unwrap();
    // random model, 3 classes: accuracy well below 70 and above 5
    assert!((5.0..70.0).contains(&acc), "chance-level check: {acc}");
}

#[test]
fn fwd_artifact_resolution() {
    let Some(rt) = runtime() else { return };
    assert_eq!(
        bench::fwd_artifact_for(&rt, "tiny-subln-absmean").unwrap(),
        "tiny_student_fwd"
    );
    assert_eq!(
        bench::fwd_artifact_for(&rt, "tiny-nosubln-none").unwrap(),
        "tiny_teacher_fwd"
    );
    assert_eq!(
        bench::fwd_artifact_for(&rt, "tiny-subln-gptq").unwrap(),
        "tiny_student_fwd_gptq"
    );
    assert!(bench::fwd_artifact_for(&rt, "nope-subln-absmean").is_err());
}

#[test]
fn trainer_checkpoint_roundtrip_through_steps() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.model("tiny-nosubln-none").unwrap();
    let mut rng = Rng::new(3);
    let params = ParamStore::init(spec, &mut rng);
    let tok = Tokenizer::new(rt.manifest.vocab);
    let stream = CorpusStream::new(&tok, rt.manifest.seq, 9);
    let mut batches = CorpusBatcher::new(stream, rt.manifest.batch, rt.manifest.seq);
    let mut tr = Trainer::new(&rt, "tiny_lm_train", params);
    for _ in 0..3 {
        let b = batches.next_batch();
        tr.train_step(&b, 1e-3).unwrap();
    }
    let dir = std::env::temp_dir().join("bd_int_ckpt");
    let path = dir.join("t.ckpt");
    tr.params.save(&path).unwrap();
    let loaded = ParamStore::load(&path).unwrap();
    assert_eq!(loaded.step, 3);
    assert_eq!(loaded.tensors["embed"], tr.params.tensors["embed"]);
    // a trainer resumed from the checkpoint still steps fine
    let mut tr2 = Trainer::new(&rt, "tiny_lm_train", loaded);
    let b = batches.next_batch();
    let loss = tr2.train_step(&b, 1e-3).unwrap();
    assert!(loss.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn micro_bitdistill_pipeline_end_to_end() {
    // A steps-scale=0.01 run of the full three-stage pipeline: proves the
    // coordinator wiring (pretrain -> teacher SFT -> CT -> distill ->
    // eval) without real training budget.
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("bd_micro_pipeline");
    std::fs::remove_dir_all(&dir).ok();
    let mut ctx = Ctx::new(&rt, &dir);
    ctx.steps_scale = 0.01;
    ctx.verbose = false;
    let opts = StudentOpts::defaults_for(Task::Sst2, 4);
    let trace = pipeline::bitdistill(&ctx, "tiny", Task::Sst2, &opts, true).unwrap();
    assert!(trace.ckpt.exists());
    let score =
        bench::evaluate_ckpt(&ctx, &trace.ckpt, Task::Sst2, "tiny", "bitdistill", &opts)
            .unwrap();
    let acc = score.accuracy.unwrap();
    assert!((0.0..=100.0).contains(&acc));
    // cached second call must be instant (checkpoint reuse)
    let t0 = std::time::Instant::now();
    pipeline::bitdistill(&ctx, "tiny", Task::Sst2, &opts, true).unwrap();
    assert!(t0.elapsed().as_secs_f32() < 2.0, "stage caching broken");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tokens_tensor_conversion_sanity() {
    let t = TensorI32::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
    let lit = t.to_literal().unwrap();
    assert_eq!(lit.element_count(), 6);
}
