//! Serve-layer integration: the continuous-batching server over a
//! synthetic (manifest-free) model spec, end to end. Unlike the HLO
//! integration tests these need no artifacts, so they always run.

use bitnet_distill::data::tokenizer::EOS;
use bitnet_distill::engine::Engine;
use bitnet_distill::params::ParamStore;
use bitnet_distill::runtime::ModelSpec;
use bitnet_distill::serve::{FinishReason, Request, Server, ServerCfg};
use bitnet_distill::substrate::Rng;

fn engines() -> (Engine, Engine) {
    let spec = ModelSpec::synthetic("tiny").unwrap();
    let mut rng = Rng::new(11);
    let params = ParamStore::init(&spec, &mut rng);
    (
        Engine::from_params(&spec, &params, false).unwrap(),
        Engine::from_params(&spec, &params, true).unwrap(),
    )
}

#[test]
fn synthetic_spec_builds_both_engines_with_ternary_memory_win() {
    let (f, t) = engines();
    assert_eq!(f.cfg.vocab, 1024);
    // packed trits vs f32 weights: the linear stack must shrink a lot
    let (tb, fb) = (t.weight_bytes(), f.weight_bytes());
    assert!(tb * 2 < fb, "{tb} vs {fb}");
    let logits = t.forward_logits(&[1, 2, 3]);
    assert!(logits.iter().all(|l| l.iter().all(|v| v.is_finite())));
}

#[test]
fn server_matches_sequential_engine_on_mixed_workload() {
    let (_, engine) = engines();
    // mixed classification + generation, co-scheduled at max_batch 4
    let gen_prompts: Vec<Vec<i32>> = vec![
        vec![1, 17, 33, 8],
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        vec![101, 202, 303, 404, 505],
    ];
    let cls_prompts: Vec<Vec<i32>> = vec![vec![3, 14, 15, 92, 6], vec![27, 18, 28, 18]];
    let label_ids = vec![10i32, 20, 30];
    let max_new = 8;

    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 4, max_queue: 32, threads: 1, ..ServerCfg::default() },
    );
    let mut ids = Vec::new();
    for p in &gen_prompts {
        ids.push(srv.submit(Request::generate(p.clone(), max_new)));
    }
    for p in &cls_prompts {
        ids.push(srv.submit(Request::classify(p.clone(), label_ids.clone())));
    }
    let mut rs = srv.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), gen_prompts.len() + cls_prompts.len());

    for (i, p) in gen_prompts.iter().enumerate() {
        let want = engine.generate(p, max_new, EOS);
        assert_eq!(rs[i].tokens, want, "generation request {i}");
        assert_eq!(rs[i].prompt_len, p.len());
    }
    for (j, p) in cls_prompts.iter().enumerate() {
        let r = &rs[gen_prompts.len() + j];
        assert_eq!(r.finish, FinishReason::Classified);
        let logits = engine.forward_logits(p);
        let last = logits.last().unwrap();
        let mut want = 0usize;
        for (c, &tid) in label_ids.iter().enumerate() {
            if last[tid as usize] > last[label_ids[want] as usize] {
                want = c;
            }
        }
        assert_eq!(r.class, Some(want), "classification request {j}");
    }

    // continuous batching actually co-scheduled lanes
    assert!(srv.stats.mean_occupancy() > 1.0);
    assert_eq!(srv.stats.completed, rs.len());
    assert!(srv.stats.peak_queue_depth >= 1);
    // timing is populated and ordered
    for r in &rs {
        assert!(r.timing.total_ms >= 0.0);
        assert!(r.timing.total_ms + 1e-6 >= r.timing.queue_ms);
    }
}

#[test]
fn threaded_server_is_bitwise_identical_end_to_end() {
    // At the synthetic tiny shape (vocab 1024) the LM-head GEMM clears
    // the pool's work floor, so threads >= 2 genuinely fan rows across
    // workers here — and must not move one bit of any response.
    let (_, engine) = engines();
    let prompts: Vec<Vec<i32>> = vec![
        vec![1, 17, 33, 8],
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        vec![101, 202, 303, 404, 505],
    ];
    let run = |threads: usize| {
        let mut srv = Server::new(
            &engine,
            ServerCfg { max_batch: 3, max_queue: 32, threads, ..ServerCfg::default() },
        );
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 8));
        }
        srv.submit(Request::classify(vec![3, 14, 15, 92], vec![10, 20, 30]));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        rs.iter()
            .map(|r| (r.tokens.clone(), r.class, r.finish))
            .collect::<Vec<_>>()
    };
    let serial = run(1);
    for threads in [2usize, 4, 8] {
        assert_eq!(run(threads), serial, "threads={threads}");
    }
    // and the serial server still matches the plain sequential engine
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(serial[i].0, engine.generate(p, 8, EOS), "request {i}");
    }
}

#[test]
fn batched_throughput_accounting_is_consistent() {
    let (_, engine) = engines();
    let n = 12;
    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 4, max_queue: 32, threads: 1, ..ServerCfg::default() },
    );
    for i in 0..n {
        srv.submit(Request::generate(vec![1 + i as i32, 7, 9], 4));
    }
    let rs = srv.run_to_completion();
    assert_eq!(rs.len(), n);
    let new_tokens: usize = rs.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(srv.stats.new_tokens, new_tokens);
    assert_eq!(srv.stats.prompt_tokens, 3 * n);
    // occupancy integral = tokens actually fed: every prompt token, plus
    // every generated token except the final one of a budget-capped
    // request (it is returned but never fed back)
    let never_fed: usize = rs
        .iter()
        .filter(|r| r.finish == FinishReason::MaxTokens && !r.tokens.is_empty())
        .count();
    assert_eq!(
        srv.stats.occupancy_sum,
        srv.stats.prompt_tokens + srv.stats.new_tokens - never_fed
    );
}

#[test]
fn chunked_prefill_server_is_bitwise_identical_end_to_end() {
    // --prefill-chunk is — like --threads and --kernel — a pure
    // throughput knob: at the synthetic tiny shape, prompts long enough
    // to span several chunks must produce bit-identical responses at
    // every chunk size, co-scheduled with short decode-heavy lanes, and
    // still match the plain sequential engine.
    let (_, engine) = engines();
    let prompts: Vec<Vec<i32>> = vec![
        (1..40).collect(),                 // 39-token prompt: 5 chunks of 8
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        (100..117).collect(),
    ];
    let run = |prefill_chunk: usize| {
        let mut srv = Server::new(
            &engine,
            ServerCfg { max_batch: 3, max_queue: 32, prefill_chunk, ..ServerCfg::default() },
        );
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 8));
        }
        srv.submit(Request::classify((200..230).collect(), vec![10, 20, 30]));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        rs.iter()
            .map(|r| (r.tokens.clone(), r.class, r.finish))
            .collect::<Vec<_>>()
    };
    let unchunked = run(1);
    for chunk in [2usize, 3, 5, 8, 64] {
        assert_eq!(run(chunk), unchunked, "prefill_chunk={chunk}");
    }
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(unchunked[i].0, engine.generate(p, 8, EOS), "request {i}");
    }
}

#[test]
fn lazy_kv_pool_reports_memory_as_slots_are_touched() {
    let (_, engine) = engines();
    let srv = Server::new(
        &engine,
        ServerCfg { max_batch: 8, max_queue: 8, ..ServerCfg::default() },
    );
    // slots are backed lazily: an idle server holds no KV memory yet
    assert_eq!(srv.kv_memory_bytes(), 0);

    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 8, max_queue: 8, ..ServerCfg::default() },
    );
    srv.submit(Request::generate(vec![1, 2, 3], 2));
    srv.run_to_completion();
    let one = srv.kv_memory_bytes();
    assert!(one > 0, "first admitted request must back one slot");
    // a single-lane workload never touches the other 7 slots
    assert_eq!(one, engine.new_cache().memory_bytes());
}
