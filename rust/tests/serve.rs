//! Serve-layer integration: the continuous-batching server over a
//! synthetic (manifest-free) model spec, end to end. Unlike the HLO
//! integration tests these need no artifacts, so they always run.

// Test crate roots sit outside src/lib.rs, so the Cargo.toml clippy
// deny-list is re-allowed here (clippy.toml only exempts #[test] fns,
// not the shared helpers): panicking is how a test fails.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::data::tokenizer::EOS;
use bitnet_distill::engine::{Engine, KernelKind};
use bitnet_distill::obs::{request_tid, TraceRecorder};
use bitnet_distill::params::ParamStore;
use bitnet_distill::runtime::ModelSpec;
use bitnet_distill::serve::{FinishReason, Request, Server, ServerCfg};
use bitnet_distill::substrate::{Json, Rng};

fn engines() -> (Engine, Engine) {
    let spec = ModelSpec::synthetic("tiny").unwrap();
    let mut rng = Rng::new(11);
    let params = ParamStore::init(&spec, &mut rng);
    (
        Engine::from_params(&spec, &params, false).unwrap(),
        Engine::from_params(&spec, &params, true).unwrap(),
    )
}

#[test]
fn synthetic_spec_builds_both_engines_with_ternary_memory_win() {
    let (f, t) = engines();
    assert_eq!(f.cfg.vocab, 1024);
    // packed trits vs f32 weights: the linear stack must shrink a lot
    let (tb, fb) = (t.weight_bytes(), f.weight_bytes());
    assert!(tb * 2 < fb, "{tb} vs {fb}");
    let logits = t.forward_logits(&[1, 2, 3]);
    assert!(logits.iter().all(|l| l.iter().all(|v| v.is_finite())));
}

#[test]
fn server_matches_sequential_engine_on_mixed_workload() {
    let (_, engine) = engines();
    // mixed classification + generation, co-scheduled at max_batch 4
    let gen_prompts: Vec<Vec<i32>> = vec![
        vec![1, 17, 33, 8],
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        vec![101, 202, 303, 404, 505],
    ];
    let cls_prompts: Vec<Vec<i32>> = vec![vec![3, 14, 15, 92, 6], vec![27, 18, 28, 18]];
    let label_ids = vec![10i32, 20, 30];
    let max_new = 8;

    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 4, max_queue: 32, threads: 1, ..ServerCfg::default() },
    );
    let mut ids = Vec::new();
    for p in &gen_prompts {
        ids.push(srv.submit(Request::generate(p.clone(), max_new)));
    }
    for p in &cls_prompts {
        ids.push(srv.submit(Request::classify(p.clone(), label_ids.clone())));
    }
    let mut rs = srv.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), gen_prompts.len() + cls_prompts.len());

    for (i, p) in gen_prompts.iter().enumerate() {
        let want = engine.generate(p, max_new, EOS);
        assert_eq!(rs[i].tokens, want, "generation request {i}");
        assert_eq!(rs[i].prompt_len, p.len());
    }
    for (j, p) in cls_prompts.iter().enumerate() {
        let r = &rs[gen_prompts.len() + j];
        assert_eq!(r.finish, FinishReason::Classified);
        let logits = engine.forward_logits(p);
        let last = logits.last().unwrap();
        let mut want = 0usize;
        for (c, &tid) in label_ids.iter().enumerate() {
            if last[tid as usize] > last[label_ids[want] as usize] {
                want = c;
            }
        }
        assert_eq!(r.class, Some(want), "classification request {j}");
    }

    // continuous batching actually co-scheduled lanes
    assert!(srv.stats.mean_occupancy() > 1.0);
    assert_eq!(srv.stats.completed, rs.len());
    assert!(srv.stats.peak_queue_depth >= 1);
    // timing is populated and ordered
    for r in &rs {
        assert!(r.timing.total_ms >= 0.0);
        assert!(r.timing.total_ms + 1e-6 >= r.timing.queue_ms);
    }
}

#[test]
fn threaded_server_is_bitwise_identical_end_to_end() {
    // At the synthetic tiny shape (vocab 1024) the LM-head GEMM clears
    // the pool's work floor, so threads >= 2 genuinely fan rows across
    // workers here — and must not move one bit of any response.
    let (_, engine) = engines();
    let prompts: Vec<Vec<i32>> = vec![
        vec![1, 17, 33, 8],
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        vec![101, 202, 303, 404, 505],
    ];
    let run = |threads: usize| {
        let mut srv = Server::new(
            &engine,
            ServerCfg { max_batch: 3, max_queue: 32, threads, ..ServerCfg::default() },
        );
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 8));
        }
        srv.submit(Request::classify(vec![3, 14, 15, 92], vec![10, 20, 30]));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        rs.iter()
            .map(|r| (r.tokens.clone(), r.class, r.finish))
            .collect::<Vec<_>>()
    };
    let serial = run(1);
    for threads in [2usize, 4, 8] {
        assert_eq!(run(threads), serial, "threads={threads}");
    }
    // and the serial server still matches the plain sequential engine
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(serial[i].0, engine.generate(p, 8, EOS), "request {i}");
    }
}

#[test]
fn batched_throughput_accounting_is_consistent() {
    let (_, engine) = engines();
    let n = 12;
    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 4, max_queue: 32, threads: 1, ..ServerCfg::default() },
    );
    for i in 0..n {
        srv.submit(Request::generate(vec![1 + i as i32, 7, 9], 4));
    }
    let rs = srv.run_to_completion();
    assert_eq!(rs.len(), n);
    let new_tokens: usize = rs.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(srv.stats.new_tokens, new_tokens);
    assert_eq!(srv.stats.prompt_tokens, 3 * n);
    // occupancy integral = tokens actually fed: every prompt token, plus
    // every generated token except the final one of a budget-capped
    // request (it is returned but never fed back)
    let never_fed: usize = rs
        .iter()
        .filter(|r| r.finish == FinishReason::MaxTokens && !r.tokens.is_empty())
        .count();
    assert_eq!(
        srv.stats.occupancy_sum,
        srv.stats.prompt_tokens + srv.stats.new_tokens - never_fed
    );
}

#[test]
fn chunked_prefill_server_is_bitwise_identical_end_to_end() {
    // --prefill-chunk is — like --threads and --kernel — a pure
    // throughput knob: at the synthetic tiny shape, prompts long enough
    // to span several chunks must produce bit-identical responses at
    // every chunk size, co-scheduled with short decode-heavy lanes, and
    // still match the plain sequential engine.
    let (_, engine) = engines();
    let prompts: Vec<Vec<i32>> = vec![
        (1..40).collect(),                 // 39-token prompt: 5 chunks of 8
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        (100..117).collect(),
    ];
    let run = |prefill_chunk: usize| {
        let mut srv = Server::new(
            &engine,
            ServerCfg { max_batch: 3, max_queue: 32, prefill_chunk, ..ServerCfg::default() },
        );
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 8));
        }
        srv.submit(Request::classify((200..230).collect(), vec![10, 20, 30]));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        rs.iter()
            .map(|r| (r.tokens.clone(), r.class, r.finish))
            .collect::<Vec<_>>()
    };
    let unchunked = run(1);
    for chunk in [2usize, 3, 5, 8, 64] {
        assert_eq!(run(chunk), unchunked, "prefill_chunk={chunk}");
    }
    for (i, p) in prompts.iter().enumerate() {
        assert_eq!(unchunked[i].0, engine.generate(p, 8, EOS), "request {i}");
    }
}

#[test]
fn tracing_is_bitwise_invisible_across_kernel_thread_chunk_matrix() {
    // The observability contract's correctness half: a live trace
    // recorder must never move one bit of any response, at every point
    // of the kernel x threads x prefill_chunk matrix. (The perf half —
    // near-zero overhead — is gated in `bench --check`.)
    let (_, engine) = engines();
    let prompts: Vec<Vec<i32>> = vec![
        (1..20).collect(), // spans several chunks at prefill_chunk 8
        vec![900, 12, 44, 7, 21, 9],
        vec![5, 5, 5],
        (100..112).collect(),
    ];
    let run = |kernel: KernelKind, threads: usize, prefill_chunk: usize, traced: bool| {
        let mut srv = Server::new(
            &engine,
            ServerCfg {
                max_batch: 3,
                max_queue: 32,
                threads,
                kernel,
                prefill_chunk,
                ..ServerCfg::default()
            },
        );
        if traced {
            srv.set_trace(TraceRecorder::enabled());
        }
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 8));
        }
        srv.submit(Request::classify((200..216).collect(), vec![10, 20, 30]));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        rs.iter()
            .map(|r| (r.tokens.clone(), r.class, r.finish, r.prompt_len))
            .collect::<Vec<_>>()
    };
    for kernel in KernelKind::ALL {
        for threads in [1usize, 4] {
            for chunk in [1usize, 8] {
                let off = run(kernel, threads, chunk, false);
                let on = run(kernel, threads, chunk, true);
                assert_eq!(on, off, "kernel={kernel:?} threads={threads} chunk={chunk}");
            }
        }
    }
}

#[test]
fn trace_export_writes_valid_chrome_json_with_request_lifecycle() {
    let (_, engine) = engines();
    let rec = TraceRecorder::enabled().process("serve test");
    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 2, max_queue: 8, prefill_chunk: 4, ..ServerCfg::default() },
    );
    srv.set_trace(rec.clone());
    srv.submit(Request::generate((1..12).collect(), 4));
    srv.submit(Request::generate(vec![7, 8, 9], 3));
    let rs = srv.run_to_completion();
    assert_eq!(rs.len(), 2);

    let dir = std::env::temp_dir().join("bd_trace_export_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    rec.write(path.to_str().unwrap()).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());

    // every event is well-formed for its phase; collect span names and
    // the [start, end] extents per (tid, name)
    let mut names = std::collections::BTreeSet::new();
    let mut extents: Vec<(f64, String, f64, f64)> = Vec::new(); // (tid, name, ts, end)
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).unwrap();
        assert!(ev.get("name").is_some() && ev.get("pid").is_some(), "{ev:?}");
        if ph == "X" {
            let ts = ev.get("ts").and_then(Json::as_f64).unwrap();
            let dur = ev.get("dur").and_then(Json::as_f64).unwrap();
            let tid = ev.get("tid").and_then(Json::as_f64).unwrap();
            assert!(ts >= 0.0 && dur >= 0.0, "{ev:?}");
            let name = ev.get("name").and_then(Json::as_str).unwrap().to_string();
            names.insert(name.clone());
            extents.push((tid, name, ts, ts + dur));
        }
    }
    // scheduler lifecycle + engine phase spans all made it to the file
    let wanted =
        ["step", "request", "queued", "prefill", "decode", "prefill_chunk", "decode_batch"];
    for want in wanted {
        assert!(names.contains(want), "missing span {want:?} in {names:?}");
    }
    // per-request nesting: each queued/prefill/decode span sits inside
    // its request span on the same track
    for id in [0u64, 1] {
        let tid = request_tid(id) as f64;
        let find = |n: &str| {
            extents
                .iter()
                .find(|(t, name, _, _)| *t == tid && name.as_str() == n)
                .unwrap_or_else(|| panic!("no {n:?} span on tid {tid}"))
        };
        let req = find("request");
        for inner in ["queued", "prefill", "decode"] {
            let s = find(inner);
            assert!(
                s.2 >= req.2 - 1e-3 && s.3 <= req.3 + 1e-3,
                "{inner} [{}, {}] outside request [{}, {}]",
                s.2,
                s.3,
                req.2,
                req.3
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lazy_kv_pool_reports_memory_as_slots_are_touched() {
    let (_, engine) = engines();
    let srv = Server::new(
        &engine,
        ServerCfg { max_batch: 8, max_queue: 8, ..ServerCfg::default() },
    );
    // slots are backed lazily: an idle server holds no KV memory yet
    assert_eq!(srv.kv_memory_bytes(), 0);

    let mut srv = Server::new(
        &engine,
        ServerCfg { max_batch: 8, max_queue: 8, ..ServerCfg::default() },
    );
    srv.submit(Request::generate(vec![1, 2, 3], 2));
    srv.run_to_completion();
    let one = srv.kv_memory_bytes();
    assert!(one > 0, "first admitted request must back one slot");
    // a single-lane workload never touches the other 7 slots
    assert_eq!(one, engine.new_cache().memory_bytes());
}
