//! The BitDistill three-stage coordinator (paper §3): trainer loops over
//! the HLO step executables, stage drivers with checkpoint caching, and
//! the evaluation harness.

pub mod eval;
pub mod stages;
pub mod trainer;

pub use eval::{eval_classification, eval_classification_engine, eval_summarization, SummaryMetrics};
pub use stages::{bitdistill, bitnet_sft, budget, eval_set, model_key, pretrain_base, run_ce_loop, run_distill_loop, teacher_key, teacher_sft, Budget, Ctx, StudentOpts};
pub use trainer::{DistillLosses, LrSchedule, Trainer, TrainStep};
