//! Evaluation harness.
//!
//! Classification accuracy runs through the HLO `*_fwd` executables
//! (batched, and exact w.r.t. the QAT forward incl. the Table-4 quantizer
//! variants). Summarization generates through the rust [`Engine`] (the
//! deployment path: greedy decode with KV cache) and scores BLEU/ROUGE.

use anyhow::Result;

use crate::data::batch::stack;
use crate::data::tokenizer::EOS;
use crate::data::{Example, Task, Tokenizer};
use crate::engine::Engine;
use crate::metrics;
use crate::params::ParamStore;
use crate::runtime::Runtime;

/// Metrics of one summarization eval (percent scales).
#[derive(Debug, Clone, Copy, Default)]
pub struct SummaryMetrics {
    pub bleu: f64,
    pub rouge1: f64,
    pub rouge2: f64,
    pub rouge_l: f64,
    pub rouge_lsum: f64,
}

impl SummaryMetrics {
    pub fn avg(&self) -> f64 {
        (self.bleu + self.rouge1 + self.rouge2 + self.rouge_l + self.rouge_lsum) / 5.0
    }
}

/// Batched classification accuracy via an HLO fwd artifact.
pub fn eval_classification(
    rt: &Runtime,
    fwd_artifact: &str,
    params: &ParamStore,
    ds: &[Example],
    tok: &Tokenizer,
    task: Task,
) -> Result<f64> {
    let spec = rt.manifest.artifact(fwd_artifact)?;
    let (b, seq) = (spec.batch, spec.seq);
    let vocab = rt.manifest.vocab;
    let label_ids: Vec<usize> = task
        .label_words()
        .iter()
        .map(|w| tok.id(w) as usize)
        .collect();

    let mut preds = Vec::with_capacity(ds.len());
    let mut golds = Vec::with_capacity(ds.len());
    let param_lits: Vec<xla::Literal> = params
        .flat()
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;

    for chunk in ds.chunks(b) {
        // pad the final chunk by repeating the first example
        let mut refs: Vec<&Example> = chunk.iter().collect();
        while refs.len() < b {
            refs.push(&chunk[0]);
        }
        let batch = stack(&refs, seq);
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(param_lits.len() + 1);
        // Literal has no cheap clone in the crate API; rebuild from host
        for t in params.flat() {
            inputs.push(t.to_literal()?);
        }
        let _ = &param_lits; // kept for future buffer-resident optimization
        inputs.push(batch.tokens.to_literal()?);
        let outs = rt.run_f32(fwd_artifact, &inputs)?;
        let logits = &outs[0]; // [b, seq, vocab]
        for (i, ex) in chunk.iter().enumerate() {
            let pos = ex.prompt_len - 1;
            let base = (i * seq + pos) * vocab;
            let row = &logits.data[base..base + vocab];
            let pred = label_ids
                .iter()
                .enumerate()
                // total_cmp: a NaN logit must not panic a whole eval run
                .max_by(|a, b| row[*a.1].total_cmp(&row[*b.1]))
                .map(|(c, _)| c)
                .unwrap();
            preds.push(pred);
            golds.push(ex.class);
        }
    }
    Ok(metrics::accuracy(&preds, &golds))
}

/// Classification accuracy through the rust engine (deployment parity).
///
/// Prompts are scored through the chunked prefill path
/// ([`crate::engine::prefill`]): time-batched GEMMs over up to
/// [`crate::engine::DEFAULT_PREFILL_CHUNK`] prompt tokens at once, with
/// the `d_model x vocab` LM head computed only at each chunk's final
/// position — bitwise identical to the per-token decode loop it
/// replaced (property-test-enforced), just faster.
pub fn eval_classification_engine(
    engine: &Engine,
    ds: &[Example],
    tok: &Tokenizer,
    task: Task,
) -> f64 {
    let label_ids: Vec<i32> = task.label_words().iter().map(|w| tok.id(w)).collect();
    let mut preds = Vec::new();
    let mut golds = Vec::new();
    let mut cache = engine.new_cache();
    let mut ps = engine.new_prefill_scratch(crate::engine::DEFAULT_PREFILL_CHUNK);
    for ex in ds {
        cache.reset();
        engine.prefill_prompt(&ex.tokens[..ex.prompt_len], &mut cache, &mut ps);
        // the exact verbalizer argmax the server runs (shared helper:
        // first of equal maxima wins, NaN can never win — total, so a
        // NaN logit cannot panic an eval run either)
        preds.push(crate::engine::argmax_labels(ps.final_logits(), &label_ids));
        golds.push(ex.class);
    }
    metrics::accuracy(&preds, &golds)
}

/// Summarization eval: greedy-generate through the engine, score vs refs.
pub fn eval_summarization(
    engine: &Engine,
    ds: &[Example],
    tok: &Tokenizer,
    max_new: usize,
) -> SummaryMetrics {
    let period = tok.id(".");
    let mut pairs = Vec::with_capacity(ds.len());
    for ex in ds {
        let hyp = engine.generate(&ex.tokens[..ex.prompt_len], max_new, EOS);
        pairs.push((hyp, ex.reference.clone()));
    }
    SummaryMetrics {
        bleu: metrics::bleu4(&pairs),
        rouge1: metrics::rouge_n(&pairs, 1),
        rouge2: metrics::rouge_n(&pairs, 2),
        rouge_l: metrics::rouge_l(&pairs),
        rouge_lsum: metrics::rouge_lsum(&pairs, period),
    }
}
