//! The BitDistill pipeline (paper §3) and its baselines, with
//! checkpoint-cached stages so every experiment reuses the expensive
//! artifacts (base pretraining, teacher SFT).
//!
//! Stage-1 "modeling refinement" is structural: the student ModelSpec has
//! SubLN tensors; loading teacher/base weights via `load_compatible`
//! leaves the freshly initialized unit SubLN gains in place (inserting
//! RMS-normalizations that start as identity-scale).
//! Stage-2 "continual pre-training" runs the QAT CE step on the corpus.
//! Stage-3 "distillation fine-tuning" runs CE + lambda*LD + gamma*AD
//! against the FP16-SFT teacher.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::data::{Batch, Batcher, CorpusBatcher, CorpusStream, Task, TaskGen, Tokenizer};
use crate::obs::{ArgV, TraceRecorder, TID_MAIN};
use crate::params::ParamStore;
use crate::pipeline::trainer::{LrSchedule, Trainer, TrainStep};
use crate::runtime::Runtime;
use crate::substrate::Rng;

/// Everything a pipeline run needs.
pub struct Ctx<'a> {
    pub rt: &'a Runtime,
    pub tok: Tokenizer,
    pub runs_dir: PathBuf,
    pub force: bool,
    pub verbose: bool,
    /// Multiplies every stage's step budget (quick smoke runs etc.).
    pub steps_scale: f64,
}

impl<'a> Ctx<'a> {
    pub fn new(rt: &'a Runtime, runs_dir: impl AsRef<Path>) -> Ctx<'a> {
        Ctx {
            tok: Tokenizer::new(rt.manifest.vocab),
            rt,
            runs_dir: runs_dir.as_ref().to_path_buf(),
            force: false,
            verbose: true,
            steps_scale: 1.0,
        }
    }

    fn scaled(&self, steps: usize) -> usize {
        ((steps as f64 * self.steps_scale).round() as usize).max(2)
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[pipeline] {msg}");
        }
    }
}

/// Stable per-task seed (FNV-1a over the name; names of equal length must
/// not collide). Shared with the native pipeline so both backends draw
/// identical train/eval splits.
pub(crate) fn task_seed(task: Task, salt: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in task.name().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ salt
}

/// Per-size training budgets (measured against this testbed's step costs:
/// tiny 1s, small 1.3s, base 9s per CE step — see EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub pretrain: usize,
    pub ct: usize,
    pub sft: usize,
    pub distill: usize,
    pub pretrain_lr: f32,
    pub sft_lr: f32,
    pub eval_n: usize,
}

pub fn budget(size: &str) -> Budget {
    match size {
        "small" => Budget { pretrain: 350, ct: 40, sft: 80, distill: 80,
                            pretrain_lr: 2e-3, sft_lr: 8e-4, eval_n: 128 },
        "base" => Budget { pretrain: 220, ct: 30, sft: 60, distill: 60,
                           pretrain_lr: 1.5e-3, sft_lr: 6e-4, eval_n: 96 },
        // tiny + the Table-3 backbones
        _ => Budget { pretrain: 400, ct: 50, sft: 260, distill: 200,
                      pretrain_lr: 1e-3, sft_lr: 1.5e-3, eval_n: 128 },
    }
}

/// Options for the student runs (ablations key off these).
#[derive(Debug, Clone)]
pub struct StudentOpts {
    pub subln: bool,
    pub quant: String, // absmean | block | gptq | awq
    pub ct_steps: Option<usize>,
    pub sft_steps: Option<usize>,
    pub use_ld: bool,
    pub use_ad: bool,
    pub lambda: f32,
    pub gamma: f32,
    pub distill_layer: i32,
    pub teacher_size: Option<String>,
}

impl StudentOpts {
    pub fn defaults_for(task: Task, n_layers: usize) -> StudentOpts {
        // paper §4.1 uses cls (lambda=10, gamma=1e5) and sum (1, 1e3) at
        // T=512 on GLUE-scale losses; our AD loss is ~100x larger at
        // T=128/tiny-vocab, so the greedy-searched equivalents here are
        // gamma=1e2 / 1.0 (the paper itself greedy-searches these; see
        // EXPERIMENTS.md Table-6 notes). Single late layer for AD (fig 3b).
        let (lambda, gamma) = if task.is_generation() { (1.0, 1.0) } else { (10.0, 1e2) };
        StudentOpts {
            subln: true,
            quant: "absmean".into(),
            ct_steps: None,
            sft_steps: None,
            use_ld: true,
            use_ad: true,
            lambda,
            gamma,
            distill_layer: n_layers as i32 - 2,
            teacher_size: None,
        }
    }
}

/// Checkpoint-tag fragment for the student variant (shared with the
/// native pipeline so ablation runs never collide in the cache).
pub(crate) fn student_suffix(opts: &StudentOpts) -> String {
    let mut s = String::new();
    if !opts.subln {
        s.push_str("_nosubln");
    }
    if opts.quant != "absmean" {
        s.push_str(&format!("_{}", opts.quant));
    }
    s
}

/// Manifest model key, mirroring aot.py::model_key.
pub fn model_key(size: &str, subln: bool, quant: &str) -> String {
    format!("{size}-{}-{quant}", if subln { "subln" } else { "nosubln" })
}

pub fn teacher_key(size: &str) -> String {
    model_key(size, false, "none")
}

// ---------------------------------------------------------------------
// Stage drivers
// ---------------------------------------------------------------------

/// Drive `steps` CE training steps through any [`TrainStep`] backend —
/// the stage loop shared by the HLO stage drivers below and the native
/// drivers in [`crate::train::stages`]. `log` is called every step;
/// callers typically filter to every 50th. Each step is recorded as a
/// `train_step` span on `trace` (`bitdistill pipeline --trace`); the
/// HLO drivers below pass a disabled recorder — a no-op by the
/// zero-cost-off contract ([`crate::obs`]). Quantization telemetry
/// (`--quant-metrics`) rides *inside* the backend, not this loop: the
/// native trainer's [`crate::obs::QuantScope`] records each step's
/// post-update lattice stats itself, so the HLO backend (which has no
/// host-side weight view) is untouched by construction.
pub fn run_ce_loop(
    tr: &mut dyn TrainStep,
    next_batch: &mut dyn FnMut() -> Batch,
    sched: &LrSchedule,
    steps: usize,
    trace: &TraceRecorder,
    log: &mut dyn FnMut(usize, f32),
) -> Result<f32> {
    let mut last = f32::NAN;
    for s in 0..steps {
        let batch = next_batch();
        let span = trace.span_args(TID_MAIN, "train_step", &[("step", ArgV::Num(s as f64))]);
        last = tr.train_step(&batch, sched.at(s))?;
        drop(span);
        log(s, last);
    }
    Ok(last)
}

/// The Stage-3 twin of [`run_ce_loop`]: `steps` distillation steps
/// against `teacher` through any [`TrainStep`] backend. `log` fires
/// every step (callers collect loss traces / filter cadence there).
/// Each step is a `distill_step` span on `trace`.
#[allow(clippy::too_many_arguments)]
pub fn run_distill_loop(
    tr: &mut dyn TrainStep,
    teacher: &ParamStore,
    next_batch: &mut dyn FnMut() -> Batch,
    sched: &LrSchedule,
    steps: usize,
    lambda: f32,
    gamma: f32,
    distill_layer: i32,
    trace: &TraceRecorder,
    log: &mut dyn FnMut(usize, crate::pipeline::trainer::DistillLosses),
) -> Result<()> {
    for s in 0..steps {
        let batch = next_batch();
        let span = trace.span_args(TID_MAIN, "distill_step", &[("step", ArgV::Num(s as f64))]);
        let l = tr.distill_step(teacher, &batch, sched.at(s), lambda, gamma, distill_layer)?;
        drop(span);
        log(s, l);
    }
    Ok(())
}

/// Pretrain the full-precision base model on the TinyWorld corpus (stands
/// in for the off-the-shelf pretrained LLM). Cached in runs/.
pub fn pretrain_base(ctx: &Ctx, size: &str) -> Result<PathBuf> {
    let path = ctx.runs_dir.join(format!("base_{size}.ckpt"));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let b = budget(size);
    let steps = ctx.scaled(b.pretrain);
    let spec = ctx.rt.manifest.model(&teacher_key(size))?;
    let mut rng = Rng::new(42);
    let params = ParamStore::init(spec, &mut rng);
    let mut tr = Trainer::new(ctx.rt, &format!("{size}_lm_train"), params);
    let stream = CorpusStream::new(&ctx.tok, ctx.rt.manifest.seq, 1);
    let mut batches = CorpusBatcher::new(stream, ctx.rt.manifest.batch, ctx.rt.manifest.seq);
    let sched = LrSchedule::new(b.pretrain_lr, steps / 20 + 1, steps);
    let last = run_ce_loop(
        &mut tr,
        &mut || batches.next_batch(),
        &sched,
        steps,
        &TraceRecorder::disabled(),
        &mut |s, l| {
            if s % 50 == 0 {
                ctx.log(&format!("pretrain {size} step {s}/{steps} loss {l:.3}"));
            }
        },
    )?;
    ctx.log(&format!("pretrain {size} done: loss {last:.3}"));
    tr.params.save(&path)?;
    Ok(path)
}

/// FP16-SFT: fine-tune the base model on the task (this IS the teacher).
pub fn teacher_sft(ctx: &Ctx, size: &str, task: Task) -> Result<PathBuf> {
    let path = ctx.runs_dir.join(format!("teacher_{size}_{}.ckpt", task.name()));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let base = pretrain_base(ctx, size)?;
    let b = budget(size);
    let steps = ctx.scaled(b.sft);
    let params = ParamStore::load(&base)?;
    let mut tr = Trainer::new(ctx.rt, &format!("{size}_lm_train"), params);
    let gen = TaskGen::new(task, &ctx.tok, ctx.rt.manifest.seq);
    let ds = gen.dataset(768, task_seed(task, 1));
    let mut batches = Batcher::new(&ds, ctx.rt.manifest.batch, ctx.rt.manifest.seq, 7);
    let sched = LrSchedule::new(b.sft_lr, steps / 20 + 1, steps);
    let last = run_ce_loop(
        &mut tr,
        &mut || batches.next_batch(),
        &sched,
        steps,
        &TraceRecorder::disabled(),
        &mut |s, l| {
            if s % 50 == 0 {
                ctx.log(&format!(
                    "teacher-sft {size}/{} step {s}/{steps} loss {l:.3}",
                    task.name()
                ));
            }
        },
    )?;
    ctx.log(&format!("teacher-sft {size}/{} done: loss {last:.3}", task.name()));
    tr.params.save(&path)?;
    Ok(path)
}

/// Initialize a student ParamStore from the base checkpoint (Stage-1:
/// structural SubLN insertion; gains start at 1).
fn init_student(ctx: &Ctx, size: &str, opts: &StudentOpts) -> Result<ParamStore> {
    let base = pretrain_base(ctx, size)?;
    let base_params = ParamStore::load(&base)?;
    let key = model_key(size, opts.subln, &opts.quant);
    let spec = ctx.rt.manifest.model(&key)?;
    let mut rng = Rng::new(43);
    let mut student = ParamStore::init(spec, &mut rng);
    let missing = student.load_compatible(&base_params);
    for m in &missing {
        if !m.starts_with("blocks.subln") {
            return Err(anyhow!("student init missing non-SubLN tensor {m}"));
        }
    }
    Ok(student)
}

/// BitNet-SFT baseline: direct QAT fine-tuning, CE only (optionally with
/// stage-2 CT first, which is the "M.D.+C.T." ablation row).
pub fn bitnet_sft(
    ctx: &Ctx,
    size: &str,
    task: Task,
    opts: &StudentOpts,
    ct: bool,
) -> Result<PathBuf> {
    let tag = format!(
        "bitnetsft_{size}_{}{}{}",
        task.name(),
        student_suffix(opts),
        if ct { "_ct" } else { "" }
    );
    let path = ctx.runs_dir.join(format!("{tag}.ckpt"));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let b = budget(size);
    let artifact = format!("{size}_bitnet_train{}", student_suffix(opts));
    let mut tr = Trainer::new(ctx.rt, &artifact, init_student(ctx, size, opts)?);

    if ct {
        let steps = ctx.scaled(opts.ct_steps.unwrap_or(b.ct));
        let stream = CorpusStream::new(&ctx.tok, ctx.rt.manifest.seq, 11);
        let mut batches =
            CorpusBatcher::new(stream, ctx.rt.manifest.batch, ctx.rt.manifest.seq);
        let sched = LrSchedule::new(b.sft_lr, steps / 10 + 1, steps);
        run_ce_loop(
            &mut tr,
            &mut || batches.next_batch(),
            &sched,
            steps,
            &TraceRecorder::disabled(),
            &mut |s, l| {
                if s % 50 == 0 {
                    ctx.log(&format!("ct {tag} step {s}/{steps} loss {l:.3}"));
                }
            },
        )?;
    }

    let steps = ctx.scaled(opts.sft_steps.unwrap_or(b.sft));
    let gen = TaskGen::new(task, &ctx.tok, ctx.rt.manifest.seq);
    let ds = gen.dataset(768, task_seed(task, 1));
    let mut batches = Batcher::new(&ds, ctx.rt.manifest.batch, ctx.rt.manifest.seq, 9);
    let sched = LrSchedule::new(b.sft_lr, steps / 20 + 1, steps);
    let last = run_ce_loop(
        &mut tr,
        &mut || batches.next_batch(),
        &sched,
        steps,
        &TraceRecorder::disabled(),
        &mut |s, l| {
            if s % 50 == 0 {
                ctx.log(&format!("bitnet-sft {tag} step {s}/{steps} loss {l:.3}"));
            }
        },
    )?;
    ctx.log(&format!("bitnet-sft {tag} done: loss {last:.3}"));
    tr.params.save(&path)?;
    Ok(path)
}

/// Losses trace of a distillation run (Fig. 3a-style curves).
pub struct DistillTrace {
    pub ckpt: PathBuf,
    pub losses: Vec<(usize, f32, f32, f32, f32)>, // step, total, ce, ld, ad
}

/// Full BitDistill: Stage-1 (structural) + optional Stage-2 CT + Stage-3
/// distillation against the FP16-SFT teacher.
pub fn bitdistill(
    ctx: &Ctx,
    size: &str,
    task: Task,
    opts: &StudentOpts,
    ct: bool,
) -> Result<DistillTrace> {
    let tsize = opts.teacher_size.clone().unwrap_or_else(|| size.to_string());
    let tag = format!(
        "bitdistill_{size}_{}{}{}{}{}{}_dl{}",
        task.name(),
        student_suffix(opts),
        if ct { "" } else { "_noct" },
        if opts.use_ld { "" } else { "_nold" },
        if opts.use_ad { "" } else { "_noad" },
        if tsize != size { format!("_t{tsize}") } else { String::new() },
        opts.distill_layer
    );
    let path = ctx.runs_dir.join(format!("{tag}.ckpt"));
    let b = budget(size);
    if path.exists() && !ctx.force {
        return Ok(DistillTrace { ckpt: path, losses: Vec::new() });
    }

    // Stage-0/teacher: FP16-SFT of the (possibly larger) teacher
    let teacher_path = teacher_sft(ctx, &tsize, task)?;
    let teacher = ParamStore::load(&teacher_path)?;

    // Stage-1: structural refinement
    let artifact = if tsize != size {
        format!("{size}_distill_train_t{tsize}")
    } else {
        format!("{size}_distill_train{}", student_suffix(opts))
    };
    let mut tr = Trainer::new(ctx.rt, &artifact, init_student(ctx, size, opts)?);

    // Stage-2: continual pre-training (CE on corpus via the bitnet step)
    if ct {
        let ct_artifact = format!("{size}_bitnet_train{}", student_suffix(opts));
        let steps = ctx.scaled(opts.ct_steps.unwrap_or(b.ct));
        let mut ct_tr = Trainer::new(ctx.rt, &ct_artifact, tr.params.clone());
        let stream = CorpusStream::new(&ctx.tok, ctx.rt.manifest.seq, 11);
        let mut batches =
            CorpusBatcher::new(stream, ctx.rt.manifest.batch, ctx.rt.manifest.seq);
        let sched = LrSchedule::new(b.sft_lr, steps / 10 + 1, steps);
        run_ce_loop(
            &mut ct_tr,
            &mut || batches.next_batch(),
            &sched,
            steps,
            &TraceRecorder::disabled(),
            &mut |s, l| {
                if s % 50 == 0 {
                    ctx.log(&format!("ct {tag} step {s}/{steps} loss {l:.3}"));
                }
            },
        )?;
        tr.params = ct_tr.params;
        // optimizer state restarts between stages (fresh task)
        tr.m = tr.params.zeros_like();
        tr.v = tr.params.zeros_like();
        tr.step = 0;
    }

    // Stage-3: distillation-based fine-tuning (eq. 13)
    let steps = ctx.scaled(opts.sft_steps.unwrap_or(b.distill));
    let gen = TaskGen::new(task, &ctx.tok, ctx.rt.manifest.seq);
    let ds = gen.dataset(768, task_seed(task, 1));
    let mut batches = Batcher::new(&ds, ctx.rt.manifest.batch, ctx.rt.manifest.seq, 9);
    let sched = LrSchedule::new(b.sft_lr, steps / 20 + 1, steps);
    let lambda = if opts.use_ld { opts.lambda } else { 0.0 };
    let gamma = if opts.use_ad { opts.gamma } else { 0.0 };
    let mut losses = Vec::new();
    run_distill_loop(
        &mut tr,
        &teacher,
        &mut || batches.next_batch(),
        &sched,
        steps,
        lambda,
        gamma,
        opts.distill_layer,
        &TraceRecorder::disabled(),
        &mut |s, l| {
            if s % 20 == 0 || s + 1 == steps {
                losses.push((s, l.total, l.ce, l.ld, l.ad));
            }
            if s % 50 == 0 {
                ctx.log(&format!(
                    "distill {tag} step {s}/{steps} total {:.3} ce {:.3} ld {:.4} ad {:.5}",
                    l.total, l.ce, l.ld, l.ad
                ));
            }
        },
    )?;
    tr.params.save(&path)?;
    ctx.log(&format!("bitdistill {tag} done"));
    Ok(DistillTrace { ckpt: path, losses })
}

/// Evaluation dataset for a task (disjoint seed from training).
pub fn eval_set(ctx: &Ctx, task: Task, n: usize) -> Vec<crate::data::Example> {
    let gen = TaskGen::new(task, &ctx.tok, ctx.rt.manifest.seq);
    gen.dataset(n, task_seed(task, 2))
}
