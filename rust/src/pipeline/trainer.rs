//! Train-loop driver over the AOT HLO step executables.
//!
//! Owns params + AdamW state host-side; each step uploads the flat state,
//! executes, and re-absorbs the returned state (PJRT returns the output
//! tuple as a single fused buffer — see DESIGN.md §5 — so state round-
//! trips through host literals; at our model sizes the copy is ~ms and
//! the matmuls dominate).

use anyhow::{bail, Context, Result};

use crate::data::Batch;
use crate::params::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::{TensorF32, TensorI32};

pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub artifact: String,
    pub params: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: usize,
    /// Use the `execute_b` device-buffer path (default). The
    /// `execute(literals)` path leaks its internally created input
    /// buffers in the C wrapper (~2x state bytes per step — measured in
    /// EXPERIMENTS.md §Perf), so it is kept only for A/B diagnostics.
    pub use_buffers: bool,
}

/// Losses returned by one distillation step (eq. 13 decomposition).
#[derive(Debug, Clone, Copy, Default)]
pub struct DistillLosses {
    pub total: f32,
    pub ce: f32,
    pub ld: f32,
    pub ad: f32,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, artifact: &str, params: ParamStore) -> Trainer<'a> {
        let m = params.zeros_like();
        let v = params.zeros_like();
        Trainer {
            rt,
            artifact: artifact.to_string(),
            params,
            m,
            v,
            step: 0,
            use_buffers: true,
        }
    }

    fn state_literals(&self) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(3 * self.params.specs.len());
        for store in [&self.params, &self.m, &self.v] {
            for t in store.flat() {
                lits.push(t.to_literal()?);
            }
        }
        Ok(lits)
    }

    fn state_buffers(&self) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = Vec::with_capacity(3 * self.params.specs.len());
        for store in [&self.params, &self.m, &self.v] {
            for t in store.flat() {
                bufs.push(self.rt.to_device_f32(t)?);
            }
        }
        Ok(bufs)
    }

    fn absorb(&mut self, outs: &mut Vec<xla::Literal>, n_losses: usize) -> Result<Vec<f32>> {
        let p = self.params.specs.len();
        if outs.len() != 3 * p + n_losses {
            bail!(
                "step output arity {} != 3*{} + {}",
                outs.len(),
                p,
                n_losses
            );
        }
        let losses: Vec<f32> = outs[3 * p..]
            .iter()
            .map(|l| l.to_vec::<f32>().map(|v| v[0]))
            .collect::<std::result::Result<_, _>>()?;
        let tensors: Vec<TensorF32> = outs[..3 * p]
            .iter()
            .map(TensorF32::from_literal)
            .collect::<Result<_>>()?;
        let mut it = tensors.into_iter();
        let pv: Vec<TensorF32> = it.by_ref().take(p).collect();
        let mv: Vec<TensorF32> = it.by_ref().take(p).collect();
        let vv: Vec<TensorF32> = it.collect();
        self.params.set_flat(pv)?;
        self.m.set_flat(mv)?;
        self.v.set_flat(vv)?;
        self.step += 1;
        self.params.step = self.step;
        Ok(losses)
    }

    /// One CE step (lm_train / bitnet_train artifacts). Returns the loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let mut outs = if self.use_buffers {
            let mut inputs = self.state_buffers()?;
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar((self.step + 1) as f32))?);
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar(lr))?);
            inputs.push(self.rt.to_device_i32(&batch.tokens)?);
            inputs.push(self.rt.to_device_i32(&batch.labels)?);
            self.rt
                .run_buffers(&self.artifact, &inputs)
                .with_context(|| format!("train_step on {}", self.artifact))?
        } else {
            let mut inputs = self.state_literals()?;
            inputs.push(TensorF32::scalar((self.step + 1) as f32).to_literal()?);
            inputs.push(TensorF32::scalar(lr).to_literal()?);
            inputs.push(batch.tokens.to_literal()?);
            inputs.push(batch.labels.to_literal()?);
            self.rt
                .run(&self.artifact, &inputs)
                .with_context(|| format!("train_step on {}", self.artifact))?
        };
        Ok(self.absorb(&mut outs, 1)?[0])
    }

    /// One stage-3 distillation step (distill_train artifacts).
    pub fn distill_step(
        &mut self,
        teacher: &ParamStore,
        batch: &Batch,
        lr: f32,
        lambda: f32,
        gamma: f32,
        distill_layer: i32,
    ) -> Result<DistillLosses> {
        let mut outs = if self.use_buffers {
            let mut inputs = self.state_buffers()?;
            for t in teacher.flat() {
                inputs.push(self.rt.to_device_f32(t)?);
            }
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar((self.step + 1) as f32))?);
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar(lr))?);
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar(lambda))?);
            inputs.push(self.rt.to_device_f32(&TensorF32::scalar(gamma))?);
            inputs.push(self.rt.to_device_i32(&TensorI32::scalar(distill_layer))?);
            inputs.push(self.rt.to_device_i32(&batch.tokens)?);
            inputs.push(self.rt.to_device_i32(&batch.labels)?);
            self.rt
                .run_buffers(&self.artifact, &inputs)
                .with_context(|| format!("distill_step on {}", self.artifact))?
        } else {
            let mut inputs = self.state_literals()?;
            for t in teacher.flat() {
                inputs.push(t.to_literal()?);
            }
            inputs.push(TensorF32::scalar((self.step + 1) as f32).to_literal()?);
            inputs.push(TensorF32::scalar(lr).to_literal()?);
            inputs.push(TensorF32::scalar(lambda).to_literal()?);
            inputs.push(TensorF32::scalar(gamma).to_literal()?);
            inputs.push(TensorI32::scalar(distill_layer).to_literal()?);
            inputs.push(batch.tokens.to_literal()?);
            inputs.push(batch.labels.to_literal()?);
            self.rt
                .run(&self.artifact, &inputs)
                .with_context(|| format!("distill_step on {}", self.artifact))?
        };
        let l = self.absorb(&mut outs, 4)?;
        Ok(DistillLosses { total: l[0], ce: l[1], ld: l[2], ad: l[3] })
    }
}

/// The backend seam of the stage drivers: one training step, whatever
/// produces the gradients — the AOT HLO executables ([`Trainer`]) or the
/// native autograd tape ([`crate::train::NativeTrainer`]). Stage loops
/// ([`crate::pipeline::stages::run_ce_loop`] and the distill loops) are
/// written against this trait, so `--backend native` and `--backend hlo`
/// share the same three-stage coordinator logic.
pub trait TrainStep {
    /// One CE step (lm_train / bitnet_train semantics); returns the loss.
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32>;

    /// One stage-3 step: CE + lambda*LD + gamma*AD against `teacher`.
    fn distill_step(
        &mut self,
        teacher: &ParamStore,
        batch: &Batch,
        lr: f32,
        lambda: f32,
        gamma: f32,
        distill_layer: i32,
    ) -> Result<DistillLosses>;
}

impl<'a> TrainStep for Trainer<'a> {
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        Trainer::train_step(self, batch, lr)
    }

    fn distill_step(
        &mut self,
        teacher: &ParamStore,
        batch: &Batch,
        lr: f32,
        lambda: f32,
        gamma: f32,
        distill_layer: i32,
    ) -> Result<DistillLosses> {
        Trainer::distill_step(self, teacher, batch, lr, lambda, gamma, distill_layer)
    }
}

/// Warmup-then-cosine learning-rate schedule (the paper greedy-searches
/// LR/epochs per run; we fix the shape and sweep only the peak).
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak: f32,
    pub warmup: usize,
    pub total: usize,
    pub floor_frac: f32,
}

impl LrSchedule {
    pub fn new(peak: f32, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule { peak, warmup, total: total.max(1), floor_frac: 0.4 }
    }

    pub fn at(&self, step: usize) -> f32 {
        if self.warmup > 0 && step < self.warmup {
            return self.peak * (step + 1) as f32 / self.warmup as f32;
        }
        let t = (step - self.warmup) as f32
            / (self.total.saturating_sub(self.warmup)).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
        self.peak * (self.floor_frac + (1.0 - self.floor_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_warms_up_and_decays() {
        let s = LrSchedule::new(1e-3, 10, 100);
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1e-3).abs() / 1e-3 < 0.11);
        assert!(s.at(99) < 0.5 * 1e-3 + 1e-9);
        // monotone decay after warmup
        let mut prev = s.at(10);
        for step in 11..100 {
            let cur = s.at(step);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn schedule_floor_is_respected() {
        let s = LrSchedule::new(2e-3, 0, 50);
        assert!(s.at(49) >= 0.4 * 2e-3 * 0.99);
    }
}
