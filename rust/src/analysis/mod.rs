//! Repo-specific static analysis — the `bitdistill lint` subsystem.
//!
//! Every perf PR in this repo rides on one contract: results are
//! **bitwise identical** across thread counts, kernel generations, and
//! obs on/off. The property tests enforce that contract *dynamically*,
//! by sampling; this layer enforces the source patterns that have
//! historically broken it *statically*, before a test ever runs:
//! NaN-panicking `partial_cmp().unwrap()` comparisons, hash-iteration
//! order leaking into gradient reduction, panics in co-scheduled server
//! lanes, wall-clock reads inside kernels, unguarded obs-recorder
//! touches, and `unsafe` without a written contract.
//!
//! Structure:
//! - [`lexer`] — line-classifying lexer: splits source into parallel
//!   per-line *code* and *comment* views (strings/chars blanked), so
//!   rules never fire on prose or literals;
//! - [`rules`] — the rule catalogue (names, scopes, hints) plus the
//!   token/indexing matchers;
//! - [`engine`] — the walker: `#[cfg(test)]` masking, the
//!   `// lint: allow(<rule>): <reason>` escape (reason mandatory,
//!   enforced by a non-suppressible meta rule), JSON + human reports;
//! - [`fixtures`] — known-bad corpus backing `lint --fixtures` and the
//!   analyzer's own regression tests.
//!
//! The pass is **self-hosted**: this crate lints clean (see
//! `engine::tests::shipped_crate_lints_clean`), and CI runs
//! `bitdistill lint --json lint.json` on every push. The rule
//! catalogue and escape syntax are documented in `src/README.md`
//! ("analysis layer").

pub mod engine;
pub mod fixtures;
pub mod lexer;
pub mod rules;

pub use engine::{default_root, lint_dir, lint_source, Finding, LintReport};
pub use fixtures::lint_fixtures;
