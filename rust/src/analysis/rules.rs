//! The rule catalogue for `bitdistill lint`, plus the small text-matching
//! helpers the engine applies to lexed code lines.
//!
//! Every rule encodes one clause of the repo's determinism / robustness
//! contract (see `src/README.md`, "analysis layer"). Rules are matched
//! against the *code view* of a line ([`super::lexer::Lexed`]), so
//! comments and string contents can never trip them. Scoping (which
//! paths a rule applies to, whether `#[cfg(test)]` code is exempt) lives
//! here as data; the walking and suppression logic lives in
//! [`super::engine`].

/// One lint rule: identity, what it guards, and how to fix a hit.
pub struct Rule {
    /// Stable kebab-case name — what `// lint: allow(<name>): …` refers to.
    pub name: &'static str,
    /// One-line statement of the contract the rule encodes.
    pub summary: &'static str,
    /// What a hit should be turned into.
    pub hint: &'static str,
    /// Human-readable scope, for docs and `lint --rules` style output.
    pub scope: &'static str,
    /// Whether the rule also applies inside `#[cfg(test)]` modules.
    pub include_tests: bool,
    /// Meta rules police the allow-escapes themselves and cannot be
    /// suppressed by an allow.
    pub meta: bool,
}

/// Rule names, as constants so the engine and fixtures can't typo them.
pub const NO_PARTIAL_CMP_UNWRAP: &str = "no-partial-cmp-unwrap";
pub const NO_HASH_ITER_IN_NUMERIC: &str = "no-hash-iter-in-numeric";
pub const NO_PANIC_IN_REQUEST_PATH: &str = "no-panic-in-request-path";
pub const NO_WALLCLOCK_IN_KERNELS: &str = "no-wallclock-in-kernels";
pub const GUARDED_RECORDER_USE: &str = "guarded-recorder-use";
pub const UNSAFE_NEEDS_CONTRACT_COMMENT: &str = "unsafe-needs-contract-comment";
pub const NO_LEGACY_ENGINE_VARIANTS: &str = "no-legacy-engine-variants";
pub const NO_BLOCKING_IO_WITHOUT_TIMEOUT: &str = "no-blocking-io-without-timeout";
pub const LINT_ALLOW_NEEDS_REASON: &str = "lint-allow-needs-reason";
pub const LINT_ALLOW_UNKNOWN_RULE: &str = "lint-allow-unknown-rule";

/// The retired Engine method matrix: every `_with` / `_kernel` /
/// `_traced` / `_obs` variant the [`NO_LEGACY_ENGINE_VARIANTS`] rule
/// keeps from growing back at call sites. The canonical replacements
/// are the `_ctx` methods taking [`crate::engine::ExecCtx`].
pub const LEGACY_ENGINE_VARIANTS: &[&str] = &[
    "decode_step_with",
    "decode_step_kernel",
    "decode_step_batch_with",
    "decode_step_batch_kernel",
    "decode_step_batch_kernel_traced",
    "decode_step_batch_kernel_obs",
    "prefill_chunk_with",
    "prefill_chunk_kernel",
    "prefill_chunk_slot_kernel",
    "prefill_chunk_slot_kernel_traced",
    "prefill_prompt_kernel",
    "forward_logits_with",
    "generate_with",
    "generate_kernel",
];

/// The full catalogue, in severity-of-surprise order.
pub const RULES: &[Rule] = &[
    Rule {
        name: NO_PARTIAL_CMP_UNWRAP,
        summary: "float comparisons must be total: partial_cmp panics on NaN \
                  and its Option tempts unwrap()",
        hint: "use f32::total_cmp / f64::total_cmp (or sort_by_key on bits)",
        scope: "everywhere, including tests",
        include_tests: true,
        meta: false,
    },
    Rule {
        name: NO_HASH_ITER_IN_NUMERIC,
        summary: "HashMap/HashSet iteration order is nondeterministic and \
                  leaks into gradient reduction / telemetry byte streams",
        hint: "use BTreeMap/BTreeSet, or collect and sort before iterating",
        scope: "engine/, train/, quant/, parallel/, obs/quantscope.rs \
                (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: NO_PANIC_IN_REQUEST_PATH,
        summary: "the scheduler's request path must reject, never panic — \
                  a panic kills every co-scheduled lane (validated-at-submit \
                  contract, PR 3)",
        hint: "validate at submit and return FinishReason::Rejected, or \
               carry a reasoned allow proving the invariant",
        scope: "serve/scheduler.rs (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: NO_WALLCLOCK_IN_KERNELS,
        summary: "wall-clock reads in numeric code invite timing-dependent \
                  control flow; timing belongs to the bench/serve/obs layers",
        hint: "move the measurement into bench/, serve/, or obs/, or carry \
               a reasoned allow",
        scope: "everywhere except bench/, serve/, obs/ (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: GUARDED_RECORDER_USE,
        summary: "obs recorder buffers may only be touched behind the \
                  zero-cost-off guard (Option on the shared inner), so \
                  disabled recorders stay one branch per site",
        hint: "guard the borrow with `if let Some(..) = &self.inner` / \
               `match &self.inner` / `is_none()` early-return",
        scope: "obs/trace.rs and obs/quantscope.rs (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: UNSAFE_NEEDS_CONTRACT_COMMENT,
        summary: "every unsafe block/impl/fn must state the contract that \
                  makes it sound",
        hint: "add a `// SAFETY: …` (or `/// # Safety`) comment directly \
               above the unsafe code",
        scope: "everywhere (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: NO_LEGACY_ENGINE_VARIANTS,
        summary: "the Engine's legacy _with/_kernel/_traced/_obs method \
                  matrix is retired; every knob rides in ExecCtx so new \
                  call sites cannot resurrect a variant per knob",
        hint: "build an engine::ExecCtx (with_pool / with_kernel / \
               with_trace / with_quant) and call the _ctx method",
        scope: "everywhere outside engine/, including tests",
        include_tests: true,
        meta: false,
    },
    Rule {
        name: NO_BLOCKING_IO_WITHOUT_TIMEOUT,
        summary: "socket IO in the network front-end must be bounded: a \
                  file doing TcpStream reads/writes without ever arming a \
                  timeout can hang a connection thread forever on a stalled \
                  peer (overload-hardening contract, PR 10)",
        hint: "call set_read_timeout / set_write_timeout (or \
               set_nonblocking) on the stream before doing IO, or carry a \
               reasoned allow proving the site cannot block",
        scope: "serve/net/ (non-test code)",
        include_tests: false,
        meta: false,
    },
    Rule {
        name: LINT_ALLOW_NEEDS_REASON,
        summary: "lint allows must say why: `// lint: allow(<rule>): <reason>`",
        hint: "append `: <reason>` explaining the invariant that makes the \
               site safe",
        scope: "every allow escape",
        include_tests: true,
        meta: true,
    },
    Rule {
        name: LINT_ALLOW_UNKNOWN_RULE,
        summary: "an allow naming an unknown rule suppresses nothing and \
                  rots silently",
        hint: "fix the rule name (see RULES in rust/src/analysis/rules.rs)",
        scope: "every allow escape",
        include_tests: true,
        meta: true,
    },
];

/// Look a rule up by its kebab-case name.
pub fn by_name(name: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.name == name)
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `true` when `code` contains `tok` as a whole identifier token (not a
/// substring of a longer identifier).
pub fn contains_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code.get(from..).and_then(|s| s.find(tok)) {
        let start = from + p;
        let end = start + tok.len();
        let before_ok = start == 0 || !is_ident(bytes.get(start - 1).copied().unwrap_or(0));
        let after_ok = !is_ident(bytes.get(end).copied().unwrap_or(0));
        if before_ok && after_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

/// `.unwrap()` exactly — `unwrap_or(..)` and friends are total and fine.
pub fn has_unwrap_call(code: &str) -> bool {
    code.contains(".unwrap()")
}

/// `.expect("…")` — the string argument is already blanked by the lexer,
/// so matching the call head is enough.
pub fn has_expect_call(code: &str) -> bool {
    code.contains(".expect(")
}

/// Heuristic for panicking `x[i]` index/slice expressions: a `[` whose
/// preceding non-space byte ends a value expression (identifier, `)`,
/// or `]`). Excludes attributes `#[..]`, slice types `&[..]`, array
/// literals `= [..]`, and macro brackets `vec![..]` by construction.
pub fn has_index_expr(code: &str) -> bool {
    let b = code.as_bytes();
    for (p, &c) in b.iter().enumerate() {
        if c != b'[' {
            continue;
        }
        let mut q = p;
        while q > 0 {
            q -= 1;
            let prev = b.get(q).copied().unwrap_or(0);
            if prev == b' ' {
                continue;
            }
            if is_ident(prev) || prev == b')' || prev == b']' {
                return true;
            }
            break;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_boundaries() {
        assert!(contains_token("a.partial_cmp(b)", "partial_cmp"));
        assert!(!contains_token("my_partial_cmp_wrapper(b)", "partial_cmp"));
        assert!(contains_token("use std::collections::HashMap;", "HashMap"));
        assert!(!contains_token("HashMapLike", "HashMap"));
        assert!(contains_token("pub fn f()", "fn"));
        assert!(!contains_token("info!(x)", "fn"));
    }

    #[test]
    fn unwrap_is_not_unwrap_or() {
        assert!(has_unwrap_call("x.partial_cmp(y).unwrap()"));
        assert!(!has_unwrap_call("x.first().unwrap_or(&0)"));
        assert!(!has_unwrap_call("x.unwrap_or_else(make)"));
    }

    #[test]
    fn index_heuristic_positives() {
        assert!(has_index_expr("let a = self.active[i];"));
        assert!(has_index_expr("let t = q.req.prompt[0];"));
        assert!(has_index_expr("(xs)[k] = 1.0;"));
        assert!(has_index_expr("grid[i][j]"));
    }

    #[test]
    fn index_heuristic_negatives() {
        assert!(!has_index_expr("#[derive(Clone)]"));
        assert!(!has_index_expr("let v = vec![1, 2];"));
        assert!(!has_index_expr("fn f(xs: &[f32]) {}"));
        assert!(!has_index_expr("let a: [u8; 4] = [0; 4];"));
        assert!(!has_index_expr("let s: &[(&str, f64)] = &[(\"a\", 1.0)];"));
    }

    #[test]
    fn catalogue_lookup() {
        assert!(by_name(NO_PANIC_IN_REQUEST_PATH).is_some());
        assert!(by_name("no-such-rule").is_none());
        // meta rules are in the catalogue (so allows naming them resolve)
        // but flagged meta
        let m = by_name(LINT_ALLOW_NEEDS_REASON).expect("meta rule present");
        assert!(m.meta);
    }
}
