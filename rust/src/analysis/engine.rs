//! The lint engine: walks `.rs` files, lexes each one
//! ([`super::lexer`]), masks `#[cfg(test)]` regions, applies the rule
//! catalogue ([`super::rules`]), and honors the
//! `// lint: allow(<rule>): <reason>` escape.
//!
//! Suppression model: an allow written in a comment on the finding's
//! line, or on the line directly above it, suppresses that rule there.
//! The *reason* is mandatory — an allow without one still suppresses,
//! but raises the non-suppressible [`rules::LINT_ALLOW_NEEDS_REASON`]
//! meta finding, so the net exit code stays non-zero until the reason
//! is written.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use super::lexer::{lex, Lexed};
use super::rules::{
    self, by_name, contains_token, has_expect_call, has_index_expr, has_unwrap_call,
};
use crate::substrate::{json, Json};

/// One lint hit, addressed `rule` + `path:line` (1-based).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    /// The offending source line, trimmed.
    pub snippet: String,
    /// Rule hint or site-specific note.
    pub note: String,
}

/// The result of linting a tree (or the fixture corpus).
pub struct LintReport {
    pub findings: Vec<Finding>,
    /// Number of files scanned.
    pub files: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// JSON shape consumed by `report --lint` and the CI artifact:
    /// `{"kind":"lint","files":N,"clean":bool,"findings":[…]}`.
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                json::obj(vec![
                    ("rule", json::s(f.rule)),
                    ("path", json::s(&f.path)),
                    ("line", json::num(f.line as f64)),
                    ("snippet", json::s(&f.snippet)),
                    ("note", json::s(&f.note)),
                ])
            })
            .collect();
        json::obj(vec![
            ("kind", json::s("lint")),
            ("files", json::num(self.files as f64)),
            ("clean", Json::Bool(self.findings.is_empty())),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Human output: one block per finding (rule, file:line, snippet,
    /// hint), then a one-line verdict.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}: {}:{}\n", f.rule, f.path, f.line));
            out.push_str(&format!("    {}\n", f.snippet));
            out.push_str(&format!("    hint: {}\n", f.note));
        }
        if self.findings.is_empty() {
            out.push_str(&format!(
                "lint clean: {} files checked against {} rules\n",
                self.files,
                rules::RULES.len()
            ));
        } else {
            out.push_str(&format!(
                "lint: {} finding(s) in {} files\n",
                self.findings.len(),
                self.files
            ));
        }
        out
    }
}

/// Where `bitdistill lint` looks when `--root` is not given: `src/`
/// relative to the working directory (CI runs in `rust/`), falling back
/// to `rust/src/` for repo-root invocations.
pub fn default_root() -> Result<PathBuf> {
    for cand in ["src", "rust/src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Ok(p);
        }
    }
    bail!("lint: no src/lib.rs under the working directory — pass --root DIR")
}

/// Lint every `.rs` file under `root` (recursively, sorted order).
pub fn lint_dir(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| anyhow!("lint: reading {}: {e}", f.display()))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    sort_findings(&mut findings);
    Ok(LintReport { findings, files: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("lint: reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow!("lint: walking {}: {e}", dir.display()))?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
}

/// An allow escape parsed out of a comment line.
struct Allow {
    rule: String,
    has_reason: bool,
}

/// Lint a single file's source against the full catalogue. `path` is the
/// file's path relative to the lint root, `/`-separated — scoping rules
/// match on it.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let in_test = test_mask(&lexed.code);
    let allows = parse_allows(&lexed.comment);
    let mut out = Vec::new();

    // file-wide precondition for rule 8: a serve/net file that arms a
    // socket timeout (or goes nonblocking) anywhere has opted into the
    // bounded-IO discipline; one that never does is flagged at each IO
    // call site
    let net_scope = path.starts_with("serve/net");
    let net_has_timeout = net_scope
        && lexed.code.iter().any(|c| {
            c.contains("set_read_timeout")
                || c.contains("set_write_timeout")
                || c.contains("set_nonblocking")
        });

    // meta findings: allows must name a real rule and carry a reason
    for (l, line_allows) in allows.iter().enumerate() {
        for a in line_allows {
            match by_name(&a.rule) {
                None => out.push(finding(rules::LINT_ALLOW_UNKNOWN_RULE, path, l, &lexed)),
                Some(_) if !a.has_reason => {
                    out.push(finding(rules::LINT_ALLOW_NEEDS_REASON, path, l, &lexed))
                }
                Some(_) => {}
            }
        }
    }

    for (l, code) in lexed.code.iter().enumerate() {
        let mut hit = |rule: &'static str| {
            if !suppressed(&allows, rule, l) {
                out.push(finding(rule, path, l, &lexed));
            }
        };

        // 1. no-partial-cmp-unwrap — everywhere, tests included
        if contains_token(code, "partial_cmp") {
            hit(rules::NO_PARTIAL_CMP_UNWRAP);
        }

        // 7. no-legacy-engine-variants — tests included; engine/ itself
        //    is exempt (the _ctx methods and their docs live there and
        //    may name the retired variants when telling their history)
        if !path.starts_with("engine/")
            && rules::LEGACY_ENGINE_VARIANTS.iter().any(|t| contains_token(code, t))
        {
            hit(rules::NO_LEGACY_ENGINE_VARIANTS);
        }

        if in_test[l] {
            continue; // the remaining rules exempt #[cfg(test)] code
        }

        // 2. no-hash-iter-in-numeric — the bitwise-deterministic dirs
        if in_numeric_scope(path)
            && (contains_token(code, "HashMap") || contains_token(code, "HashSet"))
        {
            hit(rules::NO_HASH_ITER_IN_NUMERIC);
        }

        // 3. no-panic-in-request-path — the scheduler's lane handling
        if path == "serve/scheduler.rs"
            && (has_unwrap_call(code)
                || has_expect_call(code)
                || contains_token(code, "panic")
                || contains_token(code, "unreachable")
                || contains_token(code, "todo")
                || has_index_expr(code))
        {
            hit(rules::NO_PANIC_IN_REQUEST_PATH);
        }

        // 4. no-wallclock-in-kernels — timing lives in bench/serve/obs
        if !in_timing_scope(path)
            && (code.contains("Instant::now") || contains_token(code, "SystemTime"))
        {
            hit(rules::NO_WALLCLOCK_IN_KERNELS);
        }

        // 5. guarded-recorder-use — zero-cost-off obs recorders
        if (path == "obs/trace.rs" || path == "obs/quantscope.rs")
            && (code.contains(".borrow()") || code.contains(".borrow_mut()"))
            && !recorder_guard_ok(&lexed.code, l)
        {
            hit(rules::GUARDED_RECORDER_USE);
        }

        // 6. unsafe-needs-contract-comment
        if contains_token(code, "unsafe") && !unsafe_contract_ok(&lexed, l) {
            hit(rules::UNSAFE_NEEDS_CONTRACT_COMMENT);
        }

        // 8. no-blocking-io-without-timeout — serve/net socket calls
        if net_scope && !net_has_timeout && has_net_io_call(code) {
            hit(rules::NO_BLOCKING_IO_WITHOUT_TIMEOUT);
        }
    }

    sort_findings(&mut out);
    out
}

fn finding(rule: &'static str, path: &str, l: usize, lexed: &Lexed) -> Finding {
    let raw_code = lexed.code.get(l).map(String::as_str).unwrap_or("");
    let raw_comment = lexed.comment.get(l).map(String::as_str).unwrap_or("");
    // reconstruct a readable snippet: prefer the code view, fall back to
    // the comment view (meta findings live on pure-comment lines)
    let snippet = if raw_code.trim().is_empty() { raw_comment } else { raw_code };
    let note = by_name(rule).map(|r| r.hint).unwrap_or("");
    Finding {
        rule,
        path: path.to_string(),
        line: l + 1,
        snippet: snippet.trim().to_string(),
        note: note.to_string(),
    }
}

fn in_numeric_scope(path: &str) -> bool {
    path.starts_with("engine/")
        || path.starts_with("train/")
        || path.starts_with("quant/")
        || path.starts_with("parallel/")
        || path == "obs/quantscope.rs"
}

/// The blocking socket-IO call heads rule 8 watches for. `.write(` does
/// not shadow `.write_all(` (distinct heads, both listed), and plain
/// in-memory `Read`/`Write` impls are caught too — in serve/net every
/// reader/writer ultimately wraps a socket, so the bounded-IO burden is
/// on the file either way.
fn has_net_io_call(code: &str) -> bool {
    const CALLS: &[&str] = &[
        ".accept()",
        "TcpStream::connect",
        ".read(",
        ".read_exact(",
        ".read_to_end(",
        ".read_until(",
        ".read_line(",
        ".write_all(",
        ".write(",
        ".flush(",
    ];
    CALLS.iter().any(|t| code.contains(t))
}

fn in_timing_scope(path: &str) -> bool {
    path.starts_with("bench/") || path.starts_with("serve/") || path.starts_with("obs/")
}

/// Per-line mask: `true` inside a `#[cfg(test)] mod … { … }` region.
/// Brace depth is computed over the code view, so braces in strings and
/// comments don't skew it.
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut region: Option<i64> = None;
    for (l, line) in code.iter().enumerate() {
        let t = line.trim();
        let start_depth = depth;
        if let Some(m) = mask.get_mut(l) {
            *m = region.is_some();
        }
        if t.contains("#[cfg(test)]") {
            armed = true;
        }
        if armed && contains_token(t, "mod") && t.contains('{') {
            if region.is_none() {
                region = Some(start_depth);
            }
            if let Some(m) = mask.get_mut(l) {
                *m = true;
            }
            armed = false;
        } else if armed && !t.is_empty() && !t.contains("#[cfg(test)]") && !t.starts_with("#[") {
            // the attribute attached to a non-mod item (fn, use, …):
            // single-item cfg — mark just that line and disarm
            if let Some(m) = mask.get_mut(l) {
                *m = true;
            }
            armed = false;
        }
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if let Some(d) = region {
            if depth <= d {
                if let Some(m) = mask.get_mut(l) {
                    *m = true;
                }
                region = None;
            }
        }
    }
    mask
}

fn is_kebab(c: char) -> bool {
    c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'
}

/// Parse every `lint: allow(<rule>)` / `lint: allow(<rule>): <reason>`
/// escape out of the comment view, per line.
fn parse_allows(comment: &[String]) -> Vec<Vec<Allow>> {
    const KEY: &str = "lint: allow(";
    comment
        .iter()
        .map(|line| {
            let mut found = Vec::new();
            let mut from = 0;
            while let Some(p) = line.get(from..).and_then(|s| s.find(KEY)) {
                let start = from + p + KEY.len();
                let rest = line.get(start..).unwrap_or("");
                if let Some(close) = rest.find(')') {
                    let rule = rest.get(..close).unwrap_or("").trim().to_string();
                    let after = rest.get(close + 1..).unwrap_or("").trim_start();
                    let has_reason = after.strip_prefix(':').is_some_and(|r| !r.trim().is_empty());
                    // only kebab-identifier names are allow attempts;
                    // `lint: allow(<rule>)` in prose documenting the
                    // syntax is not one and must not raise meta findings
                    if !rule.is_empty() && rule.chars().all(is_kebab) {
                        found.push(Allow { rule, has_reason });
                    }
                    from = start + close;
                } else {
                    break;
                }
            }
            found
        })
        .collect()
}

/// An allow on the finding's line or the line directly above suppresses
/// the (non-meta) rule there.
fn suppressed(allows: &[Vec<Allow>], rule: &str, l: usize) -> bool {
    let on = |idx: usize| allows.get(idx).is_some_and(|v| v.iter().any(|a| a.rule == rule));
    on(l) || (l > 0 && on(l - 1))
}

/// Walk back from a recorder borrow to the enclosing `fn` header and
/// accept the site if any line in between carries one of the
/// zero-cost-off guard idioms.
fn recorder_guard_ok(code: &[String], l: usize) -> bool {
    let guard_markers = [
        "let Some(",
        "match &self.inner",
        "match self.inner",
        ".map_or(",
        ".map_or_else(",
        "is_none()",
        "is_enabled()",
        "should_record(",
    ];
    let mut k = l;
    loop {
        let line = code.get(k).map(String::as_str).unwrap_or("");
        if contains_token(line, "fn") {
            return (k..=l).any(|j| {
                let body = code.get(j).map(String::as_str).unwrap_or("");
                guard_markers.iter().any(|m| body.contains(m))
            });
        }
        if k == 0 {
            return false;
        }
        k -= 1;
    }
}

/// Accept an `unsafe` site when the contract comment is on the same
/// line, or in the contiguous comment block above it. The upward walk
/// skips blank lines, attributes, and *other* unsafe-bearing code lines
/// (so stacked `unsafe impl Send` / `unsafe impl Sync` share one
/// contract block), and stops at any other code line.
fn unsafe_contract_ok(lexed: &Lexed, l: usize) -> bool {
    let has_contract = |idx: usize| {
        lexed
            .comment
            .get(idx)
            .is_some_and(|c| c.to_ascii_lowercase().contains("safety"))
    };
    if has_contract(l) {
        return true;
    }
    let mut k = l;
    for _ in 0..12 {
        if k == 0 {
            return false;
        }
        k -= 1;
        if has_contract(k) {
            return true;
        }
        let code = lexed.code.get(k).map(|s| s.trim()).unwrap_or("");
        if !code.is_empty() && !contains_token(code, "unsafe") && !code.starts_with("#[") {
            return false;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(path: &str, src: &str) -> Vec<&'static str> {
        lint_source(path, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn partial_cmp_flagged_everywhere_even_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn s(xs: &mut Vec<f32>) {\n        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n    }\n}\n";
        assert_eq!(rules_of("metrics/x.rs", src), vec![rules::NO_PARTIAL_CMP_UNWRAP]);
    }

    #[test]
    fn hash_scoped_to_numeric_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_of("train/qat.rs", src), vec![rules::NO_HASH_ITER_IN_NUMERIC]);
        assert!(rules_of("data/tokenizer.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_region_is_exempt_from_scoped_rules() {
        let src = "pub fn f() {}\n\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    #[test]\n    fn t() {\n        let mut m = HashMap::new();\n        m.insert(1, std::time::Instant::now());\n        assert!(m.len() == 1);\n    }\n}\n";
        assert!(rules_of("engine/x.rs", src).is_empty());
    }

    #[test]
    fn code_after_test_region_is_checked_again() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n\npub fn f() { let _ = std::time::Instant::now(); }\n";
        assert_eq!(rules_of("engine/x.rs", src), vec![rules::NO_WALLCLOCK_IN_KERNELS]);
    }

    #[test]
    fn allow_on_same_line_and_line_above() {
        let above = "pub fn step(&mut self) {\n    // lint: allow(no-panic-in-request-path): i < active.len() by loop bound\n    let a = &mut self.active[0];\n}\n";
        assert!(rules_of("serve/scheduler.rs", above).is_empty());
        let same = "pub fn step(&mut self) {\n    let a = &mut self.active[0]; // lint: allow(no-panic-in-request-path): bound checked\n}\n";
        assert!(rules_of("serve/scheduler.rs", same).is_empty());
    }

    #[test]
    fn allow_without_reason_raises_meta_finding() {
        let src = "pub fn step(&mut self) {\n    // lint: allow(no-panic-in-request-path)\n    let a = &mut self.active[0];\n}\n";
        assert_eq!(rules_of("serve/scheduler.rs", src), vec![rules::LINT_ALLOW_NEEDS_REASON]);
    }

    #[test]
    fn documenting_the_allow_syntax_is_not_an_allow() {
        // doc comments explaining the escape write `allow(<rule>)` with
        // a placeholder — prose, not an allow attempt, no meta finding
        let src = "//! Escapes look like `// lint: allow(<rule>): <reason>`.\npub fn f() {}\n";
        assert!(rules_of("engine/x.rs", src).is_empty());
    }

    #[test]
    fn allow_with_unknown_rule_raises_meta_finding_and_suppresses_nothing() {
        let src = "pub fn step(&mut self) {\n    // lint: allow(no-such-rule): because\n    let a = &mut self.active[0];\n}\n";
        let got = rules_of("serve/scheduler.rs", src);
        assert!(got.contains(&rules::LINT_ALLOW_UNKNOWN_RULE));
        assert!(got.contains(&rules::NO_PANIC_IN_REQUEST_PATH));
    }

    #[test]
    fn legacy_engine_variants_flagged_outside_engine_even_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(engine: &Engine) {\n        engine.decode_step_kernel(1, kernel, &mut cache, &mut scratch);\n    }\n}\n";
        assert_eq!(rules_of("bench/mod.rs", src), vec![rules::NO_LEGACY_ENGINE_VARIANTS]);
        let traced = "fn t(e: &Engine) { e.decode_step_batch_kernel_traced(&t, &s, &mut p, &mut b, k, &r); }\n";
        assert_eq!(rules_of("serve/scheduler.rs", traced), vec![rules::NO_LEGACY_ENGINE_VARIANTS]);
    }

    #[test]
    fn legacy_engine_variants_exempt_under_engine() {
        let src = "fn f(e: &Engine) { e.generate_with(&pool, &prompt, 4, None); }\n";
        assert!(rules_of("engine/model.rs", src).is_empty());
        assert_eq!(rules_of("pipeline/eval.rs", src), vec![rules::NO_LEGACY_ENGINE_VARIANTS]);
        // the _ctx replacements are not legacy names and never trip it
        let ctx = "fn f(e: &Engine) { e.generate_ctx(&ectx, &prompt, 4, None); }\n";
        assert!(rules_of("pipeline/eval.rs", ctx).is_empty());
    }

    #[test]
    fn blocking_io_without_timeout_scoped_to_serve_net() {
        let bare = "pub fn pump(stream: &mut TcpStream) {\n    let mut b = [0u8; 64];\n    let _ = stream.read(&mut b);\n}\n";
        assert_eq!(
            rules_of("serve/net/conn.rs", bare),
            vec![rules::NO_BLOCKING_IO_WITHOUT_TIMEOUT]
        );
        // same code outside serve/net is out of scope
        assert!(rules_of("serve/scheduler_io.rs", bare).is_empty());
        assert!(rules_of("bench/mod.rs", bare).is_empty());
    }

    #[test]
    fn arming_a_timeout_anywhere_in_the_file_satisfies_the_io_rule() {
        let src = "pub fn pump(stream: &mut TcpStream) {\n    let _ = stream.set_read_timeout(Some(T));\n    let mut b = [0u8; 64];\n    let _ = stream.read(&mut b);\n    let _ = stream.write_all(&b);\n}\n";
        assert!(rules_of("serve/net/conn.rs", src).is_empty());
        let nonblocking = "pub fn serve(l: &TcpListener) {\n    l.set_nonblocking(true).ok();\n    let _ = l.accept();\n}\n";
        assert!(rules_of("serve/net/mod.rs", nonblocking).is_empty());
    }

    #[test]
    fn io_rule_exempts_tests_and_honors_allows() {
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        let mut s = TcpStream::connect(addr).unwrap();\n        s.write_all(b\"x\").unwrap();\n    }\n}\n";
        assert!(rules_of("serve/net/mod.rs", test_src).is_empty());
        let allowed = "pub fn pump(stream: &mut TcpStream) {\n    // lint: allow(no-blocking-io-without-timeout): caller armed the timeout at accept\n    let _ = stream.flush();\n}\n";
        assert!(rules_of("serve/net/conn.rs", allowed).is_empty());
    }

    #[test]
    fn unsafe_contract_walks_over_sibling_impls() {
        let src = "// SAFETY: rows are disjoint; one writer per index.\nunsafe impl Send for W {}\nunsafe impl Sync for W {}\n";
        assert!(rules_of("parallel/w.rs", src).is_empty());
        let bare = "unsafe impl Send for W {}\n";
        assert_eq!(rules_of("parallel/w.rs", bare), vec![rules::UNSAFE_NEEDS_CONTRACT_COMMENT]);
    }

    #[test]
    fn recorder_guard_detection() {
        let guarded = "impl R {\n    pub fn push(&self, e: u32) {\n        if let Some(rc) = &self.inner {\n            rc.borrow_mut().events.push(e);\n        }\n    }\n}\n";
        assert!(rules_of("obs/trace.rs", guarded).is_empty());
        let bare = "impl R {\n    pub fn push(&self, e: u32) {\n        self.inner.borrow_mut().events.push(e);\n    }\n}\n";
        assert_eq!(rules_of("obs/trace.rs", bare), vec![rules::GUARDED_RECORDER_USE]);
    }

    #[test]
    fn strings_and_comments_never_trip_rules() {
        let src = "pub fn f() {\n    // do not call partial_cmp().unwrap() or Instant::now() here\n    let _m = \"HashMap unsafe panic! Instant::now()\";\n}\n";
        assert!(rules_of("engine/x.rs", src).is_empty());
    }

    #[test]
    fn json_shape_roundtrips() {
        let report = LintReport {
            findings: vec![Finding {
                rule: rules::NO_WALLCLOCK_IN_KERNELS,
                path: "engine/x.rs".to_string(),
                line: 3,
                snippet: "let t = std::time::Instant::now();".to_string(),
                note: "move it".to_string(),
            }],
            files: 1,
        };
        let j = report.to_json().to_string();
        let parsed = Json::parse(&j).expect("lint json parses");
        if let Json::Obj(m) = parsed {
            assert_eq!(m.get("kind").and_then(Json::as_str), Some("lint"));
            assert!(matches!(m.get("clean"), Some(Json::Bool(false))));
        } else {
            panic!("lint json must be an object");
        }
    }

    #[test]
    fn shipped_crate_lints_clean() {
        // the self-hosted contract: the crate that ships the linter
        // passes it. Every real violation is either fixed or carries a
        // reasoned allow.
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = lint_dir(&root).expect("lint walk over src/");
        assert!(report.findings.is_empty(), "self-lint found:\n{}", report.render_human());
        assert!(report.files > 30, "expected to scan the whole crate");
    }
}
