//! Line-classifying lexer for the repo lint (`bitdistill lint`).
//!
//! The rule engine ([`super::engine`]) wants to pattern-match *code*,
//! not prose: a doc comment that says "never call `partial_cmp` here"
//! or a log string containing `unwrap()` must not trip a rule. This
//! lexer walks the raw source once and splits every line into two
//! parallel views with identical byte positions:
//!
//! - `code`:    code bytes, with comments and the *contents and
//!              delimiters* of string/char literals blanked to spaces;
//! - `comment`: comment bytes (line, doc, and nested block comments),
//!              everything else blanked.
//!
//! Both views have exactly one entry per source line, so `code[i]` /
//! `comment[i]` line up with editor line `i + 1` in findings.
//!
//! Handled syntax: `//` and `/* */` comments (block comments nest, per
//! Rust), `"..."` strings with escapes and `\`-newline continuations,
//! raw strings `r"…"` / `r#"…"#` with any number of hashes, byte
//! strings `b"…"` / `br#"…"#`, char and byte-char literals (including
//! escapes like `'\n'`, `'\u{41}'`, `b'"'`), and the char-vs-lifetime
//! ambiguity: `'a'` is a literal, `<'a>` / `&'static` are lifetimes.
//! Lifetimes stay in the code view (they are code); literals are
//! blanked so `b'"'` cannot open a phantom string.

/// The two parallel per-line views of one source file.
pub struct Lexed {
    /// Code with comments + literal contents blanked (one entry per line).
    pub code: Vec<String>,
    /// Comment text with code blanked (one entry per line).
    pub comment: Vec<String>,
}

impl Lexed {
    /// Number of lines (identical for both views).
    pub fn lines(&self) -> usize {
        self.code.len()
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    LineComment,
    /// Inside `/* ... */`; Rust block comments nest, `depth` counts opens.
    BlockComment { depth: u32 },
    /// Inside `"..."` (escapes honored, may span lines).
    Str,
    /// Inside `r##"..."##`-style raw string; closes on `"` + `hashes` hashes.
    RawStr { hashes: u32 },
}

/// Accumulates the two views line by line.
#[derive(Default)]
struct Builder {
    code: Vec<String>,
    comment: Vec<String>,
    code_line: Vec<u8>,
    comment_line: Vec<u8>,
}

impl Builder {
    fn code_byte(&mut self, b: u8) {
        self.code_line.push(b);
        self.comment_line.push(b' ');
    }
    fn comment_byte(&mut self, b: u8) {
        self.comment_line.push(b);
        self.code_line.push(b' ');
    }
    /// Byte belongs to neither view (string/char contents + delimiters).
    fn blank(&mut self) {
        self.code_line.push(b' ');
        self.comment_line.push(b' ');
    }
    fn newline(&mut self) {
        let code = std::mem::take(&mut self.code_line);
        let comment = std::mem::take(&mut self.comment_line);
        self.code.push(String::from_utf8_lossy(&code).into_owned());
        self.comment.push(String::from_utf8_lossy(&comment).into_owned());
    }
    fn finish(mut self) -> Lexed {
        if !self.code_line.is_empty() || !self.comment_line.is_empty() {
            self.newline();
        }
        Lexed { code: self.code, comment: self.comment }
    }
}

fn at(b: &[u8], i: usize) -> u8 {
    b.get(i).copied().unwrap_or(0)
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// UTF-8 sequence length from the leading byte (1 for ASCII/invalid).
fn utf8_len(lead: u8) -> usize {
    if lead < 0x80 {
        1
    } else if lead < 0xE0 {
        2
    } else if lead < 0xF0 {
        3
    } else {
        4
    }
}

/// If position `i` starts a raw (byte) string — `r"`, `r#"`, `br##"`, … —
/// returns `(total_prefix_len_including_quote, hashes)`.
fn raw_str_start(b: &[u8], i: usize) -> Option<(usize, u32)> {
    if i > 0 && is_ident(at(b, i - 1)) {
        return None; // `…r"` glued to an identifier is not a prefix
    }
    let mut j = i;
    if at(b, j) == b'b' {
        j += 1;
    }
    if at(b, j) != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while at(b, j) == b'#' {
        hashes += 1;
        j += 1;
    }
    // `r#ident` (raw identifier) has no quote after the hashes
    if at(b, j) == b'"' {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// Consume a `'`-introduced token at `i` (a char literal or a lifetime).
/// Literals are blanked; a lifetime's `'` is emitted as code. Returns the
/// next unconsumed index.
fn consume_quote(b: &[u8], i: usize, out: &mut Builder) -> usize {
    if at(b, i + 1) == b'\\' {
        // escaped char literal: '\n', '\'', '\u{1F600}', …
        out.blank(); // opening '
        out.blank(); // backslash
        let mut k = i + 2;
        // the escaped character itself may BE a quote ('\''): consume it
        // unconditionally so the scan below finds the real closer
        if k < b.len() && at(b, k) != b'\n' {
            out.blank();
            k += 1;
        }
        while k < b.len() && at(b, k) != b'\'' && at(b, k) != b'\n' {
            out.blank();
            k += 1;
        }
        if at(b, k) == b'\'' {
            out.blank();
            k += 1;
        }
        return k;
    }
    let l = utf8_len(at(b, i + 1));
    if at(b, i + 1) != b'\'' && at(b, i + 1) != 0 && at(b, i + 1 + l) == b'\'' {
        // 'x' (possibly multibyte) closed by a quote: a char literal
        for _ in 0..(l + 2) {
            out.blank();
        }
        return i + l + 2;
    }
    // lifetime or loop label ('a, 'static, 'outer:) — genuine code
    out.code_byte(b'\'');
    i + 1
}

/// Lex `src` into per-line code/comment views. Never fails: unterminated
/// constructs simply stay in their state to end of file.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Builder::default();
    let mut st = State::Code;
    let mut i = 0usize;
    while i < b.len() {
        let c = at(b, i);
        if c == b'\n' {
            out.newline();
            if st == State::LineComment {
                st = State::Code;
            }
            i += 1;
            continue;
        }
        match st {
            State::LineComment => {
                out.comment_byte(c);
                i += 1;
            }
            State::BlockComment { depth } => {
                if c == b'/' && at(b, i + 1) == b'*' {
                    out.comment_byte(b'/');
                    out.comment_byte(b'*');
                    st = State::BlockComment { depth: depth + 1 };
                    i += 2;
                } else if c == b'*' && at(b, i + 1) == b'/' {
                    out.comment_byte(b'*');
                    out.comment_byte(b'/');
                    st = if depth > 1 {
                        State::BlockComment { depth: depth - 1 }
                    } else {
                        State::Code
                    };
                    i += 2;
                } else {
                    out.comment_byte(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == b'\\' {
                    out.blank(); // the backslash
                    if at(b, i + 1) == b'\n' {
                        i += 1; // \-newline continuation: let the top handle '\n'
                    } else {
                        out.blank();
                        i += 2;
                    }
                } else if c == b'"' {
                    out.blank();
                    st = State::Code;
                    i += 1;
                } else {
                    out.blank();
                    i += 1;
                }
            }
            State::RawStr { hashes } => {
                if c == b'"' {
                    let mut k = 0u32;
                    while k < hashes && at(b, i + 1 + k as usize) == b'#' {
                        k += 1;
                    }
                    if k == hashes {
                        for _ in 0..=hashes {
                            out.blank();
                        }
                        st = State::Code;
                        i += 1 + hashes as usize;
                    } else {
                        out.blank();
                        i += 1;
                    }
                } else {
                    out.blank();
                    i += 1;
                }
            }
            State::Code => {
                if c == b'/' && at(b, i + 1) == b'/' {
                    st = State::LineComment;
                    out.comment_byte(b'/');
                    out.comment_byte(b'/');
                    i += 2;
                } else if c == b'/' && at(b, i + 1) == b'*' {
                    st = State::BlockComment { depth: 1 };
                    out.comment_byte(b'/');
                    out.comment_byte(b'*');
                    i += 2;
                } else if c == b'"' {
                    out.blank();
                    st = State::Str;
                    i += 1;
                } else if let Some((pre, hashes)) = raw_str_start(b, i) {
                    for _ in 0..pre {
                        out.blank();
                    }
                    st = State::RawStr { hashes };
                    i += pre;
                } else if c == b'b'
                    && at(b, i + 1) == b'"'
                    && !(i > 0 && is_ident(at(b, i - 1)))
                {
                    out.blank();
                    out.blank();
                    st = State::Str;
                    i += 2;
                } else if c == b'b'
                    && at(b, i + 1) == b'\''
                    && !(i > 0 && is_ident(at(b, i - 1)))
                {
                    out.blank(); // the b prefix
                    i = consume_quote(b, i + 1, &mut out);
                } else if c == b'\'' {
                    i = consume_quote(b, i, &mut out);
                } else {
                    out.code_byte(c);
                    i += 1;
                }
            }
        }
    }
    out.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).code
    }

    #[test]
    fn line_comments_move_to_comment_view() {
        let l = lex("let x = 1; // trailing unwrap() note\n");
        assert_eq!(l.lines(), 1);
        assert!(l.code[0].contains("let x = 1;"));
        assert!(!l.code[0].contains("unwrap"));
        assert!(l.comment[0].contains("unwrap() note"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = code_of("let m = \"call partial_cmp().unwrap() now\";\n");
        assert!(c[0].contains("let m ="));
        assert!(!c[0].contains("partial_cmp"));
        assert!(!c[0].contains("unwrap"));
        // the statement's semicolon survives past the closing quote
        assert!(c[0].trim_end().ends_with(';'));
    }

    #[test]
    fn raw_strings_with_hashes_are_blanked() {
        let src = "let r = r#\"HashMap and unwrap() and \"quoted\"\"#;\nlet y = 2;\n";
        let c = code_of(src);
        assert!(!c[0].contains("HashMap"));
        assert!(!c[0].contains("unwrap"));
        assert!(c[1].contains("let y = 2;"));
    }

    #[test]
    fn multiline_raw_string_preserves_line_count() {
        let src = "let r = r\"line one\nInstant::now()\nline three\";\nlet z = 1;\n";
        let l = lex(src);
        assert_eq!(l.lines(), 4);
        assert!(!l.code[1].contains("Instant"));
        assert!(l.code[3].contains("let z = 1;"));
    }

    #[test]
    fn byte_char_with_quote_does_not_open_string() {
        // the '"' inside b'"' must not start a string and swallow code
        let c = code_of("if c == b'\"' { eat(); }\nlet after = 1;\n");
        assert!(c[0].contains("eat();"));
        assert!(c[1].contains("let after = 1;"));
    }

    #[test]
    fn char_vs_lifetime_disambiguation() {
        let c = code_of("fn f<'a>(x: &'a str) -> char { let c = 'a'; c }\n");
        // lifetimes stay code; the char literal is blanked
        assert!(c[0].contains("<'a>"));
        assert!(!c[0].contains("'a'"));
        assert!(c[0].contains("let c ="));
    }

    #[test]
    fn escaped_char_literals() {
        let c = code_of("let n = '\\n'; let q = '\\''; let u = '\\u{41}'; done();\n");
        assert!(!c[0].contains("\\n"));
        assert!(!c[0].contains("u{41}"));
        assert!(c[0].contains("done();"));
        // the escaped-quote literal must not leave a stray quote behind
        assert!(!c[0].contains('\''), "{:?}", c[0]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "start();\n/* outer /* inner unwrap() */ still comment */ after();\n";
        let l = lex(src);
        assert!(l.code[0].contains("start();"));
        assert!(!l.code[1].contains("unwrap"));
        assert!(l.code[1].contains("after();"));
        assert!(l.comment[1].contains("inner unwrap()"));
    }

    #[test]
    fn multiline_string_spans_lines() {
        let src = "let s = \"first\nsecond HashMap\";\nnext();\n";
        let c = code_of(src);
        assert!(!c[1].contains("HashMap"));
        assert!(c[2].contains("next();"));
    }

    #[test]
    fn string_escape_of_quote_does_not_close() {
        let c = code_of("let s = \"a\\\"b unwrap() c\"; tail();\n");
        assert!(!c[0].contains("unwrap"));
        assert!(c[0].contains("tail();"));
    }

    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let c = code_of("let r#fn = 1; use_it(r#fn);\n");
        assert!(c[0].contains("use_it"));
    }

    #[test]
    fn file_without_trailing_newline_keeps_last_line() {
        let l = lex("let a = 1;");
        assert_eq!(l.lines(), 1);
        assert!(l.code[0].contains("let a = 1;"));
    }
}
