//! Known-bad (and tricky-but-clean) fixture corpus for the lint.
//!
//! Each fixture is a snippet paired with a *virtual path* that places it
//! in a rule's scope, and the exact findings it must produce. The corpus
//! is both the analyzer's regression suite (`expected_findings_*` tests
//! below) and a live demo: `bitdistill lint --fixtures` lints it instead
//! of the tree and therefore must exit non-zero.
//!
//! Fixtures are raw-string constants on purpose: their contents —
//! `unwrap()`, `HashMap`, `unsafe` with no contract — sit inside string
//! literals of *this* file, so the self-lint of the shipped crate stays
//! clean precisely because the lexer blanks them. The corpus doubles as
//! a standing test that raw strings are handled right.

use super::engine::{lint_source, LintReport};
use super::rules;

/// One corpus entry: display name, virtual path, source, expected rule
/// hits (one entry per expected finding, sorted by line then rule).
pub struct Fixture {
    pub name: &'static str,
    pub path: &'static str,
    pub src: &'static str,
    pub expect: &'static [&'static str],
}

const BAD_PARTIAL_CMP: &str = r#"
pub fn rank(xs: &mut Vec<f32>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#;

const BAD_HASH_ITER: &str = r#"
use std::collections::HashMap;

pub fn reduce(shards: &[(usize, f32)]) -> f32 {
    let mut acc: HashMap<usize, f32> = HashMap::new();
    for &(k, v) in shards {
        *acc.entry(k).or_insert(0.0) += v;
    }
    acc.values().sum()
}
"#;

const BAD_REQUEST_PATH: &str = r#"
impl Server {
    pub fn admit(&mut self) {
        let slot = self.pool.acquire().unwrap();
        let first = self.active[0].next_token;
        self.lane.take().expect("lane must exist");
        let _ = (slot, first);
    }
}
"#;

const BAD_WALLCLOCK: &str = r#"
pub fn decode_row(&self, row: &mut [f32]) {
    let t0 = std::time::Instant::now();
    self.kernel(row);
    self.last_ns = t0.elapsed().as_nanos();
}
"#;

const BAD_RECORDER: &str = r#"
impl TraceRecorder {
    pub fn push_unguarded(&self, ev: Event) {
        self.inner.borrow_mut().events.push(ev);
    }
    pub fn push(&self, ev: Event) {
        if let Some(rc) = &self.inner {
            rc.borrow_mut().events.push(ev);
        }
    }
}
"#;

const BAD_UNSAFE: &str = r#"
unsafe impl Send for SliceWriter {}

// SAFETY: disjoint index sets per worker; one writer per slot.
unsafe impl Sync for SliceWriter {}

pub fn write_at(dst: &mut [f32], i: usize, v: f32) {
    unsafe { *dst.as_mut_ptr().add(i) = v }
}
"#;

const BAD_INTRINSIC_NO_SAFETY: &str = r#"
#[target_feature(enable = "avx2")]
unsafe fn dot_row_avx2(w: *const u8, q: *const i8, k: usize) -> i32 {
    let wv = _mm256_loadu_si256(w as *const __m256i);
    let qv = _mm256_loadu_si256(q as *const __m256i);
    let _ = k;
    hsum_epi32(_mm256_maddubs_epi16(wv, qv))
}
"#;

const BAD_LEGACY_VARIANT: &str = r#"
pub fn greedy(engine: &Engine, pool: &ThreadPool, prompt: &[u32]) -> Vec<u32> {
    let mut cache = engine.new_cache();
    let mut scratch = engine.new_scratch();
    engine.generate_with(pool, prompt, 8, None, &mut cache, &mut scratch)
}
"#;

const BAD_UNBOUNDED_SOCKET_READ: &str = r#"
pub fn pump(stream: &mut TcpStream, out: &mut Vec<u8>) -> bool {
    let mut chunk = [0u8; 4096];
    match stream.read(&mut chunk) {
        Ok(0) => false,
        Ok(n) => {
            out.extend_from_slice(chunk.get(..n).unwrap_or(&[]));
            true
        }
        Err(_) => false,
    }
}
"#;

const BAD_ALLOW_NO_REASON: &str = r#"
impl Server {
    pub fn step(&mut self) {
        // lint: allow(no-panic-in-request-path)
        let a = &mut self.active[0];
        a.fed += 1;
    }
}
"#;

const BAD_ALLOW_UNKNOWN_RULE: &str = r#"
pub fn decode_row(&self) {
    // lint: allow(no-wallclock-in-kernel): singular typo, rule is plural
    let t0 = std::time::Instant::now();
    let _ = t0;
}
"#;

const GOOD_ALLOWS: &str = r#"
impl Server {
    pub fn step(&mut self) {
        // lint: allow(no-panic-in-request-path): i < active.len() by the loop bound above
        let a = &mut self.active[0];
        let s = self.active[1].slot; // lint: allow(no-panic-in-request-path): same bound
        a.fed += s;
    }
}
"#;

const TRICKY_CLEAN: &str = r##"
pub fn tricky<'a>(xs: &'a [f32]) -> &'a f32 {
    let _msg = "call partial_cmp(x).unwrap(), Instant::now() and HashMap";
    let _raw = r#"HashSet, panic!("no"), SystemTime and unsafe"#;
    let _ch = 'h';
    let _nl = '\n';
    let _lt: &'static str = "unsafe";
    /* nested /* block with unwrap() and HashMap */ still a comment */
    xs.first().unwrap_or(&0.0)
}
"##;

const TEST_SCOPED_CLEAN: &str = r#"
pub fn double(x: f32) -> f32 {
    x * 2.0
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn scratch_state_may_hash_and_time() {
        let mut m = HashMap::new();
        m.insert(1, std::time::Instant::now());
        assert!(m.contains_key(&1));
    }
}
"#;

/// The corpus. Paths are virtual and chosen to land each snippet inside
/// the relevant rule's scope.
pub fn corpus() -> Vec<Fixture> {
    vec![
        Fixture {
            name: "partial-cmp-unwrap",
            path: "metrics/rank.rs",
            src: BAD_PARTIAL_CMP,
            expect: &[rules::NO_PARTIAL_CMP_UNWRAP],
        },
        Fixture {
            name: "hash-iter-in-numeric",
            path: "train/reduce.rs",
            src: BAD_HASH_ITER,
            expect: &[rules::NO_HASH_ITER_IN_NUMERIC, rules::NO_HASH_ITER_IN_NUMERIC],
        },
        Fixture {
            name: "panic-in-request-path",
            path: "serve/scheduler.rs",
            src: BAD_REQUEST_PATH,
            expect: &[
                rules::NO_PANIC_IN_REQUEST_PATH,
                rules::NO_PANIC_IN_REQUEST_PATH,
                rules::NO_PANIC_IN_REQUEST_PATH,
            ],
        },
        Fixture {
            name: "wallclock-in-kernel",
            path: "engine/gemv.rs",
            src: BAD_WALLCLOCK,
            expect: &[rules::NO_WALLCLOCK_IN_KERNELS],
        },
        Fixture {
            name: "unguarded-recorder",
            path: "obs/trace.rs",
            src: BAD_RECORDER,
            expect: &[rules::GUARDED_RECORDER_USE],
        },
        Fixture {
            name: "unsafe-without-contract",
            path: "parallel/pool.rs",
            src: BAD_UNSAFE,
            expect: &[
                rules::UNSAFE_NEEDS_CONTRACT_COMMENT,
                rules::UNSAFE_NEEDS_CONTRACT_COMMENT,
            ],
        },
        Fixture {
            name: "intrinsics-without-safety",
            path: "engine/simd_ext.rs",
            src: BAD_INTRINSIC_NO_SAFETY,
            expect: &[rules::UNSAFE_NEEDS_CONTRACT_COMMENT],
        },
        Fixture {
            name: "legacy-engine-variant",
            path: "pipeline/eval.rs",
            src: BAD_LEGACY_VARIANT,
            expect: &[rules::NO_LEGACY_ENGINE_VARIANTS],
        },
        Fixture {
            name: "unbounded-socket-read",
            path: "serve/net/conn.rs",
            src: BAD_UNBOUNDED_SOCKET_READ,
            expect: &[rules::NO_BLOCKING_IO_WITHOUT_TIMEOUT],
        },
        Fixture {
            name: "allow-without-reason",
            path: "serve/scheduler.rs",
            src: BAD_ALLOW_NO_REASON,
            expect: &[rules::LINT_ALLOW_NEEDS_REASON],
        },
        Fixture {
            name: "allow-unknown-rule",
            path: "engine/gemv.rs",
            src: BAD_ALLOW_UNKNOWN_RULE,
            expect: &[rules::LINT_ALLOW_UNKNOWN_RULE, rules::NO_WALLCLOCK_IN_KERNELS],
        },
        Fixture {
            name: "reasoned-allows-suppress",
            path: "serve/scheduler.rs",
            src: GOOD_ALLOWS,
            expect: &[],
        },
        Fixture {
            name: "lexer-tricky-clean",
            path: "engine/tricky.rs",
            src: TRICKY_CLEAN,
            expect: &[],
        },
        Fixture {
            name: "cfg-test-scoped-clean",
            path: "engine/scratch.rs",
            src: TEST_SCOPED_CLEAN,
            expect: &[],
        },
    ]
}

/// Lint the fixture corpus as if it were a tree — `bitdistill lint
/// --fixtures`. Always dirty by construction, so the CLI must exit
/// non-zero on it (CI asserts exactly that).
pub fn lint_fixtures() -> LintReport {
    let fixtures = corpus();
    let mut findings = Vec::new();
    for f in &fixtures {
        findings.extend(lint_source(f.path, f.src));
    }
    LintReport { findings, files: fixtures.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_fixture_produces_exactly_its_expected_findings() {
        for f in corpus() {
            let got: Vec<&'static str> =
                lint_source(f.path, f.src).into_iter().map(|x| x.rule).collect();
            assert_eq!(got, f.expect, "fixture {:?} (virtual path {:?})", f.name, f.path);
        }
    }

    #[test]
    fn corpus_is_dirty_and_names_file_lines() {
        let report = lint_fixtures();
        assert!(!report.is_clean());
        // findings address rule + path:line so the CI failure message
        // can name them directly
        for f in &report.findings {
            assert!(f.line >= 1);
            assert!(!f.path.is_empty());
            assert!(!f.rule.is_empty());
        }
        let human = report.render_human();
        assert!(human.contains("serve/scheduler.rs"));
        assert!(human.contains(rules::NO_PARTIAL_CMP_UNWRAP));
    }
}
