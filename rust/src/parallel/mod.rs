//! Deterministic multi-threaded execution layer — the scaffolding every
//! scaling PR (sharding, NUMA pinning, speculative decode) rides on.
//!
//! Dependency-free by construction (the offline vendor set has no rayon
//! or crossbeam): [`ThreadPool`] fans work over `std::thread` **scoped**
//! workers with static chunked partitioning. The determinism contract,
//! enforced by the property tests in [`gemm`]:
//!
//! > Every output element is computed by exactly one worker, with
//! > exactly the accumulation order of the serial kernel — so results
//! > are **bitwise identical** to serial for every thread count.
//!
//! That contract is what lets the serve layer turn threads on without
//! invalidating a single parity test: `Engine::decode_step_batch` over a
//! pool of N workers produces the same logits bit for bit as N = 1,
//! which in turn is bitwise identical to `Engine::decode_step`.
//!
//! ```text
//!  engine   par_gemv_ternary / par_gemm_ternary / par_gemm_f32_shared
//!           par_lut_gemv / par_lut_gemm (activation-LUT generation)
//!           par_simd_gemv / par_simd_gemm / par_simd_gemv_f32 /
//!           par_simd_gemm_f32_shared (runtime-dispatched SIMD
//!           generation)
//!           (row-partitioned; LinOp::apply* and the LM head fan out —
//!            the chunked-prefill GEMMs [engine::prefill] ride the same
//!            batch kernels, rows = prompt-chunk positions)
//!  serve    Server owns a ThreadPool sized by ServerCfg::threads
//!  train    NativeTrainer::train_step maps micro-batch shards over
//!           workers, reduces gradients in fixed shard order
//! ```
//!
//! Workers are spawned per parallel region (scoped, joined before the
//! call returns) rather than parked on condvars: zero unsafe in the
//! executor, no shutdown protocol, and a worker panic unwinds cleanly
//! through `std::thread::scope` instead of deadlocking a job queue —
//! panic-safety is a theme of this layer. The spawn cost (~tens of µs)
//! is amortized by the [`ThreadPool::with_granularity`] work floor:
//! small matmuls run inline on the caller. A persistent parked-worker
//! pool can later slot in behind the same API.

pub mod gemm;
pub mod pool;

pub use gemm::{
    par_gemm_f32_shared, par_gemm_ternary, par_gemv_f32, par_gemv_ternary, par_lut_gemm,
    par_lut_gemv, par_simd_gemm, par_simd_gemm_f32_shared, par_simd_gemv, par_simd_gemv_f32,
};
pub use pool::{SliceWriter, ThreadPool};
