//! Row-partitioned parallel GEMV/GEMM kernels — bitwise identical to
//! their serial twins in [`crate::engine::gemv`] for every thread count.
//!
//! The contract (property-test-enforced below, in the style of PR 1's
//! batch=1 parity tests): each output element is computed by exactly one
//! worker using the **same accumulation order** as the serial kernel —
//! [`dot4`] for f32, [`ternary_row_dot*`](crate::engine::gemv) for the
//! i32 ternary path — so fanning rows across workers cannot move a
//! single bit. Workers write disjoint index sets of the shared output
//! through [`SliceWriter`]; `run_chunked` joins them before returning.

use super::{SliceWriter, ThreadPool};
use crate::engine::gemv::{
    dot4, gemm_ternary, ternary_row_dot, ternary_row_dot_batch, TernGemmScratch,
};
use crate::engine::lut::{lut_gemm, lut_row_dot, lut_row_dot_batch, GROUP_TABLE};
use crate::engine::simd::{dot4_f32, simd_gemm, simd_row_dot};
use crate::engine::ternary::TernaryMatrix;

/// Parallel [`crate::engine::gemv::gemv_f32`]: output rows partitioned
/// across workers.
pub fn par_gemv_f32(
    pool: &ThreadPool,
    w: &[f32],
    n_out: usize,
    k_in: usize,
    x: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(x.len(), k_in);
    debug_assert_eq!(y.len(), n_out);
    let out = SliceWriter::new(y);
    pool.run_chunked(n_out, |range| {
        for n in range {
            let v = dot4(&w[n * k_in..(n + 1) * k_in], x);
            // Safety: each row index n is owned by exactly one worker.
            unsafe { out.write(n, v) };
        }
    });
}

/// Parallel [`crate::engine::gemv::gemv_ternary`]: packed rows
/// partitioned across workers; i32 accumulation per row is order-exact.
pub fn par_gemv_ternary(pool: &ThreadPool, m: &TernaryMatrix, q: &[i8], gamma: f32, y: &mut [f32]) {
    debug_assert_eq!(q.len(), m.cols);
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    let scale = (gamma / 127.0) * m.delta;
    let out = SliceWriter::new(y);
    pool.run_chunked(m.rows, |range| {
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            let v = ternary_row_dot(row, q, full) as f32 * scale;
            // Safety: each row index n is owned by exactly one worker.
            unsafe { out.write(n, v) };
        }
    });
}

/// Parallel [`crate::engine::gemv::gemm_f32_shared`]: weight rows
/// partitioned across workers, each streamed once for the whole batch.
/// A worker owning row `n` writes `ys[bi * n_out + n]` for every `bi` —
/// disjoint across workers, hence the [`SliceWriter`].
pub fn par_gemm_f32_shared(
    pool: &ThreadPool,
    w: &[f32],
    n_out: usize,
    k_in: usize,
    xs: &[f32],
    b: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert!(xs.len() >= b * k_in);
    debug_assert!(ys.len() >= b * n_out);
    let out = SliceWriter::new(ys);
    pool.run_chunked(n_out, |range| {
        for n in range {
            let row = &w[n * k_in..(n + 1) * k_in];
            for bi in 0..b {
                let v = dot4(row, &xs[bi * k_in..(bi + 1) * k_in]);
                // Safety: (n, bi) pairs are disjoint across workers.
                unsafe { out.write(bi * n_out + n, v) };
            }
        }
    });
}

/// Parallel [`crate::engine::gemv::gemm_ternary`]: packed weight rows
/// partitioned across workers, each LUT-decoded once per row for the
/// whole batch via [`ternary_row_dot_batch`]. `scratch` holds the
/// per-lane dequant scales (shared read-only by all workers); the
/// no-fan-out case routes to the serial kernel, which also reuses the
/// scratch accumulators — so a single-threaded server decode loop is
/// allocation-free. Fanned workers keep a private O(b) accumulator.
pub fn par_gemm_ternary(
    pool: &ThreadPool,
    m: &TernaryMatrix,
    qs: &[i8],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(qs.len() >= b * m.cols);
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    if !pool.would_fan(m.rows) {
        gemm_ternary(m, qs, gammas, b, ys, scratch);
        return;
    }
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    let scales = &scratch.scales;
    let out = SliceWriter::new(ys);
    pool.run_chunked(m.rows, |range| {
        let mut acc = vec![0i32; b];
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            ternary_row_dot_batch(row, qs, m.cols, b, full, &mut acc);
            for bi in 0..b {
                // Safety: (n, bi) pairs are disjoint across workers.
                unsafe { out.write(bi * m.rows + n, acc[bi] as f32 * scales[bi]) };
            }
        }
    });
}

/// Parallel [`crate::engine::lut::lut_gemv`]: packed rows partitioned
/// across workers, all reading the one shared activation table. Per-row
/// i32 accumulation is order-exact, so results are bitwise identical to
/// the serial LUT kernel — and therefore to the byte-decode kernels —
/// at every thread count.
pub fn par_lut_gemv(
    pool: &ThreadPool,
    m: &TernaryMatrix,
    table: &[i16],
    gamma: f32,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    debug_assert!(table.len() >= bpr * GROUP_TABLE);
    let scale = (gamma / 127.0) * m.delta;
    let out = SliceWriter::new(y);
    pool.run_chunked(m.rows, |range| {
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            let v = lut_row_dot(row, table) as f32 * scale;
            // Safety: each row index n is owned by exactly one worker.
            unsafe { out.write(n, v) };
        }
    });
}

/// Parallel [`crate::engine::lut::lut_gemm`]: weight rows partitioned
/// across workers, every worker reading the `b` lanes' shared tables.
/// Same scratch discipline as [`par_gemm_ternary`].
pub fn par_lut_gemm(
    pool: &ThreadPool,
    m: &TernaryMatrix,
    tables: &[i16],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    if !pool.would_fan(m.rows) {
        lut_gemm(m, tables, gammas, b, ys, scratch);
        return;
    }
    let bpr = m.bytes_per_row();
    debug_assert!(tables.len() >= b * bpr * GROUP_TABLE);
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    let scales = &scratch.scales;
    let out = SliceWriter::new(ys);
    pool.run_chunked(m.rows, |range| {
        let mut acc = vec![0i32; b];
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            lut_row_dot_batch(row, tables, bpr, b, &mut acc);
            for bi in 0..b {
                // Safety: (n, bi) pairs are disjoint across workers.
                unsafe { out.write(bi * m.rows + n, acc[bi] as f32 * scales[bi]) };
            }
        }
    });
}

/// Parallel [`crate::engine::simd::simd_gemv`]: packed rows partitioned
/// across workers, each row's dot taken by the runtime-dispatched SIMD
/// kernel (or its scalar fallback — same bits either way, so threading
/// composes with the cross-generation parity guarantee unchanged).
pub fn par_simd_gemv(
    pool: &ThreadPool,
    m: &TernaryMatrix,
    q: &[i8],
    gamma: f32,
    y: &mut [f32],
) {
    debug_assert_eq!(q.len(), m.cols);
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    let scale = (gamma / 127.0) * m.delta;
    let out = SliceWriter::new(y);
    pool.run_chunked(m.rows, |range| {
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            let v = simd_row_dot(row, q, full) as f32 * scale;
            // Safety: each row index n is owned by exactly one worker.
            unsafe { out.write(n, v) };
        }
    });
}

/// Parallel [`crate::engine::simd::simd_gemm`]: weight rows partitioned
/// across workers; the no-fan-out case routes to the serial SIMD kernel
/// (scratch-reusing, allocation-free), fanned workers recompute the
/// per-lane scales locally — f32 multiply is deterministic, so both
/// paths land on identical bits.
pub fn par_simd_gemm(
    pool: &ThreadPool,
    m: &TernaryMatrix,
    qs: &[i8],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(qs.len() >= b * m.cols);
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    if !pool.would_fan(m.rows) {
        simd_gemm(m, qs, gammas, b, ys, scratch);
        return;
    }
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    let scales = &scratch.scales;
    let out = SliceWriter::new(ys);
    pool.run_chunked(m.rows, |range| {
        for n in range {
            let row = &m.packed[n * bpr..(n + 1) * bpr];
            for bi in 0..b {
                let d = simd_row_dot(row, &qs[bi * m.cols..(bi + 1) * m.cols], full);
                // Safety: (n, bi) pairs are disjoint across workers.
                unsafe { out.write(bi * m.rows + n, d as f32 * scales[bi]) };
            }
        }
    });
}

/// Parallel [`crate::engine::simd::simd_gemv_f32`]: the SIMD f32 GEMV
/// the LM head rides on under `--kernel simd`. [`dot4_f32`] is bitwise
/// identical to [`dot4`], so this is bitwise identical to
/// [`par_gemv_f32`] at every thread count.
pub fn par_simd_gemv_f32(
    pool: &ThreadPool,
    w: &[f32],
    n_out: usize,
    k_in: usize,
    x: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(x.len(), k_in);
    debug_assert_eq!(y.len(), n_out);
    let out = SliceWriter::new(y);
    pool.run_chunked(n_out, |range| {
        for n in range {
            let v = dot4_f32(&w[n * k_in..(n + 1) * k_in], x);
            // Safety: each row index n is owned by exactly one worker.
            unsafe { out.write(n, v) };
        }
    });
}

/// Parallel [`crate::engine::simd::simd_gemm_f32_shared`]: batched twin
/// of [`par_simd_gemv_f32`], bitwise identical to
/// [`par_gemm_f32_shared`].
pub fn par_simd_gemm_f32_shared(
    pool: &ThreadPool,
    w: &[f32],
    n_out: usize,
    k_in: usize,
    xs: &[f32],
    b: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert!(xs.len() >= b * k_in);
    debug_assert!(ys.len() >= b * n_out);
    let out = SliceWriter::new(ys);
    pool.run_chunked(n_out, |range| {
        for n in range {
            let row = &w[n * k_in..(n + 1) * k_in];
            for bi in 0..b {
                let v = dot4_f32(row, &xs[bi * k_in..(bi + 1) * k_in]);
                // Safety: (n, bi) pairs are disjoint across workers.
                unsafe { out.write(bi * n_out + n, v) };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gemv::{gemm_f32_shared, gemv_f32, gemv_ternary};
    use crate::engine::lut::LutScratch;
    use crate::engine::ternary::act_quant_i8;
    use crate::substrate::prop;

    /// Thread counts the determinism contract is pinned at: serial,
    /// even, odd, and more workers than many of the sampled row counts.
    const THREADS: [usize; 4] = [1, 2, 3, 8];

    #[test]
    fn prop_par_gemv_f32_bitwise_matches_serial() {
        prop::check("par-gemv-f32", 20, |g| {
            let n = g.usize(1, 40); // includes rows < threads
            let k = g.usize(1, 70); // includes non-multiple-of-4 tails
            let w = g.normal_vec(n * k, 1.0);
            let x = g.normal_vec(k, 1.0);
            let mut want = vec![0.0; n];
            gemv_f32(&w, n, k, &x, &mut want);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut y = vec![0.0; n];
                par_gemv_f32(&pool, &w, n, k, &x, &mut y);
                let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "threads={threads} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_gemv_ternary_bitwise_matches_serial() {
        prop::check("par-gemv-ternary", 20, |g| {
            let n = g.usize(1, 40);
            let k = g.usize(4, 70);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.0);
            let mut q = vec![0i8; k];
            let gamma = act_quant_i8(&x, &mut q);
            let mut want = vec![0.0; n];
            gemv_ternary(&m, &q, gamma, &mut want);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut y = vec![0.0; n];
                par_gemv_ternary(&pool, &m, &q, gamma, &mut y);
                let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "threads={threads} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_gemm_f32_shared_bitwise_matches_serial() {
        prop::check("par-gemm-f32-shared", 15, |g| {
            let b = g.usize(1, 5);
            let n = g.usize(1, 40);
            let k = g.usize(1, 70);
            let w = g.normal_vec(n * k, 1.0);
            let xs = g.normal_vec(b * k, 1.0);
            let mut want = vec![0.0; b * n];
            gemm_f32_shared(&w, n, k, &xs, b, &mut want);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut ys = vec![0.0; b * n];
                par_gemm_f32_shared(&pool, &w, n, k, &xs, b, &mut ys);
                let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "threads={threads} b={b} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_gemm_ternary_bitwise_matches_serial() {
        prop::check("par-gemm-ternary", 15, |g| {
            let b = g.usize(1, 5);
            let n = g.usize(1, 30);
            let k = g.usize(4, 70);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] = act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut want = vec![0.0; b * n];
            gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut TernGemmScratch::new());
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut ys = vec![0.0; b * n];
                let mut scratch = TernGemmScratch::new();
                par_gemm_ternary(&pool, &m, &qs, &gammas, b, &mut ys, &mut scratch);
                let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "threads={threads} b={b} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_lut_gemv_bitwise_matches_serial_byte_decode() {
        // the cross-generation contract: the parallel LUT kernel must
        // reproduce the *byte-decode* serial kernel bit for bit at every
        // thread count (serial LUT == serial byte-decode is pinned in
        // engine::lut; this closes the square)
        prop::check("par-lut-gemv", 20, |g| {
            let n = g.usize(1, 40); // includes rows < threads
            let k = g.usize(4, 70); // includes non-multiple-of-4 tails
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.0);
            let mut q = vec![0i8; k];
            let gamma = act_quant_i8(&x, &mut q);
            let mut want = vec![0.0; n];
            gemv_ternary(&m, &q, gamma, &mut want);
            let mut lscratch = LutScratch::new();
            let table = lscratch.build(&q);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut y = vec![0.0; n];
                par_lut_gemv(&pool, &m, table, gamma, &mut y);
                let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "threads={threads} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_lut_gemm_bitwise_matches_serial_byte_decode() {
        // batch {1..5} x threads {1,2,3,8} with tail columns: the LUT
        // batch kernel lands on exactly ternary_row_dot's bits per lane
        prop::check("par-lut-gemm", 15, |g| {
            let b = g.usize(1, 5);
            let n = g.usize(1, 30);
            let k = g.usize(4, 70);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] = act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut want = vec![0.0; b * n];
            gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut TernGemmScratch::new());
            let mut lscratch = LutScratch::new();
            let tables = lscratch.build_batch(&qs, k, b);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut ys = vec![0.0; b * n];
                let mut scratch = TernGemmScratch::new();
                par_lut_gemm(&pool, &m, tables, &gammas, b, &mut ys, &mut scratch);
                let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "threads={threads} b={b} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_simd_gemv_bitwise_matches_serial_byte_decode() {
        // the third-generation square: parallel SIMD must reproduce the
        // serial byte-decode bits at every thread count, whether the
        // host dispatched vectors or the scalar fallback
        prop::check("par-simd-gemv", 20, |g| {
            let n = g.usize(1, 40); // includes rows < threads
            let k = g.usize(4, 200); // spans vector blocks + ragged tails
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.0);
            let mut q = vec![0i8; k];
            let gamma = act_quant_i8(&x, &mut q);
            let mut want = vec![0.0; n];
            gemv_ternary(&m, &q, gamma, &mut want);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut y = vec![0.0; n];
                par_simd_gemv(&pool, &m, &q, gamma, &mut y);
                let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "threads={threads} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_simd_gemm_bitwise_matches_serial_byte_decode() {
        prop::check("par-simd-gemm", 15, |g| {
            let b = g.usize(1, 5);
            let n = g.usize(1, 30);
            let k = g.usize(4, 150);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] = act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut want = vec![0.0; b * n];
            gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut TernGemmScratch::new());
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut ys = vec![0.0; b * n];
                let mut scratch = TernGemmScratch::new();
                par_simd_gemm(&pool, &m, &qs, &gammas, b, &mut ys, &mut scratch);
                let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "threads={threads} b={b} n={n} k={k}");
            }
        });
    }

    #[test]
    fn prop_par_simd_f32_kernels_bitwise_match_serial() {
        prop::check("par-simd-f32", 15, |g| {
            let b = g.usize(1, 5);
            let n = g.usize(1, 40);
            let k = g.usize(1, 70);
            let w = g.normal_vec(n * k, 1.0);
            let xs = g.normal_vec(b * k, 1.0);
            let mut want_v = vec![0.0; n];
            gemv_f32(&w, n, k, &xs[..k], &mut want_v);
            let mut want_m = vec![0.0; b * n];
            gemm_f32_shared(&w, n, k, &xs, b, &mut want_m);
            for threads in THREADS {
                let pool = ThreadPool::with_granularity(threads, 1);
                let mut y = vec![0.0; n];
                par_simd_gemv_f32(&pool, &w, n, k, &xs[..k], &mut y);
                let same = y.iter().zip(&want_v).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "gemv threads={threads} n={n} k={k}");
                let mut ys = vec![0.0; b * n];
                par_simd_gemm_f32_shared(&pool, &w, n, k, &xs, b, &mut ys);
                let same = ys.iter().zip(&want_m).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "gemm threads={threads} b={b} n={n} k={k}");
            }
        });
    }

    #[test]
    fn single_row_with_many_threads_is_exact() {
        // rows < threads must degenerate gracefully (one worker)
        let w = vec![0.5f32, -1.5, 2.0];
        let x = vec![1.0f32, 2.0, 3.0];
        let mut want = vec![0.0];
        gemv_f32(&w, 1, 3, &x, &mut want);
        let pool = ThreadPool::with_granularity(8, 1);
        let mut y = vec![0.0];
        par_gemv_f32(&pool, &w, 1, 3, &x, &mut y);
        assert_eq!(y[0].to_bits(), want[0].to_bits());
    }
}
