//! The executor: scoped-worker fan-out with static chunked partitioning,
//! plus [`SliceWriter`], the disjoint-write escape hatch the row-
//! partitioned kernels use to fill one output buffer from many workers.

use std::marker::PhantomData;
use std::ops::Range;

/// Rows of work each worker should own before fan-out pays for the
/// spawn: below `min_chunk * 2` total units the region runs inline on
/// the caller thread. Serial and parallel paths are bitwise identical,
/// so this is purely a performance knob.
const DEFAULT_MIN_CHUNK: usize = 256;

/// A deterministic data-parallel executor over `std::thread` scoped
/// workers. Cheap to construct (two words, `Copy`) — it holds policy,
/// not threads; workers live only for the duration of one parallel
/// region and are joined before the region returns, so a panic in any
/// worker propagates to the caller instead of poisoning shared state.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    threads: usize,
    min_chunk: usize,
}

impl ThreadPool {
    /// A pool of `threads` workers (clamped to >= 1) with the default
    /// work floor.
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool::with_granularity(threads, DEFAULT_MIN_CHUNK)
    }

    /// Single-threaded pool: every region runs inline on the caller.
    pub fn serial() -> ThreadPool {
        ThreadPool::new(1)
    }

    /// Pool with an explicit work floor (units of work per worker below
    /// which a region stays serial). Tests use `min_chunk = 1` to force
    /// fan-out on tiny shapes.
    pub fn with_granularity(threads: usize, min_chunk: usize) -> ThreadPool {
        ThreadPool { threads: threads.max(1), min_chunk: min_chunk.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers a region of `n` units will actually use.
    fn workers_for(&self, n: usize) -> usize {
        if self.threads <= 1 || n < 2 * self.min_chunk {
            1
        } else {
            self.threads.min(n / self.min_chunk).max(1)
        }
    }

    /// Whether a region of `n` units would actually fan out (> 1
    /// worker). Kernels with per-worker temporaries use this to route
    /// the no-fan-out case to their serial twin and its caller-owned
    /// scratch instead of spawning nothing and still allocating.
    pub fn would_fan(&self, n: usize) -> bool {
        self.workers_for(n) > 1
    }

    /// Split `0..n` into at most `threads` contiguous chunks and run
    /// `f(range)` on each, one chunk per worker (the caller thread takes
    /// chunk 0). Chunk boundaries depend only on `n` and the worker
    /// count, never on scheduling, and `f` sees each index exactly once
    /// — so any `f` whose per-index work is order-independent across
    /// chunks produces identical results at every thread count.
    pub fn run_chunked<F>(&self, n: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let workers = self.workers_for(n);
        if workers <= 1 {
            f(0..n);
            return;
        }
        let chunk = (n + workers - 1) / workers;
        std::thread::scope(|s| {
            let f = &f;
            for w in 1..workers {
                let lo = w * chunk;
                if lo >= n {
                    break;
                }
                let hi = (lo + chunk).min(n);
                s.spawn(move || f(lo..hi));
            }
            f(0..chunk.min(n));
        });
    }

    /// `(0..n).map(f)` with the index blocks fanned across workers:
    /// slot `i` of the result always holds `f(i)`, so reductions over
    /// the returned Vec are in fixed index order regardless of thread
    /// count. Used for heavyweight tasks (micro-batch forward/backward);
    /// no work floor is applied beyond capping workers at `n`.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = if self.threads <= 1 { 1 } else { self.threads.min(n) };
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let chunk = (n + workers - 1) / workers;
        std::thread::scope(|s| {
            let f = &f;
            let mut rest: &mut [Option<T>] = &mut out;
            let mut base = 0usize;
            loop {
                let take = chunk.min(rest.len());
                let (mine, tail) = rest.split_at_mut(take);
                rest = tail;
                let lo = base;
                base += take;
                if rest.is_empty() {
                    // last block runs on the caller thread
                    for (j, slot) in mine.iter_mut().enumerate() {
                        *slot = Some(f(lo + j));
                    }
                    break;
                }
                s.spawn(move || {
                    for (j, slot) in mine.iter_mut().enumerate() {
                        *slot = Some(f(lo + j));
                    }
                });
            }
        });
        out.into_iter()
            .map(|o| o.expect("scoped workers fill every slot before the region ends"))
            .collect()
    }
}

/// Shared view over a `&mut [T]` that lets workers write **disjoint**
/// index sets of one output buffer concurrently — the row-partitioned
/// GEMM kernels write `ys[bi * n_out + n]`, which is a disjoint but
/// non-contiguous set per worker, so safe `chunks_mut` splitting does
/// not apply. The borrow of the underlying slice is held for the
/// writer's lifetime (`PhantomData<&'a mut [T]>`), so no other access
/// can exist while workers write; `std::thread::scope`'s join publishes
/// the writes before the caller reads the buffer again.
pub struct SliceWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// Safety: SliceWriter only allows writing T values (no aliasing reads),
// and the caller contract on `write` makes the index sets disjoint
// across threads. Sending/sharing it is sound for any Send T.
unsafe impl<T: Send> Send for SliceWriter<'_, T> {}
unsafe impl<T: Send> Sync for SliceWriter<'_, T> {}

impl<'a, T> SliceWriter<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SliceWriter<'a, T> {
        SliceWriter { ptr: slice.as_mut_ptr(), len: slice.len(), _borrow: PhantomData }
    }

    /// Write `v` to index `i`.
    ///
    /// # Safety
    /// `i < len` of the wrapped slice; during one parallel region each
    /// index is written by at most one thread and read by none.
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len, "SliceWriter write {i} out of {}", self.len);
        *self.ptr.add(i) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_chunked_covers_each_index_exactly_once() {
        for threads in [1usize, 2, 3, 8] {
            for n in [0usize, 1, 2, 7, 8, 9, 64] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                let pool = ThreadPool::with_granularity(threads, 1);
                pool.run_chunked(n, |range| {
                    for i in range {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    }
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::SeqCst), 1, "threads={threads} n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn work_floor_keeps_small_regions_serial() {
        let pool = ThreadPool::with_granularity(8, 100);
        assert_eq!(pool.workers_for(199), 1);
        assert_eq!(pool.workers_for(200), 2);
        assert_eq!(pool.workers_for(100 * 8), 8);
        // worker count is capped by the work floor, not just `threads`
        assert_eq!(pool.workers_for(350), 3);
        assert_eq!(ThreadPool::serial().workers_for(1_000_000), 1);
        // would_fan mirrors workers_for
        assert!(!pool.would_fan(199));
        assert!(pool.would_fan(200));
        assert!(!ThreadPool::serial().would_fan(1_000_000));
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        pool.run_chunked(3, |r| {
            assert_eq!(r, 0..3);
        });
        let ran = AtomicUsize::new(0);
        pool.run_chunked(0, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 0, "n=0 must not invoke the body");
    }

    #[test]
    fn map_indexed_preserves_index_order() {
        for threads in [1usize, 2, 3, 8] {
            let pool = ThreadPool::with_granularity(threads, 1);
            let got = pool.map_indexed(11, |i| i * i);
            let want: Vec<usize> = (0..11).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
            assert!(pool.map_indexed(0, |i| i).is_empty());
        }
    }

    #[test]
    fn slice_writer_disjoint_writes_land() {
        let mut buf = vec![0i64; 40];
        let pool = ThreadPool::with_granularity(4, 1);
        let w = SliceWriter::new(&mut buf);
        pool.run_chunked(40, |range| {
            for i in range {
                // each index written by exactly one worker
                unsafe { w.write(i, i as i64 + 1) };
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as i64 + 1);
        }
    }
}
