//! Host-side tensors and conversion to/from PJRT literals.
//!
//! Everything that crosses the L3<->HLO boundary is either an f32 tensor
//! (parameters, optimizer state, scalars) or an i32 tensor (tokens,
//! labels, layer indices), so two concrete types beat a generic one.

use anyhow::{bail, Context, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        TensorF32 { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorF32 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major [i, j] accessor for 2-D tensors.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // XLA scalars: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = literal_dims(lit)?;
        let data = lit.to_vec::<f32>().context("literal -> f32 vec")?;
        TensorF32::from_vec(&shape, data)
    }
}

/// Dense row-major i32 tensor (tokens / labels / indices).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn scalar(v: i32) -> Self {
        TensorI32 { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorI32 { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }
}

fn literal_dims(lit: &xla::Literal) -> Result<Vec<usize>> {
    match lit.shape()? {
        xla::Shape::Array(a) => Ok(a.dims().iter().map(|&d| d as usize).collect()),
        other => bail!("expected array literal, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        assert_eq!(TensorF32::zeros(&[4, 5]).numel(), 20);
    }

    #[test]
    fn at2_row_major() {
        let t = TensorF32::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn literal_round_trip_f32() {
        let t = TensorF32::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = TensorF32::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_round_trip_scalar() {
        let t = TensorF32::scalar(7.5);
        let lit = t.to_literal().unwrap();
        let back = TensorF32::from_literal(&lit).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.data, vec![7.5]);
    }
}
