//! `bitdistill` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   pretrain   --size tiny|small|base            pretrain the base model
//!   pipeline   --backend native|hlo --size tiny --task mnli
//!              [--steps-scale X] [--batch N] [--seq N] [--threads N]
//!              [--no-ct] [--no-ld] [--no-ad] [--layer N] [--force]
//!              [--trace FILE] [--quant-metrics FILE] [--quant-every N]
//!              full three-stage BitDistill. `--backend native` needs NO
//!              artifacts/ directory: it trains on the in-crate autograd
//!              tape (src/train/), exports the student to the ternary
//!              engine and prints its eval score vs an untrained baseline.
//!              --threads N runs data-parallel micro-batch training
//!              (deterministic for a fixed thread count). --trace FILE
//!              (native only) records per-stage / per-step spans and
//!              writes a Chrome trace-event JSON for Perfetto.
//!              --quant-metrics FILE (native only) records per-layer
//!              quantization telemetry every --quant-every steps
//!              (default 10) — ternary sparsity, weight-flip rate,
//!              absmean scale + drift, clip fraction, gradient norm,
//!              and the per-component loss breakdown — as kind:"quant"
//!              JSONL rows (render with `report --quant FILE`).
//!              Telemetry on vs off is bitwise identical
//!              (test-enforced).
//!   run        --method fp16-sft|bitnet-sft|bitdistill --task mnli --size tiny
//!              [--no-subln] [--quant absmean|block|gptq|awq] [--no-ct]
//!              [--no-ld] [--no-ad] [--layer N] [--teacher-size S]
//!              [--steps-scale X] [--force]       train + evaluate one method
//!   eval       --ckpt runs/x.ckpt --task mnli [--engine hlo|f32|ternary]
//!   speed      --size tiny [--tokens 256] [--kernel byte|lut|simd]
//!              engine tokens/s + memory
//!   serve      --size tiny [--task mnli] [--requests 64] [--max-batch 16]
//!              [--max-queue 256] [--max-new 16] [--threads 1]
//!              [--prefill-chunk 1] [--prompt-len N]
//!              [--kernel byte|lut|simd|both] [--engine f32|ternary|both]
//!              [--no-report] [--trace FILE] [--metrics-every N]
//!              [--metrics-out FILE] [--quant-metrics FILE]
//!              continuous-batching server demo: queued requests through
//!              the batched engine vs the sequential baseline; emits
//!              reports/BENCH_serve.json. --threads N fans the engine
//!              GEMMs across N workers; --kernel picks the ternary
//!              kernel generation (byte-decode, activation-LUT, or
//!              runtime-dispatched SIMD — scalar-fallback on hosts
//!              without AVX2/NEON, same bits);
//!              --prefill-chunk N feeds up to N prompt tokens per lane
//!              per step (time-batched GEMMs, LM head only at each
//!              chunk's final position) — all three knobs are
//!              bitwise-output-invariant. --prompt-len N swaps the task
//!              workload for fixed-length random prompts (pure-prefill
//!              TTFT shape). --trace FILE records per-request lifecycle
//!              and engine-phase spans (one Perfetto process track per
//!              engine/kernel run) into Chrome trace-event JSON;
//!              --metrics-every N appends a bounded-histogram metrics
//!              snapshot every N scheduler steps to --metrics-out
//!              (default reports/metrics.jsonl); --quant-metrics FILE
//!              appends per-layer int8 activation-range / saturation
//!              rows (kind:"quant", phase:"serve") per engine/kernel
//!              run. Tracing and quant telemetry are
//!              bitwise-output-invariant and off by default.
//!              Works without artifacts (synthetic spec + random weights).
//!              --listen ADDR switches to the overload-hardened TCP
//!              front-end (serve/net/): newline-delimited JSON request
//!              frames in, streamed token / done / timing frames out,
//!              bounded admission with typed reject frames, deadline
//!              shedding, cancel-on-disconnect; serves until a client
//!              sends {"op":"shutdown"}, then drains and prints final
//!              stats. [--engine f32|ternary (default ternary)]
//!              [--max-conns N] [--fault-seed N] (arms the seeded
//!              deterministic chaos plan: slow reads, corrupted frames,
//!              mid-stream disconnects, accept stalls). A final metrics
//!              snapshot row always lands in --metrics-out.
//!   bench      --exp table1|table2|...|all       regenerate paper tables
//!   bench      --check [--min-speedup 1.0] [--min-lut-ratio 1.0]
//!              [--min-simd-ratio 1.0] [--min-prefill-speedup 1.5]
//!              [--prefill-chunk 8] [--prefill-prompt-len 256]
//!              [--prefill-vocab 8192] [--repeats 3]
//!              [--min-obs-ratio 0.98] [--min-quant-ratio 0.95]
//!              kernel perf gate (no artifacts needed): times gemv_f32 /
//!              byte-decode / LUT / SIMD plus chunked-vs-unchunked
//!              prefill, writes reports/BENCH_kernels.json and exits
//!              non-zero when the ternary kernels lose to f32, LUT
//!              loses to byte-decode at n_out >= 1024, SIMD loses to
//!              LUT at n_out >= 1024 on hosts with AVX2/NEON (elsewhere
//!              the scalar fallback is parity-checked, not timed
//!              against a bar), chunked prefill wins
//!              less than 1.5x prompt tok/s at prompt_len 256, decode
//!              with a live trace recorder drops below --min-obs-ratio
//!              of the uninstrumented rate, or native QAT steps with a
//!              live QuantScope at stride 10 drop below
//!              --min-quant-ratio of the uninstrumented trainer (the
//!              observability overhead contracts) — CI's bench job runs
//!              this on every push
//!   report     [--results FILE]                  render results.jsonl tables
//!              [--metrics FILE] render a serve metrics-snapshot JSONL;
//!              [--quant FILE] render a quant-telemetry JSONL (per-layer
//!              flip-rate/sparsity trajectories, loss components, serve
//!              activation saturation);
//!              [--check-trace FILE] validate a Chrome trace-event file
//!              (CI's trace gate: parses the JSON, requires a non-empty
//!              traceEvents array of well-formed span/instant/metadata
//!              events with finite non-negative timestamps and
//!              durations — negative-duration / end-before-start spans
//!              are rejected)
//!   parity     --size tiny                       engine vs HLO logits check
//!   lint       [--root DIR] [--json FILE] [--fixtures]
//!              run the repo-specific static analyzer (src/analysis/)
//!              over the crate sources: determinism-contract rules
//!              (no partial_cmp().unwrap(), no HashMap iteration in
//!              numeric dirs, no panics in the scheduler request path,
//!              no wall-clock in kernels, guarded obs-recorder use,
//!              SAFETY contracts on unsafe, no retired Engine
//!              _with/_kernel variants outside engine/) with reasoned
//!              `// lint: allow(<rule>): <reason>` escapes. Human
//!              output names rule + file:line; --json FILE additionally
//!              writes the findings as JSON (render with
//!              `report --lint FILE`). Exits non-zero on any finding.
//!              --fixtures lints the built-in known-bad corpus instead
//!              (always dirty — CI asserts the non-zero exit).
//!   list                                          list artifacts/models
//!
//! Global flags: --artifacts DIR (default artifacts), --runs DIR
//! (default runs).

use anyhow::{anyhow, bail, Result};

use bitnet_distill::bench as harness;
use bitnet_distill::data::Task;
use bitnet_distill::engine::{Engine, KernelKind};
use bitnet_distill::obs::{QuantScope, TraceRecorder};
use bitnet_distill::params::ParamStore;
use bitnet_distill::pipeline::{self, stages, Ctx, StudentOpts};
use bitnet_distill::runtime::{ModelSpec, Runtime};
use bitnet_distill::substrate::{json, Args, Json};
use bitnet_distill::train;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from<'a>(rt: &'a Runtime, args: &Args) -> Ctx<'a> {
    let mut ctx = Ctx::new(rt, args.str("runs", "runs"));
    ctx.force = args.bool("force");
    ctx.verbose = !args.bool("quiet");
    ctx.steps_scale = args.f64("steps-scale", 1.0);
    ctx
}

fn task_arg(args: &Args) -> Result<Task> {
    let name = args.str("task", "mnli");
    Task::parse(&name).ok_or_else(|| anyhow!("unknown task {name:?}"))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "pretrain" => {
            let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
            let ctx = ctx_from(&rt, args);
            let size = args.str("size", "tiny");
            let path = pipeline::pretrain_base(&ctx, &size)?;
            println!("base checkpoint: {}", path.display());
            Ok(())
        }
        "pipeline" => cmd_pipeline(args),
        "run" => cmd_run(args),
        "eval" => cmd_eval(args),
        "speed" => cmd_speed(args),
        "serve" => cmd_serve(args),
        "parity" => cmd_parity(args),
        "lint" => cmd_lint(args),
        "bench" => {
            // --check is the artifact-free kernel perf gate (CI runs it
            // on every push); the table experiments need a Runtime
            if args.bool("check") {
                return harness::bench_check(args);
            }
            let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
            let ctx = ctx_from(&rt, args);
            harness::run_experiment(&ctx, &args.str("exp", "table1"), args)
        }
        "report" => {
            // --check-trace is CI's trace-validation gate; --metrics
            // renders a `serve --metrics-every` snapshot log
            if let Some(path) = args.opt("check-trace") {
                return cmd_check_trace(path);
            }
            if let Some(path) = args.opt("metrics") {
                let md = harness::report::render_metrics(path)?;
                println!("{md}");
                return Ok(());
            }
            if let Some(path) = args.opt("quant") {
                let md = harness::report::render_quant(path)?;
                println!("{md}");
                return Ok(());
            }
            if let Some(path) = args.opt("lint") {
                let md = harness::report::render_lint(path)?;
                println!("{md}");
                return Ok(());
            }
            let md = harness::report::render(
                args.str("results", "reports/results.jsonl"),
            )?;
            println!("{md}");
            Ok(())
        }
        "list" => {
            let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
            println!("platform: {}", rt.platform());
            println!("models:");
            for k in rt.manifest.models.keys() {
                println!("  {k}");
            }
            println!("artifacts:");
            for (k, a) in &rt.manifest.artifacts {
                println!("  {k} [{}]", a.kind);
            }
            Ok(())
        }
        other => {
            bail!(
                "unknown subcommand {other:?} — see the doc comment in rust/src/main.rs \
                 (pretrain|pipeline|run|eval|speed|serve|bench|report|parity|lint|list)"
            )
        }
    }
}

fn student_opts(args: &Args, task: Task, n_layers: usize) -> StudentOpts {
    let mut o = StudentOpts::defaults_for(task, n_layers);
    if args.bool("no-subln") {
        o.subln = false;
    }
    o.quant = args.str("quant", "absmean");
    if args.bool("no-ld") {
        o.use_ld = false;
    }
    if args.bool("no-ad") {
        o.use_ad = false;
    }
    if let Some(l) = args.opt("layer") {
        o.distill_layer = l.parse().expect("--layer wants an integer");
    }
    if let Some(t) = args.opt("teacher-size") {
        o.teacher_size = Some(t.to_string());
    }
    if let Some(s) = args.opt("ct-steps") {
        o.ct_steps = Some(s.parse().expect("--ct-steps wants an integer"));
    }
    if let Some(s) = args.opt("sft-steps") {
        o.sft_steps = Some(s.parse().expect("--sft-steps wants an integer"));
    }
    o.lambda = args.f64("lambda", o.lambda as f64) as f32;
    o.gamma = args.f64("gamma", o.gamma as f64) as f32;
    o
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let backend = args.str("backend", "native");
    let size = args.str("size", "tiny");
    let task = task_arg(args)?;
    let ct = !args.bool("no-ct");
    match backend.as_str() {
        "native" => {
            let mut ctx = train::NativeCtx::new(args.str("runs", "runs"));
            ctx.force = args.bool("force");
            ctx.verbose = !args.bool("quiet");
            ctx.steps_scale = args.f64("steps-scale", 1.0);
            ctx.batch = args.usize("batch", ctx.batch);
            ctx.seq = args.usize("seq", ctx.seq);
            ctx.threads = args.usize("threads", ctx.threads);
            let trace_path = args.opt("trace").map(String::from);
            if trace_path.is_some() {
                // per-stage / per-step spans land on one named process
                // track; any clone of the recorder can export the file
                ctx.trace = TraceRecorder::enabled().process("pipeline native");
            }
            let quant_path = args.opt("quant-metrics").map(String::from);
            if quant_path.is_some() {
                // per-layer QAT telemetry every --quant-every steps; the
                // scope clone inside the trainer shares this buffer
                ctx.quant = QuantScope::enabled(args.usize("quant-every", 10));
            }
            let n_layers = ModelSpec::synthetic_with(&size, true, "absmean")?
                .config
                .n_layers;
            let opts = student_opts(args, task, n_layers);
            let r = train::run_pipeline(&ctx, &size, task, &opts, ct)?;
            if let Some(path) = &trace_path {
                ctx.trace.write(path)?;
                println!(
                    "wrote trace {path} ({} events, {} dropped)",
                    ctx.trace.len(),
                    ctx.trace.dropped()
                );
            }
            if let Some(path) = &quant_path {
                let dropped = ctx.quant.dropped();
                let rows = ctx.quant.take_rows();
                let n = rows.len();
                harness::append_jsonl_rows(rows, path)?;
                println!("wrote {n} quant telemetry rows to {path} ({dropped} dropped)");
            }
            println!("checkpoint: {}", r.ckpt.display());
            println!(
                "pipeline backend=native size={size} task={}: student {}={:.2} \
                 untrained-baseline {}={:.2}",
                task.name(),
                r.metric,
                r.student,
                r.metric,
                r.baseline
            );
            Ok(())
        }
        // the HLO path IS `run` with its default method=bitdistill
        // (train + evaluate through the AOT artifacts)
        "hlo" => {
            if args.opt("trace").is_some() {
                bail!("--trace is native-only (the HLO path runs inside AOT artifacts)");
            }
            cmd_run(args)
        }
        other => bail!("unknown --backend {other:?} (native|hlo)"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
    let ctx = ctx_from(&rt, args);
    let size = args.str("size", "tiny");
    let task = task_arg(args)?;
    let method = args.str("method", "bitdistill");
    let n_layers = rt.manifest.model(&stages::teacher_key(&size))?.config.n_layers;
    let opts = student_opts(args, task, n_layers);
    let ct = !args.bool("no-ct");

    let ckpt = match method.as_str() {
        "fp16-sft" => pipeline::teacher_sft(&ctx, &size, task)?,
        "bitnet-sft" => pipeline::bitnet_sft(&ctx, &size, task, &opts, false)?,
        "bitdistill" => pipeline::bitdistill(&ctx, &size, task, &opts, ct)?.ckpt,
        m => bail!("unknown method {m:?}"),
    };
    println!("checkpoint: {}", ckpt.display());

    let score = harness::evaluate_ckpt(&ctx, &ckpt, task, &size, &method, &opts)?;
    println!("{}", score.render());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
    let ctx = ctx_from(&rt, args);
    let task = task_arg(args)?;
    let ckpt_path = args
        .opt("ckpt")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow!("--ckpt required"))?;
    let params = ParamStore::load(&ckpt_path)?;
    let spec = rt.manifest.model(&params.model_key)?;
    let n = args.usize("n", 256);
    let ds = pipeline::eval_set(&ctx, task, n);
    let engine_kind = args.str("engine", "hlo");

    if task.is_generation() {
        let ternary = engine_kind != "f32" && spec.config.quant_method != "none";
        let engine = Engine::from_params(spec, &params, ternary)?;
        let m = pipeline::eval_summarization(&engine, &ds, &ctx.tok, 32);
        println!(
            "cnndm: bleu={:.2} r1={:.2} r2={:.2} rl={:.2} rlsum={:.2} avg={:.2}",
            m.bleu, m.rouge1, m.rouge2, m.rouge_l, m.rouge_lsum, m.avg()
        );
        return Ok(());
    }

    let acc = match engine_kind.as_str() {
        "hlo" => {
            let fwd = harness::fwd_artifact_for(&rt, &params.model_key)?;
            pipeline::eval_classification(&rt, &fwd, &params, &ds, &ctx.tok, task)?
        }
        "f32" => {
            let engine = Engine::from_params(spec, &params, false)?;
            pipeline::eval_classification_engine(&engine, &ds, &ctx.tok, task)
        }
        "ternary" => {
            let engine = Engine::from_params(spec, &params, true)?;
            pipeline::eval_classification_engine(&engine, &ds, &ctx.tok, task)
        }
        e => bail!("unknown --engine {e:?}"),
    };
    println!("{}: accuracy={acc:.2} (n={}, engine={engine_kind})", task.name(), ds.len());
    Ok(())
}

fn cmd_speed(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
    let size = args.str("size", "tiny");
    let tokens = args.usize("tokens", 256);
    let kernel = KernelKind::parse_flag(&args.str("kernel", "byte"))?;
    let report = harness::speed_report(&rt, &size, tokens, kernel)?;
    println!("{report}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(addr) = args.opt("listen") {
        return cmd_serve_listen(args, addr);
    }
    let size = args.str("size", "tiny");
    let task = task_arg(args)?;
    let n_req = args.usize("requests", 64);
    let max_batch = args.usize("max-batch", 16);
    let max_queue = args.usize("max-queue", 256);
    let max_new = args.usize("max-new", 16);
    let threads = args.usize("threads", 1);
    let prefill_chunk = args.usize("prefill-chunk", 1).max(1);
    let prompt_len = args.opt("prompt-len").map(|v| {
        v.parse::<usize>()
            .unwrap_or_else(|_| panic!("--prompt-len wants an integer, got {v:?}"))
    });
    let which = args.str("engine", "both");
    let kernel_flag = args.str("kernel", "byte");
    let kernels = KernelKind::parse_sweep(&kernel_flag)?;
    let trace_path = args.opt("trace").map(String::from);
    let metrics_every = args.usize("metrics-every", 0);
    let metrics_out = args.str("metrics-out", "reports/metrics.jsonl");
    let quant_out = args.opt("quant-metrics").map(String::from);
    // one shared recorder for the whole sweep; each engine/kernel run
    // records onto its own named Perfetto process track so request
    // timelines from different runs never interleave. Disabled (the
    // default) recorders cost one Option check per span site.
    let rec = if trace_path.is_some() {
        TraceRecorder::enabled()
    } else {
        TraceRecorder::disabled()
    };
    let mut snapshots: Vec<Json> = Vec::new();
    let mut quant_rows: Vec<Json> = Vec::new();

    let (f32e, terne) = harness::serving_engines(&size, &args.str("artifacts", "artifacts"))?;
    // the kernel selector only touches ternary matmuls, so the f32
    // engine always runs (and is labeled) as byte-decode — sweeping or
    // relabeling it would write duplicate rows under different kernel
    // keys for the identical configuration
    let mut engines: Vec<(&str, &Engine, Vec<KernelKind>)> = Vec::new();
    match which.as_str() {
        "f32" => engines.push(("f32", &f32e, vec![KernelKind::ByteDecode])),
        "ternary" => engines.push(("ternary", &terne, kernels.clone())),
        "both" => {
            engines.push(("f32", &f32e, vec![KernelKind::ByteDecode]));
            engines.push(("ternary", &terne, kernels.clone()));
        }
        e => bail!("unknown --engine {e:?} (f32|ternary|both)"),
    }

    println!(
        "serving size={size} task={} requests={n_req} max_batch={max_batch} \
         threads={threads} kernel={kernel_flag} prefill_chunk={prefill_chunk} \
         weights: f32={:.2}MB ternary={:.2}MB",
        task.name(),
        f32e.weight_bytes() as f64 / 1e6,
        terne.weight_bytes() as f64 / 1e6,
    );

    let mut rows = Vec::new();
    for (name, engine, engine_kernels) in engines {
        let tok = bitnet_distill::data::Tokenizer::new(engine.cfg.vocab);
        // --prompt-len swaps the task workload for fixed-length random
        // prompts at max_new 0 (pure prefill; --max-new is ignored) —
        // the long-prompt TTFT shape CI's serve-smoke exercises. The
        // prompt length rides in the task label so rows at different
        // lengths never merge in the report.
        let (reqs, task_name) = match prompt_len {
            Some(pl) => {
                let pl = pl.min(engine.max_seq());
                (
                    harness::long_prompt_workload(n_req, pl, engine.cfg.vocab, 321),
                    format!("longprompt{pl}"),
                )
            }
            None => (
                harness::serve_workload(task, &tok, n_req, engine.cfg.seq, max_new, 321),
                task.name().to_string(),
            ),
        };
        for kernel in engine_kernels {
            let seq_row = harness::serve_sequential(engine, name, &task_name, &reqs, kernel);
            println!("{}", seq_row.render());
            let run_trace = rec.process(&format!("serve {name}/{} {task_name}", kernel.name()));
            // a fresh scope per engine/kernel run, so the per-(layer,
            // site) accumulators never mix runs
            let run_quant = if quant_out.is_some() {
                QuantScope::enabled(1)
            } else {
                QuantScope::disabled()
            };
            let (batch_row, snaps) = harness::serve_batched_obs(
                engine,
                name,
                &task_name,
                &reqs,
                max_batch,
                max_queue,
                threads,
                kernel,
                prefill_chunk,
                &run_trace,
                &run_quant,
                metrics_every,
            );
            // tag snapshot rows with the run they came from before they
            // all land in one JSONL file
            for mut snap in snaps {
                if let Json::Obj(m) = &mut snap {
                    m.insert("engine".to_string(), json::s(name));
                    m.insert("kernel".to_string(), json::s(kernel.name()));
                }
                snapshots.push(snap);
            }
            for mut row in run_quant.take_rows() {
                if let Json::Obj(m) = &mut row {
                    m.insert("engine".to_string(), json::s(name));
                    m.insert("kernel".to_string(), json::s(kernel.name()));
                }
                quant_rows.push(row);
            }
            println!("{}", batch_row.render());
            println!(
                "  -> continuous batching speedup over sequential: {:.2}x tokens/s",
                batch_row.tok_s / seq_row.tok_s.max(1e-9)
            );
            rows.push(seq_row);
            rows.push(batch_row);
        }
    }
    if let Some(path) = &trace_path {
        rec.write(path)?;
        println!(
            "wrote trace {path} ({} events, {} dropped) — open in ui.perfetto.dev",
            rec.len(),
            rec.dropped()
        );
    }
    if !snapshots.is_empty() {
        let n = snapshots.len();
        harness::append_jsonl_rows(snapshots, &metrics_out)?;
        println!("wrote {n} metrics snapshots to {metrics_out}");
    }
    if let Some(path) = &quant_out {
        let n = quant_rows.len();
        harness::append_jsonl_rows(quant_rows, path)?;
        println!("wrote {n} quant telemetry rows to {path}");
    }
    if !args.bool("no-report") {
        harness::write_serve_report(&rows, "reports/BENCH_serve.json")?;
        harness::append_serve_results(&rows, "reports/results.jsonl")?;
        println!("wrote reports/BENCH_serve.json");
    }
    Ok(())
}

/// `bitdistill serve --listen ADDR` — the overload-hardened TCP
/// front-end ([`bitnet_distill::serve::net`]): newline-delimited JSON
/// frames, streamed tokens, bounded admission with typed reject frames,
/// deadline shedding, cancel-on-disconnect, per-connection timeouts.
/// Serves until a client sends `{"op":"shutdown"}`, then drains and
/// prints the final stats line plus connection counters. `--fault-seed
/// N` arms the deterministic chaos plan (slow reads, corrupted frames,
/// mid-stream disconnects, accept stalls — reproducible from the seed);
/// metrics snapshots land in --metrics-out (a final row is always
/// appended, so shed/cancel counters are inspectable after any run).
fn cmd_serve_listen(args: &Args, addr: &str) -> Result<()> {
    use bitnet_distill::serve::net::{FaultPlan, NetCfg, NetServer};
    use bitnet_distill::serve::ServerCfg;

    let size = args.str("size", "tiny");
    let which = args.str("engine", "ternary");
    let kernel = KernelKind::parse_flag(&args.str("kernel", "byte"))?;
    let scfg = ServerCfg {
        max_batch: args.usize("max-batch", 16),
        max_queue: args.usize("max-queue", 256),
        threads: args.usize("threads", 1),
        kernel,
        prefill_chunk: args.usize("prefill-chunk", 1).max(1),
        metrics_every: args.usize("metrics-every", 0),
    };
    let ncfg = NetCfg {
        addr: addr.to_string(),
        max_conns: args.usize("max-conns", 64),
        ..NetCfg::default()
    };
    let plan = match args.opt("fault-seed") {
        Some(s) => {
            let seed: u64 = s
                .parse()
                .map_err(|_| anyhow!("--fault-seed wants an integer, got {s:?}"))?;
            println!("fault injection armed (seed {seed})");
            FaultPlan::chaos(seed)
        }
        None => FaultPlan::off(),
    };
    let trace_path = args.opt("trace").map(String::from);
    let rec = if trace_path.is_some() {
        TraceRecorder::enabled()
    } else {
        TraceRecorder::disabled()
    };

    let (f32e, terne) = harness::serving_engines(&size, &args.str("artifacts", "artifacts"))?;
    let engine = match which.as_str() {
        "f32" => &f32e,
        "ternary" => &terne,
        e => bail!("unknown --engine {e:?} (f32|ternary)"),
    };
    let mut net = NetServer::bind(ncfg).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    if trace_path.is_some() {
        net.set_trace(rec.process(&format!("serve net {which}/{}", kernel.name())));
    }
    // printed before the blocking run() so scripts (CI's net-smoke) can
    // wait for this line, then connect
    println!("listening on {}", net.local_addr()?);
    let mut report = net.run(engine, scfg, plan);

    println!("{}", report.stats.render(report.wall_s));
    println!(
        "conns={} busy_rejected={} wire_rejects={}",
        report.conns_accepted, report.conns_busy_rejected, report.wire_rejects
    );
    // always close the metrics log with a final cumulative row — the
    // shed/cancel counters must be inspectable even at --metrics-every 0
    let metrics_out = args.str("metrics-out", "reports/metrics.jsonl");
    report.snapshots.push(report.stats.snapshot(report.wall_s, 0, 0, 0));
    let mut rows = Vec::new();
    for mut snap in report.snapshots {
        if let Json::Obj(m) = &mut snap {
            m.insert("engine".to_string(), json::s(&which));
            m.insert("kernel".to_string(), json::s(kernel.name()));
        }
        rows.push(snap);
    }
    let n = rows.len();
    harness::append_jsonl_rows(rows, &metrics_out)?;
    println!("wrote {n} metrics snapshots to {metrics_out}");
    if let Some(path) = &trace_path {
        rec.write(path)?;
        println!(
            "wrote trace {path} ({} events, {} dropped) — open in ui.perfetto.dev",
            rec.len(),
            rec.dropped()
        );
    }
    Ok(())
}

/// `bitdistill lint` — the repo-specific determinism lint (CI runs it
/// on every push). Lints `src/` (or `--root DIR`, or the built-in
/// known-bad corpus with `--fixtures`), optionally writes the findings
/// as JSON (`--json FILE`, rendered by `report --lint FILE`), and exits
/// non-zero when anything is found. The JSON is written *before* the
/// failure exit so CI keeps the evidence as an artifact.
fn cmd_lint(args: &Args) -> Result<()> {
    use bitnet_distill::analysis;
    let report = if args.bool("fixtures") {
        analysis::lint_fixtures()
    } else {
        let root = match args.opt("root") {
            Some(r) => std::path::PathBuf::from(r),
            None => analysis::default_root()?,
        };
        analysis::lint_dir(&root)?
    };
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json().to_string())
            .map_err(|e| anyhow!("lint: writing {path}: {e}"))?;
    }
    print!("{}", report.render_human());
    if !report.is_clean() {
        bail!(
            "lint: {} finding(s) — each names rule + file:line above; fix the \
             site or add `// lint: allow(<rule>): <reason>` (see src/README.md)",
            report.findings.len()
        );
    }
    Ok(())
}

fn cmd_parity(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.str("artifacts", "artifacts"))?;
    let size = args.str("size", "tiny");
    let (max_err_t, max_err_f) = harness::parity_check(&rt, &size)?;
    println!("parity {size}: ternary max|Δ|={max_err_t:.2e} teacher max|Δ|={max_err_f:.2e}");
    Ok(())
}

/// `report --check-trace FILE` — CI's trace gate. The file must parse
/// as Chrome trace-event JSON (`{"traceEvents": [...]}`) and pass
/// [`bitnet_distill::obs::validate_chrome_trace`]: every event carries
/// the fields Perfetto needs for its phase (name/pid always, ts/dur/tid
/// for "X" spans, ts for "i" instants), timestamps and durations are
/// finite and non-negative, no span ends before it starts, and at least
/// one complete span exists.
fn cmd_check_trace(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading trace {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("trace {path}: {e}"))?;
    let (spans, instants, meta) = bitnet_distill::obs::validate_chrome_trace(&j)
        .map_err(|e| anyhow!("trace {path}: {e}"))?;
    println!(
        "trace ok: {path} — {spans} spans, {instants} instants, {meta} metadata rows \
         ({} events)",
        spans + instants + meta
    );
    Ok(())
}
