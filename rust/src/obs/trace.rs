//! Span/event tracing recorder with Chrome trace-event JSON export.
//!
//! A [`TraceRecorder`] buffers monotonic-clock spans ("X" complete
//! events) and instants ("i" events) and serializes them into the
//! Chrome trace-event format (`{"traceEvents":[...]}`), loadable in
//! Perfetto or chrome://tracing. The design contract, shared with the
//! `parallel/` layer's determinism pin: **observability may never
//! change outputs**, and a disabled recorder must be near-zero cost.
//!
//! - `TraceRecorder::disabled()` carries no buffer at all: every
//!   recording call is one `Option` check and returns. The engine and
//!   scheduler hot paths take `&TraceRecorder` unconditionally and rely
//!   on this (the `obs` row in `bitdistill bench --check` gates it).
//! - `TraceRecorder::enabled()` allocates one shared bounded buffer;
//!   `clone` hands out cheap handles onto the same buffer (`Rc`, so a
//!   recorder is deliberately single-threaded — worker threads inside
//!   `parallel/` regions never record, the owning thread wraps the
//!   region in one span instead; this is what keeps recording off the
//!   bitwise-pinned kernel inner loops).
//! - Spans are scoped guards ([`TraceRecorder::span`]) or retroactive
//!   intervals over `Instant`s the caller already had
//!   ([`TraceRecorder::complete`]) — the scheduler uses the latter to
//!   emit per-request lifecycle spans (queued/prefill/decode) from the
//!   timestamps it records anyway.
//! - Track layout: `tid 0` is the scheduler/engine timeline, request
//!   `id` gets track `tid 1 + id`. [`TraceRecorder::process`] opens a
//!   named process track (fresh `pid`) so several serve runs (engine x
//!   kernel sweeps) land side by side in one trace file.
//!
//! Event names and argument keys are `&'static str` so a recording call
//! allocates nothing until it actually stores an event, and the buffer
//! is capped ([`TraceRecorder::with_capacity`]) with a dropped-event
//! counter — tracing a long-running server cannot grow without bound.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::substrate::json::{self, Json};

/// One span/instant argument value. `Str` is `&'static str` on purpose:
/// argument assembly must be allocation-free when the recorder is
/// disabled, and every tag the crate records (kernel kind, finish
/// reason, stage name) is a static label anyway. Numbers carry
/// everything else.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArgV {
    Num(f64),
    Str(&'static str),
}

impl ArgV {
    fn to_json(self) -> Json {
        match self {
            ArgV::Num(n) => json::num_or_null(n),
            ArgV::Str(s) => json::s(s),
        }
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    /// "X" complete event: a span with a duration.
    Complete { dur_us: f64 },
    /// "i" instant event (thread-scoped).
    Instant,
}

#[derive(Debug, Clone)]
struct Event {
    name: &'static str,
    pid: u64,
    tid: u64,
    ts_us: f64,
    kind: EventKind,
    args: Vec<(&'static str, ArgV)>,
}

/// Metadata ("M") events: process/track names shown by the viewer.
#[derive(Debug, Clone)]
struct Meta {
    pid: u64,
    tid: Option<u64>,
    name: String,
}

#[derive(Debug)]
struct Inner {
    epoch: Instant,
    events: Vec<Event>,
    meta: Vec<Meta>,
    cap: usize,
    dropped: u64,
    next_pid: u64,
}

/// Default event capacity: ~1M events is minutes of fully-instrumented
/// serving and a few hundred MB of JSON — past that, drop and count.
const DEFAULT_CAP: usize = 1 << 20;

/// The scheduler/engine timeline track.
pub const TID_MAIN: u64 = 0;

/// Track id for a request: `1 + id` keeps request tracks off the main
/// timeline and stable across trace-on/trace-off comparisons.
pub fn request_tid(id: u64) -> u64 {
    1 + id
}

/// A buffering span recorder (see module docs). Cheap to clone
/// (`Rc`-shared buffer); `disabled()` carries nothing.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    inner: Option<Rc<RefCell<Inner>>>,
    pid: u64,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::disabled()
    }
}

impl TraceRecorder {
    /// The no-op recorder: every recording call is one branch.
    pub fn disabled() -> TraceRecorder {
        TraceRecorder { inner: None, pid: 0 }
    }

    /// A live recorder with the default event capacity.
    pub fn enabled() -> TraceRecorder {
        TraceRecorder::with_capacity(DEFAULT_CAP)
    }

    /// A live recorder holding at most `cap` events; further events are
    /// dropped and counted (surfaced as a `trace_dropped` instant on
    /// export).
    pub fn with_capacity(cap: usize) -> TraceRecorder {
        TraceRecorder {
            inner: Some(Rc::new(RefCell::new(Inner {
                epoch: Instant::now(),
                events: Vec::new(),
                meta: Vec::new(),
                cap: cap.max(1),
                dropped: 0,
                next_pid: 1,
            }))),
            pid: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a named process track: returns a handle onto the same
    /// buffer whose events carry a fresh `pid`, so e.g. each engine x
    /// kernel serve run gets its own lane group in the viewer. On a
    /// disabled recorder this is free and returns another disabled
    /// handle.
    pub fn process(&self, name: &str) -> TraceRecorder {
        match &self.inner {
            None => TraceRecorder::disabled(),
            Some(rc) => {
                let mut inner = rc.borrow_mut();
                let pid = inner.next_pid;
                inner.next_pid += 1;
                inner.meta.push(Meta { pid, tid: None, name: name.to_string() });
                TraceRecorder { inner: Some(rc.clone()), pid }
            }
        }
    }

    /// Name a track (`tid`) within this recorder's process.
    pub fn name_track(&self, tid: u64, name: &str) {
        if let Some(rc) = &self.inner {
            let pid = self.pid;
            rc.borrow_mut().meta.push(Meta { pid, tid: Some(tid), name: name.to_string() });
        }
    }

    fn push(&self, ev: Event) {
        if let Some(rc) = &self.inner {
            let mut inner = rc.borrow_mut();
            if inner.events.len() < inner.cap {
                inner.events.push(ev);
            } else {
                inner.dropped += 1;
            }
        }
    }

    fn us_since_epoch(&self, t: Instant) -> f64 {
        match &self.inner {
            None => 0.0,
            Some(rc) => {
                let epoch = rc.borrow().epoch;
                // saturate to 0 for Instants taken before the epoch
                // (possible when a recorder is attached to an
                // already-running server)
                t.checked_duration_since(epoch)
                    .map_or(0.0, |d| d.as_secs_f64() * 1e6)
            }
        }
    }

    /// Scoped span: records `[now, guard drop]` on `tid`. The guard
    /// captures no timestamp at all when the recorder is disabled.
    pub fn span(&self, tid: u64, name: &'static str) -> SpanGuard<'_> {
        self.span_args(tid, name, &[])
    }

    /// Scoped span with arguments (static keys, no allocation unless
    /// the recorder is live).
    pub fn span_args(
        &self,
        tid: u64,
        name: &'static str,
        args: &[(&'static str, ArgV)],
    ) -> SpanGuard<'_> {
        if self.inner.is_none() {
            return SpanGuard { rec: self, tid, name, start: None, args: Vec::new() };
        }
        SpanGuard { rec: self, tid, name, start: Some(Instant::now()), args: args.to_vec() }
    }

    /// Retroactive span over two `Instant`s the caller already holds —
    /// how per-request lifecycle spans are emitted at retire time from
    /// the submit/admit/first-token timestamps the scheduler keeps
    /// anyway.
    pub fn complete(
        &self,
        tid: u64,
        name: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, ArgV)],
    ) {
        if self.inner.is_none() {
            return;
        }
        let ts = self.us_since_epoch(start);
        let dur = (self.us_since_epoch(end) - ts).max(0.0);
        self.push(Event {
            name,
            pid: self.pid,
            tid,
            ts_us: ts,
            kind: EventKind::Complete { dur_us: dur },
            args: args.to_vec(),
        });
    }

    /// Point-in-time marker.
    pub fn instant(&self, tid: u64, name: &'static str, args: &[(&'static str, ArgV)]) {
        if self.inner.is_none() {
            return;
        }
        let ts = self.us_since_epoch(Instant::now());
        self.push(Event {
            name,
            pid: self.pid,
            tid,
            ts_us: ts,
            kind: EventKind::Instant,
            args: args.to_vec(),
        });
    }

    /// Recorded event count (0 when disabled).
    pub fn len(&self) -> usize {
        self.inner.as_ref().map_or(0, |rc| rc.borrow().events.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped past the capacity cap.
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |rc| rc.borrow().dropped)
    }

    /// Discard buffered events (capacity and epoch kept) — lets the
    /// bench overhead gate time the *recording* cost without ever
    /// tripping the cap.
    pub fn clear(&self) {
        if let Some(rc) = &self.inner {
            let mut inner = rc.borrow_mut();
            inner.events.clear();
            inner.dropped = 0;
        }
    }

    /// Serialize to the Chrome trace-event JSON object form:
    /// `{"traceEvents":[...]}` with "M" metadata, "X" complete and "i"
    /// instant events. A disabled recorder yields an empty event list.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        if let Some(rc) = &self.inner {
            let inner = rc.borrow();
            for m in &inner.meta {
                let (kind, mut fields) = match m.tid {
                    None => ("process_name", vec![("pid", json::num(m.pid as f64))]),
                    Some(tid) => (
                        "thread_name",
                        vec![
                            ("pid", json::num(m.pid as f64)),
                            ("tid", json::num(tid as f64)),
                        ],
                    ),
                };
                fields.push(("ph", json::s("M")));
                fields.push(("name", json::s(kind)));
                fields.push(("args", json::obj(vec![("name", json::s(&m.name))])));
                events.push(json::obj(fields));
            }
            for e in &inner.events {
                let mut fields = vec![
                    ("name", json::s(e.name)),
                    ("cat", json::s("bitdistill")),
                    ("pid", json::num(e.pid as f64)),
                    ("tid", json::num(e.tid as f64)),
                    ("ts", json::num(e.ts_us)),
                ];
                match e.kind {
                    EventKind::Complete { dur_us } => {
                        fields.push(("ph", json::s("X")));
                        fields.push(("dur", json::num(dur_us)));
                    }
                    EventKind::Instant => {
                        fields.push(("ph", json::s("i")));
                        fields.push(("s", json::s("t")));
                    }
                }
                if !e.args.is_empty() {
                    fields.push((
                        "args",
                        Json::Obj(
                            e.args
                                .iter()
                                .map(|(k, v)| (k.to_string(), v.to_json()))
                                .collect(),
                        ),
                    ));
                }
                events.push(json::obj(fields));
            }
            if inner.dropped > 0 {
                events.push(json::obj(vec![
                    ("name", json::s("trace_dropped")),
                    ("cat", json::s("bitdistill")),
                    ("ph", json::s("i")),
                    ("s", json::s("g")),
                    ("pid", json::num(0.0)),
                    ("tid", json::num(TID_MAIN as f64)),
                    ("ts", json::num(0.0)),
                    ("args", json::obj(vec![("dropped", json::num(inner.dropped as f64))])),
                ]));
            }
        }
        json::obj(vec![("traceEvents", Json::Arr(events))])
    }

    /// Write the Chrome trace JSON to `path` (parent dirs created).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_chrome_json().to_string())
    }
}

/// Validate a parsed Chrome trace-event document — the checker behind
/// `bitdistill report --check-trace` (CI's trace gate). Returns
/// `(spans, instants, meta)` counts on success. Rejects, beyond missing
/// fields and unknown phases:
///
/// - non-finite or negative `ts` on "X"/"i" events (the recorder's
///   epoch clock can never go negative, so a negative timestamp means a
///   corrupted or hand-mangled file),
/// - non-finite or negative `dur` on "X" spans (this is where a NaN
///   would otherwise slip through a `< 0.0` check — NaN comparisons are
///   false),
/// - spans whose end lands before their start (`ts + dur` non-finite or
///   below `ts`, e.g. an overflowing `1e308 + 1e308` pair),
/// - a trace with zero "X" spans (nothing was recorded).
pub fn validate_chrome_trace(j: &Json) -> anyhow::Result<(usize, usize, usize)> {
    use anyhow::{anyhow, bail};
    let events = j
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("no traceEvents array"))?;
    let (mut spans, mut instants, mut meta) = (0usize, 0usize, 0usize);
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event {i} has no \"ph\""))?;
        let need = |k: &str| {
            ev.get(k).ok_or_else(|| anyhow!("{ph:?} event {i} missing {k:?}"))
        };
        let finite_ts = |k: &str| -> anyhow::Result<f64> {
            let v = need(k)?
                .as_f64()
                .ok_or_else(|| anyhow!("{ph:?} event {i}: {k:?} is not a number"))?;
            if !v.is_finite() || v < 0.0 {
                bail!("{ph:?} event {i}: {k:?} = {v} is negative or non-finite");
            }
            Ok(v)
        };
        need("name")?;
        need("pid")?;
        match ph {
            "X" => {
                need("tid")?;
                let ts = finite_ts("ts")?;
                let dur = finite_ts("dur")?;
                let end = ts + dur;
                if !end.is_finite() || end < ts {
                    bail!("\"X\" event {i}: span ends before it starts (ts {ts}, dur {dur})");
                }
                spans += 1;
            }
            "i" => {
                finite_ts("ts")?;
                instants += 1;
            }
            "M" => meta += 1,
            other => bail!("event {i} has unexpected ph {other:?}"),
        }
    }
    if spans == 0 {
        bail!("no complete (ph=\"X\") span events — nothing was recorded");
    }
    Ok((spans, instants, meta))
}

/// RAII scoped span: times `[creation, drop]` and records one "X"
/// event on drop. Inert (no clock read) on a disabled recorder.
#[must_use = "a span guard times until it is dropped"]
pub struct SpanGuard<'a> {
    rec: &'a TraceRecorder,
    tid: u64,
    name: &'static str,
    start: Option<Instant>,
    args: Vec<(&'static str, ArgV)>,
}

impl SpanGuard<'_> {
    /// Attach an argument after creation (e.g. a result computed inside
    /// the span). No-op when disabled.
    pub fn arg(&mut self, key: &'static str, v: ArgV) {
        if self.start.is_some() {
            self.args.push((key, v));
        }
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let ts = self.rec.us_since_epoch(start);
            let dur = (self.rec.us_since_epoch(Instant::now()) - ts).max(0.0);
            self.rec.push(Event {
                name: self.name,
                pid: self.rec.pid,
                tid: self.tid,
                ts_us: ts,
                kind: EventKind::Complete { dur_us: dur },
                args: std::mem::take(&mut self.args),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing_and_exports_empty() {
        let t = TraceRecorder::disabled();
        {
            let _g = t.span(TID_MAIN, "outer");
            t.instant(TID_MAIN, "marker", &[("x", ArgV::Num(1.0))]);
        }
        t.complete(TID_MAIN, "retro", Instant::now(), Instant::now(), &[]);
        assert!(!t.is_enabled());
        assert_eq!(t.len(), 0);
        let j = t.to_chrome_json();
        assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn export_has_required_fields_and_span_nesting() {
        let t = TraceRecorder::enabled();
        let srv = t.process("serve test");
        srv.name_track(TID_MAIN, "scheduler");
        {
            let _outer = srv.span_args(TID_MAIN, "step", &[("batch", ArgV::Num(3.0))]);
            {
                let _inner = srv.span(TID_MAIN, "decode_blocks");
                std::hint::black_box(0);
            }
            srv.instant(TID_MAIN, "admitted", &[("id", ArgV::Num(7.0))]);
        }
        let start = Instant::now();
        let end = start + std::time::Duration::from_millis(2);
        srv.complete(request_tid(7), "request", start, end, &[("finish", ArgV::Str("eos"))]);

        let j = Json::parse(&t.to_chrome_json().to_string()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 meta + 2 spans + 1 instant + 1 retroactive span
        assert_eq!(evs.len(), 6);
        for e in evs {
            assert!(e.get("ph").is_some(), "{e:?}");
        }
        let find = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("no event named {name}"))
        };
        // complete events carry ts/dur/tid and nest by containment
        let (outer, inner) = (find("step"), find("decode_blocks"));
        for e in [outer, inner] {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).is_some());
            assert!(e.get("tid").and_then(Json::as_f64).is_some());
        }
        let (ots, odur) = (
            outer.get("ts").unwrap().as_f64().unwrap(),
            outer.get("dur").unwrap().as_f64().unwrap(),
        );
        let (its, idur) = (
            inner.get("ts").unwrap().as_f64().unwrap(),
            inner.get("dur").unwrap().as_f64().unwrap(),
        );
        assert!(its >= ots && its + idur <= ots + odur, "inner span must nest in outer");
        // the retroactive request span lands on its request track
        let req = find("request");
        assert_eq!(req.get("tid").unwrap().as_f64().unwrap() as u64, request_tid(7));
        assert!(req.get("dur").unwrap().as_f64().unwrap() >= 1_000.0); // >= 1ms in us
        assert_eq!(
            req.at(&["args", "finish"]).and_then(Json::as_str),
            Some("eos")
        );
        // process metadata names the serve run
        let meta = find("process_name");
        assert_eq!(meta.at(&["args", "name"]).and_then(Json::as_str), Some("serve test"));
    }

    #[test]
    fn capacity_cap_drops_and_counts() {
        let t = TraceRecorder::with_capacity(4);
        for _ in 0..10 {
            t.instant(TID_MAIN, "tick", &[]);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        let j = t.to_chrome_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let last = evs.last().unwrap();
        assert_eq!(last.get("name").and_then(Json::as_str), Some("trace_dropped"));
        assert_eq!(last.at(&["args", "dropped"]).and_then(Json::as_f64), Some(6.0));
        t.clear();
        assert_eq!(t.len(), 0);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn validator_accepts_every_trace_the_recorder_exports() {
        let t = TraceRecorder::enabled();
        let srv = t.process("serve");
        srv.name_track(TID_MAIN, "scheduler");
        {
            let _g = srv.span(TID_MAIN, "step");
            srv.instant(TID_MAIN, "admitted", &[]);
        }
        let (spans, instants, meta) = validate_chrome_trace(&t.to_chrome_json()).unwrap();
        assert_eq!((spans, instants, meta), (1, 1, 2));
    }

    #[test]
    fn validator_rejects_hand_built_bad_traces() {
        let parse = |s: &str| Json::parse(s).unwrap();
        let bad = [
            // negative duration
            (
                r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":5.0,"dur":-1.0}]}"#,
                "negative dur",
            ),
            // NaN duration: "dur" serialized as null (the json layer's
            // non-finite contract) — must not slip through a `< 0` check
            (
                r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":5.0,"dur":null}]}"#,
                "null (NaN) dur",
            ),
            // end-before-start via overflow to infinity
            (
                r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":1e308,"dur":1e308}]}"#,
                "inf end",
            ),
            // negative timestamp
            (
                r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":-2.0,"dur":1.0}]}"#,
                "negative ts",
            ),
            // instant with a non-finite timestamp
            (
                r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":0.0,"dur":1.0},{"name":"i","ph":"i","pid":0,"ts":null}]}"#,
                "null instant ts",
            ),
            // no spans at all
            (r#"{"traceEvents":[{"name":"m","ph":"M","pid":0}]}"#, "no spans"),
            // missing traceEvents
            (r#"{"other":[]}"#, "no traceEvents"),
        ];
        for (doc, why) in bad {
            assert!(validate_chrome_trace(&parse(doc)).is_err(), "must reject: {why}");
        }
        // the well-formed sibling of the bad spans passes
        let ok = parse(
            r#"{"traceEvents":[{"name":"s","ph":"X","pid":0,"tid":0,"ts":5.0,"dur":1.0}]}"#,
        );
        assert_eq!(validate_chrome_trace(&ok).unwrap(), (1, 0, 0));
    }

    #[test]
    fn clones_share_one_buffer_and_processes_get_distinct_pids() {
        let t = TraceRecorder::enabled();
        let a = t.process("a");
        let b = t.process("b");
        a.instant(TID_MAIN, "from_a", &[]);
        b.instant(TID_MAIN, "from_b", &[]);
        assert_eq!(t.len(), 2);
        let j = t.to_chrome_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let pid_of = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .and_then(|e| e.get("pid"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_ne!(pid_of("from_a"), pid_of("from_b"));
    }
}
