//! Observability layer: span tracing + bounded metrics, dependency-free
//! like `parallel/` and `substrate/`.
//!
//! ```text
//!  TraceRecorder ──► per-request / per-phase spans ──► Chrome trace JSON
//!   (serve scheduler, engine step phases,              (--trace out.json,
//!    native training stages)                            Perfetto-loadable)
//!  Histogram / Registry ──► bounded ServeStats ──► --metrics-every JSONL
//!  QuantScope ──► per-layer QAT lattice stats + loss ──► kind:"quant" JSONL
//!   (sparsity / flip rate / scale drift / clip /        (--quant-metrics,
//!    grad norm; serve-side int8 act saturation)          report --quant)
//! ```
//!
//! The contract, test- and bench-gate-enforced:
//!
//! 1. **Zero cost off.** Instrumentation is off by default; a disabled
//!    [`TraceRecorder`] is one branch per call site, and the `obs` row
//!    in `bitdistill bench --check` gates instrumented decode
//!    throughput at >= 0.98x uninstrumented.
//! 2. **Observability never changes outputs.** Recording only *reads*
//!    the computation; trace-on vs trace-off server responses are
//!    bitwise identical across kernels x threads x prefill_chunk
//!    (pinned in `tests/serve.rs`, same style as the `parallel/`
//!    determinism contract).
//! 3. **Bounded memory.** Metrics use fixed-size log-bucketed
//!    [`Histogram`]s (~2 KB each, ~4.4% worst-case quantile error,
//!    property-tested against the exact sorted-Vec
//!    [`crate::serve::stats::quantile`]); the trace buffer is capped
//!    with a dropped-event counter.

pub mod metrics;
pub mod quantscope;
pub mod trace;

pub use metrics::{Histogram, Registry, HIST_MAX_REL_ERR};
pub use quantscope::{QuantScope, StepLosses};
pub use trace::{request_tid, validate_chrome_trace, ArgV, SpanGuard, TraceRecorder, TID_MAIN};
