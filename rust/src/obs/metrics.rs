//! Fixed-memory metrics: log-bucketed histograms with approximate
//! quantiles, and a counter/gauge/histogram snapshot registry.
//!
//! [`Histogram`] is the bounded replacement for the unbounded
//! `Vec<f64>` sample lists `ServeStats` used to keep: 256 buckets whose
//! bounds grow geometrically by `2^(1/8)` per bucket, so any sample
//! stream — a server running for months included — occupies the same
//! ~2 KB. A quantile is read back as the geometric midpoint of the
//! bucket holding that rank, clamped to the exact observed `[min, max]`,
//! which bounds the relative error by the half-bucket width
//! `2^(1/16) - 1` (~4.4%). The exact sorted-`Vec`
//! [`crate::serve::stats::quantile`] stays canonical for benches; the
//! histogram-vs-exact agreement is property-tested here within that
//! bucket error.
//!
//! Empty histograms follow the `quantile` NaN contract: `quantile`,
//! `mean`, `min` and `max` are NaN until the first sample, and
//! [`Registry::to_json`] serializes non-finite values as `null`
//! (rendered as a dash) rather than fake zeros.

use std::collections::BTreeMap;

use crate::substrate::json::{self, Json};

/// Buckets per doubling: relative bucket width `2^(1/8) - 1` (~9%),
/// so a midpoint read is within ~4.4% of any sample in the bucket.
const BUCKETS_PER_DOUBLING: f64 = 8.0;
/// Total buckets: 256 buckets x 8 per doubling = 32 doublings above
/// [`LO`] — `1e-3 .. ~4.3e6` in the recorded unit (for millisecond
/// samples: 1 microsecond up to ~71 minutes).
const BUCKETS: usize = 256;
/// Lower edge of bucket 1; everything at or below lands in bucket 0.
const LO: f64 = 1e-3;

/// Worst-case relative error of [`Histogram::quantile`] against the
/// exact sample at the same rank: half a bucket, `2^(1/16) - 1`.
pub const HIST_MAX_REL_ERR: f64 = 0.0443;

/// A fixed-memory log-bucketed histogram (see module docs).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if v <= LO {
            return 0;
        }
        let i = ((v / LO).log2() * BUCKETS_PER_DOUBLING).floor() as i64 + 1;
        (i.max(0) as usize).min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i`. Bucket 0 spans `(-inf, LO]`;
    /// buckets `i >= 1` span `[LO * 2^((i-1)/8), LO * 2^(i/8))`.
    fn midpoint(i: usize) -> f64 {
        if i == 0 {
            return LO * 0.5;
        }
        LO * 2f64.powf((i as f64 - 0.5) / BUCKETS_PER_DOUBLING)
    }

    /// Record one sample. Non-finite samples are ignored (a NaN must
    /// not poison every later quantile — mirrors the `total_cmp`
    /// hardening in the serve layer).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// NaN when empty, like the exact-`quantile` contract.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Approximate quantile: the midpoint of the bucket holding the
    /// sample at rank `floor(q * (count - 1))`, clamped to the exact
    /// observed `[min, max]`; within [`HIST_MAX_REL_ERR`] of the exact
    /// sample at that rank. NaN when empty (never a fake zero).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).floor() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::midpoint(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fixed memory regardless of sample count — the reason
    /// `ServeStats` can sit in a long-running server.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Histogram>() + self.counts.len() * std::mem::size_of::<u64>()
    }

    /// `{count, mean, min, p50, p95, p99, max}` with non-finite values
    /// as `null`.
    pub fn summary_json(&self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean", json::num_or_null(self.mean())),
            ("min", json::num_or_null(self.min())),
            ("p50", json::num_or_null(self.quantile(0.50))),
            ("p95", json::num_or_null(self.quantile(0.95))),
            ("p99", json::num_or_null(self.quantile(0.99))),
            ("max", json::num_or_null(self.max())),
        ])
    }
}

/// One snapshot row under assembly: named counters (monotonic u64),
/// gauges (instantaneous f64) and histogram summaries, serialized as a
/// flat JSON object. The serve layer's `--metrics-every` emitter builds
/// one `Registry` per snapshot and writes it as a JSONL row.
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Json>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&mut self, name: &'static str, v: u64) -> &mut Self {
        self.counters.insert(name, v);
        self
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) -> &mut Self {
        self.gauges.insert(name, v);
        self
    }

    pub fn hist(&mut self, name: &'static str, h: &Histogram) -> &mut Self {
        self.hists.insert(name, h.summary_json());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        for (k, v) in &self.counters {
            o.insert(k.to_string(), json::num(*v as f64));
        }
        for (k, v) in &self.gauges {
            o.insert(k.to_string(), json::num_or_null(*v));
        }
        for (k, v) in &self.hists {
            o.insert(k.to_string(), v.clone());
        }
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::stats::quantile_unsorted;
    use crate::substrate::Rng;

    #[test]
    fn empty_histogram_is_nan_not_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert!(h.min().is_nan() && h.max().is_nan());
        // and serializes as null, never 0
        let j = h.summary_json();
        assert_eq!(j.get("p50"), Some(&Json::Null));
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut h = Histogram::new();
        h.record(12.5);
        // clamping to [min, max] makes one-sample reads exact
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), 12.5);
        }
        assert_eq!(h.mean(), 12.5);
    }

    #[test]
    fn nan_samples_are_ignored_not_poisonous() {
        let mut h = Histogram::new();
        h.record(1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(3.0);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.5).is_finite());
    }

    #[test]
    fn memory_is_fixed_regardless_of_sample_count() {
        let mut h = Histogram::new();
        let before = h.memory_bytes();
        for i in 0..200_000u64 {
            h.record((i % 977) as f64 * 0.37 + 0.001);
        }
        assert_eq!(h.memory_bytes(), before);
        assert_eq!(h.count(), 200_000);
    }

    /// The tentpole agreement property: histogram quantiles match the
    /// exact sorted-Vec `quantile` within the bucket error, across
    /// distributions shaped like real latency data.
    #[test]
    fn histogram_matches_exact_quantile_within_bucket_error() {
        let mut rng = Rng::new(42);
        for dist in 0..3 {
            for n in [1usize, 2, 7, 100, 1000] {
                let samples: Vec<f64> = (0..n)
                    .map(|_| {
                        let u = rng.f64().max(1e-6);
                        let v = match dist {
                            0 => u * 50.0,      // uniform 0..50ms
                            1 => -u.ln() * 8.0, // exponential-ish tail
                            _ => {
                                // bimodal: fast hits + slow outliers
                                if rng.f64() < 0.9 {
                                    u * 2.0
                                } else {
                                    200.0 + u * 800.0
                                }
                            }
                        };
                        // stay above bucket 0 (values <= 1us collapse
                        // there and only the [min,max] clamp bounds
                        // them) — real ms-scale latencies always do
                        v.max(0.01)
                    })
                    .collect();
                let mut h = Histogram::new();
                for &s in &samples {
                    h.record(s);
                }
                for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                    let approx = h.quantile(q);
                    // the exact interpolated quantile lies between the
                    // two bracketing order statistics; the histogram
                    // approximates the lower one within bucket error
                    let mut sorted = samples.clone();
                    sorted.sort_by(|a, b| a.total_cmp(b));
                    let rank = (q * (n - 1) as f64).floor() as usize;
                    let lo = sorted[rank];
                    let hi = sorted[(rank + 1).min(n - 1)];
                    let e = HIST_MAX_REL_ERR + 1e-9;
                    assert!(
                        approx >= lo * (1.0 - e) - 1e-9 && approx <= hi * (1.0 + e) + 1e-9,
                        "dist={dist} n={n} q={q}: approx {approx} vs exact [{lo}, {hi}]"
                    );
                    // sanity: both agree with the canonical exact path
                    let exact = quantile_unsorted(&samples, q);
                    assert!(exact >= sorted[0] && exact <= sorted[n - 1]);
                }
            }
        }
    }

    #[test]
    fn registry_serializes_flat_row_with_nested_hists() {
        let mut h = Histogram::new();
        for v in [5.0, 15.0, 25.0] {
            h.record(v);
        }
        let mut r = Registry::new();
        r.counter("completed", 3)
            .gauge("tok_s", 123.4)
            .gauge("idle_frac", f64::NAN)
            .hist("total_ms", &h);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("completed").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("tok_s").and_then(Json::as_f64), Some(123.4));
        assert_eq!(j.get("idle_frac"), Some(&Json::Null));
        assert_eq!(j.at(&["total_ms", "count"]).and_then(Json::as_f64), Some(3.0));
        let p50 = j.at(&["total_ms", "p50"]).and_then(Json::as_f64).unwrap();
        assert!((p50 - 15.0).abs() / 15.0 < 0.05, "p50 {p50}");
    }
}
