//! QuantScope — per-layer quantization & distillation telemetry, from
//! QAT training through ternary serving.
//!
//! The paper's central claim is that continual pre-training closes the
//! fine-tuned-FP vs 1.58-bit gap; this module makes *why* observable.
//! At a configurable step stride it snapshots, for every transformer
//! layer, the ternary lattice the QAT forward actually trains on
//! (shared dispatch with [`crate::train::qat::quantize_weight_codes`],
//! so telemetry and training cannot disagree on the grid):
//!
//! - **sparsity** — fraction of 0 codes (the Fig. 2 statistic),
//! - **flip rate** — fraction of codes that changed vs the previous
//!   recorded snapshot (the BitDistiller-style convergence signal:
//!   it should decay through Stage-2 continual pre-training),
//! - **scale** and **scale drift** — element-weighted mean absmean
//!   scale and its change since the previous snapshot,
//! - **clip fraction** — fraction of weights with `|w / gamma| > 1`
//!   pre-round (outliers the ternary grid clamps),
//! - **grad norm** — L2 norm over the layer's seven ternary matrices,
//! - the per-component **loss breakdown** (CE, logits-KL, MiniLM
//!   relation-KL and its per-head divergence).
//!
//! On the serve side, [`QuantScope::observe_act`] accumulates per-layer
//! int8 activation-range/saturation counters at the two activation
//! quantization sites of the ternary decode path.
//!
//! Everything lands in a `kind:"quant"` JSONL time series (drained via
//! [`QuantScope::take_rows`]) plus [`Registry`] histogram summaries,
//! and it all rides the same zero-cost-off recorder contract as
//! [`super::trace::TraceRecorder`]: a disabled scope is one `Option`
//! check per site, recording only *reads* the computation, and
//! telemetry-on vs telemetry-off training and serving are bitwise
//! identical (test-enforced, like the PR 6 trace layer).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::obs::{Histogram, Registry};
use crate::params::ParamStore;
use crate::runtime::ModelCfg;
use crate::substrate::json::{self, Json};

/// Mirrors `quant::EPS`: the pre-round clip test divides by
/// `scale + EPS` exactly as the quantizers do.
const EPS: f32 = 1e-6;

/// Default row capacity: a recorded step emits `n_layers + 1` rows, so
/// this is tens of thousands of recorded steps even on deep models.
const DEFAULT_ROW_CAP: usize = 1 << 18;

/// The seven ternary matrices of one transformer layer, in traversal
/// order, with their `[k, n]` shapes — pinned to the stacked-tensor
/// layout of `train/model.rs::register_params` and
/// `engine/model.rs::from_params` (both slice `blocks.*` as
/// `[li * k * n ..]`).
fn layer_matrices(cfg: &ModelCfg) -> [(&'static str, usize, usize); 7] {
    let (d, ff) = (cfg.d_model, cfg.d_ff);
    let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
    [
        ("blocks.wq", d, qd),
        ("blocks.wk", d, kvd),
        ("blocks.wv", d, kvd),
        ("blocks.wo", qd, d),
        ("blocks.w_gate", d, ff),
        ("blocks.w_up", d, ff),
        ("blocks.w_down", ff, d),
    ]
}

/// Per-step loss breakdown handed to [`QuantScope::record_step`]. CE
/// stages carry `total == ce` and `None` elsewhere; distill steps fill
/// every component (`ad_heads` is empty when the per-head divergence
/// was not computed).
#[derive(Debug, Clone, Default)]
pub struct StepLosses {
    pub total: f32,
    pub ce: f32,
    pub ld: Option<f32>,
    pub ad: Option<f32>,
    pub ad_heads: Vec<f32>,
}

impl StepLosses {
    /// A CE-only step (pretrain / teacher-SFT / Stage-2 CT).
    pub fn ce_only(loss: f32) -> StepLosses {
        StepLosses { total: loss, ce: loss, ..StepLosses::default() }
    }
}

/// Serve-side activation accumulator for one (layer, site).
#[derive(Debug, Default, Clone)]
struct ActAcc {
    rows: u64,
    codes: u64,
    saturated: u64,
    gamma_sum: f64,
    gamma_min: f64,
    gamma_max: f64,
}

#[derive(Debug)]
struct Inner {
    /// Record every `every`-th step (step 1 always records, so a short
    /// smoke run still emits rows).
    every: usize,
    stage: String,
    rows: Vec<Json>,
    cap: usize,
    dropped: u64,
    /// Previous recorded snapshot per layer: concatenated codes of the
    /// seven matrices (traversal order) + element-weighted mean scale.
    /// Cleared on [`QuantScope::set_stage`] so flip rates never compare
    /// across different models (teacher vs student).
    prev_codes: Vec<Vec<i8>>,
    prev_scale: Vec<f64>,
    // crate-level summary histograms, exported as the final
    // `phase:"summary"` row via `Registry`
    h_sparsity: Histogram,
    h_flip: Histogram,
    h_clip: Histogram,
    h_grad: Histogram,
    steps_recorded: u64,
    /// (layer, site) -> int8 activation range/saturation accumulators.
    act: BTreeMap<(usize, &'static str), ActAcc>,
}

/// Quantization telemetry recorder (see module docs). Cheap to clone
/// (`Rc`-shared buffer, deliberately single-threaded like
/// [`super::trace::TraceRecorder`]: only the coordinating thread
/// records — the `parallel/` workers never touch it); `disabled()`
/// carries nothing and costs one branch per site.
#[derive(Debug, Clone)]
pub struct QuantScope {
    inner: Option<Rc<RefCell<Inner>>>,
}

impl Default for QuantScope {
    fn default() -> Self {
        QuantScope::disabled()
    }
}

impl QuantScope {
    /// The no-op scope: every recording call is one branch.
    pub fn disabled() -> QuantScope {
        QuantScope { inner: None }
    }

    /// A live scope recording every `every`-th training step (plus step
    /// 1), with the default row capacity.
    pub fn enabled(every: usize) -> QuantScope {
        QuantScope::with_capacity(every, DEFAULT_ROW_CAP)
    }

    /// A live scope holding at most `cap` JSONL rows; further rows are
    /// dropped and counted (surfaced in the summary row).
    pub fn with_capacity(every: usize, cap: usize) -> QuantScope {
        QuantScope {
            inner: Some(Rc::new(RefCell::new(Inner {
                every: every.max(1),
                stage: String::new(),
                rows: Vec::new(),
                cap: cap.max(1),
                dropped: 0,
                prev_codes: Vec::new(),
                prev_scale: Vec::new(),
                h_sparsity: Histogram::new(),
                h_flip: Histogram::new(),
                h_clip: Histogram::new(),
                h_grad: Histogram::new(),
                steps_recorded: 0,
                act: BTreeMap::new(),
            }))),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Whether `step` (1-based, the post-update optimizer counter) is on
    /// the recording stride — the one check call sites pay per step, so
    /// the stat computation (and any caller-side prep like the per-head
    /// divergence) is skipped entirely off-stride.
    pub fn should_record(&self, step: usize) -> bool {
        match &self.inner {
            None => false,
            Some(rc) => {
                let every = rc.borrow().every;
                step == 1 || step % every == 0
            }
        }
    }

    /// Label the rows that follow with a pipeline stage ("pretrain",
    /// "teacher_sft", "ct", "distill") and reset the flip-rate baseline:
    /// stages may swap the model under the scope (teacher vs student),
    /// and a flip rate across different weight tensors is noise.
    pub fn set_stage(&self, stage: &str) {
        if let Some(rc) = &self.inner {
            let mut inner = rc.borrow_mut();
            inner.stage = stage.to_string();
            inner.prev_codes.clear();
            inner.prev_scale.clear();
        }
    }

    /// Record one training step: per-layer lattice statistics (when the
    /// model quantizes) plus a `layer:-1` loss-breakdown row. `grads`
    /// is the already-reduced gradient map the optimizer consumed —
    /// recording reads it, never writes. No-op off-stride or disabled.
    pub fn record_step(
        &self,
        step: usize,
        cfg: &ModelCfg,
        params: &ParamStore,
        grads: &BTreeMap<String, Vec<f32>>,
        losses: &StepLosses,
    ) {
        if !self.should_record(step) {
            return;
        }
        let rc = self.inner.as_ref().expect("should_record is false when disabled");
        let mut inner = rc.borrow_mut();
        inner.steps_recorded += 1;
        let stage = inner.stage.clone();
        if cfg.quant_method != "none" {
            let mats = layer_matrices(cfg);
            if inner.prev_codes.len() != cfg.n_layers {
                inner.prev_codes = vec![Vec::new(); cfg.n_layers];
                inner.prev_scale = vec![f64::NAN; cfg.n_layers];
            }
            for li in 0..cfg.n_layers {
                let mut codes: Vec<i8> = Vec::new();
                let (mut scale_sum, mut clipped, mut total) = (0.0f64, 0usize, 0usize);
                let mut grad_sq = 0.0f64;
                for &(name, k, n) in &mats {
                    let Some(t) = params.tensors.get(name) else { continue };
                    let w = &t.data[li * k * n..(li + 1) * k * n];
                    let q = crate::train::qat::quantize_weight_codes(w, k, n, &cfg.quant_method);
                    for (&wi, &si) in w.iter().zip(&q.scales) {
                        scale_sum += si as f64;
                        if (wi / (si + EPS)).abs() > 1.0 {
                            clipped += 1;
                        }
                    }
                    total += w.len();
                    codes.extend_from_slice(&q.codes);
                    if let Some(g) = grads.get(name) {
                        for &gv in &g[li * k * n..(li + 1) * k * n] {
                            grad_sq += (gv as f64) * (gv as f64);
                        }
                    }
                }
                if total == 0 {
                    continue;
                }
                let n = total as f64;
                let sparsity = codes.iter().filter(|&&c| c == 0).count() as f64 / n;
                let scale = scale_sum / n;
                let prev = &inner.prev_codes[li];
                let flip_rate = if prev.len() == codes.len() {
                    codes.iter().zip(prev).filter(|(a, b)| a != b).count() as f64 / n
                } else {
                    0.0 // first recorded step of this stage: no baseline
                };
                let scale_drift = if inner.prev_scale[li].is_finite() {
                    scale - inner.prev_scale[li]
                } else {
                    0.0
                };
                let clip_frac = clipped as f64 / n;
                let grad_norm = grad_sq.sqrt();
                inner.prev_codes[li] = codes;
                inner.prev_scale[li] = scale;
                inner.h_sparsity.record(sparsity);
                inner.h_flip.record(flip_rate);
                inner.h_clip.record(clip_frac);
                inner.h_grad.record(grad_norm);
                let row = json::obj(vec![
                    ("kind", json::s("quant")),
                    ("phase", json::s("train")),
                    ("stage", json::s(&stage)),
                    ("step", json::num(step as f64)),
                    ("layer", json::num(li as f64)),
                    ("sparsity", json::num_or_null(sparsity)),
                    ("flip_rate", json::num_or_null(flip_rate)),
                    ("scale", json::num_or_null(scale)),
                    ("scale_drift", json::num_or_null(scale_drift)),
                    ("clip_frac", json::num_or_null(clip_frac)),
                    ("grad_norm", json::num_or_null(grad_norm)),
                ]);
                push_row(&mut inner, row);
            }
        }
        // the loss-breakdown row rides on layer -1 so one JSONL stream
        // carries both time series
        let mut fields = vec![
            ("kind", json::s("quant")),
            ("phase", json::s("train")),
            ("stage", json::s(&stage)),
            ("step", json::num(step as f64)),
            ("layer", json::num(-1.0)),
            ("loss", json::num_or_null(losses.total as f64)),
            ("ce", json::num_or_null(losses.ce as f64)),
        ];
        if let Some(ld) = losses.ld {
            fields.push(("ld", json::num_or_null(ld as f64)));
        }
        if let Some(ad) = losses.ad {
            fields.push(("ad", json::num_or_null(ad as f64)));
        }
        if !losses.ad_heads.is_empty() {
            fields.push((
                "ad_heads",
                Json::Arr(losses.ad_heads.iter().map(|&h| json::num_or_null(h as f64)).collect()),
            ));
        }
        let row = json::obj(fields);
        push_row(&mut inner, row);
    }

    /// Serve side: accumulate one lane's int8 activation-quant result at
    /// `site` ("attn_in" / "ffn_in") of layer `layer` — the activation
    /// range (per-row absmax `gamma`) and the fraction of codes
    /// saturated at the int8 rails. Called on the coordinating thread
    /// only (the act-quant loops of the batched decode path run there);
    /// aggregation, not per-step rows, so serving stays O(1) memory.
    pub fn observe_act(&self, layer: usize, site: &'static str, gamma: f32, codes: &[i8]) {
        let Some(rc) = &self.inner else { return };
        let mut inner = rc.borrow_mut();
        let acc = inner.act.entry((layer, site)).or_insert_with(|| ActAcc {
            gamma_min: f64::INFINITY,
            gamma_max: f64::NEG_INFINITY,
            ..ActAcc::default()
        });
        acc.rows += 1;
        acc.codes += codes.len() as u64;
        acc.saturated += codes.iter().filter(|&&c| c == 127 || c == -127).count() as u64;
        let g = gamma as f64;
        if g.is_finite() {
            acc.gamma_sum += g;
            acc.gamma_min = acc.gamma_min.min(g);
            acc.gamma_max = acc.gamma_max.max(g);
        }
    }

    /// Recorded (undrained) JSONL row count — serve accumulators and the
    /// summary row are materialized by [`QuantScope::take_rows`] and not
    /// counted here.
    pub fn len(&self) -> usize {
        self.inner.as_ref().map_or(0, |rc| rc.borrow().rows.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows dropped past the capacity cap.
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |rc| rc.borrow().dropped)
    }

    /// Discard buffered rows and serve accumulators (stride, stage and
    /// flip baseline kept) — lets the bench overhead gate time the
    /// recording cost without ever tripping the cap.
    pub fn clear(&self) {
        if let Some(rc) = &self.inner {
            let mut inner = rc.borrow_mut();
            inner.rows.clear();
            inner.dropped = 0;
            inner.act.clear();
        }
    }

    /// Drain everything recorded so far as `kind:"quant"` JSONL rows:
    /// the per-step training rows, one `phase:"serve"` row per
    /// (layer, site) activation accumulator, and a final
    /// `phase:"summary"` row carrying the [`Registry`] histogram
    /// summaries (sparsity / flip_rate / clip_frac / grad_norm) and
    /// drop counters. Empty on a disabled scope.
    pub fn take_rows(&self) -> Vec<Json> {
        let Some(rc) = &self.inner else { return Vec::new() };
        let mut inner = rc.borrow_mut();
        let mut rows = std::mem::take(&mut inner.rows);
        for ((layer, site), acc) in std::mem::take(&mut inner.act) {
            let n = acc.codes.max(1) as f64;
            rows.push(json::obj(vec![
                ("kind", json::s("quant")),
                ("phase", json::s("serve")),
                ("layer", json::num(layer as f64)),
                ("site", json::s(site)),
                ("rows_q", json::num(acc.rows as f64)),
                ("gamma_mean", json::num_or_null(acc.gamma_sum / acc.rows.max(1) as f64)),
                ("gamma_min", json::num_or_null(acc.gamma_min)),
                ("gamma_max", json::num_or_null(acc.gamma_max)),
                ("sat_frac", json::num_or_null(acc.saturated as f64 / n)),
            ]));
        }
        if inner.steps_recorded > 0 {
            let mut reg = Registry::new();
            reg.counter("steps_recorded", inner.steps_recorded)
                .counter("rows_dropped", inner.dropped)
                .hist("sparsity", &inner.h_sparsity)
                .hist("flip_rate", &inner.h_flip)
                .hist("clip_frac", &inner.h_clip)
                .hist("grad_norm", &inner.h_grad);
            let mut row = reg.to_json();
            if let Json::Obj(o) = &mut row {
                o.insert("kind".to_string(), json::s("quant"));
                o.insert("phase".to_string(), json::s("summary"));
            }
            rows.push(row);
        }
        rows
    }
}

fn push_row(inner: &mut Inner, row: Json) {
    if inner.rows.len() < inner.cap {
        inner.rows.push(row);
    } else {
        inner.dropped += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelSpec;
    use crate::substrate::Rng;

    fn micro_cfg_and_params() -> (ModelCfg, ParamStore) {
        let spec = ModelSpec::synthetic_with("micro", true, "absmean").unwrap();
        let mut rng = Rng::new(11);
        let params = ParamStore::init(&spec, &mut rng);
        (spec.config, params)
    }

    #[test]
    fn disabled_scope_records_nothing() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::disabled();
        assert!(!qs.is_enabled());
        assert!(!qs.should_record(1));
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(1.0));
        qs.observe_act(0, "attn_in", 1.0, &[1, -127, 0]);
        assert_eq!(qs.len(), 0);
        assert!(qs.take_rows().is_empty());
    }

    #[test]
    fn stride_records_step_one_and_multiples() {
        let qs = QuantScope::enabled(10);
        assert!(qs.should_record(1), "step 1 always records");
        assert!(!qs.should_record(7));
        assert!(qs.should_record(10));
        assert!(qs.should_record(20));
        assert!(!qs.should_record(21));
    }

    #[test]
    fn record_step_emits_per_layer_rows_and_loss_row() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        qs.set_stage("ct");
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(2.5));
        let rows = qs.take_rows();
        // n_layers layer rows + 1 loss row + 1 summary row
        assert_eq!(rows.len(), cfg.n_layers + 2, "{rows:?}");
        let layer0 = &rows[0];
        assert_eq!(layer0.get("kind").and_then(Json::as_str), Some("quant"));
        assert_eq!(layer0.get("stage").and_then(Json::as_str), Some("ct"));
        assert_eq!(layer0.get("layer").and_then(Json::as_f64), Some(0.0));
        let sparsity = layer0.get("sparsity").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&sparsity), "sparsity {sparsity}");
        // random init quantizes to a non-degenerate ternary spread
        assert!(sparsity > 0.0 && sparsity < 1.0, "sparsity {sparsity}");
        assert!(layer0.get("scale").and_then(Json::as_f64).unwrap() > 0.0);
        let clip = layer0.get("clip_frac").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&clip));
        // first recorded step: no baseline, flip rate 0
        assert_eq!(layer0.get("flip_rate").and_then(Json::as_f64), Some(0.0));
        let loss_row = &rows[cfg.n_layers];
        assert_eq!(loss_row.get("layer").and_then(Json::as_f64), Some(-1.0));
        assert_eq!(loss_row.get("ce").and_then(Json::as_f64), Some(2.5));
        let summary = rows.last().unwrap();
        assert_eq!(summary.get("phase").and_then(Json::as_str), Some("summary"));
        assert_eq!(
            summary.at(&["sparsity", "count"]).and_then(Json::as_f64),
            Some(cfg.n_layers as f64)
        );
    }

    #[test]
    fn flip_rate_is_zero_for_frozen_weights_and_positive_after_change() {
        let (cfg, mut params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        qs.set_stage("ct");
        let grads = BTreeMap::new();
        qs.record_step(1, &cfg, &params, &grads, &StepLosses::ce_only(1.0));
        qs.record_step(2, &cfg, &params, &grads, &StepLosses::ce_only(1.0));
        // flip some weights hard enough to cross the ternary threshold
        {
            let t = params.tensors.get_mut("blocks.wq").unwrap();
            for v in t.data.iter_mut().take(64) {
                *v = -*v + 1.0;
            }
        }
        qs.record_step(3, &cfg, &params, &grads, &StepLosses::ce_only(1.0));
        let rows = qs.take_rows();
        let flips: Vec<f64> = rows
            .iter()
            .filter(|r| {
                r.get("layer").and_then(Json::as_f64) == Some(0.0)
                    && r.get("phase").and_then(Json::as_str) == Some("train")
            })
            .map(|r| r.get("flip_rate").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(flips.len(), 3);
        assert_eq!(flips[0], 0.0, "no baseline yet");
        assert_eq!(flips[1], 0.0, "identical weights cannot flip");
        assert!(flips[2] > 0.0, "layer-0 weights changed: {flips:?}");
    }

    #[test]
    fn fp_model_skips_layer_rows_but_keeps_losses() {
        let (mut cfg, params) = micro_cfg_and_params();
        cfg.quant_method = "none".into();
        let qs = QuantScope::enabled(1);
        qs.set_stage("teacher_sft");
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(3.0));
        let rows = qs.take_rows();
        // loss row + summary only — an FP model has no ternary lattice
        assert_eq!(rows.len(), 2, "{rows:?}");
        assert_eq!(rows[0].get("layer").and_then(Json::as_f64), Some(-1.0));
    }

    #[test]
    fn distill_losses_carry_components_and_heads() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        qs.set_stage("distill");
        let losses = StepLosses {
            total: 3.0,
            ce: 1.0,
            ld: Some(1.5),
            ad: Some(0.5),
            ad_heads: vec![0.4, 0.6],
        };
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &losses);
        let rows = qs.take_rows();
        let loss_row = rows
            .iter()
            .find(|r| r.get("layer").and_then(Json::as_f64) == Some(-1.0))
            .unwrap();
        assert_eq!(loss_row.get("ld").and_then(Json::as_f64), Some(1.5));
        assert_eq!(loss_row.get("ad").and_then(Json::as_f64), Some(0.5));
        let heads = loss_row.get("ad_heads").and_then(Json::as_arr).unwrap();
        assert_eq!(heads.len(), 2);
        assert_eq!(heads[1].as_f64(), Some(0.6));
    }

    #[test]
    fn grad_norm_reads_the_layer_slice() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        // gradient of 1.0 on every wq entry of layer 0 only
        let (d, qd) = (cfg.d_model, cfg.q_dim());
        let mut g = vec![0.0f32; cfg.n_layers * d * qd];
        for v in g.iter_mut().take(d * qd) {
            *v = 1.0;
        }
        let mut grads = BTreeMap::new();
        grads.insert("blocks.wq".to_string(), g);
        qs.record_step(1, &cfg, &params, &grads, &StepLosses::ce_only(1.0));
        let rows = qs.take_rows();
        let norm_of = |layer: f64| {
            rows.iter()
                .find(|r| {
                    r.get("layer").and_then(Json::as_f64) == Some(layer)
                        && r.get("phase").and_then(Json::as_str) == Some("train")
                })
                .and_then(|r| r.get("grad_norm"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        let want = ((d * qd) as f64).sqrt();
        assert!((norm_of(0.0) - want).abs() < 1e-6, "{} vs {want}", norm_of(0.0));
        assert_eq!(norm_of(1.0), 0.0, "layer 1 got no gradient");
    }

    #[test]
    fn set_stage_resets_flip_baseline() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        qs.set_stage("ct");
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(1.0));
        qs.set_stage("distill");
        qs.record_step(2, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(1.0));
        let rows = qs.take_rows();
        for r in rows.iter().filter(|r| r.get("stage").and_then(Json::as_str) == Some("distill")) {
            if r.get("layer").and_then(Json::as_f64) == Some(0.0) {
                assert_eq!(
                    r.get("flip_rate").and_then(Json::as_f64),
                    Some(0.0),
                    "stage switch must reset the baseline"
                );
            }
        }
    }

    #[test]
    fn serve_accumulators_aggregate_saturation_and_range() {
        let qs = QuantScope::enabled(1);
        qs.observe_act(2, "attn_in", 1.5, &[127, -127, 0, 5]);
        qs.observe_act(2, "attn_in", 0.5, &[0, 0, 0, 0]);
        qs.observe_act(2, "ffn_in", 2.0, &[127, 127]);
        let rows = qs.take_rows();
        assert_eq!(rows.len(), 2, "{rows:?}");
        let attn = rows
            .iter()
            .find(|r| r.get("site").and_then(Json::as_str) == Some("attn_in"))
            .unwrap();
        assert_eq!(attn.get("phase").and_then(Json::as_str), Some("serve"));
        assert_eq!(attn.get("layer").and_then(Json::as_f64), Some(2.0));
        assert_eq!(attn.get("rows_q").and_then(Json::as_f64), Some(2.0));
        assert_eq!(attn.get("sat_frac").and_then(Json::as_f64), Some(0.25));
        assert_eq!(attn.get("gamma_mean").and_then(Json::as_f64), Some(1.0));
        assert_eq!(attn.get("gamma_max").and_then(Json::as_f64), Some(1.5));
        let ffn = rows
            .iter()
            .find(|r| r.get("site").and_then(Json::as_str) == Some("ffn_in"))
            .unwrap();
        assert_eq!(ffn.get("sat_frac").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn capacity_cap_drops_and_counts() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::with_capacity(1, 3);
        for s in 1..=4 {
            qs.record_step(s, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(1.0));
        }
        assert_eq!(qs.len(), 3);
        assert!(qs.dropped() > 0);
        qs.clear();
        assert_eq!(qs.len(), 0);
        assert_eq!(qs.dropped(), 0);
    }

    #[test]
    fn rows_parse_as_jsonl() {
        let (cfg, params) = micro_cfg_and_params();
        let qs = QuantScope::enabled(1);
        qs.set_stage("ct");
        qs.record_step(1, &cfg, &params, &BTreeMap::new(), &StepLosses::ce_only(1.0));
        qs.observe_act(0, "attn_in", 1.0, &[1, 2, 3]);
        for row in qs.take_rows() {
            let text = row.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.get("kind").and_then(Json::as_str), Some("quant"), "{text}");
        }
    }
}
