//! Probabilistic SVO grammar over the TinyWorld lexicon.
//!
//! `Sentence` is a symbolic representation (topic + slots); rendering
//! produces word sequences, and meaning-preserving / meaning-inverting
//! transforms generate the NLI-style tasks:
//!   - `entailed()`     synonym substitution + optional detail drop
//!   - `contradicted()` verb antonym or negation, or adjective antonym
//!   - `question()`     wh-extraction for the QNLI analog
//!
//! Everything is driven by a seeded [`Rng`], so datasets are exactly
//! reproducible.

use super::lexicon::{Topic, ADJ_ANTONYMS, ADJ_GROUPS, TOPICS};
use crate::substrate::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Sentence {
    pub topic: usize,
    pub subj: usize,
    /// index into topic.verbs; `verb_neg` selects the antonym column.
    pub verb: usize,
    pub verb_neg: bool,
    /// "does not <verb>" instead of "<verb>"
    pub negated: bool,
    /// Some((group, variant)) adjective on the subject.
    pub adj: Option<(usize, usize)>,
    pub obj: usize,
    /// Some(place) appends "near the <place>".
    pub place: Option<usize>,
}

impl Sentence {
    pub fn sample(rng: &mut Rng) -> Sentence {
        let topic = rng.below(TOPICS.len());
        Sentence::sample_in_topic(topic, rng)
    }

    pub fn sample_in_topic(topic: usize, rng: &mut Rng) -> Sentence {
        let t = &TOPICS[topic];
        Sentence {
            topic,
            subj: rng.below(t.subjects.len()),
            verb: rng.below(t.verbs.len()),
            verb_neg: false,
            negated: false,
            adj: if rng.bool(0.7) {
                Some((rng.below(ADJ_GROUPS.len()), rng.below(3)))
            } else {
                None
            },
            obj: rng.below(t.objects.len()),
            place: if rng.bool(0.4) { Some(rng.below(t.places.len())) } else { None },
        }
    }

    fn t(&self) -> &'static Topic {
        &TOPICS[self.topic]
    }

    pub fn verb_word(&self) -> &'static str {
        let (v, a) = self.t().verbs[self.verb];
        if self.verb_neg {
            a
        } else {
            v
        }
    }

    /// Render to words (without terminal punctuation).
    pub fn words(&self) -> Vec<&'static str> {
        let t = self.t();
        let mut w = vec!["the"];
        if let Some((g, v)) = self.adj {
            w.push(ADJ_GROUPS[g].0[v]);
        }
        w.push(t.subjects[self.subj]);
        if self.negated {
            w.push("never");
        }
        w.push(self.verb_word());
        w.push("the");
        w.push(t.objects[self.obj]);
        if let Some(p) = self.place {
            w.push("near");
            w.push("the");
            w.push(t.places[p]);
        }
        w
    }

    /// Meaning-preserving variant: adjective synonym swap and/or dropping
    /// the place detail (a subset statement is still entailed).
    pub fn entailed(&self, rng: &mut Rng) -> Sentence {
        let mut s = self.clone();
        if let Some((g, v)) = s.adj {
            let nv = (v + 1 + rng.below(2)) % 3;
            s.adj = Some((g, nv));
        }
        if s.place.is_some() && rng.bool(0.5) {
            s.place = None;
        }
        s
    }

    /// Meaning-inverting variant: negation, verb antonym, or adjective
    /// antonym.
    pub fn contradicted(&self, rng: &mut Rng) -> Sentence {
        let mut s = self.clone();
        let mut moves: Vec<u8> = vec![0, 1];
        if let Some((g, _)) = s.adj {
            if ADJ_ANTONYMS.iter().any(|&(a, b)| a == g || b == g) {
                moves.push(2);
            }
        }
        match *rng.choose(&moves) {
            0 => s.negated = !s.negated,
            1 => s.verb_neg = !s.verb_neg,
            _ => {
                let (g, _) = s.adj.unwrap();
                let &(a, b) = ADJ_ANTONYMS
                    .iter()
                    .find(|&&(a, b)| a == g || b == g)
                    .unwrap();
                let ng = if a == g { b } else { a };
                s.adj = Some((ng, rng.below(3)));
            }
        }
        s
    }

    /// Unrelated-but-on-topic sentence (the MNLI "neutral" class): same
    /// topic, different subject and object.
    pub fn neutral(&self, rng: &mut Rng) -> Sentence {
        let t = self.t();
        loop {
            let s = Sentence::sample_in_topic(self.topic, rng);
            if s.subj != self.subj && s.obj != self.obj {
                return s;
            }
            // tiny topics can collide; force-move the subject
            if t.subjects.len() <= 2 {
                let mut s2 = s;
                s2.subj = (self.subj + 1) % t.subjects.len();
                return s2;
            }
        }
    }

    /// "who <verb> the <obj> ?" — answered by this sentence.
    pub fn question(&self) -> Vec<&'static str> {
        vec!["who", self.verb_word(), "the", self.t().objects[self.obj], "?"]
    }
}

/// A topic-coherent paragraph (for LM pretraining and the CNNDM analog).
pub struct Paragraph {
    pub topic: usize,
    pub sentences: Vec<Sentence>,
}

impl Paragraph {
    pub fn sample(rng: &mut Rng, min_s: usize, max_s: usize) -> Paragraph {
        let topic = rng.below(TOPICS.len());
        let n = rng.range(min_s, max_s);
        let sentences = (0..n)
            .map(|_| Sentence::sample_in_topic(topic, rng))
            .collect();
        Paragraph { topic, sentences }
    }

    pub fn words(&self) -> Vec<&'static str> {
        let mut out = Vec::new();
        for (i, s) in self.sentences.iter().enumerate() {
            if i > 0 && i % 2 == 0 {
                out.push("meanwhile");
            }
            out.extend(s.words());
            out.push(".");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    #[test]
    fn render_has_svo_shape() {
        let mut rng = Rng::new(0);
        let s = Sentence::sample(&mut rng);
        let w = s.words();
        assert_eq!(w[0], "the");
        assert!(w.len() >= 5);
    }

    #[test]
    fn prop_entailed_changes_only_meaning_preserving_slots() {
        prop::check("entail-preserves", 100, |g| {
            let s = Sentence::sample(g.rng());
            let e = s.entailed(g.rng());
            assert_eq!(e.subj, s.subj);
            assert_eq!(e.verb, s.verb);
            assert_eq!(e.verb_neg, s.verb_neg);
            assert_eq!(e.negated, s.negated);
            assert_eq!(e.obj, s.obj);
            // adjective stays in the same synonym group
            match (s.adj, e.adj) {
                (Some((g1, _)), Some((g2, _))) => assert_eq!(g1, g2),
                (None, None) => {}
                other => panic!("adj changed presence: {other:?}"),
            }
        });
    }

    #[test]
    fn prop_contradicted_flips_meaning() {
        prop::check("contradict-flips", 100, |g| {
            let s = Sentence::sample(g.rng());
            let c = s.contradicted(g.rng());
            let flipped = (c.negated != s.negated)
                || (c.verb_neg != s.verb_neg)
                || (c.adj.map(|a| a.0) != s.adj.map(|a| a.0));
            assert!(flipped, "{s:?} -> {c:?}");
        });
    }

    #[test]
    fn prop_neutral_differs() {
        prop::check("neutral-differs", 100, |g| {
            let s = Sentence::sample(g.rng());
            let n = s.neutral(g.rng());
            assert_eq!(n.topic, s.topic);
            assert!(n.subj != s.subj || n.obj != s.obj);
        });
    }

    #[test]
    fn question_mentions_object() {
        let mut rng = Rng::new(1);
        let s = Sentence::sample(&mut rng);
        let q = s.question();
        assert_eq!(q[0], "who");
        assert!(q.contains(&TOPICS[s.topic].objects[s.obj]));
    }

    #[test]
    fn paragraph_stays_on_topic() {
        let mut rng = Rng::new(2);
        let p = Paragraph::sample(&mut rng, 3, 6);
        assert!(p.sentences.iter().all(|s| s.topic == p.topic));
        assert!(p.sentences.len() >= 3);
    }
}
