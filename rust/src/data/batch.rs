//! Batching: examples -> [B, T] i32 tensors ready for the HLO steps.

use super::corpus::CorpusStream;
use super::tasks::Example;
use crate::substrate::Rng;
use crate::tensor::TensorI32;

#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: TensorI32,
    pub labels: TensorI32,
    /// Indices of the examples in the source dataset (for eval joins).
    pub idx: Vec<usize>,
}

pub fn stack(examples: &[&Example], seq: usize) -> Batch {
    let b = examples.len();
    let mut tokens = Vec::with_capacity(b * seq);
    let mut labels = Vec::with_capacity(b * seq);
    for ex in examples {
        assert_eq!(ex.tokens.len(), seq);
        tokens.extend_from_slice(&ex.tokens);
        labels.extend_from_slice(&ex.labels);
    }
    Batch {
        tokens: TensorI32::from_vec(&[b, seq], tokens).unwrap(),
        labels: TensorI32::from_vec(&[b, seq], labels).unwrap(),
        idx: Vec::new(),
    }
}

/// Epoch-shuffling batcher over a fixed dataset.
pub struct Batcher<'a> {
    data: &'a [Example],
    batch: usize,
    seq: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a [Example], batch: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        Batcher { data, batch, seq, order, cursor: 0, rng }
    }

    /// Next batch, reshuffling at epoch boundaries (wraps around so a
    /// batch is always full).
    pub fn next_batch(&mut self) -> Batch {
        let mut picks = Vec::with_capacity(self.batch);
        let mut idx = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            let i = self.order[self.cursor];
            picks.push(&self.data[i]);
            idx.push(i);
            self.cursor += 1;
        }
        let mut b = stack(&picks, self.seq);
        b.idx = idx;
        b
    }
}

/// LM batcher over the infinite corpus stream.
pub struct CorpusBatcher<'a> {
    stream: CorpusStream<'a>,
    batch: usize,
    seq: usize,
}

impl<'a> CorpusBatcher<'a> {
    pub fn new(stream: CorpusStream<'a>, batch: usize, seq: usize) -> Self {
        CorpusBatcher { stream, batch, seq }
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut labels = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let (t, l) = self.stream.next_example();
            tokens.extend(t);
            labels.extend(l);
        }
        Batch {
            tokens: TensorI32::from_vec(&[self.batch, self.seq], tokens).unwrap(),
            labels: TensorI32::from_vec(&[self.batch, self.seq], labels).unwrap(),
            idx: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{Task, TaskGen};
    use crate::data::tokenizer::Tokenizer;

    #[test]
    fn batcher_covers_dataset_each_epoch() {
        let tok = Tokenizer::new(1024);
        let g = TaskGen::new(Task::Sst2, &tok, 128);
        let ds = g.dataset(32, 5);
        let mut b = Batcher::new(&ds, 8, 128, 1);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let batch = b.next_batch();
            assert_eq!(batch.tokens.shape, vec![8, 128]);
            seen.extend(batch.idx);
        }
        assert_eq!(seen.len(), 32, "one epoch touches every example");
    }

    #[test]
    fn corpus_batcher_shapes() {
        let tok = Tokenizer::new(1024);
        let s = CorpusStream::new(&tok, 128, 2);
        let mut b = CorpusBatcher::new(s, 4, 128);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.shape, vec![4, 128]);
        assert_eq!(batch.labels.shape, vec![4, 128]);
    }
}
