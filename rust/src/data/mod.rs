//! Data substrate: tokenizer, TinyWorld grammar, the GLUE/CNNDM task
//! analogs, the FALCON-corpus analog, and batching.

pub mod batch;
pub mod corpus;
pub mod grammar;
pub mod lexicon;
pub mod tasks;
pub mod tokenizer;

pub use batch::{Batch, Batcher, CorpusBatcher};
pub use corpus::CorpusStream;
pub use tasks::{Example, Task, TaskGen, IGNORE};
pub use tokenizer::Tokenizer;
