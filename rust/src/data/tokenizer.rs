//! Word-level tokenizer over the closed TinyWorld lexicon.
//!
//! The generators emit word sequences directly, so tokenization is exact
//! lookup (no BPE merges needed for a closed vocabulary). Ids are stable
//! across runs: specials first, then the lexicon in declaration order.

use std::collections::HashMap;

use super::lexicon;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const UNK: i32 = 4;

pub struct Tokenizer {
    id_of: HashMap<&'static str, i32>,
    word_of: Vec<&'static str>,
    /// Total vocab size reported to the model (padded to the manifest's
    /// vocab so embedding shapes match even as the lexicon grows).
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Tokenizer {
        let words = lexicon::all_words();
        assert!(
            words.len() <= vocab_size,
            "lexicon ({}) exceeds model vocab ({})",
            words.len(),
            vocab_size
        );
        let mut id_of = HashMap::new();
        for (i, w) in words.iter().enumerate() {
            id_of.insert(*w, i as i32);
        }
        Tokenizer { id_of, word_of: words, vocab_size }
    }

    pub fn n_words(&self) -> usize {
        self.word_of.len()
    }

    pub fn id(&self, word: &str) -> i32 {
        *self.id_of.get(word).unwrap_or(&UNK)
    }

    pub fn encode(&self, words: &[&str]) -> Vec<i32> {
        words.iter().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> Vec<&'static str> {
        ids.iter()
            .filter_map(|&id| {
                if id == PAD || id == BOS || id == EOS || id == SEP {
                    None
                } else {
                    self.word_of.get(id as usize).copied()
                }
            })
            .collect()
    }

    /// Decode including structural tokens (debugging).
    pub fn decode_all(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| *self.word_of.get(id as usize).unwrap_or(&"<bad>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    #[test]
    fn specials_have_fixed_ids() {
        let t = Tokenizer::new(1024);
        assert_eq!(t.id("<pad>"), PAD);
        assert_eq!(t.id("<bos>"), BOS);
        assert_eq!(t.id("<eos>"), EOS);
        assert_eq!(t.id("<sep>"), SEP);
        assert_eq!(t.id("<unk>"), UNK);
    }

    #[test]
    fn encode_decode_round_trip() {
        let t = Tokenizer::new(1024);
        let words = ["the", "farmer", "feeds", "the", "horse", "."];
        let ids = t.encode(&words);
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(t.decode(&ids), words.to_vec());
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = Tokenizer::new(1024);
        assert_eq!(t.id("zzzznotaword"), UNK);
    }

    #[test]
    fn prop_all_lexicon_words_round_trip() {
        let t = Tokenizer::new(1024);
        prop::check("tokenizer-round-trip", 200, |g| {
            let words = lexicon::all_words();
            let w = *g.choose(&words[5..]); // skip specials
            let id = t.id(w);
            assert!(id >= 5, "{w} -> special id {id}");
            assert_eq!(t.decode(&[id]), vec![w]);
        });
    }

    #[test]
    fn ids_below_vocab() {
        let t = Tokenizer::new(1024);
        for w in lexicon::all_words() {
            assert!((t.id(w) as usize) < t.vocab_size);
        }
    }
}
