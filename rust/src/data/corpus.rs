//! LM pretraining / continual-pre-training stream — the FALCON-corpus
//! analog (paper §3.2 uses 10B FALCON tokens; here: the TinyWorld grammar,
//! which plays the same role of in-distribution text that is not the
//! downstream task).

use super::grammar::Paragraph;
use super::tasks::IGNORE;
use super::tokenizer::{Tokenizer, BOS, PAD};
use crate::substrate::Rng;

pub struct CorpusStream<'a> {
    tok: &'a Tokenizer,
    rng: Rng,
    seq: usize,
    buf: Vec<i32>,
}

impl<'a> CorpusStream<'a> {
    pub fn new(tok: &'a Tokenizer, seq: usize, seed: u64) -> Self {
        CorpusStream { tok, rng: Rng::new(seed), seq, buf: Vec::new() }
    }

    /// Next packed LM sequence: (tokens, labels) with labels[t] =
    /// tokens[t+1] everywhere except the final position / padding.
    ///
    /// Besides plain narrative paragraphs, the stream mixes in the text
    /// *formats* the downstream tasks use — questions ("who ... ?"),
    /// review sentences ("the review says ... is <adj>") and lead-summary
    /// paragraphs ("... tldr : ...") — mirroring how a real pretraining
    /// corpus (FALCON) contains QA text, reviews and headlines. Without
    /// this, the pretrained base treats task prompts as OOD and
    /// fine-tuning from it is brittle (see EXPERIMENTS.md §Perf notes).
    pub fn next_example(&mut self) -> (Vec<i32>, Vec<i32>) {
        use super::grammar::Sentence;
        use super::lexicon::{ADJ_GROUPS, TOPICS};
        while self.buf.len() < self.seq + 1 {
            let p = Paragraph::sample(&mut self.rng, 3, 6);
            self.buf.push(BOS);
            self.buf.extend(self.tok.encode(&p.words()));
            match self.rng.below(4) {
                0 => {
                    // QA pair about the paragraph's first sentence
                    let s = &p.sentences[0];
                    let mut w = s.question();
                    w.push("the");
                    w.push(TOPICS[s.topic].subjects[s.subj]);
                    w.push(".");
                    self.buf.extend(self.tok.encode(&w));
                }
                1 => {
                    // a review sentence with a random adjective
                    let s = Sentence::sample(&mut self.rng);
                    let g = self.rng.below(ADJ_GROUPS.len());
                    let w = vec![
                        "the", "review", "says", "the",
                        TOPICS[s.topic].subjects[s.subj], "is",
                        ADJ_GROUPS[g].0[self.rng.below(3)], ".",
                    ];
                    self.buf.extend(self.tok.encode(&w));
                }
                2 => {
                    // a lead-summary: "tldr :" followed by a paraphrase of
                    // the first sentence
                    let lead = p.sentences[0].entailed(&mut self.rng);
                    let mut w = vec!["tldr", ":"];
                    w.extend(lead.words());
                    w.push(".");
                    self.buf.extend(self.tok.encode(&w));
                }
                _ => {}
            }
        }
        let tokens: Vec<i32> = self.buf[..self.seq].to_vec();
        let mut labels: Vec<i32> = self.buf[1..=self.seq].to_vec();
        self.buf.drain(..self.seq);
        for (l, &t) in labels.iter_mut().zip(tokens.iter().skip(1)) {
            if t == PAD {
                *l = IGNORE;
            }
        }
        (tokens, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_packed_and_shifted() {
        let tok = Tokenizer::new(1024);
        let mut s = CorpusStream::new(&tok, 64, 1);
        let (t1, l1) = s.next_example();
        assert_eq!(t1.len(), 64);
        assert_eq!(l1.len(), 64);
        // labels are next tokens
        let (t2, _) = s.next_example();
        assert_eq!(l1[63], t2[0]);
        for i in 0..63 {
            assert_eq!(l1[i], t1[i + 1]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tok = Tokenizer::new(1024);
        let mut a = CorpusStream::new(&tok, 32, 9);
        let mut b = CorpusStream::new(&tok, 32, 9);
        for _ in 0..5 {
            assert_eq!(a.next_example(), b.next_example());
        }
    }

    #[test]
    fn token_ids_in_vocab() {
        let tok = Tokenizer::new(1024);
        let mut s = CorpusStream::new(&tok, 128, 3);
        for _ in 0..10 {
            let (t, _) = s.next_example();
            assert!(t.iter().all(|&v| (0..1024).contains(&v)));
        }
    }
}
