//! The closed lexicon of the synthetic "TinyWorld" language.
//!
//! Stands in for the FALCON/GLUE/CNNDM vocabulary (DESIGN.md
//! #Hardware-adaptation): a topic-clustered SVO grammar whose word
//! inventory is small enough for a 1k-entry tokenizer, yet carries the
//! structure the paper's tasks need — synonym groups (entailment),
//! antonym pairs (contradiction), sentiment polarity (SST-2 analog) and
//! topical coherence (summarization / LM pretraining).

/// One content word with its semantics.
pub struct Word {
    pub text: &'static str,
    /// Index of the synonym group it belongs to (same group => same meaning).
    pub syn_group: u16,
    /// Sentiment: -1, 0, +1.
    pub polarity: i8,
}

/// A topic clusters a subset of the lexicon; sentences within a paragraph
/// stay on-topic, which is what makes continual pre-training informative.
pub struct Topic {
    pub name: &'static str,
    pub subjects: &'static [&'static str],
    pub verbs: &'static [(&'static str, &'static str)], // (verb, antonym-ish)
    pub objects: &'static [&'static str],
    pub places: &'static [&'static str],
}

pub const TOPICS: &[Topic] = &[
    Topic {
        name: "farm",
        subjects: &["farmer", "horse", "cow", "goat", "shepherd", "donkey"],
        verbs: &[("feeds", "starves"), ("guards", "abandons"), ("leads", "follows"), ("raises", "neglects")],
        objects: &["barn", "field", "fence", "tractor", "harvest", "meadow"],
        places: &["valley", "village", "hillside", "pasture"],
    },
    Topic {
        name: "sea",
        subjects: &["sailor", "captain", "whale", "dolphin", "fisherman", "pirate"],
        verbs: &[("sails", "anchors"), ("catches", "releases"), ("rescues", "deserts"), ("charts", "loses")],
        objects: &["ship", "harbor", "net", "lighthouse", "island", "storm"],
        places: &["bay", "reef", "coast", "strait"],
    },
    Topic {
        name: "city",
        subjects: &["driver", "teacher", "doctor", "painter", "baker", "engineer"],
        verbs: &[("builds", "demolishes"), ("repairs", "breaks"), ("opens", "closes"), ("teaches", "misleads")],
        objects: &["bridge", "school", "market", "tower", "library", "station"],
        places: &["street", "square", "district", "avenue"],
    },
    Topic {
        name: "forest",
        subjects: &["hunter", "wolf", "bear", "ranger", "fox", "owl"],
        verbs: &[("tracks", "ignores"), ("protects", "threatens"), ("finds", "hides"), ("watches", "overlooks")],
        objects: &["trail", "den", "river", "cabin", "thicket", "clearing"],
        places: &["grove", "ridge", "canyon", "glade"],
    },
    Topic {
        name: "court",
        subjects: &["king", "queen", "knight", "minister", "herald", "duke"],
        verbs: &[("crowns", "deposes"), ("defends", "betrays"), ("rewards", "punishes"), ("summons", "banishes")],
        objects: &["castle", "treaty", "throne", "banner", "feast", "council"],
        places: &["hall", "keep", "courtyard", "chamber"],
    },
    Topic {
        name: "lab",
        subjects: &["chemist", "student", "professor", "robot", "inventor", "scholar"],
        verbs: &[("measures", "guesses"), ("proves", "refutes"), ("mixes", "separates"), ("records", "erases")],
        objects: &["sample", "formula", "machine", "crystal", "journal", "experiment"],
        places: &["workshop", "archive", "basement", "observatory"],
    },
];

/// Adjective synonym groups with sentiment polarity. Each row is a group
/// of interchangeable adjectives: (words, polarity).
pub const ADJ_GROUPS: &[(&[&str], i8)] = &[
    (&["happy", "cheerful", "joyful"], 1),
    (&["brave", "bold", "fearless"], 1),
    (&["wise", "clever", "smart"], 1),
    (&["kind", "gentle", "friendly"], 1),
    (&["strong", "mighty", "sturdy"], 1),
    (&["splendid", "wonderful", "excellent"], 1),
    (&["sad", "gloomy", "miserable"], -1),
    (&["cruel", "brutal", "savage"], -1),
    (&["foolish", "reckless", "careless"], -1),
    (&["weak", "frail", "feeble"], -1),
    (&["dreadful", "terrible", "awful"], -1),
    (&["lazy", "idle", "sluggish"], -1),
    (&["old", "ancient", "aged"], 0),
    (&["young", "youthful", "new"], 0),
    (&["quiet", "silent", "calm"], 0),
    (&["tall", "towering", "lofty"], 0),
    (&["small", "tiny", "little"], 0),
    (&["distant", "remote", "faraway"], 0),
];

/// Antonym adjective pairs (group indices into ADJ_GROUPS): used for
/// contradiction generation. Pairs are (positive-ish, negative-ish).
pub const ADJ_ANTONYMS: &[(usize, usize)] = &[
    (0, 6),  // happy vs sad
    (1, 8),  // brave vs foolish
    (2, 8),  // wise vs foolish
    (3, 7),  // kind vs cruel
    (4, 9),  // strong vs weak
    (5, 10), // splendid vs dreadful
];

/// Function words, punctuation, structural markers, label words and
/// digit-words that complete the closed vocabulary.
pub const FUNCTION_WORDS: &[&str] = &[
    "the", "a", "and", "but", "near", "with", "in", "at", "of", "to",
    "is", "was", "not", "never", "always", "often", "while", "because",
    "who", "what", "where", "which", "did", "does", "yes", "no",
    "meanwhile", "later", "yesterday", "today", "everyone", "nobody",
    "says", "said", "that", "it", "he", "she", "they", "this", "very",
    ".", ",", "?", ":", ";",
    // label words (classification targets are ordinary tokens)
    "entailment", "neutral", "contradiction", "positive", "negative",
    // summarization prompt marker
    "tldr",
    // review/report scaffolding for SST-2 and CNNDM analogs
    "review", "report", "story", "news", "crowd", "journey", "morning",
    "evening", "winter", "summer", "festival", "journeyed", "returned",
    "visited", "praised", "blamed", "remembered", "forgot", "won", "lost",
];

/// Specials occupy the first token ids.
pub const SPECIALS: &[&str] = &["<pad>", "<bos>", "<eos>", "<sep>", "<unk>"];

/// Assemble the full word list (deterministic order -> stable token ids).
pub fn all_words() -> Vec<&'static str> {
    let mut out: Vec<&'static str> = Vec::new();
    out.extend_from_slice(SPECIALS);
    out.extend_from_slice(FUNCTION_WORDS);
    for t in TOPICS {
        out.extend_from_slice(t.subjects);
        for (v, a) in t.verbs {
            out.push(v);
            out.push(a);
        }
        out.extend_from_slice(t.objects);
        out.extend_from_slice(t.places);
    }
    for (group, _) in ADJ_GROUPS {
        out.extend_from_slice(group);
    }
    // de-dup while preserving first occurrence
    let mut seen = std::collections::BTreeSet::new();
    out.retain(|w| seen.insert(*w));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_is_closed_and_small() {
        let words = all_words();
        assert!(words.len() > 200, "lexicon too small: {}", words.len());
        assert!(words.len() < 1024 - 8, "must fit the 1k vocab: {}", words.len());
    }

    #[test]
    fn no_duplicate_words() {
        let words = all_words();
        let set: std::collections::BTreeSet<_> = words.iter().collect();
        assert_eq!(set.len(), words.len());
    }

    #[test]
    fn antonym_pairs_have_opposite_polarity() {
        for &(a, b) in ADJ_ANTONYMS {
            assert_ne!(ADJ_GROUPS[a].1, ADJ_GROUPS[b].1);
        }
    }

    #[test]
    fn every_topic_is_nonempty() {
        for t in TOPICS {
            assert!(!t.subjects.is_empty() && !t.verbs.is_empty());
            assert!(!t.objects.is_empty() && !t.places.is_empty());
        }
    }
}
