//! Downstream task datasets — the GLUE / CNNDM analogs (DESIGN.md
//! #Hardware-adaptation).
//!
//! Every example is already tokenized and supervision-masked:
//! `labels[t] = tokens[t+1]` on supervised positions and -100 elsewhere,
//! so the HLO train steps never shift internally.
//!
//! Classification follows the paper's LLM-finetuning formulation: the
//! label is an ordinary vocabulary word predicted at the position after
//! the final `<sep>` ("verbalizer" style), trained with CE on exactly
//! that position.

use super::grammar::{Paragraph, Sentence};
use super::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::substrate::Rng;

pub const IGNORE: i32 = -100;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Mnli,
    Qnli,
    Sst2,
    Cnndm,
}

impl Task {
    pub fn parse(name: &str) -> Option<Task> {
        match name {
            "mnli" => Some(Task::Mnli),
            "qnli" => Some(Task::Qnli),
            "sst2" => Some(Task::Sst2),
            "cnndm" => Some(Task::Cnndm),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Mnli => "mnli",
            Task::Qnli => "qnli",
            Task::Sst2 => "sst2",
            Task::Cnndm => "cnndm",
        }
    }

    pub fn label_words(&self) -> &'static [&'static str] {
        match self {
            Task::Mnli => &["entailment", "neutral", "contradiction"],
            Task::Qnli => &["yes", "no"],
            Task::Sst2 => &["positive", "negative"],
            Task::Cnndm => &[],
        }
    }

    pub fn is_generation(&self) -> bool {
        matches!(self, Task::Cnndm)
    }
}

/// One tokenized training/eval example.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    /// Classification: index into `label_words`. Generation: usize::MAX.
    pub class: usize,
    /// Length of the prompt prefix (generation tasks decode from here).
    pub prompt_len: usize,
    /// Reference summary token ids (generation tasks only).
    pub reference: Vec<i32>,
}

pub struct TaskGen<'a> {
    pub task: Task,
    pub tok: &'a Tokenizer,
    pub seq: usize,
}

impl<'a> TaskGen<'a> {
    pub fn new(task: Task, tok: &'a Tokenizer, seq: usize) -> Self {
        TaskGen { task, tok, seq }
    }

    /// Generate a deterministic split. Train and eval use disjoint seeds.
    pub fn dataset(&self, n: usize, seed: u64) -> Vec<Example> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| self.example(&mut rng)).collect()
    }

    pub fn example(&self, rng: &mut Rng) -> Example {
        match self.task {
            Task::Mnli => self.mnli(rng),
            Task::Qnli => self.qnli(rng),
            Task::Sst2 => self.sst2(rng),
            Task::Cnndm => self.cnndm(rng),
        }
    }

    /// Build `<bos> prompt-words <sep> label-word <eos>`; labels supervise
    /// only the label-word position.
    fn classification(&self, prompt: Vec<&'static str>, class: usize) -> Example {
        let label_word = self.task.label_words()[class];
        let mut tokens = vec![BOS];
        tokens.extend(self.tok.encode(&prompt));
        tokens.push(SEP);
        let prompt_len = tokens.len();
        tokens.push(self.tok.id(label_word));
        tokens.push(EOS);
        let mut labels = vec![IGNORE; tokens.len()];
        // predict the label word from the position holding <sep>
        labels[prompt_len - 1] = self.tok.id(label_word);
        self.pad(tokens, labels, class, prompt_len, Vec::new())
    }

    fn pad(
        &self,
        mut tokens: Vec<i32>,
        _labels: Vec<i32>,
        class: usize,
        prompt_len: usize,
        reference: Vec<i32>,
    ) -> Example {
        tokens.truncate(self.seq);
        while tokens.len() < self.seq {
            tokens.push(PAD);
        }
        // Dense causal-LM supervision: labels[t] = tokens[t+1] on every
        // non-pad position (standard LLM task fine-tuning). Label-only CE
        // starves the gradient at batch 8 — only 8 supervised tokens per
        // step — and plateaus at chance; dense supervision matches the
        // paper's full fine-tuning setting. The class label is still the
        // token at `prompt_len`, predicted from position prompt_len - 1.
        let mut labels = vec![IGNORE; self.seq];
        for t in 0..self.seq - 1 {
            if tokens[t] != PAD && tokens[t + 1] != PAD {
                labels[t] = tokens[t + 1];
            }
        }
        Example { tokens, labels, class, prompt_len, reference }
    }

    fn mnli(&self, rng: &mut Rng) -> Example {
        let premise = Sentence::sample(rng);
        let class = rng.below(3);
        let hypothesis = match class {
            0 => premise.entailed(rng),
            1 => premise.neutral(rng),
            _ => premise.contradicted(rng),
        };
        let mut p = premise.words();
        p.push(".");
        let sep_at = p.len();
        p.extend(hypothesis.words());
        p.push(".");
        // interleave an explicit separator word boundary via <sep> token:
        // classification() adds the trailing <sep>; insert one between the
        // two sentences here.
        let mut words = p;
        words.insert(sep_at, "<sep-marker>"); // replaced below
        let mut prompt: Vec<&'static str> = Vec::with_capacity(words.len());
        let mut ex_tokens: Vec<i32> = vec![BOS];
        for w in words {
            if w == "<sep-marker>" {
                ex_tokens.extend(self.tok.encode(&prompt));
                ex_tokens.push(SEP);
                prompt.clear();
            } else {
                prompt.push(w);
            }
        }
        ex_tokens.extend(self.tok.encode(&prompt));
        ex_tokens.push(SEP);
        let prompt_len = ex_tokens.len();
        let label_word = self.task.label_words()[class];
        ex_tokens.push(self.tok.id(label_word));
        ex_tokens.push(EOS);
        let mut labels = vec![IGNORE; ex_tokens.len()];
        labels[prompt_len - 1] = self.tok.id(label_word);
        self.pad(ex_tokens, labels, class, prompt_len, Vec::new())
    }

    fn qnli(&self, rng: &mut Rng) -> Example {
        let answer_sent = Sentence::sample(rng);
        let class = rng.below(2); // 0 = yes (answerable), 1 = no
        let (question, context) = if class == 0 {
            (answer_sent.question(), answer_sent.clone())
        } else {
            // a question about a *different* sentence: both the verb and
            // the object mismatch the context, so "answerable?" reduces to
            // token matching (learnable within this testbed's budgets)
            let mut other = Sentence::sample_in_topic(answer_sent.topic, rng);
            while other.verb == answer_sent.verb || other.obj == answer_sent.obj {
                other = Sentence::sample_in_topic(answer_sent.topic, rng);
            }
            (other.question(), answer_sent.clone())
        };
        let mut tokens = vec![BOS];
        tokens.extend(self.tok.encode(&question));
        tokens.push(SEP);
        let mut ctx = context.words();
        ctx.push(".");
        tokens.extend(self.tok.encode(&ctx));
        tokens.push(SEP);
        let prompt_len = tokens.len();
        let label_word = self.task.label_words()[class];
        tokens.push(self.tok.id(label_word));
        tokens.push(EOS);
        let mut labels = vec![IGNORE; tokens.len()];
        labels[prompt_len - 1] = self.tok.id(label_word);
        self.pad(tokens, labels, class, prompt_len, Vec::new())
    }

    fn sst2(&self, rng: &mut Rng) -> Example {
        use super::lexicon::ADJ_GROUPS;
        // A short "review" whose 1-2 polar adjectives share a sentiment;
        // the label is that sentiment. (Kept free of negation/mixed
        // polarity so the verbalizer mapping is learnable within this
        // testbed's O(100)-step budgets — DESIGN.md #Hardware-adaptation.)
        let subj = Sentence::sample(rng);
        let polarity: i8 = if rng.bool(0.5) { 1 } else { -1 };
        let polar: Vec<usize> = (0..ADJ_GROUPS.len())
            .filter(|&g| ADJ_GROUPS[g].1 == polarity)
            .collect();
        let n_adj = rng.range(1, 2);
        let mut words: Vec<&'static str> = vec!["the", "review", "says", "the"];
        words.push(super::lexicon::TOPICS[subj.topic].subjects[subj.subj]);
        words.push("is");
        for i in 0..n_adj {
            if i > 0 {
                words.push("and");
            }
            let g = *rng.choose(&polar);
            words.push(ADJ_GROUPS[g].0[rng.below(3)]);
        }
        let class = if polarity > 0 { 0 } else { 1 };
        self.classification(words, class)
    }

    fn cnndm(&self, rng: &mut Rng) -> Example {
        // Article: 3-5 on-topic sentences. Summary: synonym-paraphrase of
        // the LEAD sentence (the real CNNDM's lead bias, made exact).
        let para = Paragraph::sample(rng, 3, 5);
        let lead = &para.sentences[0];
        let summary_sent = lead.entailed(rng);
        let mut summary = summary_sent.words();
        summary.push(".");

        let mut tokens = vec![BOS];
        let mut article = para.words();
        article.push("tldr");
        article.push(":");
        tokens.extend(self.tok.encode(&article));
        tokens.push(SEP);
        let prompt_len = tokens.len();
        let ref_ids = self.tok.encode(&summary);
        tokens.extend(&ref_ids);
        tokens.push(EOS);

        let mut labels = vec![IGNORE; tokens.len()];
        // supervise the summary span: predict tokens[t+1] from t
        for t in (prompt_len - 1)..(tokens.len() - 1).min(self.seq - 1) {
            labels[t] = tokens[t + 1];
        }
        self.pad(tokens, labels, usize::MAX, prompt_len, ref_ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    fn tok() -> Tokenizer {
        Tokenizer::new(1024)
    }

    #[test]
    fn mnli_examples_are_balanced_and_masked() {
        let t = tok();
        let g = TaskGen::new(Task::Mnli, &t, 128);
        let ds = g.dataset(300, 7);
        let mut counts = [0usize; 3];
        for ex in &ds {
            counts[ex.class] += 1;
            // dense causal supervision: every supervised position predicts
            // the next token
            for (i, &l) in ex.labels.iter().enumerate() {
                if l != IGNORE {
                    assert_eq!(l, ex.tokens[i + 1]);
                }
            }
            // and the class-label position is supervised with the label word
            let lw = t.id(Task::Mnli.label_words()[ex.class]);
            assert_eq!(ex.labels[ex.prompt_len - 1], lw);
            assert_eq!(ex.tokens[ex.prompt_len], lw);
        }
        assert!(counts.iter().all(|&c| c > 60), "{counts:?}");
    }

    #[test]
    fn prop_examples_fit_seq_and_are_padded(){
        let t = tok();
        prop::check("task-shapes", 60, |gen| {
            let task = *gen.choose(&[Task::Mnli, Task::Qnli, Task::Sst2, Task::Cnndm]);
            let g = TaskGen::new(task, &t, 128);
            let ex = g.example(gen.rng());
            assert_eq!(ex.tokens.len(), 128);
            assert_eq!(ex.labels.len(), 128);
            assert!(ex.tokens.iter().all(|&v| (0..1024).contains(&v)));
            assert_eq!(ex.tokens[0], BOS);
        });
    }

    #[test]
    fn qnli_yes_question_matches_context() {
        let t = tok();
        let g = TaskGen::new(Task::Qnli, &t, 128);
        let ds = g.dataset(200, 3);
        for ex in ds.iter().filter(|e| e.class == 0) {
            // the question's verb appears in the context too
            let words = t.decode(&ex.tokens);
            let qmark = words.iter().position(|&w| w == "?").unwrap();
            let verb = words[1]; // "who <verb> the <obj> ?"
            assert!(words[qmark..].contains(&verb), "{words:?}");
        }
    }

    #[test]
    fn sst2_label_matches_polarity() {
        use super::super::lexicon::ADJ_GROUPS;
        let t = tok();
        let g = TaskGen::new(Task::Sst2, &t, 128);
        let ds = g.dataset(200, 11);
        for ex in &ds {
            let words = t.decode(&ex.tokens);
            // every polar adjective in the review shares the label's sign
            let mut n_polar = 0;
            for w in &words {
                for (group, pol) in ADJ_GROUPS {
                    if group.contains(w) && *pol != 0 {
                        n_polar += 1;
                        let expect = if *pol > 0 { 0 } else { 1 };
                        assert_eq!(ex.class, expect, "{words:?}");
                    }
                }
            }
            assert!(n_polar >= 1, "{words:?}");
        }
    }

    #[test]
    fn cnndm_supervises_summary_span_only() {
        let t = tok();
        let g = TaskGen::new(Task::Cnndm, &t, 128);
        let ds = g.dataset(50, 13);
        for ex in &ds {
            assert!(!ex.reference.is_empty());
            let sup = ex.labels.iter().filter(|&&l| l != IGNORE).count();
            assert!(sup >= ex.reference.len(), "summary span supervised");
            // decoding from prompt_len-1 should teach the reference:
            // labels[prompt_len-1] is the first reference token
            assert_eq!(ex.labels[ex.prompt_len - 1], ex.reference[0]);
        }
    }

    #[test]
    fn datasets_are_deterministic() {
        let t = tok();
        let g = TaskGen::new(Task::Mnli, &t, 128);
        let a = g.dataset(20, 42);
        let b = g.dataset(20, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
