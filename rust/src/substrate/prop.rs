//! Mini property-testing framework (the offline vendor set has no
//! `proptest`/`quickcheck`).
//!
//! `check(name, cases, |g| { ... })` runs a closure over `cases` randomized
//! inputs drawn through the [`Gen`] handle. On failure it re-runs with the
//! failing seed to confirm, then panics with the seed so the case can be
//! replayed by `PROP_SEED=<seed> cargo test <name>`.

use super::rng::Rng;

pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, std);
        v
    }
    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `f` over `cases` random inputs. Panics with a replayable seed on the
/// first failing case.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, f: F) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    for case in 0..cases {
        let seed = base.unwrap_or(0x5EED_0000 + case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), seed };
            f(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with PROP_SEED={seed}): {msg}"
            );
        }
        if base.is_some() {
            break; // replay mode: one case only
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 50, |g| {
            let a = g.f32(-10.0, 10.0);
            let b = g.f32(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn reports_seed_on_failure() {
        check("always-fails", 3, |g| {
            let v = g.usize(0, 100);
            assert!(v > 1000, "v={v}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen-ranges", 100, |g| {
            let n = g.usize(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f32(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&x));
            let v = g.vec_usize(5, 10, 20);
            assert!(v.iter().all(|&e| (10..=20).contains(&e)));
        });
    }
}
