//! Minimal-dependency JSON parser + emitter.
//!
//! The offline vendor set has no `serde`/`serde_json`, so the manifest
//! (artifacts/manifest.json), checkpoints headers and experiment reports go
//! through this module. It implements the full JSON grammar (RFC 8259)
//! minus `\u` surrogate pairs outside the BMP, which the manifest never
//! contains.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest only contains
/// integers small enough to round-trip exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("models")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Path access: `j.at(&["models", "tiny", "n_params"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        path.iter().try_fold(self, |j, k| j.get(k))
    }

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization (lossless round-trip with `parse`).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
/// `Num` for finite values, `Null` otherwise. JSON has no NaN/Inf and
/// the `Num` writer would emit an unparseable literal for them — empty
/// percentile samples (the `quantile` NaN contract) must serialize as
/// `null` and render as a dash.
pub fn num_or_null(n: f64) -> Json {
    if n.is_finite() {
        Json::Num(n)
    } else {
        Json::Null
    }
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m":{"p":[{"n":"embed","s":[1024,128],"std":0.02}],"x":true},"v":1024}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(num_or_null(f64::NAN), Json::Null);
        assert_eq!(num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(num_or_null(2.5), Json::Num(2.5));
        let row = obj(vec![("p50", num_or_null(f64::NAN)), ("n", num_or_null(3.0))]);
        assert_eq!(Json::parse(&row.to_string()).unwrap(), row);
    }

    #[test]
    fn unicode_round_trip() {
        let j = Json::Str("héllo → 世界".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
