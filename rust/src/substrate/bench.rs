//! Micro-benchmark harness (the offline vendor set has no `criterion`).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warm-up, then timed iterations until both a minimum wall-clock budget
//! and a minimum iteration count are met; reports mean / p50 / p95 and a
//! derived throughput. Output is stable, grep-friendly `key=value` rows so
//! EXPERIMENTS.md tables can be cut directly from bench logs.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self, extra: &str) {
        println!(
            "bench name={} iters={} mean={} p50={} p95={}{}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            if extra.is_empty() { "" } else { " " },
            extra
        );
    }

    /// items/s given how many logical items one iteration processes.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Benchmark `f`, keeping its result alive through `std::hint::black_box`.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_millis(300), 10, 3, &mut f)
}

/// Longer-budget variant for expensive end-to-end paths.
pub fn bench_slow<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_secs(2), 5, 1, &mut f)
}

fn bench_cfg<T>(
    name: &str,
    min_time: Duration,
    min_iters: usize,
    warmup: usize,
    f: &mut dyn FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    // lint: allow(no-wallclock-in-kernels): this IS the timing harness the bench/ layer sits on
    let start = Instant::now();
    while samples_ns.len() < min_iters || start.elapsed() < min_time {
        // lint: allow(no-wallclock-in-kernels): per-iteration sample timer of the same harness
        let t = Instant::now();
        std::hint::black_box(f());
        samples_ns.push(t.elapsed().as_nanos() as f64);
        if samples_ns.len() > 100_000 {
            break;
        }
    }
    let mut sorted = samples_ns.clone();
    sorted.sort_by(f64::total_cmp); // NaN-safe (panic-free stats path)
    let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
    // percentiles via the crate-wide interpolating quantile (serve::stats)
    // rather than nearest-rank truncation, which mis-indexes for small n
    let q = crate::serve::stats::quantile;
    BenchResult {
        name: name.to_string(),
        iters: samples_ns.len(),
        mean_ns: mean,
        p50_ns: q(&sorted, 0.50),
        p95_ns: q(&sorted, 0.95),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_cfg(
            "spin",
            Duration::from_millis(5),
            5,
            1,
            &mut || (0..1000u64).sum::<u64>(),
        );
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}
