//! Deterministic PRNG: xoshiro256++ (no `rand` crate in the offline
//! vendor set). Used for parameter initialization, data generation and the
//! mini property-testing framework. Seeded streams are stable across runs,
//! which makes every experiment in EXPERIMENTS.md exactly reproducible.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small/sequential seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream for a named subsystem.
    pub fn fork(&mut self, tag: &str) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()) as f32; // (0, 1]
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with N(0, std^2).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }
}
