//! Tiny argv parser (the offline vendor set has no `clap`).
//!
//! Grammar: `bitdistill <subcommand> [--flag value | --flag | positional]...`
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--steps", "100", "--size=base", "--quick"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.str("size", ""), "base");
        assert!(a.bool("quick"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["eval", "ckpt.bin", "--task", "mnli"]);
        assert_eq!(a.positional, vec!["ckpt.bin"]);
        assert_eq!(a.str("task", ""), "mnli");
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.usize("steps", 42), 42);
        assert_eq!(a.f64("lr", 1e-3), 1e-3);
    }

    #[test]
    fn flag_with_negative_number_value() {
        let a = parse(&["x", "--layer=-1"]);
        assert_eq!(a.str("layer", ""), "-1");
    }
}
