//! From-scratch substrates. The offline vendor set ships only `xla` and
//! `anyhow`, so the JSON codec, argv parser, PRNG, property-testing
//! harness and bench harness that a production repo would normally pull
//! from crates.io are implemented (and unit-tested) here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
