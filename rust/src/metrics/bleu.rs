//! Corpus BLEU-4 with add-1 smoothing on higher-order n-grams
//! (Lin & Och smoothing-1) and the standard brevity penalty.

use std::collections::HashMap;

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU-4 over (hypothesis, reference) pairs, in percent.
pub fn bleu4(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    let mut matched = [0usize; 4];
    let mut total = [0usize; 4];
    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=4 {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(rf, n);
            for (g, &c) in &h {
                let rc = r.get(g).copied().unwrap_or(0);
                matched[n - 1] += c.min(rc);
            }
            total[n - 1] += hyp.len().saturating_sub(n - 1);
        }
    }
    if hyp_len == 0 {
        return 0.0;
    }
    let mut logp = 0.0f64;
    for n in 0..4 {
        // smoothing-1: add 1 to numerator+denominator for n >= 2 when the
        // numerator would otherwise be 0
        let (m, t) = if n == 0 {
            (matched[0] as f64, total[0] as f64)
        } else {
            ((matched[n] + 1) as f64, (total[n] + 1) as f64)
        };
        if m == 0.0 || t == 0.0 {
            return 0.0;
        }
        logp += (m / t).ln() / 4.0;
    }
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    100.0 * bp * logp.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let s: Vec<i32> = (0..20).collect();
        let b = bleu4(&[(s.clone(), s)]);
        assert!(b > 99.0, "{b}");
    }

    #[test]
    fn disjoint_is_0() {
        let a: Vec<i32> = (0..20).collect();
        let b: Vec<i32> = (100..120).collect();
        assert_eq!(bleu4(&[(a, b)]), 0.0);
    }

    #[test]
    fn partial_overlap_is_between() {
        let r: Vec<i32> = (0..20).collect();
        let mut h = r.clone();
        for i in 10..20 {
            h[i] = 100 + i as i32; // half corrupted
        }
        let b = bleu4(&[(h, r)]);
        assert!(b > 1.0 && b < 60.0, "{b}");
    }

    #[test]
    fn brevity_penalty_hurts_short_hyps() {
        let r: Vec<i32> = (0..20).collect();
        let full = bleu4(&[(r.clone(), r.clone())]);
        let short = bleu4(&[(r[..10].to_vec(), r.clone())]);
        assert!(short < full * 0.8, "short={short} full={full}");
    }

    #[test]
    fn corpus_level_pools_counts() {
        let r1: Vec<i32> = (0..10).collect();
        let r2: Vec<i32> = (20..30).collect();
        let b = bleu4(&[(r1.clone(), r1), (r2.clone(), r2)]);
        assert!(b > 99.0);
    }
}
