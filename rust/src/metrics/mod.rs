//! Evaluation metrics, from scratch: accuracy, BLEU [PRWZ02] and
//! ROUGE-1/2/L/Lsum [Lin04] — the exact metric set of the paper's
//! Tables 1-2.

pub mod bleu;
pub mod rouge;

pub use bleu::bleu4;
pub use rouge::{rouge_l, rouge_lsum, rouge_n, RougeScore};

/// Classification accuracy in percent.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    100.0 * hits as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 3]), 100.0);
        assert_eq!(accuracy(&[1, 0, 3], &[1, 2, 3]), 100.0 * 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }
}
