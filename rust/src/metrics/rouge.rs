//! ROUGE-1 / ROUGE-2 / ROUGE-L / ROUGE-Lsum F1 scores [Lin04], averaged
//! over the corpus (matching the `rouge_score` package's aggregation the
//! paper reports).

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, Default)]
pub struct RougeScore {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

fn f1(p: f64, r: f64) -> f64 {
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

fn counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// ROUGE-N for one pair.
fn rouge_n_pair(hyp: &[i32], rf: &[i32], n: usize) -> RougeScore {
    let h = counts(hyp, n);
    let r = counts(rf, n);
    let overlap: usize = h
        .iter()
        .map(|(g, &c)| c.min(r.get(g).copied().unwrap_or(0)))
        .sum();
    let hyp_total = hyp.len().saturating_sub(n - 1);
    let ref_total = rf.len().saturating_sub(n - 1);
    if hyp_total == 0 || ref_total == 0 {
        return RougeScore::default();
    }
    let p = overlap as f64 / hyp_total as f64;
    let rec = overlap as f64 / ref_total as f64;
    RougeScore { precision: p, recall: rec, f1: f1(p, rec) }
}

fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    // O(|a|*|b|) DP with two rows
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                prev[j + 1].max(cur[j])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    // prev has b.len() + 1 entries, so last() is the full-LCS cell
    prev.last().copied().unwrap_or(0)
}

fn rouge_l_pair(hyp: &[i32], rf: &[i32]) -> RougeScore {
    if hyp.is_empty() || rf.is_empty() {
        return RougeScore::default();
    }
    let l = lcs_len(hyp, rf) as f64;
    let p = l / hyp.len() as f64;
    let r = l / rf.len() as f64;
    RougeScore { precision: p, recall: r, f1: f1(p, r) }
}

/// Split on sentence boundaries (`.` token id) for Lsum.
fn sentences(seq: &[i32], period: i32) -> Vec<&[i32]> {
    let mut out = Vec::new();
    let mut start = 0;
    for (i, &t) in seq.iter().enumerate() {
        if t == period {
            if i > start {
                out.push(&seq[start..i]);
            }
            start = i + 1;
        }
    }
    if start < seq.len() {
        out.push(&seq[start..]);
    }
    out
}

/// Union-LCS ROUGE-Lsum for one pair.
fn rouge_lsum_pair(hyp: &[i32], rf: &[i32], period: i32) -> RougeScore {
    let hs = sentences(hyp, period);
    let rs = sentences(rf, period);
    if hs.is_empty() || rs.is_empty() {
        return RougeScore::default();
    }
    // union-LCS: for each reference sentence, the union of its LCS token
    // hits against all hypothesis sentences (approximated by max-LCS,
    // which coincides for our single-sentence summaries)
    let mut hit = 0.0;
    for r in &rs {
        let best = hs.iter().map(|h| lcs_len(h, r)).max().unwrap_or(0);
        hit += best as f64;
    }
    let p = hit / hyp.iter().filter(|&&t| t != period).count().max(1) as f64;
    let rec = hit / rf.iter().filter(|&&t| t != period).count().max(1) as f64;
    RougeScore { precision: p.min(1.0), recall: rec.min(1.0), f1: f1(p.min(1.0), rec.min(1.0)) }
}

fn avg(scores: impl Iterator<Item = RougeScore>) -> RougeScore {
    let mut n = 0usize;
    let mut acc = RougeScore::default();
    for s in scores {
        acc.precision += s.precision;
        acc.recall += s.recall;
        acc.f1 += s.f1;
        n += 1;
    }
    if n > 0 {
        acc.precision /= n as f64;
        acc.recall /= n as f64;
        acc.f1 /= n as f64;
    }
    acc
}

/// Corpus ROUGE-N (average F1 over pairs), percent.
pub fn rouge_n(pairs: &[(Vec<i32>, Vec<i32>)], n: usize) -> f64 {
    100.0 * avg(pairs.iter().map(|(h, r)| rouge_n_pair(h, r, n))).f1
}

/// Corpus ROUGE-L, percent.
pub fn rouge_l(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    100.0 * avg(pairs.iter().map(|(h, r)| rouge_l_pair(h, r))).f1
}

/// Corpus ROUGE-Lsum, percent. `period` is the sentence-boundary token.
pub fn rouge_lsum(pairs: &[(Vec<i32>, Vec<i32>)], period: i32) -> f64 {
    100.0 * avg(pairs.iter().map(|(h, r)| rouge_lsum_pair(h, r, period))).f1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    #[test]
    fn identical_is_100() {
        let s: Vec<i32> = (0..15).collect();
        let pairs = vec![(s.clone(), s)];
        assert!((rouge_n(&pairs, 1) - 100.0).abs() < 1e-9);
        assert!((rouge_n(&pairs, 2) - 100.0).abs() < 1e-9);
        assert!((rouge_l(&pairs) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_0() {
        let pairs = vec![((0..10).collect::<Vec<i32>>(), (50..60).collect())];
        assert_eq!(rouge_n(&pairs, 1), 0.0);
        assert_eq!(rouge_l(&pairs), 0.0);
    }

    #[test]
    fn lcs_known_value() {
        assert_eq!(lcs_len(&[1, 2, 3, 4, 5], &[2, 4, 5]), 3);
        assert_eq!(lcs_len(&[1, 2, 3], &[3, 2, 1]), 1);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }

    #[test]
    fn rouge_l_rewards_order() {
        // same unigrams, different order -> R1 perfect, RL lower
        let r: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        let h: Vec<i32> = vec![6, 5, 4, 3, 2, 1];
        let pairs = vec![(h, r)];
        assert!((rouge_n(&pairs, 1) - 100.0).abs() < 1e-9);
        assert!(rouge_l(&pairs) < 40.0);
    }

    #[test]
    fn lsum_splits_sentences() {
        let period = 99;
        let r = vec![1, 2, 3, period, 4, 5, 6];
        let h = vec![4, 5, 6, period, 1, 2, 3];
        let pairs = vec![(h, r)];
        // sentence-level matching recovers both sentences fully
        assert!(rouge_lsum(&pairs, period) > 99.0);
    }

    #[test]
    fn prop_scores_bounded() {
        prop::check("rouge-bounded", 100, |g| {
            let hn = g.usize(1, 30);
            let rn = g.usize(1, 30);
            let h: Vec<i32> = g.vec_usize(hn, 0, 20).iter().map(|&v| v as i32).collect();
            let r: Vec<i32> = g.vec_usize(rn, 0, 20).iter().map(|&v| v as i32).collect();
            let pairs = vec![(h, r)];
            for v in [rouge_n(&pairs, 1), rouge_n(&pairs, 2), rouge_l(&pairs),
                      rouge_lsum(&pairs, 5), crate::metrics::bleu4(&pairs)] {
                assert!((0.0..=100.0001).contains(&v), "{v}");
            }
        });
    }
}
