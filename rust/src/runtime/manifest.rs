//! artifacts/manifest.json — the L2<->L3 contract.
//!
//! Emitted by `python/compile/aot.py`, parsed here into typed structs. It
//! carries (a) per-model parameter specs (name/shape/init/weight-decay) in
//! the canonical flat order shared with the HLO executables, and (b) per
//! -artifact positional IO signatures used for sanity checks.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::substrate::Json;

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub act: String,
    pub tie_embeddings: bool,
    pub use_subln: bool,
    pub quant_method: String,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub seq: usize,
}

impl ModelCfg {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_kind: String, // "normal" | "ones"
    pub init_std: f32,
    pub weight_decay: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub key: String,
    pub config: ModelCfg,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    /// A manifest-free student spec (SubLN + absmean, tied embeddings)
    /// for the serving demos and benches: lets `bitdistill serve`,
    /// `benches/serve.rs` and the serve integration tests run on a
    /// machine with no AOT artifacts. Serving throughput and memory do
    /// not depend on weight values, so random init over this spec is a
    /// faithful stand-in; dims mirror the aot.py size table.
    pub fn synthetic(size: &str) -> Result<ModelSpec> {
        ModelSpec::synthetic_with(size, true, "absmean")
    }

    /// The general manifest-free spec builder: `use_subln` / `quant`
    /// select the student variants and the FP teacher (`false, "none"`),
    /// mirroring aot.py's model_key grid. "micro" is an extra sub-tiny
    /// size for unit tests and the native train bench. Used by the
    /// native training backend, which needs every model role without an
    /// artifacts directory.
    pub fn synthetic_with(size: &str, use_subln: bool, quant: &str) -> Result<ModelSpec> {
        let (d, l, h, kv, hd, ff) = match size {
            "micro" => (32usize, 2usize, 2usize, 1usize, 16usize, 96usize),
            "tiny" => (128, 4, 4, 2, 32, 384),
            "small" => (256, 6, 8, 4, 32, 768),
            "base" => (384, 8, 8, 4, 48, 1152),
            other => bail!("no synthetic config for size {other:?} (micro|tiny|small|base)"),
        };
        let config = ModelCfg {
            name: size.to_string(),
            vocab: 1024,
            d_model: d,
            n_layers: l,
            n_heads: h,
            n_kv_heads: kv,
            head_dim: hd,
            d_ff: ff,
            act: "silu".to_string(),
            tie_embeddings: true,
            use_subln,
            quant_method: quant.to_string(),
            rope_theta: 1e4,
            norm_eps: 1e-6,
            seq: 128,
        };
        let (qd, kvd) = (config.q_dim(), config.kv_dim());
        let mut params = Vec::new();
        let mut push = |name: &str, shape: Vec<usize>, kind: &str| {
            params.push(ParamSpec {
                name: name.to_string(),
                shape: shape.clone(),
                init_kind: kind.to_string(),
                init_std: 0.02,
                weight_decay: shape.len() >= 2,
            });
        };
        push("embed", vec![config.vocab, d], "normal");
        push("blocks.attn_norm", vec![l, d], "ones");
        push("blocks.wq", vec![l, d, qd], "normal");
        push("blocks.wk", vec![l, d, kvd], "normal");
        push("blocks.wv", vec![l, d, kvd], "normal");
        push("blocks.wo", vec![l, qd, d], "normal");
        if use_subln {
            push("blocks.subln_attn", vec![l, qd], "ones");
        }
        push("blocks.ffn_norm", vec![l, d], "ones");
        push("blocks.w_gate", vec![l, d, ff], "normal");
        push("blocks.w_up", vec![l, d, ff], "normal");
        push("blocks.w_down", vec![l, ff, d], "normal");
        if use_subln {
            push("blocks.subln_ffn", vec![l, ff], "ones");
        }
        push("final_norm", vec![d], "ones");
        let n_params = params.iter().map(ParamSpec::numel).sum();
        Ok(ModelSpec {
            key: format!(
                "{size}-{}-{quant}-synthetic",
                if use_subln { "subln" } else { "nosubln" }
            ),
            config,
            n_params,
            params,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String, // lm_train | bitnet_train | distill_train | fwd | kernel
    pub model: String,
    pub teacher_model: Option<String>,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub vocab: usize,
    pub batch: usize,
    pub seq: usize,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest: missing usize field {key:?}"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing str field {key:?}"))?
        .to_string())
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow!("manifest: missing bool field {key:?}"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("manifest: missing num field {key:?}"))
}

fn str_list(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?} (run `make artifacts`?)", path.as_ref()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (key, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: no models"))?
        {
            let cj = mj.get("config").ok_or_else(|| anyhow!("model {key}: no config"))?;
            let config = ModelCfg {
                name: get_str(cj, "name")?,
                vocab: get_usize(cj, "vocab")?,
                d_model: get_usize(cj, "d_model")?,
                n_layers: get_usize(cj, "n_layers")?,
                n_heads: get_usize(cj, "n_heads")?,
                n_kv_heads: get_usize(cj, "n_kv_heads")?,
                head_dim: get_usize(cj, "head_dim")?,
                d_ff: get_usize(cj, "d_ff")?,
                act: get_str(cj, "act")?,
                tie_embeddings: get_bool(cj, "tie_embeddings")?,
                use_subln: get_bool(cj, "use_subln")?,
                quant_method: get_str(cj, "quant_method")?,
                rope_theta: get_f64(cj, "rope_theta")?,
                norm_eps: get_f64(cj, "norm_eps")?,
                seq: get_usize(cj, "seq")?,
            };
            let params = mj
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model {key}: no params"))?
                .iter()
                .map(|pj| {
                    let init = pj.get("init").ok_or_else(|| anyhow!("param: no init"))?;
                    Ok(ParamSpec {
                        name: get_str(pj, "name")?,
                        shape: pj
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("param: no shape"))?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        init_kind: get_str(init, "kind")?,
                        init_std: get_f64(init, "std")? as f32,
                        weight_decay: get_bool(pj, "weight_decay")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                key.clone(),
                ModelSpec {
                    key: key.clone(),
                    config,
                    n_params: get_usize(mj, "n_params")?,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, aj) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: no artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: get_str(aj, "file")?,
                    kind: get_str(aj, "kind")?,
                    model: get_str(aj, "model")?,
                    teacher_model: aj
                        .get("teacher_model")
                        .and_then(Json::as_str)
                        .map(String::from),
                    batch: get_usize(aj, "batch")?,
                    seq: get_usize(aj, "seq")?,
                    inputs: aj.get("inputs").map(str_list).unwrap_or_default(),
                    outputs: aj.get("outputs").map(str_list).unwrap_or_default(),
                },
            );
        }

        Ok(Manifest {
            vocab: get_usize(&j, "vocab")?,
            batch: get_usize(&j, "batch")?,
            seq: get_usize(&j, "seq")?,
            models,
            artifacts,
        })
    }

    pub fn model(&self, key: &str) -> Result<&ModelSpec> {
        self.models
            .get(key)
            .ok_or_else(|| anyhow!("manifest has no model {key:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no artifact {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "vocab": 1024, "batch": 8, "seq": 128,
      "models": {
        "tiny-subln-absmean": {
          "config": {"name":"tiny","vocab":1024,"d_model":128,"n_layers":4,
            "n_heads":4,"n_kv_heads":2,"head_dim":32,"d_ff":384,"act":"silu",
            "tie_embeddings":true,"use_subln":true,"quant_method":"absmean",
            "rope_theta":10000.0,"norm_eps":1e-6,"seq":128},
          "n_params": 920704,
          "params": [
            {"name":"embed","shape":[1024,128],
             "init":{"kind":"normal","std":0.02},"weight_decay":true},
            {"name":"final_norm","shape":[128],
             "init":{"kind":"ones","std":0.0},"weight_decay":false}
          ]
        }
      },
      "artifacts": {
        "tiny_bitnet_train": {
          "name":"tiny_bitnet_train","file":"tiny_bitnet_train.hlo.txt",
          "kind":"bitnet_train","model":"tiny-subln-absmean",
          "batch":8,"seq":128,
          "inputs":["param.embed","step","lr","tokens","labels"],
          "outputs":["param.embed","loss.total"]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.vocab, 1024);
        let spec = m.model("tiny-subln-absmean").unwrap();
        assert_eq!(spec.config.d_model, 128);
        assert_eq!(spec.params.len(), 2);
        assert_eq!(spec.params[0].shape, vec![1024, 128]);
        assert!(spec.params[0].weight_decay);
        assert_eq!(spec.params[1].init_kind, "ones");
        let art = m.artifact("tiny_bitnet_train").unwrap();
        assert_eq!(art.kind, "bitnet_train");
        assert_eq!(art.inputs.len(), 5);
    }

    #[test]
    fn synthetic_specs_are_complete() {
        for size in ["tiny", "small", "base"] {
            let s = ModelSpec::synthetic(size).unwrap();
            assert_eq!(s.config.name, size);
            assert_eq!(s.config.q_dim(), s.config.n_heads * s.config.head_dim);
            assert!(s.n_params > 0);
            let names: Vec<&str> = s.params.iter().map(|p| p.name.as_str()).collect();
            for need in ["embed", "blocks.wq", "blocks.w_down", "final_norm"] {
                assert!(names.contains(&need), "{size} missing {need}");
            }
            // embedding rows must cover the tokenizer vocab
            assert_eq!(s.params[0].shape, vec![s.config.vocab, s.config.d_model]);
        }
        assert!(ModelSpec::synthetic("huge").is_err());
    }

    #[test]
    fn synthetic_with_builds_teacher_and_student_variants() {
        let teacher = ModelSpec::synthetic_with("tiny", false, "none").unwrap();
        assert!(!teacher.config.use_subln);
        assert_eq!(teacher.config.quant_method, "none");
        assert_eq!(teacher.key, "tiny-nosubln-none-synthetic");
        assert!(teacher.params.iter().all(|p| !p.name.starts_with("blocks.subln")));
        let student = ModelSpec::synthetic_with("tiny", true, "absmean").unwrap();
        assert_eq!(student.key, ModelSpec::synthetic("tiny").unwrap().key);
        // every teacher tensor exists in the student with the same shape,
        // so Stage-1 load_compatible leaves only the SubLN gains fresh
        for tp in &teacher.params {
            let sp = student.params.iter().find(|p| p.name == tp.name).unwrap();
            assert_eq!(sp.shape, tp.shape, "{}", tp.name);
        }
        assert!(ModelSpec::synthetic_with("micro", true, "absmean").is_ok());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"vocab":1,"batch":1,"seq":1,"models":{},"artifacts":{}}"#).is_ok());
    }
}
