//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! `Runtime` owns one `xla::PjRtClient` (CPU) plus a compile cache keyed by
//! artifact name. Executables are compiled lazily on first use — compiling
//! a train step takes O(seconds), so the pipeline reuses the cache across
//! stages. Interchange is HLO *text* (see python/compile/aot.py docstring).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactSpec, Manifest, ModelCfg, ModelSpec, ParamSpec};

use crate::tensor::TensorF32;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub verbose: bool,
}

impl Runtime {
    /// Open the artifacts directory (must contain manifest.json).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
            verbose: false,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        // lint: allow(no-wallclock-in-kernels): one-shot artifact-compile timing on the CLI load path, not in a kernel
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        if self.verbose {
            eprintln!("[runtime] compiled {name} in {:.1}s", t0.elapsed().as_secs_f32());
        }
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with positional literal inputs; returns the
    /// decomposed output tuple (aot.py lowers with return_tuple=True).
    pub fn run(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?;
        if !spec.inputs.is_empty() && spec.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.load(name)?;
        let out = exe.execute::<xla::Literal>(inputs)?;
        let mut tuple = out
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("artifact {name}: empty output"))?
            .to_literal_sync()?;
        let parts = tuple.decompose_tuple()?;
        if !spec.outputs.is_empty() && spec.outputs.len() != parts.len() {
            return Err(anyhow!(
                "artifact {name}: manifest says {} outputs, executable returned {}",
                spec.outputs.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Upload a host f32 tensor to a device buffer (caller-managed
    /// lifetime — this avoids the C-wrapper `execute(literals)` path,
    /// which leaks its internally created input device buffers; see
    /// EXPERIMENTS.md §Perf "memory leak" note).
    pub fn to_device_f32(&self, t: &crate::tensor::TensorF32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }

    /// Upload a host i32 tensor to a device buffer.
    pub fn to_device_i32(&self, t: &crate::tensor::TensorI32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }

    /// Execute with pre-uploaded device buffers (`execute_b`): the
    /// allocation-clean hot path for training loops.
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?;
        if !spec.inputs.is_empty() && spec.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.load(name)?;
        let out = exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        let mut tuple = out
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("artifact {name}: empty output"))?
            .to_literal_sync()?;
        let parts = tuple.decompose_tuple()?;
        if !spec.outputs.is_empty() && spec.outputs.len() != parts.len() {
            return Err(anyhow!(
                "artifact {name}: manifest says {} outputs, executable returned {}",
                spec.outputs.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Convenience: run and convert every output to a host tensor.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<TensorF32>> {
        self.run(name, inputs)?
            .iter()
            .map(TensorF32::from_literal)
            .collect()
    }
}
