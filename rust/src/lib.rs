//! # BitNet Distillation (BitDistill) — reproduction library
//!
//! A three-layer reproduction of *BitNet Distillation* (Wu et al., 2025):
//! fine-tune full-precision LLMs into 1.58-bit (ternary) BitNet students
//! for downstream tasks via (1) SubLN refinement, (2) continual
//! pre-training, and (3) logits + MiniLM attention-relation distillation.
//!
//! - Layer 1/2 (JAX + Pallas) are AOT-lowered to HLO text artifacts at
//!   build time (`make artifacts`); Python never runs at train/serve time.
//! - Layer 3 (this crate) drives all training loops through the PJRT CPU
//!   client ([`runtime`]), owns the data pipeline ([`data`]), the
//!   three-stage coordinator ([`pipeline`]), the deployment-time ternary
//!   inference engine ([`engine`]) and the paper-table harness ([`bench`]).
//! - The [`serve`] layer turns the engine into a continuous-batching
//!   inference server: bounded admission queue -> scheduler (join on
//!   arrival, retire on finish) -> batched engine
//!   ([`engine::Engine::decode_step_batch_ctx`] over a KV-slot pool) ->
//!   latency/throughput stats. `bitdistill serve` drives it from the CLI;
//!   `benches/serve.rs` tracks batched-vs-sequential throughput.
//! - The [`train`] layer is a native CPU training backend: a tape-based
//!   reverse-mode autograd ([`train::tape`]), the differentiable model
//!   forward with QAT/STE fake-quant on the crate's own lattices, the
//!   eq. 8-14 losses, and AdamW — so `bitdistill pipeline --backend
//!   native` runs all three BitDistill stages and exports a ternary
//!   [`engine::Engine`] with **no** `artifacts/` directory at all. The
//!   HLO and native backends share the stage drivers through the
//!   [`pipeline::TrainStep`] seam.
//! - The [`engine`]'s ternary hot path exists in three bitwise-identical
//!   generations behind [`engine::KernelKind`]: per-byte trit decoding
//!   ([`engine::gemv`]), TL-style activation lookup tables
//!   ([`engine::lut`], one table load + add per packed weight byte), and
//!   runtime-dispatched SIMD ([`engine::simd`], AVX2/NEON in-register
//!   nibble decode with a bitwise-identical scalar fallback on other
//!   hosts). `bitdistill serve|bench --kernel` select it; the CI `bench`
//!   job perf-gates all three via `bitdistill bench --check`. Every
//!   execution knob (thread pool, kernel, tracing, quant telemetry)
//!   rides in one [`engine::ExecCtx`] value passed to the engine's
//!   `_ctx` methods — the old per-knob `_with`/`_kernel` method matrix
//!   is retired (lint-enforced outside `engine/`).
//! - The [`parallel`] layer is the deterministic multi-threaded
//!   execution substrate all three lean on: a dependency-free
//!   [`parallel::ThreadPool`] (scoped `std::thread` workers, chunked row
//!   partitioning) whose row-partitioned kernels are **bitwise
//!   identical** to the serial ones at every thread count
//!   (property-test-enforced). `ServerCfg::threads` / `--threads` size
//!   the serve-side pool; `NativeTrainer::threads` fans micro-batch
//!   shards across workers with gradients reduced in fixed shard order.
//!
//! - The [`obs`] layer is the crate's observability substrate:
//!   a zero-cost-off span [`obs::TraceRecorder`] (per-request and
//!   per-phase spans exported as Chrome trace-event JSON via
//!   `bitdistill serve|pipeline --trace out.json`, Perfetto-loadable)
//!   and fixed-memory log-bucketed [`obs::Histogram`]s that
//!   [`serve::ServeStats`] sits on, so server memory stays bounded at
//!   any request count (`serve --metrics-every N` emits JSONL
//!   snapshots), plus [`obs::QuantScope`] — per-layer quantization
//!   telemetry (ternary sparsity / flip rate / scale drift / clip
//!   fraction / grad norm and the distillation loss breakdown during
//!   QAT, int8 activation saturation during serving) emitted as
//!   `kind:"quant"` JSONL via `--quant-metrics` and rendered by
//!   `report --quant`. Telemetry may never change outputs — on vs off
//!   training and serving are bitwise identical (test-enforced), and
//!   `bench --check` gates instrumentation overhead.
//!
//! - The [`analysis`] layer is the crate's own static analyzer
//!   (`bitdistill lint`): a dependency-free lexer + rule engine that
//!   encodes the determinism contract as source rules — no
//!   `partial_cmp().unwrap()` (NaN panics), no `HashMap` iteration in
//!   the bitwise-deterministic dirs, no panics in the scheduler's
//!   request path (validated-at-submit), no wall-clock in kernels,
//!   obs recorders only behind the zero-cost-off guard, a written
//!   `// SAFETY:` contract on every `unsafe`, and no calls to the
//!   retired Engine `_with`/`_kernel` variants outside `engine/`
//!   ([`engine::ExecCtx`] is the only execution-context surface).
//!   Escapes are explicit and
//!   reasoned (`// lint: allow(<rule>): <reason>`); the pass is
//!   self-hosted (this crate lints clean, test-enforced) and runs in
//!   CI on every push.
//!
//! See DESIGN.md for the per-table/figure experiment index and
//! `src/README.md` for the layer map (including the "analysis layer"
//! rule catalogue and escape syntax).

// Clippy bar (see `[lints.clippy]` in rust/Cargo.toml): `unwrap_used`,
// `float_cmp`, and `indexing_slicing` are denied crate-wide so the bar
// survives toolchain bumps. Modules that predate the deny-list carry
// scoped allows below; the request path (`serve`) holds the no-unwrap
// bar outright, and the `analysis` layer — which polices everyone
// else — holds the full bar except slice work inside its own lexer.
// Test code is exempted via rust/clippy.toml (`allow-unwrap-in-tests`).
#[allow(clippy::indexing_slicing)]
pub mod analysis;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod bench;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod data;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod engine;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod metrics;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod obs;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod parallel;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod params;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod pipeline;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod quant;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod runtime;
// the continuous-batching request path: panics here kill co-scheduled
// lanes, so `unwrap_used` stays denied (indexing sites carry reasoned
// `lint: allow` escapes checked by `bitdistill lint` instead)
#[allow(clippy::indexing_slicing, clippy::float_cmp)]
pub mod serve;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod substrate;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod tensor;
#[allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]
pub mod train;
