//! # BitNet Distillation (BitDistill) — reproduction library
//!
//! A three-layer reproduction of *BitNet Distillation* (Wu et al., 2025):
//! fine-tune full-precision LLMs into 1.58-bit (ternary) BitNet students
//! for downstream tasks via (1) SubLN refinement, (2) continual
//! pre-training, and (3) logits + MiniLM attention-relation distillation.
//!
//! - Layer 1/2 (JAX + Pallas) are AOT-lowered to HLO text artifacts at
//!   build time (`make artifacts`); Python never runs at train/serve time.
//! - Layer 3 (this crate) drives all training loops through the PJRT CPU
//!   client ([`runtime`]), owns the data pipeline ([`data`]), the
//!   three-stage coordinator ([`pipeline`]), the deployment-time ternary
//!   inference engine ([`engine`]) and the paper-table harness ([`bench`]).
//!
//! See DESIGN.md for the per-table/figure experiment index.

pub mod bench;
pub mod data;
pub mod engine;
pub mod metrics;
pub mod params;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod substrate;
pub mod tensor;
