//! Differentiable transformer forward over a [`crate::runtime::ModelSpec`]
//! — the native mirror of `python/compile/model.py::forward`, built on
//! the [`Tape`]. Reuses the `ParamStore` flat naming (`embed`,
//! `blocks.*` stacked per layer, `final_norm`, optional `lm_head`), so
//! any checkpoint the HLO path produced loads unchanged, and the trained
//! result exports straight into [`crate::engine::Engine::from_params`].
//!
//! Like the JAX forward it also captures the pre-RoPE Q/K/V projection
//! states of one layer (K/V repeated to the full head count) for the
//! MiniLM attention-relation distillation loss.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::params::ParamStore;
use crate::runtime::ModelCfg;
use crate::train::qat;
use crate::train::tape::{Tape, TensorId};

/// Tape handles of every parameter, by canonical name.
pub type ParamIds = BTreeMap<String, TensorId>;

/// Register every tensor of `store` as a tape leaf.
pub fn register_params(tape: &mut Tape, store: &ParamStore) -> ParamIds {
    let mut ids = BTreeMap::new();
    for spec in &store.specs {
        let t = &store.tensors[&spec.name];
        ids.insert(spec.name.clone(), tape.leaf(&t.shape, t.data.clone()));
    }
    ids
}

/// Outputs of one forward pass.
pub struct ForwardOut {
    /// [b*t, vocab] logits.
    pub logits: TensorId,
    /// Pre-RoPE (Q, K, V) of the captured layer, K/V repeated to the
    /// full head count; each [b*t, n_heads*head_dim]. `None` when
    /// `capture_layer` was out of range.
    pub states: Option<[TensorId; 3]>,
}

fn get(ids: &ParamIds, name: &str) -> Result<TensorId> {
    ids.get(name).copied().ok_or_else(|| anyhow!("forward: missing param {name:?}"))
}

/// Run the transformer on `tokens` ([b, t] row-major). Quantization
/// (QAT fake-quant with STE) is on iff `cfg.quant_method != "none"`,
/// matching the Layer-2 convention. `capture_layer < 0` captures nothing.
pub fn forward(
    tape: &mut Tape,
    cfg: &ModelCfg,
    ids: &ParamIds,
    tokens: &[i32],
    b: usize,
    t: usize,
    capture_layer: i32,
) -> Result<ForwardOut> {
    assert_eq!(tokens.len(), b * t, "tokens/b*t mismatch");
    let (d, ff, l) = (cfg.d_model, cfg.d_ff, cfg.n_layers);
    let (nh, nkv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
    let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
    let eps = cfg.norm_eps as f32;
    let theta = cfg.rope_theta as f32;
    let quant = cfg.quant_method != "none";
    let method = cfg.quant_method.as_str();
    let rep = nh / nkv;

    let embed = get(ids, "embed")?;
    let mut x = tape.embedding(embed, tokens);
    let mut states = None;

    // per-layer slices of the stacked block tensors
    let norm_slice = |tape: &mut Tape, id: TensorId, li: usize, dim: usize| {
        tape.slice(id, li * dim, &[dim])
    };
    let (w_attn_norm, w_ffn_norm) = (get(ids, "blocks.attn_norm")?, get(ids, "blocks.ffn_norm")?);
    let (wq_s, wk_s, wv_s, wo_s) = (
        get(ids, "blocks.wq")?,
        get(ids, "blocks.wk")?,
        get(ids, "blocks.wv")?,
        get(ids, "blocks.wo")?,
    );
    let (wg_s, wu_s, wd_s) = (
        get(ids, "blocks.w_gate")?,
        get(ids, "blocks.w_up")?,
        get(ids, "blocks.w_down")?,
    );
    let sub_a = if cfg.use_subln { Some(get(ids, "blocks.subln_attn")?) } else { None };
    let sub_f = if cfg.use_subln { Some(get(ids, "blocks.subln_ffn")?) } else { None };

    for li in 0..l {
        // weight slice + optional fake-quant (the BitLinear QAT forward)
        let lin_w = |tape: &mut Tape, stacked: TensorId, k: usize, n: usize| {
            let w = tape.slice(stacked, li * k * n, &[k, n]);
            if quant {
                qat::fake_quant_weight(tape, w, k, n, method)
            } else {
                w
            }
        };

        // ---- attention ----
        let attn_norm = norm_slice(tape, w_attn_norm, li, d);
        let a_in = tape.rmsnorm(x, attn_norm, eps);
        let a_q = if quant { qat::fake_quant_act(tape, a_in) } else { a_in };
        let wq = lin_w(tape, wq_s, d, qd);
        let wk = lin_w(tape, wk_s, d, kvd);
        let wv = lin_w(tape, wv_s, d, kvd);
        let q = tape.matmul(a_q, wq);
        let k = tape.matmul(a_q, wk);
        let v = tape.matmul(a_q, wv);

        if capture_layer == li as i32 {
            let k_rep = if rep > 1 { tape.repeat_heads(k, hd, rep) } else { k };
            let v_rep = if rep > 1 { tape.repeat_heads(v, hd, rep) } else { v };
            states = Some([q, k_rep, v_rep]);
        }

        let qr = tape.rope(q, nh, hd, t, theta);
        let kr = tape.rope(k, nkv, hd, t, theta);
        let mut attn = tape.attention(qr, kr, v, b, t, nh, nkv, hd);
        if let Some(sa) = sub_a {
            let g = norm_slice(tape, sa, li, qd);
            attn = tape.rmsnorm(attn, g, eps); // SubLN, eq. (4)
        }
        let attn_q = if quant { qat::fake_quant_act(tape, attn) } else { attn };
        let wo = lin_w(tape, wo_s, qd, d);
        let o = tape.matmul(attn_q, wo);
        x = tape.add(x, o);

        // ---- FFN ----
        let ffn_norm = norm_slice(tape, w_ffn_norm, li, d);
        let f_in = tape.rmsnorm(x, ffn_norm, eps);
        let f_q = if quant { qat::fake_quant_act(tape, f_in) } else { f_in };
        let wg = lin_w(tape, wg_s, d, ff);
        let wu = lin_w(tape, wu_s, d, ff);
        let gate = tape.matmul(f_q, wg);
        let up = tape.matmul(f_q, wu);
        let act = if cfg.act == "silu" { tape.silu(gate) } else { tape.gelu(gate) };
        let mut ffv = tape.mul(up, act);
        if let Some(sf) = sub_f {
            let g = norm_slice(tape, sf, li, ff);
            ffv = tape.rmsnorm(ffv, g, eps); // SubLN, eq. (5)
        }
        let ff_q = if quant { qat::fake_quant_act(tape, ffv) } else { ffv };
        let wd = lin_w(tape, wd_s, ff, d);
        let down = tape.matmul(ff_q, wd);
        x = tape.add(x, down);
    }

    let final_norm = get(ids, "final_norm")?;
    let xf = tape.rmsnorm(x, final_norm, eps);
    // LM head stays full-precision, as in Layer 2
    let logits = if cfg.tie_embeddings {
        tape.matmul_t(xf, embed)
    } else {
        tape.matmul(xf, get(ids, "lm_head")?)
    };
    Ok(ForwardOut { logits, states })
}

/// Convenience: run a no-gradient forward and return the logits (and
/// captured states) as plain vectors — the teacher path of the distill
/// step and the eval helper for tests. Uses an evaluation-only tape
/// (no gradient buffers).
pub fn forward_values(
    cfg: &ModelCfg,
    store: &ParamStore,
    tokens: &[i32],
    b: usize,
    t: usize,
    capture_layer: i32,
) -> Result<(Vec<f32>, Option<[Vec<f32>; 3]>)> {
    let mut tape = Tape::no_grad();
    let ids = register_params(&mut tape, store);
    let out = forward(&mut tape, cfg, &ids, tokens, b, t, capture_layer)?;
    let logits = tape.value(out.logits).to_vec();
    let states = out.states.map(|s| {
        [
            tape.value(s[0]).to_vec(),
            tape.value(s[1]).to_vec(),
            tape.value(s[2]).to_vec(),
        ]
    });
    Ok((logits, states))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::model::mini_model;
    use crate::engine::Engine;

    #[test]
    fn f32_forward_matches_engine_logits() {
        // The train-side forward and the deployment engine must agree in
        // full precision — this anchors the train -> export path.
        for tie in [true, false] {
            let (spec, store) = mini_model(true, tie);
            let mut cfg = spec.config.clone();
            cfg.quant_method = "none".into(); // f32 forward
            let tokens = [1i32, 5, 9, 2, 7, 3];
            let (logits, _) =
                forward_values(&cfg, &store, &tokens, 1, tokens.len(), -1).unwrap();
            let engine = Engine::from_params(&spec, &store, false).unwrap();
            let want = engine.forward_logits(&tokens);
            for (pos, row) in want.iter().enumerate() {
                for (v, (&a, &b)) in
                    row.iter().zip(&logits[pos * cfg.vocab..(pos + 1) * cfg.vocab]).enumerate()
                {
                    assert!(
                        (a - b).abs() <= 1e-3 * a.abs().max(1.0),
                        "tie={tie} pos={pos} vocab={v}: engine {a} vs tape {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn qat_forward_matches_ternary_engine() {
        // The fake-quant (STE) forward computes the same function as the
        // packed-ternary engine: Q_w and Q_act are identical lattices.
        let (spec, store) = mini_model(true, true);
        let cfg = spec.config.clone(); // quant_method = absmean
        let tokens = [3i32, 9, 1, 7];
        let (logits, _) = forward_values(&cfg, &store, &tokens, 1, tokens.len(), -1).unwrap();
        let engine = Engine::from_params(&spec, &store, true).unwrap();
        let want = engine.forward_logits(&tokens);
        for (pos, row) in want.iter().enumerate() {
            for (v, (&a, &b)) in
                row.iter().zip(&logits[pos * cfg.vocab..(pos + 1) * cfg.vocab]).enumerate()
            {
                assert!(
                    (a - b).abs() <= 5e-3 * a.abs().max(1.0),
                    "pos={pos} vocab={v}: ternary engine {a} vs QAT tape {b}"
                );
            }
        }
    }

    #[test]
    fn batched_rows_are_independent_sequences() {
        // rows of the [b, t] batch must not attend across each other
        let (spec, store) = mini_model(true, true);
        let mut cfg = spec.config.clone();
        cfg.quant_method = "none".into();
        let seq_a = [1i32, 5, 9];
        let seq_b = [7i32, 2, 4];
        let both: Vec<i32> = seq_a.iter().chain(&seq_b).copied().collect();
        let (solo, _) = forward_values(&cfg, &store, &seq_a, 1, 3, -1).unwrap();
        let (batched, _) = forward_values(&cfg, &store, &both, 2, 3, -1).unwrap();
        for i in 0..solo.len() {
            assert!((solo[i] - batched[i]).abs() < 1e-5, "lane 0 diverged at {i}");
        }
    }

    #[test]
    fn captured_states_have_full_head_width() {
        let (spec, store) = mini_model(true, true); // 2 heads, 1 kv head
        let cfg = spec.config.clone();
        let tokens = [1i32, 2, 3, 4];
        let (_, states) = forward_values(&cfg, &store, &tokens, 1, 4, 1).unwrap();
        let s = states.expect("layer 1 exists");
        for part in &s {
            assert_eq!(part.len(), 4 * cfg.q_dim(), "states repeated to full heads");
        }
        // out-of-range layer captures nothing
        let (_, none) = forward_values(&cfg, &store, &tokens, 1, 4, -1).unwrap();
        assert!(none.is_none());
    }
}
