//! Reverse-mode autograd over host f32 tensors — the native training
//! substrate (no PJRT, no artifacts).
//!
//! A [`Tape`] is a linear record of operations: every op computes its
//! value eagerly at construction and is replayed in reverse by
//! [`Tape::backward`], accumulating gradients into per-node buffers.
//! The op set is exactly what the BitDistill forward + losses need
//! (matmul, rmsnorm/SubLN, rope, softmax, causal GQA attention,
//! silu/gelu, embedding, CE, logits-KL, MiniLM relation-KL) plus a
//! generic [`Tape::ste`] node whose backward is identity — the seam the
//! QAT fake-quantizers ([`crate::train::qat`]) plug into.
//!
//! Gradient accumulation across micro-batches happens *outside* the
//! tape: one tape per micro-batch, grads summed by
//! [`crate::train::optim::GradAccum`]. Every op here is covered by a
//! finite-difference gradient check in the test module below.

/// Handle to a node on the tape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorId(pub usize);

enum Op {
    Leaf,
    Add(TensorId, TensorId),
    Mul(TensorId, TensorId),
    Scale(TensorId, f32),
    /// Elementwise weighted sum of same-shape nodes (loss combination).
    AddScaled(Vec<(TensorId, f32)>),
    /// Contiguous sub-range view (per-layer slice of a stacked tensor).
    Slice { x: TensorId, offset: usize },
    /// y[n, m] = x[n, k] @ w[k, m] (the checkpoint x@W orientation).
    Matmul { x: TensorId, w: TensorId, n: usize, k: usize, m: usize },
    /// y[n, m] = x[n, k] @ w[m, k]^T (tied-embedding LM head).
    MatmulT { x: TensorId, w: TensorId, n: usize, k: usize, m: usize },
    /// Row gather: y[i, :] = table[tokens[i], :].
    Embedding { table: TensorId, tokens: Vec<i32>, d: usize },
    /// Per-row RMS normalization with a gain vector (also SubLN).
    RmsNorm { x: TensorId, gain: TensorId, rows: usize, dim: usize, eps: f32 },
    /// Rotate-half RoPE per head; row r sits at position r % seq.
    Rope { x: TensorId, heads: usize, half: usize, seq: usize, cos: Vec<f32>, sin: Vec<f32> },
    Silu(TensorId),
    Gelu(TensorId),
    /// Row softmax over the last dim.
    SoftmaxRows { x: TensorId, rows: usize, dim: usize },
    /// Causal GQA attention over [b*t, heads*hd] rows; saves the probs.
    Attention {
        q: TensorId,
        k: TensorId,
        v: TensorId,
        b: usize,
        t: usize,
        heads: usize,
        kv_heads: usize,
        hd: usize,
        probs: Vec<f32>,
    },
    /// GQA head repeat: out head j = in head j / rep (jnp.repeat order).
    RepeatHeads { x: TensorId, hd: usize, rep: usize },
    /// Straight-through estimator: forward an externally computed value,
    /// backward identity.
    Ste { x: TensorId },
    /// scalar = sum_i weights[i] * x[i] (test scalarizer).
    WeightedSum { x: TensorId, weights: Vec<f32> },
    /// Mean CE over rows whose label is >= 0 (IGNORE = negative).
    CrossEntropy { logits: TensorId, labels: Vec<i32>, rows: usize, vocab: usize },
    /// Mean KL(teacher || student) at temperature tau over masked rows;
    /// `teacher_logp` are precomputed teacher log-probs (constants).
    KlTeacher {
        logits: TensorId,
        teacher_logp: Vec<f32>,
        mask: Vec<bool>,
        tau: f32,
        rows: usize,
        vocab: usize,
    },
    /// MiniLM attention-relation KL against constant teacher relation
    /// log-probs [b, split, t, t]; state rows are [b*t, split*d].
    RelationKl {
        state: TensorId,
        teacher_logp: Vec<f32>,
        b: usize,
        t: usize,
        split: usize,
        d: usize,
    },
}

pub struct Tape {
    shapes: Vec<Vec<usize>>,
    vals: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    ops: Vec<Op>,
    /// Evaluation-only: skip gradient-buffer allocation (teacher passes).
    no_grad: bool,
}

const NORM_FLOOR: f32 = 1e-8;

fn silu_f(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

fn gelu_f(v: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh())
}

/// Stable per-row log-softmax (shared by CE / KL forward and backward,
/// and by the host-side teacher computations in [`crate::train::losses`]).
pub(crate) fn log_softmax_row(row: &[f32], out: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for &v in row {
        z += (v - m).exp();
    }
    let lz = z.ln() + m;
    for (o, &v) in out.iter_mut().zip(row) {
        *o = v - lz;
    }
}

impl Default for Tape {
    fn default() -> Self {
        Tape::new()
    }
}

impl Tape {
    pub fn new() -> Tape {
        Tape {
            shapes: Vec::new(),
            vals: Vec::new(),
            grads: Vec::new(),
            ops: Vec::new(),
            no_grad: false,
        }
    }

    /// Evaluation-only tape: no gradient buffers are allocated (roughly
    /// halves the memory of a forward), and [`Tape::backward`] is
    /// unavailable. Used for the stop-gradient teacher passes.
    pub fn no_grad() -> Tape {
        Tape { no_grad: true, ..Tape::new() }
    }

    fn push(&mut self, shape: Vec<usize>, val: Vec<f32>, op: Op) -> TensorId {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), val.len());
        let id = TensorId(self.ops.len());
        self.grads.push(if self.no_grad { Vec::new() } else { vec![0.0; val.len()] });
        self.shapes.push(shape);
        self.vals.push(val);
        self.ops.push(op);
        id
    }

    pub fn n_nodes(&self) -> usize {
        self.ops.len()
    }

    pub fn value(&self, id: TensorId) -> &[f32] {
        &self.vals[id.0]
    }

    pub fn grad(&self, id: TensorId) -> &[f32] {
        &self.grads[id.0]
    }

    pub fn shape(&self, id: TensorId) -> &[usize] {
        &self.shapes[id.0]
    }

    pub fn scalar(&self, id: TensorId) -> f32 {
        self.vals[id.0][0]
    }

    // ------------------------------------------------------------------
    // op constructors (forward runs eagerly)
    // ------------------------------------------------------------------

    pub fn leaf(&mut self, shape: &[usize], data: Vec<f32>) -> TensorId {
        self.push(shape.to_vec(), data, Op::Leaf)
    }

    pub fn add(&mut self, a: TensorId, b: TensorId) -> TensorId {
        assert_eq!(self.shapes[a.0], self.shapes[b.0], "add shape mismatch");
        let val: Vec<f32> =
            self.vals[a.0].iter().zip(&self.vals[b.0]).map(|(x, y)| x + y).collect();
        self.push(self.shapes[a.0].clone(), val, Op::Add(a, b))
    }

    pub fn mul(&mut self, a: TensorId, b: TensorId) -> TensorId {
        assert_eq!(self.shapes[a.0], self.shapes[b.0], "mul shape mismatch");
        let val: Vec<f32> =
            self.vals[a.0].iter().zip(&self.vals[b.0]).map(|(x, y)| x * y).collect();
        self.push(self.shapes[a.0].clone(), val, Op::Mul(a, b))
    }

    pub fn scale(&mut self, a: TensorId, c: f32) -> TensorId {
        let val: Vec<f32> = self.vals[a.0].iter().map(|x| x * c).collect();
        self.push(self.shapes[a.0].clone(), val, Op::Scale(a, c))
    }

    pub fn add_scaled(&mut self, terms: &[(TensorId, f32)]) -> TensorId {
        assert!(!terms.is_empty());
        let shape = self.shapes[terms[0].0 .0].clone();
        let mut val = vec![0.0f32; self.vals[terms[0].0 .0].len()];
        for &(id, c) in terms {
            assert_eq!(self.shapes[id.0], shape, "add_scaled shape mismatch");
            for (o, &v) in val.iter_mut().zip(&self.vals[id.0]) {
                *o += c * v;
            }
        }
        self.push(shape, val, Op::AddScaled(terms.to_vec()))
    }

    /// View of `len(shape)` contiguous elements starting at `offset`.
    pub fn slice(&mut self, x: TensorId, offset: usize, shape: &[usize]) -> TensorId {
        let len: usize = shape.iter().product();
        assert!(offset + len <= self.vals[x.0].len(), "slice out of range");
        let val = self.vals[x.0][offset..offset + len].to_vec();
        self.push(shape.to_vec(), val, Op::Slice { x, offset })
    }

    pub fn matmul(&mut self, x: TensorId, w: TensorId) -> TensorId {
        let (xs, ws) = (&self.shapes[x.0], &self.shapes[w.0]);
        assert_eq!(xs.len(), 2, "matmul x must be 2-D");
        assert_eq!(ws.len(), 2, "matmul w must be 2-D");
        let (n, k, m) = (xs[0], xs[1], ws[1]);
        assert_eq!(ws[0], k, "matmul inner dim mismatch");
        let (xv, wv) = (&self.vals[x.0], &self.vals[w.0]);
        let mut y = vec![0.0f32; n * m];
        for i in 0..n {
            let yi = &mut y[i * m..(i + 1) * m];
            for kk in 0..k {
                let a = xv[i * k + kk];
                if a != 0.0 {
                    let wr = &wv[kk * m..(kk + 1) * m];
                    for j in 0..m {
                        yi[j] += a * wr[j];
                    }
                }
            }
        }
        self.push(vec![n, m], y, Op::Matmul { x, w, n, k, m })
    }

    pub fn matmul_t(&mut self, x: TensorId, w: TensorId) -> TensorId {
        let (xs, ws) = (&self.shapes[x.0], &self.shapes[w.0]);
        assert_eq!(xs.len(), 2);
        assert_eq!(ws.len(), 2);
        let (n, k, m) = (xs[0], xs[1], ws[0]);
        assert_eq!(ws[1], k, "matmul_t inner dim mismatch");
        let (xv, wv) = (&self.vals[x.0], &self.vals[w.0]);
        let mut y = vec![0.0f32; n * m];
        for i in 0..n {
            let xr = &xv[i * k..(i + 1) * k];
            for j in 0..m {
                let wr = &wv[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for e in 0..k {
                    acc += xr[e] * wr[e];
                }
                y[i * m + j] = acc;
            }
        }
        self.push(vec![n, m], y, Op::MatmulT { x, w, n, k, m })
    }

    pub fn embedding(&mut self, table: TensorId, tokens: &[i32]) -> TensorId {
        let ts = &self.shapes[table.0];
        assert_eq!(ts.len(), 2, "embedding table must be 2-D");
        let (vocab, d) = (ts[0], ts[1]);
        let tv = &self.vals[table.0];
        let mut y = vec![0.0f32; tokens.len() * d];
        for (i, &tk) in tokens.iter().enumerate() {
            let tk = tk as usize;
            assert!(tk < vocab, "token {tk} out of vocab {vocab}");
            y[i * d..(i + 1) * d].copy_from_slice(&tv[tk * d..(tk + 1) * d]);
        }
        self.push(
            vec![tokens.len(), d],
            y,
            Op::Embedding { table, tokens: tokens.to_vec(), d },
        )
    }

    pub fn rmsnorm(&mut self, x: TensorId, gain: TensorId, eps: f32) -> TensorId {
        let xs = &self.shapes[x.0];
        assert_eq!(xs.len(), 2, "rmsnorm x must be 2-D");
        let (rows, dim) = (xs[0], xs[1]);
        assert_eq!(self.vals[gain.0].len(), dim, "rmsnorm gain dim mismatch");
        let (xv, gv) = (&self.vals[x.0], &self.vals[gain.0]);
        let mut y = vec![0.0f32; rows * dim];
        for r in 0..rows {
            let xr = &xv[r * dim..(r + 1) * dim];
            let ms = xr.iter().map(|v| v * v).sum::<f32>() / dim as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            for i in 0..dim {
                y[r * dim + i] = xr[i] * inv * gv[i];
            }
        }
        self.push(vec![rows, dim], y, Op::RmsNorm { x, gain, rows, dim, eps })
    }

    /// Rotate-half RoPE matching [`crate::engine::Engine`]'s tables:
    /// freq_i = theta^{-i/half}, row r is at position r % seq.
    pub fn rope(&mut self, x: TensorId, heads: usize, hd: usize, seq: usize, theta: f32) -> TensorId {
        let xs = &self.shapes[x.0];
        assert_eq!(xs.len(), 2);
        let (rows, width) = (xs[0], xs[1]);
        assert_eq!(width, heads * hd, "rope width mismatch");
        assert_eq!(rows % seq, 0, "rope rows must be a multiple of seq");
        let half = hd / 2;
        let mut cos = vec![0.0f32; seq * half];
        let mut sin = vec![0.0f32; seq * half];
        for p in 0..seq {
            for i in 0..half {
                let freq = 1.0 / theta.powf(i as f32 / half as f32);
                let ang = p as f32 * freq;
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        let xv = &self.vals[x.0];
        let mut y = xv.clone();
        for r in 0..rows {
            let pos = r % seq;
            for h in 0..heads {
                let base = r * width + h * hd;
                for i in 0..half {
                    let (a, b) = (xv[base + i], xv[base + half + i]);
                    let (c, s) = (cos[pos * half + i], sin[pos * half + i]);
                    y[base + i] = a * c - b * s;
                    y[base + half + i] = a * s + b * c;
                }
            }
        }
        self.push(vec![rows, width], y, Op::Rope { x, heads, half, seq, cos, sin })
    }

    pub fn silu(&mut self, x: TensorId) -> TensorId {
        let val: Vec<f32> = self.vals[x.0].iter().map(|&v| silu_f(v)).collect();
        self.push(self.shapes[x.0].clone(), val, Op::Silu(x))
    }

    pub fn gelu(&mut self, x: TensorId) -> TensorId {
        let val: Vec<f32> = self.vals[x.0].iter().map(|&v| gelu_f(v)).collect();
        self.push(self.shapes[x.0].clone(), val, Op::Gelu(x))
    }

    pub fn softmax_rows(&mut self, x: TensorId) -> TensorId {
        let xs = &self.shapes[x.0];
        assert_eq!(xs.len(), 2);
        let (rows, dim) = (xs[0], xs[1]);
        let xv = &self.vals[x.0];
        let mut y = vec![0.0f32; rows * dim];
        for r in 0..rows {
            log_softmax_row(&xv[r * dim..(r + 1) * dim], &mut y[r * dim..(r + 1) * dim]);
            for v in &mut y[r * dim..(r + 1) * dim] {
                *v = v.exp();
            }
        }
        self.push(vec![rows, dim], y, Op::SoftmaxRows { x, rows, dim })
    }

    /// Causal GQA attention. `q`: [b*t, heads*hd] (post-RoPE), `k`/`v`:
    /// [b*t, kv_heads*hd]; query head h attends kv head h / (heads/kv).
    pub fn attention(
        &mut self,
        q: TensorId,
        k: TensorId,
        v: TensorId,
        b: usize,
        t: usize,
        heads: usize,
        kv_heads: usize,
        hd: usize,
    ) -> TensorId {
        let (qd, kvd) = (heads * hd, kv_heads * hd);
        assert_eq!(self.shapes[q.0], vec![b * t, qd], "attention q shape");
        assert_eq!(self.shapes[k.0], vec![b * t, kvd], "attention k shape");
        assert_eq!(self.shapes[v.0], vec![b * t, kvd], "attention v shape");
        let rep = heads / kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let (qv, kv, vv) = (&self.vals[q.0], &self.vals[k.0], &self.vals[v.0]);
        let mut probs = vec![0.0f32; b * heads * t * t];
        let mut y = vec![0.0f32; b * t * qd];
        let mut scores = vec![0.0f32; t];
        for bi in 0..b {
            for h in 0..heads {
                let kh = h / rep;
                for ti in 0..t {
                    let qrow = &qv[(bi * t + ti) * qd + h * hd..(bi * t + ti) * qd + (h + 1) * hd];
                    for u in 0..=ti {
                        let krow =
                            &kv[(bi * t + u) * kvd + kh * hd..(bi * t + u) * kvd + (kh + 1) * hd];
                        let mut dot = 0.0f32;
                        for e in 0..hd {
                            dot += qrow[e] * krow[e];
                        }
                        scores[u] = dot * scale;
                    }
                    let m = scores[..=ti].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for s in &mut scores[..=ti] {
                        *s = (*s - m).exp();
                        z += *s;
                    }
                    let inv_z = 1.0 / z;
                    let pbase = ((bi * heads + h) * t + ti) * t;
                    let out =
                        &mut y[(bi * t + ti) * qd + h * hd..(bi * t + ti) * qd + (h + 1) * hd];
                    for u in 0..=ti {
                        let p = scores[u] * inv_z;
                        probs[pbase + u] = p;
                        let vrow =
                            &vv[(bi * t + u) * kvd + kh * hd..(bi * t + u) * kvd + (kh + 1) * hd];
                        for e in 0..hd {
                            out[e] += p * vrow[e];
                        }
                    }
                }
            }
        }
        self.push(
            vec![b * t, qd],
            y,
            Op::Attention { q, k, v, b, t, heads, kv_heads, hd, probs },
        )
    }

    pub fn repeat_heads(&mut self, x: TensorId, hd: usize, rep: usize) -> TensorId {
        let xs = &self.shapes[x.0];
        assert_eq!(xs.len(), 2);
        let (rows, width) = (xs[0], xs[1]);
        assert_eq!(width % hd, 0);
        let in_heads = width / hd;
        let xv = &self.vals[x.0];
        let mut y = vec![0.0f32; rows * width * rep];
        for r in 0..rows {
            for j in 0..in_heads * rep {
                let src = r * width + (j / rep) * hd;
                let dst = r * width * rep + j * hd;
                y[dst..dst + hd].copy_from_slice(&xv[src..src + hd]);
            }
        }
        self.push(vec![rows, width * rep], y, Op::RepeatHeads { x, hd, rep })
    }

    /// STE node: forward the supplied `value` (e.g. a fake-quantized copy
    /// of `x`), backward identity. `value.len()` must match `x`.
    pub fn ste(&mut self, x: TensorId, value: Vec<f32>) -> TensorId {
        assert_eq!(value.len(), self.vals[x.0].len(), "ste value length");
        self.push(self.shapes[x.0].clone(), value, Op::Ste { x })
    }

    pub fn weighted_sum(&mut self, x: TensorId, weights: Vec<f32>) -> TensorId {
        assert_eq!(weights.len(), self.vals[x.0].len());
        let s: f32 = self.vals[x.0].iter().zip(&weights).map(|(v, w)| v * w).sum();
        self.push(vec![], vec![s], Op::WeightedSum { x, weights })
    }

    /// Mean cross-entropy over rows with label >= 0 (negative = IGNORE).
    pub fn cross_entropy(&mut self, logits: TensorId, labels: &[i32]) -> TensorId {
        let ls = &self.shapes[logits.0];
        assert_eq!(ls.len(), 2);
        let (rows, vocab) = (ls[0], ls[1]);
        assert_eq!(labels.len(), rows, "labels/rows mismatch");
        let lv = &self.vals[logits.0];
        let mut logp = vec![0.0f32; vocab];
        let mut total = 0.0f32;
        let mut n = 0usize;
        for (r, &lab) in labels.iter().enumerate() {
            if lab < 0 {
                continue;
            }
            log_softmax_row(&lv[r * vocab..(r + 1) * vocab], &mut logp);
            total -= logp[lab as usize];
            n += 1;
        }
        let loss = total / n.max(1) as f32;
        self.push(
            vec![],
            vec![loss],
            Op::CrossEntropy { logits, labels: labels.to_vec(), rows, vocab },
        )
    }

    /// Mean KL(P_teacher^tau || P_student^tau) over masked rows.
    /// `teacher_logp` is the teacher's log-softmax at temperature tau
    /// ([rows, vocab], constant — no gradient flows to the teacher).
    pub fn kl_teacher(
        &mut self,
        logits: TensorId,
        teacher_logp: Vec<f32>,
        mask: Vec<bool>,
        tau: f32,
    ) -> TensorId {
        let ls = &self.shapes[logits.0];
        assert_eq!(ls.len(), 2);
        let (rows, vocab) = (ls[0], ls[1]);
        assert_eq!(teacher_logp.len(), rows * vocab);
        assert_eq!(mask.len(), rows);
        let lv = &self.vals[logits.0];
        let mut srow = vec![0.0f32; vocab];
        let mut slogp = vec![0.0f32; vocab];
        let mut total = 0.0f32;
        let mut n = 0usize;
        for r in 0..rows {
            if !mask[r] {
                continue;
            }
            for (s, &l) in srow.iter_mut().zip(&lv[r * vocab..(r + 1) * vocab]) {
                *s = l / tau;
            }
            log_softmax_row(&srow, &mut slogp);
            let tl = &teacher_logp[r * vocab..(r + 1) * vocab];
            for v in 0..vocab {
                total += tl[v].exp() * (tl[v] - slogp[v]);
            }
            n += 1;
        }
        let loss = total / n.max(1) as f32;
        self.push(
            vec![],
            vec![loss],
            Op::KlTeacher { logits, teacher_logp, mask, tau, rows, vocab },
        )
    }

    /// MiniLM relation KL (eq. 10-12): student `state` rows [b*t, split*d]
    /// against constant teacher relation log-probs [b, split, t, t]
    /// (from [`relation_logprobs_of`]). Mean over (b, split, t).
    pub fn relation_kl(
        &mut self,
        state: TensorId,
        teacher_logp: Vec<f32>,
        b: usize,
        t: usize,
        split: usize,
    ) -> TensorId {
        let ss = &self.shapes[state.0];
        assert_eq!(ss.len(), 2);
        assert_eq!(ss[0], b * t, "relation state rows");
        assert_eq!(ss[1] % split, 0, "relation width not divisible by split");
        let d = ss[1] / split;
        assert_eq!(teacher_logp.len(), b * split * t * t);
        let sl = relation_logprobs_of(&self.vals[state.0], b, t, split, d);
        let mut total = 0.0f32;
        for i in 0..teacher_logp.len() {
            let tl = teacher_logp[i];
            total += tl.exp() * (tl - sl[i]);
        }
        let loss = total / (b * split * t) as f32;
        self.push(vec![], vec![loss], Op::RelationKl { state, teacher_logp, b, t, split, d })
    }

    // ------------------------------------------------------------------
    // backward
    // ------------------------------------------------------------------

    /// Reverse sweep from `loss` (seeded with 1.0). Grads accumulate into
    /// every node reachable from the loss; leaves keep theirs for
    /// collection by the optimizer.
    pub fn backward(&mut self, loss: TensorId) {
        assert!(!self.no_grad, "backward on a no-grad (evaluation) tape");
        assert_eq!(self.vals[loss.0].len(), 1, "backward seeds a scalar");
        self.grads[loss.0][0] = 1.0;
        for i in (0..self.ops.len()).rev() {
            let go = std::mem::take(&mut self.grads[i]);
            if go.iter().all(|&v| v == 0.0) {
                self.grads[i] = go;
                continue;
            }
            let op = std::mem::replace(&mut self.ops[i], Op::Leaf);
            self.backprop_one(&op, &go);
            self.ops[i] = op;
            self.grads[i] = go;
        }
    }

    fn backprop_one(&mut self, op: &Op, go: &[f32]) {
        match op {
            Op::Leaf => {}
            Op::Add(a, b) => {
                for (g, &v) in self.grads[a.0].iter_mut().zip(go) {
                    *g += v;
                }
                for (g, &v) in self.grads[b.0].iter_mut().zip(go) {
                    *g += v;
                }
            }
            Op::Mul(a, b) => {
                let (a, b) = (*a, *b);
                for j in 0..go.len() {
                    self.grads[a.0][j] += go[j] * self.vals[b.0][j];
                }
                for j in 0..go.len() {
                    self.grads[b.0][j] += go[j] * self.vals[a.0][j];
                }
            }
            Op::Scale(a, c) => {
                for (g, &v) in self.grads[a.0].iter_mut().zip(go) {
                    *g += c * v;
                }
            }
            Op::AddScaled(terms) => {
                for &(id, c) in terms {
                    for (g, &v) in self.grads[id.0].iter_mut().zip(go) {
                        *g += c * v;
                    }
                }
            }
            Op::Slice { x, offset } => {
                let dst = &mut self.grads[x.0][*offset..*offset + go.len()];
                for (g, &v) in dst.iter_mut().zip(go) {
                    *g += v;
                }
            }
            Op::Matmul { x, w, n, k, m } => {
                let (n, k, m) = (*n, *k, *m);
                // dx[i, kk] += go[i, :] . w[kk, :]
                {
                    let wv = &self.vals[w.0];
                    let gx = &mut self.grads[x.0];
                    for i in 0..n {
                        let gr = &go[i * m..(i + 1) * m];
                        for kk in 0..k {
                            let wr = &wv[kk * m..(kk + 1) * m];
                            let mut acc = 0.0f32;
                            for j in 0..m {
                                acc += gr[j] * wr[j];
                            }
                            gx[i * k + kk] += acc;
                        }
                    }
                }
                // dw[kk, :] += sum_i x[i, kk] * go[i, :]
                {
                    let xv = &self.vals[x.0];
                    let gw = &mut self.grads[w.0];
                    for i in 0..n {
                        let gr = &go[i * m..(i + 1) * m];
                        for kk in 0..k {
                            let a = xv[i * k + kk];
                            if a != 0.0 {
                                let wr = &mut gw[kk * m..(kk + 1) * m];
                                for j in 0..m {
                                    wr[j] += a * gr[j];
                                }
                            }
                        }
                    }
                }
            }
            Op::MatmulT { x, w, n, k, m } => {
                let (n, k, m) = (*n, *k, *m);
                // dx[i, :] += sum_j go[i, j] * w[j, :]
                {
                    let wv = &self.vals[w.0];
                    let gx = &mut self.grads[x.0];
                    for i in 0..n {
                        let xr = &mut gx[i * k..(i + 1) * k];
                        for j in 0..m {
                            let g = go[i * m + j];
                            if g != 0.0 {
                                let wr = &wv[j * k..(j + 1) * k];
                                for e in 0..k {
                                    xr[e] += g * wr[e];
                                }
                            }
                        }
                    }
                }
                // dw[j, :] += sum_i go[i, j] * x[i, :]
                {
                    let xv = &self.vals[x.0];
                    let gw = &mut self.grads[w.0];
                    for i in 0..n {
                        let xr = &xv[i * k..(i + 1) * k];
                        for j in 0..m {
                            let g = go[i * m + j];
                            if g != 0.0 {
                                let wr = &mut gw[j * k..(j + 1) * k];
                                for e in 0..k {
                                    wr[e] += g * xr[e];
                                }
                            }
                        }
                    }
                }
            }
            Op::Embedding { table, tokens, d } => {
                let d = *d;
                let gt = &mut self.grads[table.0];
                for (i, &tk) in tokens.iter().enumerate() {
                    let dst = &mut gt[tk as usize * d..(tk as usize + 1) * d];
                    for (g, &v) in dst.iter_mut().zip(&go[i * d..(i + 1) * d]) {
                        *g += v;
                    }
                }
            }
            Op::RmsNorm { x, gain, rows, dim, eps } => {
                let (rows, dim, eps) = (*rows, *dim, *eps);
                let (x, gain) = (*x, *gain);
                for r in 0..rows {
                    let xr = &self.vals[x.0][r * dim..(r + 1) * dim];
                    let gv = &self.vals[gain.0];
                    let gr = &go[r * dim..(r + 1) * dim];
                    let ms = xr.iter().map(|v| v * v).sum::<f32>() / dim as f32;
                    let inv = 1.0 / (ms + eps).sqrt();
                    // s = sum_i go_i * g_i * x_i
                    let mut s = 0.0f32;
                    for i in 0..dim {
                        s += gr[i] * gv[i] * xr[i];
                    }
                    let c = inv * inv * inv * s / dim as f32;
                    let gx = &mut self.grads[x.0][r * dim..(r + 1) * dim];
                    for i in 0..dim {
                        gx[i] += inv * gv[i] * gr[i] - c * xr[i];
                    }
                    let gg = &mut self.grads[gain.0];
                    for i in 0..dim {
                        gg[i] += gr[i] * xr[i] * inv;
                    }
                }
            }
            Op::Rope { x, heads, half, seq, cos, sin } => {
                let (heads, half, seq) = (*heads, *half, *seq);
                let hd = 2 * half;
                let width = heads * hd;
                let rows = go.len() / width;
                let gx = &mut self.grads[x.0];
                for r in 0..rows {
                    let pos = r % seq;
                    for h in 0..heads {
                        let base = r * width + h * hd;
                        for i in 0..half {
                            let (ga, gb) = (go[base + i], go[base + half + i]);
                            let (c, s) = (cos[pos * half + i], sin[pos * half + i]);
                            // transpose (= inverse) of the rotation
                            gx[base + i] += ga * c + gb * s;
                            gx[base + half + i] += -ga * s + gb * c;
                        }
                    }
                }
            }
            Op::Silu(a) => {
                let a = *a;
                for j in 0..go.len() {
                    let v = self.vals[a.0][j];
                    let sig = 1.0 / (1.0 + (-v).exp());
                    self.grads[a.0][j] += go[j] * sig * (1.0 + v * (1.0 - sig));
                }
            }
            Op::Gelu(a) => {
                let a = *a;
                const C: f32 = 0.797_884_6;
                for j in 0..go.len() {
                    let v = self.vals[a.0][j];
                    let u = C * (v + 0.044715 * v * v * v);
                    let th = u.tanh();
                    let d = 0.5 * (1.0 + th)
                        + 0.5 * v * (1.0 - th * th) * C * (1.0 + 3.0 * 0.044715 * v * v);
                    self.grads[a.0][j] += go[j] * d;
                }
            }
            Op::SoftmaxRows { x, rows, dim } => {
                let (rows, dim) = (*rows, *dim);
                // recompute the row softmax from x (cheap; this op is not
                // on the model path — attention keeps its own saved probs)
                let x = *x;
                for r in 0..rows {
                    let xr = &self.vals[x.0][r * dim..(r + 1) * dim];
                    let mut y = vec![0.0f32; dim];
                    log_softmax_row(xr, &mut y);
                    for v in &mut y {
                        *v = v.exp();
                    }
                    let gr = &go[r * dim..(r + 1) * dim];
                    let dot: f32 = y.iter().zip(gr).map(|(a, b)| a * b).sum();
                    let gx = &mut self.grads[x.0][r * dim..(r + 1) * dim];
                    for i in 0..dim {
                        gx[i] += y[i] * (gr[i] - dot);
                    }
                }
            }
            Op::Attention { q, k, v, b, t, heads, kv_heads, hd, probs } => {
                let (b, t, heads, kv_heads, hd) = (*b, *t, *heads, *kv_heads, *hd);
                let (qd, kvd) = (heads * hd, kv_heads * hd);
                let rep = heads / kv_heads;
                let scale = 1.0 / (hd as f32).sqrt();
                let (q, k, v) = (*q, *k, *v);
                let mut dprob = vec![0.0f32; t];
                let mut dscore = vec![0.0f32; t];
                for bi in 0..b {
                    for h in 0..heads {
                        let kh = h / rep;
                        for ti in 0..t {
                            let gout = &go
                                [(bi * t + ti) * qd + h * hd..(bi * t + ti) * qd + (h + 1) * hd];
                            if gout.iter().all(|&g| g == 0.0) {
                                continue;
                            }
                            let pbase = ((bi * heads + h) * t + ti) * t;
                            // dV and dprobs
                            for u in 0..=ti {
                                let p = probs[pbase + u];
                                let vrow = &self.vals[v.0][(bi * t + u) * kvd + kh * hd
                                    ..(bi * t + u) * kvd + (kh + 1) * hd];
                                let mut dp = 0.0f32;
                                for e in 0..hd {
                                    dp += gout[e] * vrow[e];
                                }
                                dprob[u] = dp;
                                let gv = &mut self.grads[v.0][(bi * t + u) * kvd + kh * hd
                                    ..(bi * t + u) * kvd + (kh + 1) * hd];
                                for e in 0..hd {
                                    gv[e] += p * gout[e];
                                }
                            }
                            // softmax backward
                            let mut dot = 0.0f32;
                            for u in 0..=ti {
                                dot += probs[pbase + u] * dprob[u];
                            }
                            for u in 0..=ti {
                                dscore[u] = probs[pbase + u] * (dprob[u] - dot);
                            }
                            // dQ and dK
                            let qrow_base = (bi * t + ti) * qd + h * hd;
                            for u in 0..=ti {
                                let ds = dscore[u] * scale;
                                if ds == 0.0 {
                                    continue;
                                }
                                let krow = &self.vals[k.0][(bi * t + u) * kvd + kh * hd
                                    ..(bi * t + u) * kvd + (kh + 1) * hd];
                                let qrow =
                                    &self.vals[q.0][qrow_base..qrow_base + hd];
                                for e in 0..hd {
                                    self.grads[q.0][qrow_base + e] += ds * krow[e];
                                }
                                let gk = &mut self.grads[k.0][(bi * t + u) * kvd + kh * hd
                                    ..(bi * t + u) * kvd + (kh + 1) * hd];
                                for e in 0..hd {
                                    gk[e] += ds * qrow[e];
                                }
                            }
                        }
                    }
                }
            }
            Op::RepeatHeads { x, hd, rep } => {
                let (hd, rep) = (*hd, *rep);
                let rows = self.shapes[x.0][0];
                let in_width = self.shapes[x.0][1];
                let out_width = in_width * rep;
                let in_heads = in_width / hd;
                let gx = &mut self.grads[x.0];
                for r in 0..rows {
                    for j in 0..in_heads * rep {
                        let src = r * in_width + (j / rep) * hd;
                        let g = &go[r * out_width + j * hd..r * out_width + (j + 1) * hd];
                        for e in 0..hd {
                            gx[src + e] += g[e];
                        }
                    }
                }
            }
            Op::Ste { x } => {
                for (g, &v) in self.grads[x.0].iter_mut().zip(go) {
                    *g += v;
                }
            }
            Op::WeightedSum { x, weights } => {
                let g = go[0];
                for (gx, &w) in self.grads[x.0].iter_mut().zip(weights) {
                    *gx += g * w;
                }
            }
            Op::CrossEntropy { logits, labels, rows, vocab } => {
                let (rows, vocab) = (*rows, *vocab);
                let g = go[0];
                let n = labels.iter().filter(|&&l| l >= 0).count().max(1) as f32;
                let logits = *logits;
                let mut logp = vec![0.0f32; vocab];
                for (r, &lab) in labels.iter().enumerate().take(rows) {
                    if lab < 0 {
                        continue;
                    }
                    let lr = &self.vals[logits.0][r * vocab..(r + 1) * vocab];
                    log_softmax_row(lr, &mut logp);
                    let gl = &mut self.grads[logits.0][r * vocab..(r + 1) * vocab];
                    for v in 0..vocab {
                        let p = logp[v].exp();
                        gl[v] += g * p / n;
                    }
                    gl[lab as usize] -= g / n;
                }
            }
            Op::KlTeacher { logits, teacher_logp, mask, tau, rows, vocab } => {
                let (rows, vocab, tau) = (*rows, *vocab, *tau);
                let g = go[0];
                let n = mask.iter().filter(|&&m| m).count().max(1) as f32;
                let logits = *logits;
                let mut srow = vec![0.0f32; vocab];
                let mut slogp = vec![0.0f32; vocab];
                for r in 0..rows {
                    if !mask[r] {
                        continue;
                    }
                    let lr = &self.vals[logits.0][r * vocab..(r + 1) * vocab];
                    for (s, &l) in srow.iter_mut().zip(lr) {
                        *s = l / tau;
                    }
                    log_softmax_row(&srow, &mut slogp);
                    let tl = &teacher_logp[r * vocab..(r + 1) * vocab];
                    let gl = &mut self.grads[logits.0][r * vocab..(r + 1) * vocab];
                    for v in 0..vocab {
                        gl[v] += g * (slogp[v].exp() - tl[v].exp()) / (tau * n);
                    }
                }
            }
            Op::RelationKl { state, teacher_logp, b, t, split, d } => {
                let (b, t, split, d) = (*b, *t, *split, *d);
                let g = go[0];
                let norm = (b * split * t) as f32;
                let state = *state;
                let inv_sqrt_d = 1.0 / (d as f32).sqrt();
                let width = split * d;
                for bi in 0..b {
                    for s in 0..split {
                        // gather u (normalized rows) and raw norms
                        let mut u = vec![0.0f32; t * d];
                        let mut norms = vec![0.0f32; t];
                        for ti in 0..t {
                            let v = &self.vals[state.0]
                                [(bi * t + ti) * width + s * d..(bi * t + ti) * width + (s + 1) * d];
                            let nn = v.iter().map(|x| x * x).sum::<f32>().sqrt();
                            let nc = nn.max(NORM_FLOOR);
                            norms[ti] = nn;
                            for e in 0..d {
                                u[ti * d + e] = v[e] / nc;
                            }
                        }
                        // rel + student probs per row
                        let mut rel = vec![0.0f32; t * t];
                        for ti in 0..t {
                            for ui in 0..t {
                                let mut dot = 0.0f32;
                                for e in 0..d {
                                    dot += u[ti * d + e] * u[ui * d + e];
                                }
                                rel[ti * t + ui] = dot * inv_sqrt_d;
                            }
                        }
                        let mut ps = vec![0.0f32; t * t];
                        for ti in 0..t {
                            log_softmax_row(
                                &rel[ti * t..(ti + 1) * t],
                                &mut ps[ti * t..(ti + 1) * t],
                            );
                            for v in &mut ps[ti * t..(ti + 1) * t] {
                                *v = v.exp();
                            }
                        }
                        // d rel = g * (ps - pt) / norm
                        let tbase = (bi * split + s) * t * t;
                        let mut drel = vec![0.0f32; t * t];
                        for i in 0..t * t {
                            drel[i] = g * (ps[i] - teacher_logp[tbase + i].exp()) / norm;
                        }
                        // d u[ti] = sum_ui (drel[ti,ui] + drel[ui,ti]) u[ui] / sqrt(d)
                        let mut du = vec![0.0f32; t * d];
                        for ti in 0..t {
                            for ui in 0..t {
                                let c = (drel[ti * t + ui] + drel[ui * t + ti]) * inv_sqrt_d;
                                if c != 0.0 {
                                    for e in 0..d {
                                        du[ti * d + e] += c * u[ui * d + e];
                                    }
                                }
                            }
                        }
                        // d v = (du - u (u . du)) / ||v||   (clamped: du/eps)
                        for ti in 0..t {
                            let gs = &mut self.grads[state.0]
                                [(bi * t + ti) * width + s * d..(bi * t + ti) * width + (s + 1) * d];
                            if norms[ti] > NORM_FLOOR {
                                let mut dot = 0.0f32;
                                for e in 0..d {
                                    dot += u[ti * d + e] * du[ti * d + e];
                                }
                                for e in 0..d {
                                    gs[e] += (du[ti * d + e] - u[ti * d + e] * dot) / norms[ti];
                                }
                            } else {
                                for e in 0..d {
                                    gs[e] += du[ti * d + e] / NORM_FLOOR;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Relation log-probs of one state tensor ([b*t, split*d] rows):
/// regroup into `split` relation heads, L2-normalize, scaled dot-product
/// by sqrt(d), log-softmax over keys. Mirrors
/// `python/compile/losses.py::_relation_logprobs`. Shared by the tape op
/// (student side, with gradients) and the host-side teacher computation.
pub fn relation_logprobs_of(state: &[f32], b: usize, t: usize, split: usize, d: usize) -> Vec<f32> {
    assert_eq!(state.len(), b * t * split * d);
    let width = split * d;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; b * split * t * t];
    let mut u = vec![0.0f32; t * d];
    let mut rel = vec![0.0f32; t];
    for bi in 0..b {
        for s in 0..split {
            for ti in 0..t {
                let v = &state[(bi * t + ti) * width + s * d..(bi * t + ti) * width + (s + 1) * d];
                let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(NORM_FLOOR);
                for e in 0..d {
                    u[ti * d + e] = v[e] / n;
                }
            }
            for ti in 0..t {
                for ui in 0..t {
                    let mut dot = 0.0f32;
                    for e in 0..d {
                        dot += u[ti * d + e] * u[ui * d + e];
                    }
                    rel[ui] = dot * inv_sqrt_d;
                }
                let base = ((bi * split + s) * t + ti) * t;
                log_softmax_row(&rel, &mut out[base..base + t]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::Rng;

    /// Finite-difference check: `build` constructs a scalar loss from
    /// leaves created out of `inputs`; analytic grads must match central
    /// differences at rtol 1e-2 (f32).
    fn fd_check<F>(name: &str, inputs: &[(Vec<usize>, Vec<f32>)], build: F)
    where
        F: Fn(&mut Tape, &[TensorId]) -> TensorId,
    {
        let run = |data: &[Vec<f32>]| -> (f32, Vec<Vec<f32>>) {
            let mut tape = Tape::new();
            let ids: Vec<TensorId> = inputs
                .iter()
                .zip(data)
                .map(|((shape, _), d)| tape.leaf(shape, d.clone()))
                .collect();
            let loss = build(&mut tape, &ids);
            assert!(tape.value(loss).len() == 1, "{name}: loss must be scalar");
            tape.backward(loss);
            let grads = ids.iter().map(|&id| tape.grad(id).to_vec()).collect();
            (tape.scalar(loss), grads)
        };
        let base: Vec<Vec<f32>> = inputs.iter().map(|(_, d)| d.clone()).collect();
        let (_, grads) = run(&base);
        for (pi, (_, d0)) in inputs.iter().enumerate() {
            for j in 0..d0.len() {
                let h = 3e-3 * d0[j].abs().max(1.0);
                let mut plus = base.clone();
                plus[pi][j] += h;
                let mut minus = base.clone();
                minus[pi][j] -= h;
                let fd = (run(&plus).0 - run(&minus).0) / (2.0 * h);
                let an = grads[pi][j];
                let tol = 1e-2 * an.abs().max(fd.abs()) + 2e-3;
                assert!(
                    (an - fd).abs() <= tol,
                    "{name}: input {pi}[{j}] analytic {an} vs fd {fd}"
                );
            }
        }
    }

    fn rand_vec(n: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn fd_add_mul_scale_add_scaled() {
        let a = rand_vec(6, 1, 1.0);
        let b = rand_vec(6, 2, 1.0);
        let w = rand_vec(6, 3, 1.0);
        fd_check(
            "add",
            &[(vec![2, 3], a.clone()), (vec![2, 3], b.clone())],
            |t, ids| {
                let s = t.add(ids[0], ids[1]);
                t.weighted_sum(s, vec![0.3, -0.7, 1.1, 0.2, -0.5, 0.9])
            },
        );
        fd_check(
            "mul",
            &[(vec![2, 3], a.clone()), (vec![2, 3], b.clone())],
            |t, ids| {
                let s = t.mul(ids[0], ids[1]);
                t.weighted_sum(s, vec![0.3, -0.7, 1.1, 0.2, -0.5, 0.9])
            },
        );
        fd_check("scale", &[(vec![2, 3], a.clone())], |t, ids| {
            let s = t.scale(ids[0], -1.7);
            t.weighted_sum(s, vec![1.0; 6])
        });
        fd_check(
            "add_scaled",
            &[(vec![6], a), (vec![6], b), (vec![6], w)],
            |t, ids| {
                let s = t.add_scaled(&[(ids[0], 1.0), (ids[1], 2.5), (ids[2], -0.5)]);
                t.weighted_sum(s, vec![0.4, 0.1, -0.2, 0.8, 0.6, -1.0])
            },
        );
    }

    #[test]
    fn fd_matmul_and_matmul_t() {
        let x = rand_vec(6, 4, 0.7);
        let w = rand_vec(6, 5, 0.7);
        fd_check(
            "matmul",
            &[(vec![2, 3], x.clone()), (vec![3, 2], w.clone())],
            |t, ids| {
                let y = t.matmul(ids[0], ids[1]);
                t.weighted_sum(y, vec![0.5, -1.0, 0.25, 2.0])
            },
        );
        fd_check("matmul_t", &[(vec![2, 3], x), (vec![2, 3], w)], |t, ids| {
            let y = t.matmul_t(ids[0], ids[1]);
            t.weighted_sum(y, vec![0.5, -1.0, 0.25, 2.0])
        });
    }

    #[test]
    fn fd_embedding() {
        let table = rand_vec(4 * 3, 6, 0.8);
        fd_check("embedding", &[(vec![4, 3], table)], |t, ids| {
            let y = t.embedding(ids[0], &[2, 0, 2]);
            t.weighted_sum(y, vec![0.3; 9])
        });
    }

    #[test]
    fn fd_rmsnorm() {
        let x = rand_vec(8, 7, 1.0);
        let g = rand_vec(4, 8, 0.5);
        fd_check("rmsnorm", &[(vec![2, 4], x), (vec![4], g)], |t, ids| {
            let y = t.rmsnorm(ids[0], ids[1], 1e-6);
            t.weighted_sum(y, vec![0.7, -0.2, 0.5, 1.0, -0.8, 0.1, 0.4, -0.6])
        });
    }

    #[test]
    fn fd_rope() {
        // 2 rows (seq 2) x 1 head x hd 4
        let x = rand_vec(8, 9, 1.0);
        fd_check("rope", &[(vec![2, 4], x)], |t, ids| {
            let y = t.rope(ids[0], 1, 4, 2, 100.0);
            t.weighted_sum(y, vec![0.7, -0.2, 0.5, 1.0, -0.8, 0.1, 0.4, -0.6])
        });
    }

    #[test]
    fn fd_silu_gelu_softmax() {
        let x = rand_vec(6, 10, 1.2);
        fd_check("silu", &[(vec![2, 3], x.clone())], |t, ids| {
            let y = t.silu(ids[0]);
            t.weighted_sum(y, vec![0.5, -0.4, 1.0, 0.2, -0.9, 0.3])
        });
        fd_check("gelu", &[(vec![2, 3], x.clone())], |t, ids| {
            let y = t.gelu(ids[0]);
            t.weighted_sum(y, vec![0.5, -0.4, 1.0, 0.2, -0.9, 0.3])
        });
        fd_check("softmax_rows", &[(vec![2, 3], x)], |t, ids| {
            let y = t.softmax_rows(ids[0]);
            t.weighted_sum(y, vec![0.5, -0.4, 1.0, 0.2, -0.9, 0.3])
        });
    }

    #[test]
    fn fd_attention() {
        // b=1, t=3, heads=2, kv_heads=1, hd=2
        let (b, t, h, kv, hd) = (1usize, 3usize, 2usize, 1usize, 2usize);
        let q = rand_vec(b * t * h * hd, 11, 0.8);
        let k = rand_vec(b * t * kv * hd, 12, 0.8);
        let v = rand_vec(b * t * kv * hd, 13, 0.8);
        let wsum = rand_vec(b * t * h * hd, 14, 1.0);
        fd_check(
            "attention",
            &[
                (vec![b * t, h * hd], q),
                (vec![b * t, kv * hd], k),
                (vec![b * t, kv * hd], v),
            ],
            |tp, ids| {
                let y = tp.attention(ids[0], ids[1], ids[2], b, t, h, kv, hd);
                tp.weighted_sum(y, wsum.clone())
            },
        );
    }

    #[test]
    fn fd_repeat_heads_and_slice() {
        let x = rand_vec(2 * 4, 15, 1.0);
        fd_check("repeat_heads", &[(vec![2, 4], x.clone())], |t, ids| {
            let y = t.repeat_heads(ids[0], 2, 3); // 2 heads of hd 2 -> 6 heads
            t.weighted_sum(y, rand_vec(2 * 12, 16, 1.0))
        });
        fd_check("slice", &[(vec![2, 4], x)], |t, ids| {
            let y = t.slice(ids[0], 2, &[3]);
            t.weighted_sum(y, vec![1.0, -2.0, 0.5])
        });
    }

    #[test]
    fn fd_cross_entropy_and_kl() {
        let logits = rand_vec(3 * 5, 17, 1.5);
        let labels = vec![2i32, -100, 4];
        fd_check("cross_entropy", &[(vec![3, 5], logits.clone())], |t, ids| {
            t.cross_entropy(ids[0], &labels)
        });
        // teacher log-probs at tau from a second random logits set
        let tau = 5.0f32;
        let t_logits = rand_vec(3 * 5, 18, 1.5);
        let mut tlp = vec![0.0f32; 15];
        for r in 0..3 {
            let row: Vec<f32> = t_logits[r * 5..(r + 1) * 5].iter().map(|v| v / tau).collect();
            log_softmax_row(&row, &mut tlp[r * 5..(r + 1) * 5]);
        }
        let mask = vec![true, false, true];
        fd_check("kl_teacher", &[(vec![3, 5], logits)], |t, ids| {
            t.kl_teacher(ids[0], tlp.clone(), mask.clone(), tau)
        });
    }

    #[test]
    fn fd_relation_kl() {
        let (b, t, split, d) = (1usize, 3usize, 2usize, 2usize);
        let state = rand_vec(b * t * split * d, 19, 1.0);
        let teacher = rand_vec(b * t * split * d, 20, 1.0);
        let tlp = relation_logprobs_of(&teacher, b, t, split, d);
        fd_check("relation_kl", &[(vec![b * t, split * d], state)], |tp, ids| {
            tp.relation_kl(ids[0], tlp.clone(), b, t, split)
        });
    }

    #[test]
    fn ste_passes_gradient_through_unchanged() {
        // forward uses the quantized value; backward is identity
        let mut tape = Tape::new();
        let x = tape.leaf(&[4], vec![0.3, -1.2, 0.05, 2.0]);
        let q = tape.ste(x, vec![0.0, -1.0, 0.0, 2.0]); // arbitrary "quantized"
        assert_eq!(tape.value(q), &[0.0, -1.0, 0.0, 2.0]);
        let w = vec![0.5, -0.25, 1.0, 0.125];
        let loss = tape.weighted_sum(q, w.clone());
        tape.backward(loss);
        assert_eq!(tape.grad(x), w.as_slice(), "STE must be identity in backward");
    }

    #[test]
    fn cross_entropy_matches_manual() {
        let mut tape = Tape::new();
        let logits = tape.leaf(&[2, 3], vec![1.0, 2.0, 0.5, 0.0, 0.0, 0.0]);
        let loss = tape.cross_entropy(logits, &[1, -100]);
        // row 0: -log softmax[1]
        let z: f32 = [1.0f32, 2.0, 0.5].iter().map(|v| v.exp()).sum();
        let want = -(2.0 - z.ln());
        assert!((tape.scalar(loss) - want).abs() < 1e-5);
    }

    #[test]
    fn kl_is_zero_when_student_equals_teacher() {
        let logits_data = vec![0.5f32, -1.0, 2.0, 0.1, 0.2, 0.3];
        let tau = 5.0f32;
        let mut tlp = vec![0.0f32; 6];
        for r in 0..2 {
            let row: Vec<f32> =
                logits_data[r * 3..(r + 1) * 3].iter().map(|v| v / tau).collect();
            log_softmax_row(&row, &mut tlp[r * 3..(r + 1) * 3]);
        }
        let mut tape = Tape::new();
        let s = tape.leaf(&[2, 3], logits_data);
        let loss = tape.kl_teacher(s, tlp, vec![true, true], tau);
        assert!(tape.scalar(loss).abs() < 1e-6);
        tape.backward(loss);
        assert!(tape.grad(s).iter().all(|g| g.abs() < 1e-6));
    }

    #[test]
    fn relation_logprobs_rows_normalize() {
        let state = rand_vec(2 * 4 * 6, 21, 1.0); // b=2, t=4, split=3, d=2
        let lp = relation_logprobs_of(&state, 2, 4, 3, 2);
        for row in lp.chunks(4) {
            let s: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5, "row prob mass {s}");
        }
    }

    #[test]
    fn no_grad_tape_skips_gradient_buffers() {
        let mut tape = Tape::no_grad();
        let a = tape.leaf(&[3], vec![1.0, 2.0, 3.0]);
        let b = tape.scale(a, 2.0);
        assert_eq!(tape.value(b), &[2.0, 4.0, 6.0]);
        assert!(tape.grad(a).is_empty(), "evaluation tape allocates no grads");
    }

    #[test]
    #[should_panic(expected = "no-grad")]
    fn no_grad_tape_rejects_backward() {
        let mut tape = Tape::no_grad();
        let a = tape.leaf(&[1], vec![1.0]);
        let l = tape.weighted_sum(a, vec![1.0]);
        tape.backward(l);
    }

    #[test]
    fn grads_accumulate_on_reused_nodes() {
        // y = x + x  =>  dy/dx = 2
        let mut tape = Tape::new();
        let x = tape.leaf(&[2], vec![1.0, -1.0]);
        let y = tape.add(x, x);
        let loss = tape.weighted_sum(y, vec![1.0, 1.0]);
        tape.backward(loss);
        assert_eq!(tape.grad(x), &[2.0, 2.0]);
    }
}
