//! Native CPU training subsystem: the full BitDistill three-stage
//! pipeline with **zero** PJRT/HLO artifacts.
//!
//! ```text
//! tape.rs    reverse-mode autograd over host f32 tensors
//! model.rs   differentiable ModelSpec forward (+ Q/K/V state capture)
//! losses.rs  CE + logits-KL + MiniLM attention-relation (eq. 8-14)
//! qat.rs     STE fake-quant on the crate's absmean/int8 lattices
//! optim.rs   AdamW (python optim.py constants) + GradAccum
//! stages.rs  native three-stage drivers + `pipeline --backend native`
//! ```
//!
//! [`NativeTrainer`] is the native implementation of the
//! [`crate::pipeline::TrainStep`] backend seam: the same stage drivers
//! that loop over HLO executables loop over tapes here, checkpoints stay
//! in [`crate::params::ParamStore`] format, and the trained student
//! exports into the packed-ternary [`crate::engine::Engine`] — train ->
//! quantize -> serve in one binary, on any machine.

pub mod losses;
pub mod model;
pub mod optim;
pub mod qat;
pub mod stages;
pub mod tape;

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

pub use optim::{AdamW, GradAccum};
pub use stages::{run_pipeline, NativeCtx, PipelineReport};
pub use tape::{Tape, TensorId};

use crate::data::Batch;
use crate::obs::{ArgV, QuantScope, StepLosses, TraceRecorder, TID_MAIN};
use crate::parallel::ThreadPool;
use crate::params::ParamStore;
use crate::pipeline::trainer::{DistillLosses, TrainStep};
use crate::runtime::ModelSpec;

/// Tape-backed trainer: owns the params + AdamW state and runs CE /
/// distillation steps natively. Quantization (QAT) is on iff the spec's
/// `quant_method != "none"`, mirroring the Layer-2 step kinds.
pub struct NativeTrainer {
    pub spec: ModelSpec,
    /// Teacher architecture for [`NativeTrainer::distill_step`] (the
    /// teacher's *weights* arrive per call, as in the HLO trainer).
    pub teacher_spec: Option<ModelSpec>,
    pub params: ParamStore,
    pub opt: AdamW,
    /// Gradient-accumulation factor for CE steps
    /// ([`NativeTrainer::train_step`]): the batch splits into this many
    /// micro-batches (1 = off), gradients weighted by each chunk's row
    /// share. Distill steps always run full-batch.
    pub micro_batches: usize,
    /// Worker threads for data-parallel micro-batch execution (1 =
    /// serial). Shard boundaries depend only on `micro_batches`, each
    /// shard's forward/backward runs single-threaded against the shared
    /// immutable parameter snapshot, and gradients are reduced in fixed
    /// shard order — so loss and gradients are **bitwise identical** for
    /// every thread count (test-enforced below).
    pub threads: usize,
    /// Span recorder (`bitdistill pipeline --trace`): each step's
    /// forward/backward and optimizer phases become spans. Disabled by
    /// default — an `Option` check per phase, nothing more. Recording
    /// happens only on the coordinating thread (the per-shard worker
    /// closures never touch it), and never changes a trained bit.
    pub trace: TraceRecorder,
    /// Quantization telemetry (`bitdistill pipeline --quant-metrics`):
    /// at its stride, each step's post-update lattice statistics and
    /// loss breakdown are recorded from the coordinating thread, after
    /// the optimizer has consumed the gradients. Same contract as
    /// `trace`: disabled = one branch per step, recording only reads —
    /// on-vs-off training is bitwise identical (test-enforced).
    pub quant: QuantScope,
}

impl NativeTrainer {
    pub fn new(spec: ModelSpec, params: ParamStore) -> NativeTrainer {
        let opt = AdamW::new(&params);
        NativeTrainer {
            spec,
            teacher_spec: None,
            params,
            opt,
            micro_batches: 1,
            threads: 1,
            trace: TraceRecorder::disabled(),
            quant: QuantScope::disabled(),
        }
    }

    pub fn with_teacher(mut self, teacher_spec: ModelSpec) -> NativeTrainer {
        self.teacher_spec = Some(teacher_spec);
        self
    }

    /// Fresh optimizer state (between pipeline stages).
    pub fn reset_opt(&mut self) {
        self.opt = AdamW::new(&self.params);
    }

    /// One CE step (native analog of the lm_train / bitnet_train
    /// executables). Returns the batch CE loss.
    ///
    /// Data-parallel: the `micro_batches` shards fan across `threads`
    /// workers, each running forward/backward on its rows against the
    /// shared immutable parameter snapshot; the shard losses/gradients
    /// are then reduced serially in shard order. Shard boundaries and
    /// the reduction are independent of `threads`, so the step is
    /// bitwise reproducible at any thread count.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let (b, t) = (batch.tokens.shape[0], batch.tokens.shape[1]);
        let micro = self.micro_batches.clamp(1, b);
        let cfg = self.spec.config.clone();
        // deterministic shard boundaries: depend on (b, micro) only
        let mut splits = Vec::with_capacity(micro);
        let mut r0 = 0usize;
        for c in 0..micro {
            let rows = (b - r0 + (micro - c) - 1) / (micro - c);
            splits.push((r0, r0 + rows));
            r0 += rows;
        }
        let params = &self.params;
        // forward/backward for one shard; loss and gradients use the same
        // row-share weighting, so an uneven split still reproduces the
        // full-batch step (exactly, when supervision is uniform per row)
        let run_shard = |c: usize| -> Result<(Tape, model::ParamIds, TensorId, f32)> {
            let (r0, r1) = splits[c];
            let rows = r1 - r0;
            let mut tape = Tape::new();
            let ids = model::register_params(&mut tape, params);
            let out = model::forward(
                &mut tape,
                &cfg,
                &ids,
                &batch.tokens.data[r0 * t..r1 * t],
                rows,
                t,
                -1,
            )?;
            let l = losses::ce(&mut tape, out.logits, &batch.labels.data[r0 * t..r1 * t]);
            tape.backward(l);
            Ok((tape, ids, l, rows as f32 / b as f32))
        };

        let trace = self.trace.clone();
        let fb_span = trace.span_args(
            TID_MAIN,
            "forward_backward",
            &[
                ("shards", ArgV::Num(micro as f64)),
                ("threads", ArgV::Num(self.threads.max(1) as f64)),
            ],
        );
        let mut acc = GradAccum::new();
        let mut loss = 0.0f32;
        if self.threads <= 1 {
            // serial: stream each shard's tape straight into the
            // accumulator (one live gradient set, as pre-parallel)
            for c in 0..micro {
                let (tape, ids, l, share) = run_shard(c)?;
                loss += tape.scalar(l) * share;
                acc.add_weighted(&tape, &ids, share);
            }
        } else {
            // data-parallel: workers copy their gradients out, reduction
            // runs in fixed shard order — the adds are op-for-op those of
            // the serial loop, so results are bitwise identical at every
            // thread count
            let results = ThreadPool::new(self.threads).map_indexed(micro, |c| {
                run_shard(c).map(|(tape, ids, l, share)| {
                    let mut grads = BTreeMap::new();
                    for (name, &id) in &ids {
                        grads.insert(name.clone(), tape.grad(id).to_vec());
                    }
                    (tape.scalar(l) * share, grads, share)
                })
            });
            for res in results {
                let (l, grads, share) = res?;
                loss += l;
                acc.add_weighted_grads(&grads, share);
            }
        }
        drop(fb_span);
        let grads = acc.take();
        let opt_span = trace.span(TID_MAIN, "optim");
        self.opt.step(&mut self.params, &grads, lr);
        self.params.step = self.opt.t;
        drop(opt_span);
        // telemetry reads the post-update lattice + the gradients the
        // optimizer just consumed; it writes nothing back
        self.quant
            .record_step(self.opt.t, &cfg, &self.params, &grads, &StepLosses::ce_only(loss));
        Ok(loss)
    }

    /// One stage-3 distillation step (native analog of distill_train):
    /// CE + lambda*LD + gamma*AD against a constant teacher forward.
    pub fn distill_step(
        &mut self,
        teacher: &ParamStore,
        batch: &Batch,
        lr: f32,
        lambda: f32,
        gamma: f32,
        distill_layer: i32,
    ) -> Result<DistillLosses> {
        let (b, t) = (batch.tokens.shape[0], batch.tokens.shape[1]);
        let cfg = self.spec.config.clone();
        let tspec = self.teacher_spec.clone().ok_or_else(|| {
            anyhow!("distill_step needs a teacher spec (NativeTrainer::with_teacher)")
        })?;

        // Teacher forward (stop-gradient: runs on its own throwaway tape).
        // Student layer i maps onto the (possibly deeper) teacher
        // proportionally, as in python steps.py.
        let (ls, lt) = (cfg.n_layers as i32, tspec.config.n_layers as i32);
        let t_dl = if distill_layer >= 0 && gamma != 0.0 {
            (distill_layer + 1) * lt / ls - 1
        } else {
            -1
        };
        let trace = self.trace.clone();
        let need_teacher = lambda != 0.0 || gamma != 0.0;
        let t_span = trace.span(TID_MAIN, "teacher_fwd");
        let (t_logits, t_states) = if need_teacher {
            model::forward_values(&tspec.config, teacher, &batch.tokens.data, b, t, t_dl)?
        } else {
            (Vec::new(), None)
        };
        drop(t_span);

        let s_span = trace.span(TID_MAIN, "student_fwd_bwd");
        let mut tape = Tape::new();
        let ids = model::register_params(&mut tape, &self.params);
        let capture = if gamma != 0.0 { distill_layer } else { -1 };
        let out = model::forward(&mut tape, &cfg, &ids, &batch.tokens.data, b, t, capture)?;
        let labels = &batch.labels.data;
        let ce_id = losses::ce(&mut tape, out.logits, labels);
        let ld_id = if lambda != 0.0 {
            Some(losses::logits_kd(&mut tape, out.logits, &t_logits, labels, losses::TAU))
        } else {
            None
        };
        let ad_id = match (&t_states, &out.states) {
            (Some(ts), Some(ss)) if gamma != 0.0 => {
                Some(losses::attention_relation(&mut tape, ss, ts, b, t, cfg.n_heads))
            }
            _ => None,
        };
        let total_id = losses::combine(&mut tape, ce_id, ld_id, ad_id, lambda, gamma);
        tape.backward(total_id);
        drop(s_span);

        let mut acc = GradAccum::new();
        acc.add(&tape, &ids);
        let grads = acc.mean();
        let opt_span = trace.span(TID_MAIN, "optim");
        self.opt.step(&mut self.params, &grads, lr);
        self.params.step = self.opt.t;
        drop(opt_span);
        let result = DistillLosses {
            total: tape.scalar(total_id),
            ce: tape.scalar(ce_id),
            ld: ld_id.map_or(0.0, |i| tape.scalar(i)),
            ad: ad_id.map_or(0.0, |i| tape.scalar(i)),
        };
        if self.quant.should_record(self.opt.t) {
            // the per-head AD decomposition is a pure host-side re-read
            // of the captured Q/K/V states — only computed on-stride
            let ad_heads = match (&t_states, &out.states) {
                (Some(ts), Some(ss)) if gamma != 0.0 => losses::attention_relation_per_head(
                    [tape.value(ss[0]), tape.value(ss[1]), tape.value(ss[2])],
                    ts,
                    b,
                    t,
                    cfg.n_heads,
                ),
                _ => Vec::new(),
            };
            let step_losses = StepLosses {
                total: result.total,
                ce: result.ce,
                ld: ld_id.map(|_| result.ld),
                ad: ad_id.map(|_| result.ad),
                ad_heads,
            };
            self.quant.record_step(self.opt.t, &cfg, &self.params, &grads, &step_losses);
        }
        Ok(result)
    }
}

impl TrainStep for NativeTrainer {
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        NativeTrainer::train_step(self, batch, lr)
    }

    fn distill_step(
        &mut self,
        teacher: &ParamStore,
        batch: &Batch,
        lr: f32,
        lambda: f32,
        gamma: f32,
        distill_layer: i32,
    ) -> Result<DistillLosses> {
        NativeTrainer::distill_step(self, teacher, batch, lr, lambda, gamma, distill_layer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::IGNORE;
    use crate::engine::model::mini_model;
    use crate::tensor::TensorI32;

    /// A learnable synthetic LM task on the mini vocab: each row walks
    /// the vocab with a fixed stride, so next-token is a deterministic
    /// function of the current token.
    fn cyclic_batch(b: usize, t: usize, vocab: i32) -> Batch {
        let mut tokens = Vec::with_capacity(b * t);
        let mut labels = Vec::with_capacity(b * t);
        for r in 0..b {
            let start = (r as i32 * 5) % vocab;
            for p in 0..t {
                tokens.push((start + 3 * p as i32) % vocab);
            }
            for p in 0..t {
                if p + 1 < t {
                    labels.push((start + 3 * (p as i32 + 1)) % vocab);
                } else {
                    labels.push(IGNORE);
                }
            }
        }
        Batch {
            tokens: TensorI32::from_vec(&[b, t], tokens).unwrap(),
            labels: TensorI32::from_vec(&[b, t], labels).unwrap(),
            idx: Vec::new(),
        }
    }

    #[test]
    fn fifty_native_qat_steps_strictly_reduce_ce() {
        // the mini spec has quant_method = "absmean": this is full QAT
        // (STE weights + int8 activations) end to end.
        let (spec, store) = mini_model(true, true);
        let mut tr = NativeTrainer::new(spec, store);
        let batch = cyclic_batch(4, 16, 32);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for s in 0..50 {
            last = tr.train_step(&batch, 3e-3).unwrap();
            assert!(last.is_finite(), "step {s}: loss {last}");
            if s == 0 {
                first = last;
            }
        }
        assert!(
            last < first,
            "50 QAT steps must strictly reduce CE: first {first}, last {last}"
        );
        assert_eq!(tr.params.step, 50);
    }

    #[test]
    fn gradient_accumulation_matches_full_batch() {
        // uniform supervision per row => row-share weighting makes the
        // accumulated gradient equal the full-batch gradient, including
        // for an uneven split (5 rows over 2 micro-batches = 3 + 2).
        let (spec, store) = mini_model(true, true);
        let batch = cyclic_batch(5, 8, 32);
        let mut full = NativeTrainer::new(spec.clone(), store.clone());
        let mut split = NativeTrainer::new(spec, store);
        split.micro_batches = 2;
        let lf = full.train_step(&batch, 1e-3).unwrap();
        let ls = split.train_step(&batch, 1e-3).unwrap();
        assert!((lf - ls).abs() < 1e-4, "losses diverged: {lf} vs {ls}");
        for (name, t) in &full.params.tensors {
            let s = &split.params.tensors[name];
            for (i, (&a, &b)) in t.data.iter().zip(&s.data).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{name}[{i}]: accum {b} vs full {a}"
                );
            }
        }
    }

    #[test]
    fn train_step_is_bitwise_identical_across_thread_counts() {
        // the data-parallel contract: with shard boundaries fixed by
        // micro_batches, the thread count must not move one bit of the
        // loss or of any updated parameter (fixed shard-order reduction
        // over per-shard single-threaded tapes). Uneven split (7 rows
        // over 4 shards) included on purpose.
        let batch = cyclic_batch(7, 10, 32);
        let run = |threads: usize| {
            let (spec, store) = mini_model(true, true);
            let mut tr = NativeTrainer::new(spec, store);
            tr.micro_batches = 4;
            tr.threads = threads;
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(tr.train_step(&batch, 2e-3).unwrap());
            }
            (losses, tr.params)
        };
        let (loss1, params1) = run(1);
        for threads in [2usize, 4] {
            let (lossn, paramsn) = run(threads);
            for (a, b) in loss1.iter().zip(&lossn) {
                assert_eq!(a.to_bits(), b.to_bits(), "loss diverged at threads={threads}");
            }
            for (name, t1) in &params1.tensors {
                let tn = &paramsn.tensors[name];
                for (i, (a, b)) in t1.data.iter().zip(&tn.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{name}[{i}] diverged at threads={threads}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_telemetry_on_vs_off_is_bitwise_identical() {
        // the QuantScope half of the zero-cost-off contract: recording
        // per-layer lattice stats at stride 1 must not move one bit of
        // any loss or trained parameter, serial or data-parallel.
        let batch = cyclic_batch(7, 10, 32);
        let run = |threads: usize, scope: QuantScope| {
            let (spec, store) = mini_model(true, true);
            let mut tr = NativeTrainer::new(spec, store);
            tr.micro_batches = 4;
            tr.threads = threads;
            tr.quant = scope;
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(tr.train_step(&batch, 2e-3).unwrap());
            }
            (losses, tr.params)
        };
        for threads in [1usize, 4] {
            let (loss_off, params_off) = run(threads, QuantScope::disabled());
            let scope = QuantScope::enabled(1);
            scope.set_stage("ct");
            let (loss_on, params_on) = run(threads, scope.clone());
            assert!(scope.len() > 0, "telemetry must actually have recorded");
            for (a, b) in loss_off.iter().zip(&loss_on) {
                assert_eq!(a.to_bits(), b.to_bits(), "loss moved at threads={threads}");
            }
            for (name, t_off) in &params_off.tensors {
                let t_on = &params_on.tensors[name];
                for (i, (a, b)) in t_off.data.iter().zip(&t_on.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{name}[{i}] moved with telemetry on at threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_telemetry_distill_step_is_bitwise_identical_and_records_components() {
        let batch = cyclic_batch(2, 8, 32);
        let run = |scope: QuantScope| {
            let (spec, store) = mini_model(true, true);
            let (mut tspec, tstore) = mini_model(false, true);
            tspec.config.quant_method = "none".into();
            let mut tr = NativeTrainer::new(spec, store).with_teacher(tspec);
            tr.quant = scope;
            let mut totals = Vec::new();
            for _ in 0..2 {
                totals.push(tr.distill_step(&tstore, &batch, 1e-3, 1.0, 1.0, 0).unwrap().total);
            }
            (totals, tr.params)
        };
        let (off, params_off) = run(QuantScope::disabled());
        let scope = QuantScope::enabled(1);
        scope.set_stage("distill");
        let (on, params_on) = run(scope.clone());
        for (a, b) in off.iter().zip(&on) {
            assert_eq!(a.to_bits(), b.to_bits(), "distill loss moved with telemetry on");
        }
        for (name, t_off) in &params_off.tensors {
            let t_on = &params_on.tensors[name];
            for ((a, b), i) in t_off.data.iter().zip(&t_on.data).zip(0usize..) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}[{i}] moved with telemetry on");
            }
        }
        // the distill loss rows must carry the full component breakdown
        let rows = scope.take_rows();
        let loss_rows: Vec<_> = rows
            .iter()
            .filter(|r| {
                r.get("layer").and_then(crate::substrate::Json::as_f64) == Some(-1.0)
            })
            .collect();
        assert_eq!(loss_rows.len(), 2);
        for r in loss_rows {
            assert!(r.get("ld").is_some(), "distill row missing ld: {r}");
            assert!(r.get("ad").is_some(), "distill row missing ad: {r}");
            let heads = r.get("ad_heads").and_then(crate::substrate::Json::as_arr).unwrap();
            assert!(!heads.is_empty(), "per-head AD must be recorded on-stride");
        }
    }

    #[test]
    fn distill_step_reports_all_loss_components() {
        let (spec, store) = mini_model(true, true);
        let (mut tspec, tstore) = mini_model(false, true);
        tspec.config.quant_method = "none".into(); // FP teacher
        let before = store.tensors["embed"].data.clone();
        let mut tr = NativeTrainer::new(spec, store).with_teacher(tspec);
        let batch = cyclic_batch(2, 8, 32);
        let l = tr.distill_step(&tstore, &batch, 1e-3, 1.0, 1.0, 0).unwrap();
        assert!(l.total.is_finite() && l.ce.is_finite());
        assert!(l.ld >= 0.0, "KL is non-negative: {}", l.ld);
        assert!(l.ad >= 0.0, "AD is non-negative: {}", l.ad);
        assert!(
            (l.total - (l.ce + l.ld + l.ad)).abs() < 1e-4,
            "total {} != ce {} + ld {} + ad {}",
            l.total,
            l.ce,
            l.ld,
            l.ad
        );
        assert_ne!(before, tr.params.tensors["embed"].data, "params must move");
    }

    #[test]
    fn distill_ablations_zero_their_components() {
        let (spec, store) = mini_model(true, true);
        let (mut tspec, tstore) = mini_model(false, true);
        tspec.config.quant_method = "none".into();
        let mut tr = NativeTrainer::new(spec, store).with_teacher(tspec);
        let batch = cyclic_batch(2, 8, 32);
        let l = tr.distill_step(&tstore, &batch, 1e-3, 0.0, 0.0, 0).unwrap();
        assert_eq!(l.ld, 0.0);
        assert_eq!(l.ad, 0.0);
        assert!((l.total - l.ce).abs() < 1e-6);
    }
}
