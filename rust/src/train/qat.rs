//! QAT fake-quantizers with straight-through-estimator backward — the
//! native mirror of `python/compile/quantizers.py::bitlinear`'s two
//! halves, built on the existing [`crate::quant`] lattices so the
//! training-time grid is bit-identical to the export/deployment grid
//! ([`crate::engine::ternary`]).
//!
//! Each function computes the quantized *value* host-side and attaches
//! it to the tape via [`Tape::ste`], whose backward is identity: the
//! forward sees the ternary/int8 lattice, the gradient sees a straight
//! pass-through (STE).

use crate::quant;
use crate::train::tape::{Tape, TensorId};

/// Row-block size of the Block-Quant analog (python BLOCK).
pub const BLOCK_ROWS: usize = 64;
const EPS: f32 = 1e-6;

/// Ternary codes + per-element scales for a [k, n] matrix under
/// `method` — the single dispatch both the QAT forward and the
/// QuantScope telemetry go through, so the lattice they see is the same
/// by construction. "awq" folds its activation rescale into the matmul
/// in the JAX path; the native trainer treats it as absmean (documented
/// fallback), and "block" falls back to per-tensor absmean when `k` is
/// not a multiple of [`BLOCK_ROWS`] (the graceful path `quant::block`
/// now reports as an error instead of panicking).
pub fn quantize_weight_codes(w: &[f32], k: usize, n: usize, method: &str) -> quant::QuantResult {
    match method {
        "block" => match quant::block(w, k, n, BLOCK_ROWS) {
            Ok(r) => r,
            Err(_) => quant::absmean(w),
        },
        "gptq" => quant::gptq(w, k, n),
        // "absmean", "awq" and anything unknown: per-tensor absmean
        _ => quant::absmean(w),
    }
}

/// Dequantized ternary weights for a [k, n] matrix under `method` —
/// [`quantize_weight_codes`] played back onto the f32 grid.
pub fn quantize_weight_value(w: &[f32], k: usize, n: usize, method: &str) -> Vec<f32> {
    quantize_weight_codes(w, k, n, method).dequant()
}

/// Fake-quantize a [k, n] weight node: forward = ternary dequant,
/// backward = identity (STE).
pub fn fake_quant_weight(tape: &mut Tape, w: TensorId, k: usize, n: usize, method: &str) -> TensorId {
    let q = quantize_weight_value(tape.value(w), k, n, method);
    tape.ste(w, q)
}

/// Per-token (per-row) int8 absmax activation fake-quant, paper eq. (3):
/// Q(x) = (gamma/127) * RoundClip(127 x / (gamma + eps), -128, 127),
/// with gamma = absmax of the row. Forward matches
/// [`crate::engine::ternary::act_quant_i8`] dequantized; backward is STE.
pub fn fake_quant_act(tape: &mut Tape, x: TensorId) -> TensorId {
    let shape = tape.shape(x).to_vec();
    assert_eq!(shape.len(), 2, "fake_quant_act wants [rows, dim]");
    let dim = shape[1];
    let xv = tape.value(x);
    let mut q = vec![0.0f32; xv.len()];
    for r in 0..shape[0] {
        let row = &xv[r * dim..(r + 1) * dim];
        let gamma = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = 127.0 / (gamma + EPS);
        let inv = gamma / 127.0;
        for (o, &v) in q[r * dim..(r + 1) * dim].iter_mut().zip(row) {
            *o = (v * scale).round().clamp(-128.0, 127.0) * inv;
        }
    }
    tape.ste(x, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ternary::act_quant_i8;
    use crate::substrate::Rng;

    fn rand_vec(n: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn weight_fake_quant_forward_is_ternary_lattice() {
        let w = rand_vec(8 * 6, 1, 0.05);
        let mut tape = Tape::new();
        let wid = tape.leaf(&[8, 6], w.clone());
        let q = fake_quant_weight(&mut tape, wid, 8, 6, "absmean");
        let want = quant::absmean(&w).dequant();
        assert_eq!(tape.value(q), want.as_slice());
        // every forward value sits on {-delta, 0, +delta}
        let delta = w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32;
        for &v in tape.value(q) {
            assert!(
                v.abs() < 1e-7 || (v.abs() - delta).abs() < 1e-6,
                "{v} not on the ternary lattice (delta {delta})"
            );
        }
    }

    #[test]
    fn weight_fake_quant_gradient_is_identity() {
        let w = rand_vec(12, 2, 0.05);
        let mut tape = Tape::new();
        let wid = tape.leaf(&[4, 3], w);
        let q = fake_quant_weight(&mut tape, wid, 4, 3, "absmean");
        let weights = rand_vec(12, 3, 1.0);
        let loss = tape.weighted_sum(q, weights.clone());
        tape.backward(loss);
        assert_eq!(tape.grad(wid), weights.as_slice(), "STE backward must be identity");
    }

    #[test]
    fn block_method_falls_back_when_rows_do_not_divide() {
        // k = 10 is not a multiple of BLOCK_ROWS: per-tensor fallback
        let w = rand_vec(10 * 4, 4, 0.05);
        let got = quantize_weight_value(&w, 10, 4, "block");
        assert_eq!(got, quant::absmean(&w).dequant());
        // k = 64 uses the real block path
        let w2 = rand_vec(64 * 4, 5, 0.05);
        let got2 = quantize_weight_value(&w2, 64, 4, "block");
        assert_eq!(got2, quant::block(&w2, 64, 4, BLOCK_ROWS).unwrap().dequant());
    }

    #[test]
    fn act_fake_quant_matches_engine_lattice() {
        let x = rand_vec(3 * 7, 6, 1.5);
        let mut tape = Tape::new();
        let xid = tape.leaf(&[3, 7], x.clone());
        let q = fake_quant_act(&mut tape, xid);
        for r in 0..3 {
            let row = &x[r * 7..(r + 1) * 7];
            let mut qi = vec![0i8; 7];
            let gamma = act_quant_i8(row, &mut qi);
            for (e, &code) in qi.iter().enumerate() {
                let want = code as f32 * gamma / 127.0;
                let got = tape.value(q)[r * 7 + e];
                assert!((got - want).abs() < 1e-6, "row {r} elem {e}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn act_fake_quant_gradient_is_identity() {
        let x = rand_vec(2 * 5, 7, 2.0);
        let mut tape = Tape::new();
        let xid = tape.leaf(&[2, 5], x);
        let q = fake_quant_act(&mut tape, xid);
        let weights = rand_vec(10, 8, 1.0);
        let loss = tape.weighted_sum(q, weights.clone());
        tape.backward(loss);
        assert_eq!(tape.grad(xid), weights.as_slice());
    }
}
