//! AdamW for the native trainer — the exact constants and update rule of
//! `python/compile/optim.py` (B1 0.9, B2 0.95, eps 1e-8, weight decay
//! 0.01 on matrices only) — plus [`GradAccum`], the micro-batch gradient
//! accumulator that bridges tapes to optimizer steps.

use std::collections::BTreeMap;

use crate::params::ParamStore;
use crate::train::model::ParamIds;
use crate::train::tape::Tape;

pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.01;

/// Optimizer state: first/second moments shaped like the params.
pub struct AdamW {
    pub m: ParamStore,
    pub v: ParamStore,
    /// Completed steps (bias correction uses t+1 inside [`AdamW::step`]).
    pub t: usize,
}

impl AdamW {
    pub fn new(params: &ParamStore) -> AdamW {
        AdamW { m: params.zeros_like(), v: params.zeros_like(), t: 0 }
    }

    /// One AdamW update from name-keyed gradients. Parameters without a
    /// gradient entry are left untouched.
    pub fn step(&mut self, params: &mut ParamStore, grads: &BTreeMap<String, Vec<f32>>, lr: f32) {
        self.t += 1;
        let s = self.t as f32;
        let bc1 = 1.0 - BETA1.powf(s);
        let bc2 = 1.0 - BETA2.powf(s);
        for spec in params.specs.clone() {
            let Some(g) = grads.get(&spec.name) else { continue };
            let p = params.tensors.get_mut(&spec.name).expect("spec/tensor mismatch");
            let m = self.m.tensors.get_mut(&spec.name).expect("m state");
            let v = self.v.tensors.get_mut(&spec.name).expect("v state");
            assert_eq!(g.len(), p.data.len(), "grad size for {}", spec.name);
            let decay = if p.shape.len() >= 2 { WEIGHT_DECAY } else { 0.0 };
            for i in 0..g.len() {
                let gi = g[i];
                let mi = BETA1 * m.data[i] + (1.0 - BETA1) * gi;
                let vi = BETA2 * v.data[i] + (1.0 - BETA2) * gi * gi;
                m.data[i] = mi;
                v.data[i] = vi;
                let mut upd = (mi / bc1) / ((vi / bc2).sqrt() + ADAM_EPS);
                upd += decay * p.data[i];
                p.data[i] -= lr * upd;
            }
        }
    }
}

/// Name-keyed gradient accumulator: sums tape gradients across
/// micro-batches, then hands the mean to [`AdamW::step`].
#[derive(Default)]
pub struct GradAccum {
    grads: BTreeMap<String, Vec<f32>>,
    pub micro_batches: usize,
}

impl GradAccum {
    pub fn new() -> GradAccum {
        GradAccum { grads: BTreeMap::new(), micro_batches: 0 }
    }

    /// Add one tape's parameter gradients (post-[`Tape::backward`]).
    pub fn add(&mut self, tape: &Tape, ids: &ParamIds) {
        self.add_weighted(tape, ids, 1.0);
    }

    /// Add one tape's gradients scaled by `weight`. With weights
    /// `rows_i / total_rows` per micro-batch, uneven batch splits still
    /// reproduce the full-batch gradient (exact when supervision is
    /// uniform across rows); collect via [`GradAccum::take`].
    pub fn add_weighted(&mut self, tape: &Tape, ids: &ParamIds, weight: f32) {
        self.add_entries(ids.iter().map(|(name, &id)| (name, tape.grad(id))), weight);
    }

    /// Add pre-extracted gradients scaled by `weight` — the worker-side
    /// twin of [`GradAccum::add_weighted`] for data-parallel training:
    /// each worker copies its tape's gradients out, and the reducer
    /// calls this in **fixed shard order**, performing exactly the adds
    /// of the serial path (shared [`GradAccum::add_entries`] body: same
    /// keys, same BTreeMap order, same `acc += weight * g` per element)
    /// — so results are bitwise independent of the thread count.
    pub fn add_weighted_grads(&mut self, grads: &BTreeMap<String, Vec<f32>>, weight: f32) {
        self.add_entries(grads.iter().map(|(name, g)| (name, g.as_slice())), weight);
    }

    /// The one merge body both `add_*` entry points share: name-keyed
    /// `acc += weight * g` in BTreeMap (alphabetical) order, inserting
    /// scaled copies for names seen for the first time.
    fn add_entries<'g>(
        &mut self,
        entries: impl Iterator<Item = (&'g String, &'g [f32])>,
        weight: f32,
    ) {
        for (name, g) in entries {
            match self.grads.get_mut(name) {
                Some(acc) => {
                    for (a, &v) in acc.iter_mut().zip(g) {
                        *a += weight * v;
                    }
                }
                None => {
                    self.grads.insert(name.clone(), g.iter().map(|&v| weight * v).collect());
                }
            }
        }
        self.micro_batches += 1;
    }

    /// The accumulated gradients as-is (use with [`GradAccum::add_weighted`],
    /// whose weights already normalize).
    pub fn take(self) -> BTreeMap<String, Vec<f32>> {
        self.grads
    }

    /// Mean gradients over the accumulated micro-batches (equal-weight
    /// [`GradAccum::add`] path).
    pub fn mean(mut self) -> BTreeMap<String, Vec<f32>> {
        let n = self.micro_batches.max(1) as f32;
        for g in self.grads.values_mut() {
            for v in g.iter_mut() {
                *v /= n;
            }
        }
        self.grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelCfg, ModelSpec, ParamSpec};
    use crate::substrate::Rng;

    fn two_param_store() -> ParamStore {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            n_kv_heads: 1,
            head_dim: 2,
            d_ff: 4,
            act: "silu".into(),
            tie_embeddings: true,
            use_subln: false,
            quant_method: "none".into(),
            rope_theta: 1e4,
            norm_eps: 1e-6,
            seq: 4,
        };
        let spec = ModelSpec {
            key: "t".into(),
            config: cfg,
            n_params: 10,
            params: vec![
                ParamSpec {
                    name: "mat".into(),
                    shape: vec![2, 4],
                    init_kind: "normal".into(),
                    init_std: 0.5,
                    weight_decay: true,
                },
                ParamSpec {
                    name: "gain".into(),
                    shape: vec![2],
                    init_kind: "ones".into(),
                    init_std: 0.0,
                    weight_decay: false,
                },
            ],
        };
        let mut rng = Rng::new(3);
        ParamStore::init(&spec, &mut rng)
    }

    #[test]
    fn adam_moves_params_against_gradient() {
        let mut params = two_param_store();
        let before = params.tensors["mat"].data.clone();
        let mut opt = AdamW::new(&params);
        let mut grads = BTreeMap::new();
        grads.insert("mat".to_string(), vec![1.0f32; 8]);
        opt.step(&mut params, &grads, 1e-2);
        for (b, a) in before.iter().zip(&params.tensors["mat"].data) {
            assert!(a < b, "positive gradient must decrease the param: {b} -> {a}");
        }
        // untouched param stays put
        assert!(params.tensors["gain"].data.iter().all(|&v| v == 1.0));
        assert_eq!(opt.t, 1);
    }

    #[test]
    fn weight_decay_applies_to_matrices_only() {
        let mut params = two_param_store();
        params.tensors.get_mut("mat").unwrap().data.fill(1.0);
        params.tensors.get_mut("gain").unwrap().data.fill(1.0);
        let mut opt = AdamW::new(&params);
        // zero gradients: only decay can move anything
        let mut grads = BTreeMap::new();
        grads.insert("mat".to_string(), vec![0.0f32; 8]);
        grads.insert("gain".to_string(), vec![0.0f32; 2]);
        opt.step(&mut params, &grads, 1e-1);
        assert!(
            params.tensors["mat"].data.iter().all(|&v| v < 1.0),
            "matrices decay toward zero"
        );
        assert!(
            params.tensors["gain"].data.iter().all(|&v| v == 1.0),
            "norm gains must not decay"
        );
    }

    #[test]
    fn grad_accum_means_across_micro_batches() {
        let mut t1 = Tape::new();
        let a1 = t1.leaf(&[2], vec![1.0, 2.0]);
        let l1 = t1.weighted_sum(a1, vec![1.0, 1.0]);
        t1.backward(l1);
        let mut t2 = Tape::new();
        let a2 = t2.leaf(&[2], vec![1.0, 2.0]);
        let l2 = t2.weighted_sum(a2, vec![3.0, 5.0]);
        t2.backward(l2);

        let mut ids1 = BTreeMap::new();
        ids1.insert("p".to_string(), a1);
        let mut ids2 = BTreeMap::new();
        ids2.insert("p".to_string(), a2);

        let mut acc = GradAccum::new();
        acc.add(&t1, &ids1);
        acc.add(&t2, &ids2);
        assert_eq!(acc.micro_batches, 2);
        let g = acc.mean();
        assert_eq!(g["p"], vec![2.0, 3.0]); // mean of [1,1] and [3,5]
    }
}
