//! Native three-stage BitDistill drivers: the artifact-free twin of
//! [`crate::pipeline::stages`]. Same coordinator shape — Stage-1
//! structural SubLN insertion via `load_compatible`, Stage-2 continual
//! pre-training (QAT CE on the corpus), Stage-3 CE + LD + AD against the
//! FP teacher — but every step runs on the autograd tape through the
//! shared [`run_ce_loop`] seam, so `bitdistill pipeline --backend
//! native` works on a machine that has never seen `python/compile`.
//!
//! Budgets are sized to the pure-rust step cost (slower per step than
//! the XLA executables), and the default sequence length is shorter:
//! the synthetic tasks fit comfortably in 64 tokens.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::data::{Batcher, CorpusBatcher, CorpusStream, Task, TaskGen, Tokenizer};
use crate::engine::Engine;
use crate::obs::{QuantScope, TraceRecorder, TID_MAIN};
use crate::params::ParamStore;
use crate::pipeline::eval::{eval_classification_engine, eval_summarization};
use crate::pipeline::stages::{
    run_ce_loop, run_distill_loop, student_suffix, task_seed, Budget, StudentOpts,
};
use crate::pipeline::trainer::LrSchedule;
use crate::runtime::ModelSpec;
use crate::substrate::Rng;
use crate::train::NativeTrainer;

/// Everything a native pipeline run needs (no [`crate::runtime::Runtime`]).
pub struct NativeCtx {
    pub tok: Tokenizer,
    pub runs_dir: PathBuf,
    pub force: bool,
    pub verbose: bool,
    /// Multiplies every stage's step budget (CI smoke runs etc.).
    pub steps_scale: f64,
    pub batch: usize,
    pub seq: usize,
    /// Data-parallel worker threads per CE step: the batch splits into
    /// `threads` micro-batch shards, one per worker, gradients reduced
    /// in fixed shard order (deterministic for a fixed thread count;
    /// thread counts with the same shard split are bitwise identical —
    /// see [`NativeTrainer::threads`]).
    pub threads: usize,
    /// Span recorder (`bitdistill pipeline --trace`): each stage becomes
    /// a `stage:*` span, each step a `train_step`/`distill_step` span
    /// with forward/backward/optim sub-spans
    /// ([`NativeTrainer::trace`]). Disabled by default — zero-cost-off
    /// per the [`crate::obs`] contract, and recording never changes a
    /// trained bit.
    pub trace: TraceRecorder,
    /// Quantization telemetry (`bitdistill pipeline --quant-metrics` +
    /// `--quant-every`): every stage driver labels it
    /// ([`QuantScope::set_stage`]) and every trainer it configures
    /// records per-layer lattice statistics and the loss breakdown at
    /// the stride ([`NativeTrainer::quant`]). Disabled by default, same
    /// zero-cost-off / bitwise-identical contract as `trace`.
    pub quant: QuantScope,
}

impl NativeCtx {
    pub fn new(runs_dir: impl AsRef<Path>) -> NativeCtx {
        NativeCtx {
            tok: Tokenizer::new(1024),
            runs_dir: runs_dir.as_ref().to_path_buf(),
            force: false,
            verbose: true,
            steps_scale: 1.0,
            batch: 8,
            seq: 64,
            threads: 1,
            trace: TraceRecorder::disabled(),
            quant: QuantScope::disabled(),
        }
    }

    /// Apply the ctx's execution shape to a freshly built trainer:
    /// `threads` workers over `threads` micro-batch shards, sharing the
    /// ctx's span and quant recorders.
    fn configure(&self, mut tr: NativeTrainer) -> NativeTrainer {
        tr.threads = self.threads.max(1);
        tr.micro_batches = self.threads.max(1);
        tr.trace = self.trace.clone();
        tr.quant = self.quant.clone();
        tr
    }

    fn scaled(&self, steps: usize) -> usize {
        ((steps as f64 * self.steps_scale).round() as usize).max(2)
    }

    /// Cache-tag fragment for non-default run shapes: a smoke run
    /// (`--steps-scale 0.05`) and a full run must never share
    /// checkpoints, or the full run would silently report the
    /// barely-trained student's scores.
    fn run_tag(&self) -> String {
        if (self.steps_scale - 1.0).abs() < 1e-12
            && self.batch == 8
            && self.seq == 64
            && self.threads <= 1
        {
            String::new()
        } else {
            // threads > 1 is part of the tag (a different shard split is
            // a different numerical trajectory); threads == 1 is omitted
            // so pre-parallel cached checkpoints keep resolving
            let t = if self.threads > 1 {
                format!("_t{}", self.threads)
            } else {
                String::new()
            };
            format!("_x{:.3}_b{}_q{}{t}", self.steps_scale, self.batch, self.seq)
        }
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[native-pipeline] {msg}");
        }
    }
}

/// Per-size budgets for the native backend (one tape step costs more
/// than one compiled HLO step, so these are smaller than
/// [`crate::pipeline::stages::budget`]).
pub fn native_budget(size: &str) -> Budget {
    match size {
        "micro" => Budget { pretrain: 30, ct: 6, sft: 20, distill: 16,
                            pretrain_lr: 2e-3, sft_lr: 2e-3, eval_n: 48 },
        "small" => Budget { pretrain: 120, ct: 16, sft: 70, distill: 50,
                            pretrain_lr: 2e-3, sft_lr: 8e-4, eval_n: 96 },
        "base" => Budget { pretrain: 80, ct: 12, sft: 48, distill: 36,
                           pretrain_lr: 1.5e-3, sft_lr: 6e-4, eval_n: 64 },
        _ => Budget { pretrain: 200, ct: 24, sft: 150, distill: 110,
                      pretrain_lr: 1e-3, sft_lr: 1.5e-3, eval_n: 128 },
    }
}

/// Pretrain the full-precision base model on the corpus (stands in for
/// the off-the-shelf LLM). Cached as `native_base_<size>.ckpt`.
pub fn pretrain_base(ctx: &NativeCtx, size: &str) -> Result<PathBuf> {
    let path = ctx.runs_dir.join(format!("native_base_{size}{}.ckpt", ctx.run_tag()));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let b = native_budget(size);
    let steps = ctx.scaled(b.pretrain);
    let spec = ModelSpec::synthetic_with(size, false, "none")?;
    let mut rng = Rng::new(42);
    let params = ParamStore::init(&spec, &mut rng);
    let mut tr = ctx.configure(NativeTrainer::new(spec, params));
    let stream = CorpusStream::new(&ctx.tok, ctx.seq, 1);
    let mut batches = CorpusBatcher::new(stream, ctx.batch, ctx.seq);
    let sched = LrSchedule::new(b.pretrain_lr, steps / 20 + 1, steps);
    let stage_span = ctx.trace.span(TID_MAIN, "stage:pretrain");
    ctx.quant.set_stage("pretrain");
    let last = run_ce_loop(
        &mut tr,
        &mut || batches.next_batch(),
        &sched,
        steps,
        &ctx.trace,
        &mut |s, l| {
            if s % 20 == 0 {
                ctx.log(&format!("pretrain {size} step {s}/{steps} loss {l:.3}"));
            }
        },
    )?;
    drop(stage_span);
    ctx.log(&format!("pretrain {size} done: loss {last:.3}"));
    tr.params.save(&path)?;
    Ok(path)
}

/// FP-SFT of the base model on the task — this IS the teacher.
pub fn teacher_sft(ctx: &NativeCtx, size: &str, task: Task) -> Result<PathBuf> {
    let path = ctx
        .runs_dir
        .join(format!("native_teacher_{size}_{}{}.ckpt", task.name(), ctx.run_tag()));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let base = pretrain_base(ctx, size)?;
    let b = native_budget(size);
    let steps = ctx.scaled(b.sft);
    let spec = ModelSpec::synthetic_with(size, false, "none")?;
    let mut params = ParamStore::load(&base)?;
    params.model_key = spec.key.clone();
    let mut tr = ctx.configure(NativeTrainer::new(spec, params));
    let gen = TaskGen::new(task, &ctx.tok, ctx.seq);
    let ds = gen.dataset(768, task_seed(task, 1));
    let mut batches = Batcher::new(&ds, ctx.batch, ctx.seq, 7);
    let sched = LrSchedule::new(b.sft_lr, steps / 20 + 1, steps);
    let stage_span = ctx.trace.span(TID_MAIN, "stage:teacher_sft");
    ctx.quant.set_stage("teacher_sft");
    let last = run_ce_loop(
        &mut tr,
        &mut || batches.next_batch(),
        &sched,
        steps,
        &ctx.trace,
        &mut |s, l| {
            if s % 20 == 0 {
                ctx.log(&format!(
                    "teacher-sft {size}/{} step {s}/{steps} loss {l:.3}",
                    task.name()
                ));
            }
        },
    )?;
    drop(stage_span);
    ctx.log(&format!("teacher-sft {size}/{} done: loss {last:.3}", task.name()));
    tr.params.save(&path)?;
    Ok(path)
}

/// Stage-1: student spec (SubLN tensors) initialized from the base
/// checkpoint; the freshly initialized unit SubLN gains stay in place.
fn init_student(ctx: &NativeCtx, size: &str, opts: &StudentOpts) -> Result<(ModelSpec, ParamStore)> {
    let base = pretrain_base(ctx, size)?;
    let base_params = ParamStore::load(&base)?;
    let spec = ModelSpec::synthetic_with(size, opts.subln, &opts.quant)?;
    let mut rng = Rng::new(43);
    let mut student = ParamStore::init(&spec, &mut rng);
    let missing = student.load_compatible(&base_params);
    for m in &missing {
        if !m.starts_with("blocks.subln") {
            return Err(anyhow!("native student init missing non-SubLN tensor {m}"));
        }
    }
    Ok((spec, student))
}

/// Full native BitDistill: Stage-1 (structural) + optional Stage-2 CT +
/// Stage-3 distillation against the FP teacher. Returns the student
/// checkpoint path (cached by tag).
pub fn bitdistill(
    ctx: &NativeCtx,
    size: &str,
    task: Task,
    opts: &StudentOpts,
    ct: bool,
) -> Result<PathBuf> {
    let tsize = opts.teacher_size.clone().unwrap_or_else(|| size.to_string());
    let tag = format!(
        "native_bitdistill_{size}_{}{}{}{}{}{}_dl{}{}",
        task.name(),
        student_suffix(opts),
        if ct { "" } else { "_noct" },
        if opts.use_ld { "" } else { "_nold" },
        if opts.use_ad { "" } else { "_noad" },
        if tsize != size { format!("_t{tsize}") } else { String::new() },
        opts.distill_layer,
        ctx.run_tag()
    );
    let path = ctx.runs_dir.join(format!("{tag}.ckpt"));
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let b = native_budget(size);

    // Stage-0/teacher: FP-SFT of the (possibly larger) teacher
    let teacher_path = teacher_sft(ctx, &tsize, task)?;
    let teacher = ParamStore::load(&teacher_path)?;
    let teacher_spec = ModelSpec::synthetic_with(&tsize, false, "none")?;

    // Stage-1: structural refinement
    let (spec, params) = init_student(ctx, size, opts)?;
    let mut tr = ctx.configure(NativeTrainer::new(spec, params).with_teacher(teacher_spec));

    // Stage-2: continual pre-training (QAT CE on the corpus)
    if ct {
        let steps = ctx.scaled(opts.ct_steps.unwrap_or(b.ct));
        let stream = CorpusStream::new(&ctx.tok, ctx.seq, 11);
        let mut batches = CorpusBatcher::new(stream, ctx.batch, ctx.seq);
        let sched = LrSchedule::new(b.sft_lr, steps / 10 + 1, steps);
        let stage_span = ctx.trace.span(TID_MAIN, "stage:ct");
        ctx.quant.set_stage("ct");
        run_ce_loop(
            &mut tr,
            &mut || batches.next_batch(),
            &sched,
            steps,
            &ctx.trace,
            &mut |s, l| {
                if s % 20 == 0 {
                    ctx.log(&format!("ct {tag} step {s}/{steps} loss {l:.3}"));
                }
            },
        )?;
        drop(stage_span);
        // optimizer state restarts between stages (fresh task)
        tr.reset_opt();
    }

    // Stage-3: distillation-based fine-tuning (eq. 13)
    let steps = ctx.scaled(opts.sft_steps.unwrap_or(b.distill));
    let gen = TaskGen::new(task, &ctx.tok, ctx.seq);
    let ds = gen.dataset(768, task_seed(task, 1));
    let mut batches = Batcher::new(&ds, ctx.batch, ctx.seq, 9);
    let sched = LrSchedule::new(b.sft_lr, steps / 20 + 1, steps);
    let lambda = if opts.use_ld { opts.lambda } else { 0.0 };
    let gamma = if opts.use_ad { opts.gamma } else { 0.0 };
    let stage_span = ctx.trace.span(TID_MAIN, "stage:distill");
    ctx.quant.set_stage("distill");
    run_distill_loop(
        &mut tr,
        &teacher,
        &mut || batches.next_batch(),
        &sched,
        steps,
        lambda,
        gamma,
        opts.distill_layer,
        &ctx.trace,
        &mut |s, l| {
            if s % 20 == 0 || s + 1 == steps {
                ctx.log(&format!(
                    "distill {tag} step {s}/{steps} total {:.3} ce {:.3} ld {:.4} ad {:.5}",
                    l.total, l.ce, l.ld, l.ad
                ));
            }
        },
    )?;
    drop(stage_span);
    tr.params.save(&path)?;
    ctx.log(&format!("bitdistill {tag} done"));
    Ok(path)
}

/// Outcome of one end-to-end native pipeline run: the stage-3 student,
/// exported to the packed-ternary engine, scored against an untrained
/// (random-init) ternary baseline on the same eval split.
pub struct PipelineReport {
    pub ckpt: PathBuf,
    /// "accuracy" (classification, %) or "sum-avg" (generation).
    pub metric: &'static str,
    pub student: f64,
    pub baseline: f64,
}

/// Run all three stages natively, export the student into a ternary
/// [`Engine`], and evaluate both it and an untrained baseline.
pub fn run_pipeline(
    ctx: &NativeCtx,
    size: &str,
    task: Task,
    opts: &StudentOpts,
    ct: bool,
) -> Result<PipelineReport> {
    let ckpt = bitdistill(ctx, size, task, opts, ct)?;
    let params = ParamStore::load(&ckpt)?;
    let spec = ModelSpec::synthetic_with(size, opts.subln, &opts.quant)?;
    // deployment path: packed ternary weights + int8 activations. The
    // engine packs per-tensor absmean only — for the Table-4 variants
    // the deployed lattice differs from the QAT one, so flag it.
    if opts.quant != "absmean" {
        ctx.log(&format!(
            "note: engine export packs absmean; {} QAT eval is approximate",
            opts.quant
        ));
    }
    let engine = Engine::from_params(&spec, &params, true)?;
    let mut rng = Rng::new(999);
    let baseline_params = ParamStore::init(&spec, &mut rng);
    let baseline_engine = Engine::from_params(&spec, &baseline_params, true)?;

    let n = native_budget(size).eval_n;
    let gen = TaskGen::new(task, &ctx.tok, ctx.seq);
    let ds = gen.dataset(n, task_seed(task, 2));
    let (metric, student, baseline) = if task.is_generation() {
        let lim = ds.len().min(48);
        let s = eval_summarization(&engine, &ds[..lim], &ctx.tok, 24);
        let b = eval_summarization(&baseline_engine, &ds[..lim], &ctx.tok, 24);
        ("sum-avg", s.avg(), b.avg())
    } else {
        (
            "accuracy",
            eval_classification_engine(&engine, &ds, &ctx.tok, task),
            eval_classification_engine(&baseline_engine, &ds, &ctx.tok, task),
        )
    };
    ctx.log(&format!(
        "eval {}/{}: student {metric}={student:.2} vs untrained baseline {baseline:.2}",
        size,
        task.name()
    ));
    Ok(PipelineReport { ckpt, metric, student, baseline })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_budgets_cover_all_sizes() {
        for size in ["micro", "tiny", "small", "base", "unknown-falls-back"] {
            let b = native_budget(size);
            assert!(b.pretrain >= 2 && b.distill >= 2 && b.eval_n > 0, "{size}");
        }
    }

    #[test]
    fn micro_pipeline_emits_quant_telemetry_for_every_stage() {
        use crate::substrate::Json;
        let dir = std::env::temp_dir().join("bd_native_quantscope_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut ctx = NativeCtx::new(&dir);
        ctx.verbose = false;
        ctx.steps_scale = 0.02;
        ctx.batch = 2;
        ctx.seq = 32;
        ctx.quant = QuantScope::enabled(1);
        let task = Task::Sst2;
        let spec = ModelSpec::synthetic_with("micro", true, "absmean").unwrap();
        let opts = StudentOpts::defaults_for(task, spec.config.n_layers);
        run_pipeline(&ctx, "micro", task, &opts, true).unwrap();
        let rows = ctx.quant.take_rows();
        let stage_of = |r: &Json| r.get("stage").and_then(Json::as_str).map(str::to_string);
        let is_layer_row =
            |r: &Json| r.get("layer").and_then(Json::as_f64).is_some_and(|l| l >= 0.0);
        let stages: std::collections::BTreeSet<String> =
            rows.iter().filter_map(stage_of).collect();
        for s in ["pretrain", "teacher_sft", "ct", "distill"] {
            assert!(stages.contains(s), "missing stage {s} in {stages:?}");
        }
        // quantized stages carry per-layer lattice rows; FP stages are
        // loss-only (no ternary lattice to report)
        assert!(
            rows.iter().any(|r| stage_of(r).as_deref() == Some("ct") && is_layer_row(r)),
            "CT stage must emit per-layer rows"
        );
        assert!(
            !rows.iter().any(|r| stage_of(r).as_deref() == Some("pretrain") && is_layer_row(r)),
            "FP pretrain must not emit per-layer rows"
        );
        // distill loss rows carry the component breakdown
        assert!(
            rows.iter().any(|r| stage_of(r).as_deref() == Some("distill")
                && r.get("ad_heads").is_some()),
            "distill rows must carry the per-head AD breakdown"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn micro_pipeline_runs_all_three_stages_without_artifacts() {
        // end-to-end: pretrain -> teacher SFT -> (stage 1+2+3) -> ternary
        // engine eval, at a micro scale that stays fast in debug builds.
        let dir = std::env::temp_dir().join("bd_native_pipeline_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut ctx = NativeCtx::new(&dir);
        ctx.verbose = false;
        ctx.steps_scale = 0.02; // 2-step stages: wiring, not convergence
        ctx.batch = 2;
        ctx.seq = 32;
        let task = Task::Sst2;
        let spec = ModelSpec::synthetic_with("micro", true, "absmean").unwrap();
        let opts = StudentOpts::defaults_for(task, spec.config.n_layers);
        let report = run_pipeline(&ctx, "micro", task, &opts, true).unwrap();
        assert!(report.ckpt.exists());
        assert_eq!(report.metric, "accuracy");
        assert!(report.student.is_finite() && report.baseline.is_finite());
        // checkpoint round-trips into the spec it was trained under
        let p = ParamStore::load(&report.ckpt).unwrap();
        assert_eq!(p.model_key, spec.key);
        // caching: a second call must reuse the checkpoint
        let again = run_pipeline(&ctx, "micro", task, &opts, true).unwrap();
        assert_eq!(again.ckpt, report.ckpt);
        std::fs::remove_dir_all(&dir).ok();
    }
}
