//! The BitDistill Stage-3 objective on the tape (paper §3.3, eq. 8-14):
//! L = L_CE + lambda * L_LD + gamma * L_AD — the native mirror of
//! `python/compile/losses.py`. Teacher quantities are host-side
//! constants (stop-gradient); only the student side is differentiable.

use crate::data::IGNORE;
use crate::train::tape::{log_softmax_row, relation_logprobs_of, Tape, TensorId};

/// Logits-distillation temperature (python steps.py TAU, paper §4.1).
pub const TAU: f32 = 5.0;

/// Eq. (14): mean CE over supervised positions (labels != IGNORE).
pub fn ce(tape: &mut Tape, logits: TensorId, labels: &[i32]) -> TensorId {
    tape.cross_entropy(logits, labels)
}

/// Eq. (8)-(9): KL(P_teacher^tau || P_student^tau) on supervised
/// positions. `teacher_logits` is a [rows, vocab] constant.
pub fn logits_kd(
    tape: &mut Tape,
    student_logits: TensorId,
    teacher_logits: &[f32],
    labels: &[i32],
    tau: f32,
) -> TensorId {
    let rows = labels.len();
    assert_eq!(teacher_logits.len() % rows, 0);
    let vocab = teacher_logits.len() / rows;
    let mut tlp = vec![0.0f32; teacher_logits.len()];
    let mut scaled = vec![0.0f32; vocab];
    for r in 0..rows {
        for (s, &l) in scaled.iter_mut().zip(&teacher_logits[r * vocab..(r + 1) * vocab]) {
            *s = l / tau;
        }
        log_softmax_row(&scaled, &mut tlp[r * vocab..(r + 1) * vocab]);
    }
    let mask: Vec<bool> = labels.iter().map(|&l| l != IGNORE).collect();
    tape.kl_teacher(student_logits, tlp, mask, tau)
}

/// Eq. (10)-(12) / Algorithm 1: MiniLM multi-head attention-relation KD
/// over the Q, K and V relations of the distilled layer. Student states
/// are tape nodes ([b*t, split*d_s] each); teacher states are constants
/// ([b*t, split*d_t] each — the teacher may be wider, the TxT relation
/// matrices align regardless). `split` is the student head count
/// (python: split_heads = cfg.n_heads).
pub fn attention_relation(
    tape: &mut Tape,
    student_states: &[TensorId; 3],
    teacher_states: &[Vec<f32>; 3],
    b: usize,
    t: usize,
    split: usize,
) -> TensorId {
    let mut terms = Vec::with_capacity(3);
    for i in 0..3 {
        let tw = teacher_states[i].len() / (b * t);
        assert_eq!(tw % split, 0, "teacher width {tw} not divisible by split {split}");
        let td = tw / split;
        let tlp = relation_logprobs_of(&teacher_states[i], b, t, split, td);
        let kl = tape.relation_kl(student_states[i], tlp, b, t, split);
        terms.push((kl, 1.0f32)); // alpha_i = 1 for all relations (§4.1)
    }
    tape.add_scaled(&terms)
}

/// Per-head decomposition of [`attention_relation`] for telemetry
/// (QuantScope's `ad_heads`): head `h` gets the summed Q/K/V relation
/// KL of its own TxT relation matrices, normalized so the mean over
/// heads equals the scalar AD loss. Pure host-side read — it touches no
/// tape state and therefore cannot perturb training (the bitwise
/// on-vs-off contract).
pub fn attention_relation_per_head(
    student_states: [&[f32]; 3],
    teacher_states: &[Vec<f32>; 3],
    b: usize,
    t: usize,
    split: usize,
) -> Vec<f32> {
    let mut heads = vec![0.0f32; split];
    for i in 0..3 {
        let sw = student_states[i].len() / (b * t);
        let tw = teacher_states[i].len() / (b * t);
        assert_eq!(sw % split, 0, "student width {sw} not divisible by split {split}");
        assert_eq!(tw % split, 0, "teacher width {tw} not divisible by split {split}");
        let slp = relation_logprobs_of(student_states[i], b, t, split, sw / split);
        let tlp = relation_logprobs_of(&teacher_states[i], b, t, split, tw / split);
        for bi in 0..b {
            for (s, head) in heads.iter_mut().enumerate() {
                let base = (bi * split + s) * t * t;
                for idx in base..base + t * t {
                    let tl = tlp[idx];
                    *head += tl.exp() * (tl - slp[idx]);
                }
            }
        }
    }
    for h in heads.iter_mut() {
        *h /= (b * t) as f32;
    }
    heads
}

/// Eq. (13): total = ce + lambda * ld + gamma * ad.
pub fn combine(
    tape: &mut Tape,
    ce: TensorId,
    ld: Option<TensorId>,
    ad: Option<TensorId>,
    lambda: f32,
    gamma: f32,
) -> TensorId {
    let mut terms = vec![(ce, 1.0f32)];
    if let Some(ld) = ld {
        terms.push((ld, lambda));
    }
    if let Some(ad) = ad {
        terms.push((ad, gamma));
    }
    tape.add_scaled(&terms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::Rng;

    fn rand_vec(n: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn logits_kd_zero_for_identical_models_and_positive_otherwise() {
        let rows = 4;
        let vocab = 6;
        let s = rand_vec(rows * vocab, 1, 1.0);
        let labels = vec![1, IGNORE, 3, 0];
        let mut tape = Tape::new();
        let sid = tape.leaf(&[rows, vocab], s.clone());
        let same = logits_kd(&mut tape, sid, &s, &labels, TAU);
        assert!(tape.scalar(same).abs() < 1e-6);
        let other = rand_vec(rows * vocab, 2, 1.0);
        let diff = logits_kd(&mut tape, sid, &other, &labels, TAU);
        assert!(tape.scalar(diff) > 0.0, "KL must be positive for different dists");
    }

    #[test]
    fn attention_relation_zero_when_states_match() {
        let (b, t, split, d) = (1usize, 3usize, 2usize, 4usize);
        let q = rand_vec(b * t * split * d, 3, 1.0);
        let k = rand_vec(b * t * split * d, 4, 1.0);
        let v = rand_vec(b * t * split * d, 5, 1.0);
        let mut tape = Tape::new();
        let ids = [
            tape.leaf(&[b * t, split * d], q.clone()),
            tape.leaf(&[b * t, split * d], k.clone()),
            tape.leaf(&[b * t, split * d], v.clone()),
        ];
        let teacher = [q, k, v];
        let loss = attention_relation(&mut tape, &ids, &teacher, b, t, split);
        assert!(tape.scalar(loss).abs() < 1e-5, "AD of identical states: {}", tape.scalar(loss));
    }

    #[test]
    fn attention_relation_aligns_across_widths() {
        // teacher twice as wide as the student: TxT relations still align
        let (b, t, split) = (1usize, 4usize, 2usize);
        let (ds, dt) = (3usize, 6usize);
        let s = [
            rand_vec(b * t * split * ds, 6, 1.0),
            rand_vec(b * t * split * ds, 7, 1.0),
            rand_vec(b * t * split * ds, 8, 1.0),
        ];
        let teacher = [
            rand_vec(b * t * split * dt, 9, 1.0),
            rand_vec(b * t * split * dt, 10, 1.0),
            rand_vec(b * t * split * dt, 11, 1.0),
        ];
        let mut tape = Tape::new();
        let ids = [
            tape.leaf(&[b * t, split * ds], s[0].clone()),
            tape.leaf(&[b * t, split * ds], s[1].clone()),
            tape.leaf(&[b * t, split * ds], s[2].clone()),
        ];
        let loss = attention_relation(&mut tape, &ids, &teacher, b, t, split);
        let v = tape.scalar(loss);
        assert!(v.is_finite() && v > 0.0, "cross-width AD loss: {v}");
        tape.backward(loss);
        assert!(tape.grad(ids[0]).iter().any(|&g| g != 0.0), "grads flow to student states");
    }

    #[test]
    fn per_head_decomposition_means_to_the_scalar_ad_loss() {
        let (b, t, split) = (2usize, 4usize, 2usize);
        let (ds, dt) = (3usize, 6usize);
        let s = [
            rand_vec(b * t * split * ds, 21, 1.0),
            rand_vec(b * t * split * ds, 22, 1.0),
            rand_vec(b * t * split * ds, 23, 1.0),
        ];
        let teacher = [
            rand_vec(b * t * split * dt, 24, 1.0),
            rand_vec(b * t * split * dt, 25, 1.0),
            rand_vec(b * t * split * dt, 26, 1.0),
        ];
        let mut tape = Tape::new();
        let ids = [
            tape.leaf(&[b * t, split * ds], s[0].clone()),
            tape.leaf(&[b * t, split * ds], s[1].clone()),
            tape.leaf(&[b * t, split * ds], s[2].clone()),
        ];
        let loss = attention_relation(&mut tape, &ids, &teacher, b, t, split);
        let ad = tape.scalar(loss);
        let heads = attention_relation_per_head(
            [s[0].as_slice(), s[1].as_slice(), s[2].as_slice()],
            &teacher,
            b,
            t,
            split,
        );
        assert_eq!(heads.len(), split);
        assert!(heads.iter().all(|h| h.is_finite()));
        let mean = heads.iter().sum::<f32>() / split as f32;
        assert!(
            (mean - ad).abs() < 1e-4 * ad.abs().max(1.0),
            "per-head mean {mean} vs scalar AD {ad}"
        );
    }

    #[test]
    fn combine_weights_components() {
        let mut tape = Tape::new();
        let ce = tape.leaf(&[], vec![2.0]);
        let ld = tape.leaf(&[], vec![0.5]);
        let ad = tape.leaf(&[], vec![0.25]);
        let total = combine(&mut tape, ce, Some(ld), Some(ad), 10.0, 100.0);
        assert!((tape.scalar(total) - (2.0 + 5.0 + 25.0)).abs() < 1e-5);
        let ce_only = combine(&mut tape, ce, None, None, 10.0, 100.0);
        assert!((tape.scalar(ce_only) - 2.0).abs() < 1e-6);
    }
}
