//! `bitdistill report` — render reports/results.jsonl into the paper's
//! table layout (methods x tasks), so EXPERIMENTS.md tables can be
//! regenerated from raw rows at any time.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::substrate::Json;

#[derive(Default, Clone)]
struct Cell {
    accuracy: Option<f64>,
    avg: Option<f64>,
}

/// Render a markdown summary of every (size, task, method) row present.
pub fn render(path: impl AsRef<Path>) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    // (size, task) -> method -> cell   (last write wins: latest run)
    let mut grid: BTreeMap<(String, String), BTreeMap<String, Cell>> = BTreeMap::new();
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        let (Some(task), Some(size), Some(method)) = (
            j.get("task").and_then(Json::as_str),
            j.get("size").and_then(Json::as_str),
            j.get("method").and_then(Json::as_str),
        ) else {
            continue;
        };
        let mut cell = Cell {
            accuracy: j.get("accuracy").and_then(Json::as_f64),
            avg: None,
        };
        if let Some(b) = j.get("bleu").and_then(Json::as_f64) {
            let mut vals = vec![b];
            for k in ["rouge1", "rouge2", "rougeL", "rougeLsum"] {
                if let Some(v) = j.get(k).and_then(Json::as_f64) {
                    vals.push(v);
                }
            }
            cell.avg = Some(vals.iter().sum::<f64>() / vals.len() as f64);
        }
        grid.entry((size.to_string(), task.to_string()))
            .or_default()
            .insert(method.to_string(), cell);
    }

    let mut out = String::from("| size | task | method | accuracy | sum-avg |\n");
    out.push_str("|---|---|---|---|---|\n");
    for ((size, task), methods) in &grid {
        for (method, cell) in methods {
            out.push_str(&format!(
                "| {size} | {task} | {method} | {} | {} |\n",
                cell.accuracy.map_or("—".into(), |a| format!("{a:.2}")),
                cell.avg.map_or("—".into(), |a| format!("{a:.2}")),
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_mixed_rows() {
        let dir = std::env::temp_dir().join("bd_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"task":"mnli","size":"tiny","method":"fp16-sft","accuracy":76.56}"#, "\n",
                r#"{"note":"=== header ==="}"#, "\n",
                r#"{"task":"cnndm","size":"tiny","method":"bitdistill","bleu":6.21,"rouge1":52.81,"rouge2":9.55,"rougeL":52.81,"rougeLsum":44.56}"#, "\n",
                // duplicate: later row must win
                r#"{"task":"mnli","size":"tiny","method":"fp16-sft","accuracy":77.00}"#, "\n",
            ),
        )
        .unwrap();
        let md = render(&p).unwrap();
        assert!(md.contains("| tiny | mnli | fp16-sft | 77.00 | — |"), "{md}");
        assert!(md.contains("| tiny | cnndm | bitdistill | — | 33.19 |"), "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(render("/nonexistent/results.jsonl").is_err());
    }
}
