//! `bitdistill report` — render reports/results.jsonl into the paper's
//! table layout (methods x tasks), so EXPERIMENTS.md tables can be
//! regenerated from raw rows at any time.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::stats::quantile_unsorted;
use crate::substrate::Json;

#[derive(Default, Clone)]
struct Cell {
    accuracy: Option<f64>,
    avg: Option<f64>,
}

/// Render a markdown summary of every (size, task, method) row present,
/// plus a serving-throughput table when `kind:"serve"` rows exist and a
/// training-throughput table when `kind:"train"` rows exist (medians
/// across repeated runs via the serve-layer quantile).
pub fn render(path: impl AsRef<Path>) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    // (size, task) -> method -> cell   (last write wins: latest run)
    let mut grid: BTreeMap<(String, String), BTreeMap<String, Cell>> = BTreeMap::new();
    // (engine, mode, task, max_batch, threads, kernel, prefill_chunk)
    // -> (tok_s, p95, prefill_p50, prefill_p95 samples); rows written
    // before the threads column existed default to 1, rows before the
    // kernel column existed to "byte" (the only kernel that existed
    // then), and rows before the prefill_chunk column existed to 1
    // (the legacy one-token-per-step prefill)
    #[allow(clippy::type_complexity)]
    let mut serve: BTreeMap<
        (String, String, String, usize, usize, String, usize),
        (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>),
    > = BTreeMap::new();
    // (backend, size, phase) -> (tok_s, p50, p95 samples)
    let mut train: BTreeMap<(String, String, String), (Vec<f64>, Vec<f64>, Vec<f64>)> =
        BTreeMap::new();
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("kind").and_then(Json::as_str) == Some("train") {
            let key = (
                j.get("backend").and_then(Json::as_str).unwrap_or("?").to_string(),
                j.get("size").and_then(Json::as_str).unwrap_or("?").to_string(),
                j.get("phase").and_then(Json::as_str).unwrap_or("?").to_string(),
            );
            let entry = train.entry(key).or_default();
            if let Some(v) = j.get("tok_s").and_then(Json::as_f64) {
                entry.0.push(v);
            }
            if let Some(v) = j.get("p50_ms").and_then(Json::as_f64) {
                entry.1.push(v);
            }
            if let Some(v) = j.get("p95_ms").and_then(Json::as_f64) {
                entry.2.push(v);
            }
            continue;
        }
        if j.get("kind").and_then(Json::as_str) == Some("serve") {
            let key = (
                j.get("engine").and_then(Json::as_str).unwrap_or("?").to_string(),
                j.get("mode").and_then(Json::as_str).unwrap_or("?").to_string(),
                j.get("serve_task").and_then(Json::as_str).unwrap_or("?").to_string(),
                j.get("max_batch").and_then(Json::as_usize).unwrap_or(0),
                j.get("threads").and_then(Json::as_usize).unwrap_or(1),
                j.get("kernel").and_then(Json::as_str).unwrap_or("byte").to_string(),
                j.get("prefill_chunk").and_then(Json::as_usize).unwrap_or(1),
            );
            let entry = serve.entry(key).or_default();
            if let Some(v) = j.get("tok_s").and_then(Json::as_f64) {
                entry.0.push(v);
            }
            if let Some(v) = j.get("p95_ms").and_then(Json::as_f64) {
                entry.1.push(v);
            }
            if let Some(v) = j.get("prefill_p50_ms").and_then(Json::as_f64) {
                entry.2.push(v);
            }
            if let Some(v) = j.get("prefill_p95_ms").and_then(Json::as_f64) {
                entry.3.push(v);
            }
            continue;
        }
        let (Some(task), Some(size), Some(method)) = (
            j.get("task").and_then(Json::as_str),
            j.get("size").and_then(Json::as_str),
            j.get("method").and_then(Json::as_str),
        ) else {
            continue;
        };
        let mut cell = Cell {
            accuracy: j.get("accuracy").and_then(Json::as_f64),
            avg: None,
        };
        if let Some(b) = j.get("bleu").and_then(Json::as_f64) {
            let mut vals = vec![b];
            for k in ["rouge1", "rouge2", "rougeL", "rougeLsum"] {
                if let Some(v) = j.get(k).and_then(Json::as_f64) {
                    vals.push(v);
                }
            }
            cell.avg = Some(vals.iter().sum::<f64>() / vals.len() as f64);
        }
        grid.entry((size.to_string(), task.to_string()))
            .or_default()
            .insert(method.to_string(), cell);
    }

    let mut out = String::from("| size | task | method | accuracy | sum-avg |\n");
    out.push_str("|---|---|---|---|---|\n");
    for ((size, task), methods) in &grid {
        for (method, cell) in methods {
            out.push_str(&format!(
                "| {size} | {task} | {method} | {} | {} |\n",
                cell.accuracy.map_or("—".into(), |a| format!("{a:.2}")),
                cell.avg.map_or("—".into(), |a| format!("{a:.2}")),
            ));
        }
    }
    if !serve.is_empty() {
        out.push_str("\n## serving (median across runs)\n");
        out.push_str(
            "| engine | mode | task | max_batch | threads | kernel | chunk | tok/s | \
             p95 ms | ttft p50 ms | ttft p95 ms |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
        let med = |v: &[f64]| -> String {
            // rows with no samples — pre-TTFT-column runs, or runs where
            // every request expired/was rejected (null percentiles) —
            // render a dash rather than inventing a number
            if v.is_empty() {
                "—".into()
            } else {
                format!("{:.2}", quantile_unsorted(v, 0.5))
            }
        };
        for ((engine, mode, task, mb, threads, kernel, chunk), (tok_s, p95, pf50, pf95)) in
            &serve
        {
            out.push_str(&format!(
                "| {engine} | {mode} | {task} | {mb} | {threads} | {kernel} | {chunk} | \
                 {:.1} | {} | {} | {} |\n",
                quantile_unsorted(tok_s, 0.5),
                med(p95),
                med(pf50),
                med(pf95),
            ));
        }
    }
    if !train.is_empty() {
        out.push_str("\n## training (median across runs)\n");
        out.push_str("| backend | size | phase | tok/s | p50 ms | p95 ms |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for ((backend, size, phase), (tok_s, p50, p95)) in &train {
            out.push_str(&format!(
                "| {backend} | {size} | {phase} | {:.1} | {:.2} | {:.2} |\n",
                quantile_unsorted(tok_s, 0.5),
                quantile_unsorted(p50, 0.5),
                quantile_unsorted(p95, 0.5),
            ));
        }
    }
    Ok(out)
}

/// Render a `serve --metrics-every` JSONL log (`kind:"metrics"` rows,
/// one per periodic snapshot) as a markdown time series. Histogram
/// percentiles that never saw a sample serialize as `null` and render
/// as a dash — the same no-invented-numbers contract as the serve
/// table; rows written before the `kv_resident_lanes` / `batch_fill`
/// columns existed dash those columns too.
pub fn render_metrics(path: impl AsRef<Path>) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    let num = |j: Option<&Json>, prec: usize| -> String {
        match j.and_then(Json::as_f64) {
            Some(v) => format!("{v:.prec$}"),
            None => "—".into(),
        }
    };
    let mut out = String::from(
        "| engine | kernel | steps | wall s | tok/s | active | queue | kv lanes | \
         batch p50 | completed | expired | rejected | total p50 ms | total p95 ms | \
         ttft p50 ms |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    let mut rows = 0usize;
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("kind").and_then(Json::as_str) != Some("metrics") {
            continue;
        }
        rows += 1;
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
            j.get("engine").and_then(Json::as_str).unwrap_or("?"),
            j.get("kernel").and_then(Json::as_str).unwrap_or("?"),
            num(j.get("steps"), 0),
            num(j.get("wall_s"), 2),
            num(j.get("tok_s"), 1),
            num(j.get("active"), 0),
            num(j.get("queue_depth"), 0),
            num(j.get("kv_resident_lanes"), 0),
            num(j.at(&["batch_fill", "p50"]), 1),
            num(j.get("completed"), 0),
            num(j.get("expired"), 0),
            num(j.get("rejected"), 0),
            num(j.at(&["total_ms", "p50"]), 2),
            num(j.at(&["total_ms", "p95"]), 2),
            num(j.at(&["ttft_ms", "p50"]), 2),
        ));
    }
    if rows == 0 {
        bail!("no kind:\"metrics\" rows in {:?}", path.as_ref());
    }
    Ok(out)
}

/// Render a `--quant-metrics` JSONL log (`kind:"quant"` rows from
/// [`crate::obs::QuantScope`]) as markdown per-layer trajectory tables:
///
/// - **per-layer quantization trajectory** — one row per (stage,
///   layer), first→last flip rate (the paper's convergence signal:
///   weight flips decay as Stage-2 CT settles the ternary codes),
///   final sparsity / clip fraction / absmean-scale drift;
/// - **loss components** — the recorded per-step CE / logits-KL /
///   attention-relation breakdown (dashes where a component was off);
/// - **serve activation quantization** — per (layer, site) int8
///   activation range and saturation from the serving accumulators.
///
/// Stages render in first-appearance order (pipeline order, not
/// alphabetical). Errors when the file holds no `kind:"quant"` rows —
/// same contract as [`render_metrics`].
pub fn render_quant(path: impl AsRef<Path>) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    #[derive(Default)]
    struct LayerAcc {
        recs: usize,
        flip_first: f64,
        flip_last: f64,
        sparsity_last: f64,
        clip_last: f64,
        drift_last: f64,
    }
    let mut stage_order: Vec<String> = Vec::new();
    let mut layers: BTreeMap<(usize, i64), LayerAcc> = BTreeMap::new();
    // (stage, step, total, ce, ld?, ad?, mean over ad_heads?)
    #[allow(clippy::type_complexity)]
    let mut losses: Vec<(String, f64, f64, f64, Option<f64>, Option<f64>, Option<f64>)> =
        Vec::new();
    let mut serve_rows: Vec<Json> = Vec::new();
    let mut n = 0usize;
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("kind").and_then(Json::as_str) != Some("quant") {
            continue;
        }
        n += 1;
        match j.get("phase").and_then(Json::as_str) {
            Some("serve") => {
                serve_rows.push(j);
                continue;
            }
            // the aggregate Registry row: totals only, no trajectory
            Some("summary") => continue,
            _ => {}
        }
        let stage = j.get("stage").and_then(Json::as_str).unwrap_or("?").to_string();
        let si = match stage_order.iter().position(|s| s == &stage) {
            Some(i) => i,
            None => {
                stage_order.push(stage.clone());
                stage_order.len() - 1
            }
        };
        let step = j.get("step").and_then(Json::as_f64).unwrap_or(0.0);
        let layer = j.get("layer").and_then(Json::as_f64).unwrap_or(-1.0) as i64;
        if layer < 0 {
            let heads = j.get("ad_heads").and_then(Json::as_arr).and_then(|a| {
                let vs: Vec<f64> = a.iter().filter_map(Json::as_f64).collect();
                (!vs.is_empty()).then(|| vs.iter().sum::<f64>() / vs.len() as f64)
            });
            losses.push((
                stage,
                step,
                j.get("loss").and_then(Json::as_f64).unwrap_or(f64::NAN),
                j.get("ce").and_then(Json::as_f64).unwrap_or(f64::NAN),
                j.get("ld").and_then(Json::as_f64),
                j.get("ad").and_then(Json::as_f64),
                heads,
            ));
            continue;
        }
        let a = layers.entry((si, layer)).or_default();
        let flip = j.get("flip_rate").and_then(Json::as_f64).unwrap_or(f64::NAN);
        if a.recs == 0 {
            a.flip_first = flip;
        }
        a.recs += 1;
        a.flip_last = flip;
        a.sparsity_last = j.get("sparsity").and_then(Json::as_f64).unwrap_or(f64::NAN);
        a.clip_last = j.get("clip_frac").and_then(Json::as_f64).unwrap_or(f64::NAN);
        a.drift_last = j.get("scale_drift").and_then(Json::as_f64).unwrap_or(f64::NAN);
    }
    if n == 0 {
        bail!("no kind:\"quant\" rows in {:?}", path.as_ref());
    }
    let opt = |v: Option<f64>, prec: usize| -> String {
        match v {
            Some(x) => format!("{x:.prec$}"),
            None => "—".into(),
        }
    };
    let mut out = String::new();
    if !layers.is_empty() {
        out.push_str("## quantization per layer (first → last recorded step per stage)\n");
        out.push_str(
            "| stage | layer | recs | flip first | flip last | sparsity | clip | \
             scale drift |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|\n");
        for ((si, layer), a) in &layers {
            let stage = stage_order.get(*si).map(String::as_str).unwrap_or("?");
            out.push_str(&format!(
                "| {stage} | {layer} | {} | {:.4} | {:.4} | {:.3} | {:.3} | {:.5} |\n",
                a.recs, a.flip_first, a.flip_last, a.sparsity_last,
                a.clip_last, a.drift_last,
            ));
        }
    }
    if !losses.is_empty() {
        out.push_str("\n## loss components\n");
        out.push_str("| stage | step | total | ce | ld | ad | ad heads mean |\n");
        out.push_str("|---|---|---|---|---|---|---|\n");
        for (stage, step, total, ce, ld, ad, heads) in &losses {
            out.push_str(&format!(
                "| {stage} | {step:.0} | {total:.3} | {ce:.3} | {} | {} | {} |\n",
                opt(*ld, 3),
                opt(*ad, 3),
                opt(*heads, 3),
            ));
        }
    }
    if !serve_rows.is_empty() {
        out.push_str("\n## serve activation quantization\n");
        out.push_str(
            "| layer | site | rows | gamma mean | gamma min | gamma max | sat frac |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|\n");
        for j in &serve_rows {
            let num = |k: &str, prec: usize| -> String {
                match j.get(k).and_then(Json::as_f64) {
                    Some(v) => format!("{v:.prec$}"),
                    None => "—".into(),
                }
            };
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} |\n",
                num("layer", 0),
                j.get("site").and_then(Json::as_str).unwrap_or("?"),
                num("rows_q", 0),
                num("gamma_mean", 4),
                num("gamma_min", 4),
                num("gamma_max", 4),
                num("sat_frac", 4),
            ));
        }
    }
    Ok(out)
}

/// Render a `bitdistill lint --json` findings file as markdown —
/// `report --lint lint.json`.
///
/// Expects the `{"kind":"lint","files":N,"clean":bool,"findings":[…]}`
/// shape written by [`crate::analysis::LintReport::to_json`]. A clean
/// report renders as a one-line verdict; findings render as a table
/// addressing each hit by rule + `file:line` — no invented values,
/// missing fields render as dashes, same contract as
/// [`render_metrics`] / [`render_quant`]. Errors on unreadable files,
/// non-JSON input, or a JSON document of a different kind.
pub fn render_lint(path: impl AsRef<Path>) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow!("lint report {:?}: {e}", path.as_ref()))?;
    if j.get("kind").and_then(Json::as_str) != Some("lint") {
        bail!("not a lint report (want kind:\"lint\"): {:?}", path.as_ref());
    }
    let files = j.get("files").and_then(Json::as_usize).unwrap_or(0);
    let findings = j.get("findings").and_then(Json::as_arr).unwrap_or(&[]);
    let mut out = String::new();
    out.push_str("## lint findings\n");
    if findings.is_empty() {
        out.push_str(&format!("lint clean: {files} files checked.\n"));
        return Ok(out);
    }
    out.push_str(&format!(
        "{} finding(s) across {files} files — fix the site or add \
         `// lint: allow(<rule>): <reason>`.\n\n",
        findings.len()
    ));
    out.push_str("| rule | location | snippet | hint |\n");
    out.push_str("|---|---|---|---|\n");
    for f in findings {
        let s = |k: &str| f.get(k).and_then(Json::as_str).unwrap_or("—");
        let line = f
            .get("line")
            .and_then(Json::as_usize)
            .map(|l| l.to_string())
            .unwrap_or_else(|| "—".into());
        // pipes inside a snippet (closure args) would break the table
        let snippet = s("snippet").replace('|', "\\|");
        out.push_str(&format!(
            "| {} | {}:{line} | `{snippet}` | {} |\n",
            s("rule"),
            s("path"),
            s("note"),
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_mixed_rows() {
        let dir = std::env::temp_dir().join("bd_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"task":"mnli","size":"tiny","method":"fp16-sft","accuracy":76.56}"#, "\n",
                r#"{"note":"=== header ==="}"#, "\n",
                r#"{"task":"cnndm","size":"tiny","method":"bitdistill","bleu":6.21,"rouge1":52.81,"rouge2":9.55,"rougeL":52.81,"rougeLsum":44.56}"#, "\n",
                // duplicate: later row must win
                r#"{"task":"mnli","size":"tiny","method":"fp16-sft","accuracy":77.00}"#, "\n",
            ),
        )
        .unwrap();
        let md = render(&p).unwrap();
        assert!(md.contains("| tiny | mnli | fp16-sft | 77.00 | — |"), "{md}");
        assert!(md.contains("| tiny | cnndm | bitdistill | — | 33.19 |"), "{md}");
        assert!(!md.contains("## serving"), "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renders_serve_rows_with_median_across_runs() {
        let dir = std::env::temp_dir().join("bd_report_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":16,"tok_s":100.0,"p95_ms":8.0}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":16,"tok_s":300.0,"p95_ms":10.0}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":16,"threads":4,"tok_s":900.0,"p95_ms":3.0}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":16,"threads":4,"kernel":"lut","tok_s":1800.0,"p95_ms":1.5}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":16,"threads":4,"kernel":"simd","tok_s":2400.0,"p95_ms":1.2}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"seq","serve_task":"mnli","max_batch":1,"tok_s":50.0,"p95_ms":4.0}"#, "\n",
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"longprompt","max_batch":4,"kernel":"byte","prefill_chunk":8,"tok_s":2500.0,"p95_ms":40.0,"prefill_p50_ms":11.0,"prefill_p95_ms":13.0}"#, "\n",
            ),
        )
        .unwrap();
        let md = render(&p).unwrap();
        // median of [100, 300] = 200 — interpolated, not nearest-rank;
        // rows without a threads field (pre-threads runs) default to 1,
        // rows without a kernel field (pre-kernel runs) to "byte", and
        // rows without a prefill_chunk field (pre-chunk runs) to 1 with
        // dashed TTFT columns
        assert!(
            md.contains("| ternary | batch | mnli | 16 | 1 | byte | 1 | 200.0 | 9.00 | — | — |"),
            "{md}"
        );
        // the per-thread-count row keys separately
        assert!(
            md.contains("| ternary | batch | mnli | 16 | 4 | byte | 1 | 900.0 | 3.00 | — | — |"),
            "{md}"
        );
        // and the kernel column keys separately from the back-filled rows
        assert!(
            md.contains("| ternary | batch | mnli | 16 | 4 | lut | 1 | 1800.0 | 1.50 | — | — |"),
            "{md}"
        );
        // the third (SIMD) kernel generation renders as its own row too
        assert!(
            md.contains("| ternary | batch | mnli | 16 | 4 | simd | 1 | 2400.0 | 1.20 | — | — |"),
            "{md}"
        );
        assert!(
            md.contains("| ternary | seq | mnli | 1 | 1 | byte | 1 | 50.0 | 4.00 | — | — |"),
            "{md}"
        );
        // a chunked-prefill row carries its chunk and TTFT columns
        assert!(
            md.contains(
                "| ternary | batch | longprompt | 4 | 1 | byte | 8 | 2500.0 | 40.00 | 11.00 | 13.00 |"
            ),
            "{md}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renders_train_rows_with_median_across_runs() {
        let dir = std::env::temp_dir().join("bd_report_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"kind":"train","backend":"native","size":"tiny","phase":"ce","steps":6,"tok_s":400.0,"p50_ms":160.0,"p95_ms":200.0}"#, "\n",
                r#"{"kind":"train","backend":"native","size":"tiny","phase":"ce","steps":6,"tok_s":600.0,"p50_ms":140.0,"p95_ms":180.0}"#, "\n",
                r#"{"kind":"train","backend":"native","size":"tiny","phase":"distill","steps":4,"tok_s":100.0,"p50_ms":640.0,"p95_ms":700.0}"#, "\n",
            ),
        )
        .unwrap();
        let md = render(&p).unwrap();
        assert!(md.contains("## training"), "{md}");
        // median of [400, 600] = 500, [160, 140] -> 150, [200, 180] -> 190
        assert!(md.contains("| native | tiny | ce | 500.0 | 150.00 | 190.00 |"), "{md}");
        assert!(md.contains("| native | tiny | distill | 100.0 | 640.00 | 700.00 |"), "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_rows_with_null_percentiles_render_dashes() {
        // an all-expired run serializes its percentiles as null (the
        // NaN contract) — the report must dash them, not crash or print
        // a fake 0.00
        let dir = std::env::temp_dir().join("bd_report_null_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"kind":"serve","engine":"ternary","mode":"batch","serve_task":"mnli","max_batch":8,"tok_s":10.0,"p95_ms":null,"prefill_p50_ms":null,"prefill_p95_ms":null}"#,
                "\n",
            ),
        )
        .unwrap();
        let md = render(&p).unwrap();
        assert!(
            md.contains("| ternary | batch | mnli | 8 | 1 | byte | 1 | 10.0 | — | — | — |"),
            "{md}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renders_metrics_snapshots() {
        let dir = std::env::temp_dir().join("bd_report_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("metrics.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"kind":"metrics","engine":"ternary","kernel":"byte","steps":50,"wall_s":0.5,"tok_s":800.0,"active":4,"queue_depth":2,"kv_resident_lanes":3,"completed":10,"expired":0,"rejected":0,"batch_fill":{"count":50,"p50":3.5},"total_ms":{"count":10,"p50":3.5,"p95":6.0},"ttft_ms":{"count":10,"p50":1.25}}"#, "\n",
                // early snapshot: nothing finished yet, percentiles null;
                // also a pre-kv/batch-column row — those columns dash
                r#"{"kind":"metrics","engine":"ternary","kernel":"lut","steps":10,"wall_s":0.1,"tok_s":0.0,"active":4,"queue_depth":8,"completed":0,"expired":0,"rejected":0,"total_ms":{"count":0,"p50":null,"p95":null},"ttft_ms":{"count":0,"p50":null}}"#, "\n",
                r#"{"kind":"serve","engine":"x","mode":"batch"}"#, "\n",
            ),
        )
        .unwrap();
        let md = render_metrics(&p).unwrap();
        assert!(
            md.contains(
                "| ternary | byte | 50 | 0.50 | 800.0 | 4 | 2 | 3 | 3.5 | 10 | 0 | 0 | 3.50 | 6.00 | 1.25 |"
            ),
            "{md}"
        );
        assert!(
            md.contains(
                "| ternary | lut | 10 | 0.10 | 0.0 | 4 | 8 | — | — | 0 | 0 | 0 | — | — | — |"
            ),
            "{md}"
        );
        // exactly the two metrics rows — the serve row is skipped
        assert_eq!(md.lines().count(), 4, "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renders_quant_trajectories() {
        let dir = std::env::temp_dir().join("bd_report_quant_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("quant.jsonl");
        std::fs::write(
            &p,
            concat!(
                // stage appears mid-file order: ct before distill, and the
                // table must keep that pipeline order, not alphabetize
                r#"{"kind":"quant","phase":"train","stage":"ct","step":1,"layer":0,"sparsity":0.30,"flip_rate":0.0,"scale":0.012,"scale_drift":0.0,"clip_frac":0.05,"grad_norm":1.5}"#, "\n",
                r#"{"kind":"quant","phase":"train","stage":"ct","step":10,"layer":0,"sparsity":0.35,"flip_rate":0.02,"scale":0.011,"scale_drift":0.001,"clip_frac":0.04,"grad_norm":1.1}"#, "\n",
                r#"{"kind":"quant","phase":"train","stage":"ct","step":1,"layer":-1,"loss":3.2,"ce":3.2}"#, "\n",
                r#"{"kind":"quant","phase":"train","stage":"distill","step":1,"layer":-1,"loss":2.5,"ce":2.0,"ld":0.4,"ad":0.1,"ad_heads":[0.2,0.0]}"#, "\n",
                r#"{"kind":"quant","phase":"serve","layer":0,"site":"attn_in","rows_q":64,"gamma_mean":1.2,"gamma_min":0.8,"gamma_max":2.0,"sat_frac":0.01}"#, "\n",
                r#"{"kind":"quant","phase":"summary","steps_recorded":2}"#, "\n",
                r#"{"kind":"metrics","engine":"x"}"#, "\n",
            ),
        )
        .unwrap();
        let md = render_quant(&p).unwrap();
        // first -> last flip within the ct stage, last sparsity/clip/drift
        assert!(
            md.contains("| ct | 0 | 2 | 0.0000 | 0.0200 | 0.350 | 0.040 | 0.00100 |"),
            "{md}"
        );
        // loss rows: CE-only stage dashes the distill components,
        // distill carries all of them (ad heads mean of [0.2, 0.0])
        assert!(md.contains("| ct | 1 | 3.200 | 3.200 | — | — | — |"), "{md}");
        assert!(
            md.contains("| distill | 1 | 2.500 | 2.000 | 0.400 | 0.100 | 0.100 |"),
            "{md}"
        );
        // serve accumulator row
        assert!(
            md.contains("| 0 | attn_in | 64 | 1.2000 | 0.8000 | 2.0000 | 0.0100 |"),
            "{md}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quant_without_rows_errors() {
        let dir = std::env::temp_dir().join("bd_report_quant_empty_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("quant.jsonl");
        std::fs::write(&p, "{\"kind\":\"metrics\"}\n").unwrap();
        assert!(render_quant(&p).is_err());
        assert!(render_quant("/nonexistent/quant.jsonl").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_without_rows_errors() {
        let dir = std::env::temp_dir().join("bd_report_metrics_empty_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("metrics.jsonl");
        std::fs::write(&p, "{\"kind\":\"serve\"}\n").unwrap();
        assert!(render_metrics(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(render("/nonexistent/results.jsonl").is_err());
        assert!(render_metrics("/nonexistent/metrics.jsonl").is_err());
    }

    #[test]
    fn renders_lint_findings_table() {
        let dir = std::env::temp_dir().join("bd_report_lint_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lint.json");
        std::fs::write(&p, crate::analysis::lint_fixtures().to_json().to_string()).unwrap();
        let md = render_lint(&p).unwrap();
        assert!(md.contains("## lint findings"), "{md}");
        // the table addresses each hit by rule + file:line
        assert!(md.contains("no-panic-in-request-path"), "{md}");
        assert!(md.contains("serve/scheduler.rs:"), "{md}");
        assert!(md.contains("| rule | location | snippet | hint |"), "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renders_clean_lint_report() {
        let dir = std::env::temp_dir().join("bd_report_lint_clean_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lint.json");
        std::fs::write(
            &p,
            "{\"kind\":\"lint\",\"files\":42,\"clean\":true,\"findings\":[]}",
        )
        .unwrap();
        let md = render_lint(&p).unwrap();
        assert!(md.contains("lint clean: 42 files checked."), "{md}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_report_of_wrong_kind_errors() {
        let dir = std::env::temp_dir().join("bd_report_lint_kind_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lint.json");
        std::fs::write(&p, "{\"kind\":\"serve\"}").unwrap();
        assert!(render_lint(&p).is_err());
        assert!(render_lint("/nonexistent/lint.json").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
