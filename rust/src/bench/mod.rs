//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §4 for the index). Results are printed as
//! aligned text tables and appended to reports/results.jsonl so composed
//! experiments (Fig. 1) can reuse cached rows.

pub mod report;

use std::io::Write as _;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::data::{Task, TaskGen, Tokenizer};
use crate::engine::{Engine, ExecCtx, KernelKind};
use crate::obs::{QuantScope, TraceRecorder};
use crate::params::ParamStore;
use crate::pipeline::{self, stages, Ctx, StudentOpts, SummaryMetrics};
use crate::runtime::{ModelSpec, Runtime};
use crate::serve::{ms_or_dash, quantile, FinishReason, Percentiles, Request, Server, ServerCfg};
use crate::substrate::{json, Args, Json, Rng};

/// One evaluated run.
pub struct Score {
    pub task: Task,
    pub size: String,
    pub method: String,
    pub accuracy: Option<f64>,
    pub summary: Option<SummaryMetrics>,
}

impl Score {
    pub fn render(&self) -> String {
        match (self.accuracy, &self.summary) {
            (Some(a), _) => format!(
                "{} {} {} accuracy={a:.2}",
                self.size,
                self.task.name(),
                self.method
            ),
            (_, Some(m)) => format!(
                "{} {} {} bleu={:.2} r1={:.2} r2={:.2} rl={:.2} rlsum={:.2} avg={:.2}",
                self.size,
                self.task.name(),
                self.method,
                m.bleu,
                m.rouge1,
                m.rouge2,
                m.rouge_l,
                m.rouge_lsum,
                m.avg()
            ),
            _ => "<empty score>".into(),
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", json::s(self.task.name())),
            ("size", json::s(&self.size)),
            ("method", json::s(&self.method)),
        ];
        if let Some(a) = self.accuracy {
            pairs.push(("accuracy", json::num(a)));
        }
        if let Some(m) = &self.summary {
            pairs.push(("bleu", json::num(m.bleu)));
            pairs.push(("rouge1", json::num(m.rouge1)));
            pairs.push(("rouge2", json::num(m.rouge2)));
            pairs.push(("rougeL", json::num(m.rouge_l)));
            pairs.push(("rougeLsum", json::num(m.rouge_lsum)));
        }
        json::obj(pairs)
    }
}

fn report(ctx: &Ctx, line: &str, score: Option<&Score>) -> Result<()> {
    let dir = Path::new("reports");
    std::fs::create_dir_all(dir)?;
    println!("{line}");
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join("results.jsonl"))?;
    if let Some(s) = score {
        writeln!(f, "{}", s.to_json().to_string())?;
    } else {
        writeln!(f, "{}", json::obj(vec![("note", json::s(line))]).to_string())?;
    }
    let _ = ctx;
    Ok(())
}

/// Map a manifest model key to its logits-forward artifact.
pub fn fwd_artifact_for(rt: &Runtime, model_key: &str) -> Result<String> {
    let mut it = model_key.splitn(3, '-');
    let (size, subln, quant) = (
        it.next().unwrap_or(""),
        it.next().unwrap_or(""),
        it.next().unwrap_or(""),
    );
    let name = if quant == "none" {
        format!("{size}_teacher_fwd")
    } else {
        let mut suffix = String::new();
        if subln == "nosubln" {
            suffix.push_str("_nosubln");
        }
        if quant != "absmean" {
            suffix.push_str(&format!("_{quant}"));
        }
        format!("{size}_student_fwd{suffix}")
    };
    rt.manifest.artifact(&name)?;
    Ok(name)
}

/// Evaluate a checkpoint on a task: HLO fwd for classification, rust
/// engine (deployment path) for generation.
pub fn evaluate_ckpt(
    ctx: &Ctx,
    ckpt: &Path,
    task: Task,
    size: &str,
    method: &str,
    _opts: &StudentOpts,
) -> Result<Score> {
    let params = ParamStore::load(ckpt)?;
    let spec = ctx.rt.manifest.model(&params.model_key)?;
    let n = pipeline::budget(size).eval_n;
    let ds = pipeline::eval_set(ctx, task, n);
    let mut score = Score {
        task,
        size: size.into(),
        method: method.into(),
        accuracy: None,
        summary: None,
    };
    if task.is_generation() {
        let ternary = spec.config.quant_method != "none";
        let engine = Engine::from_params(spec, &params, ternary)?;
        score.summary = Some(pipeline::eval_summarization(
            &engine,
            &ds[..ds.len().min(64)],
            &ctx.tok,
            24,
        ));
    } else {
        let fwd = fwd_artifact_for(ctx.rt, &params.model_key)?;
        score.accuracy = Some(pipeline::eval_classification(
            ctx.rt, &fwd, &params, &ds, &ctx.tok, task,
        )?);
    }
    Ok(score)
}

// -----------------------------------------------------------------------
// speed / memory (Tables 1-2 right columns, Fig. 1 right panels)
// -----------------------------------------------------------------------

pub fn speed_report(
    rt: &Runtime,
    size: &str,
    tokens: usize,
    kernel: KernelKind,
) -> Result<String> {
    let skey = stages::model_key(size, true, "absmean");
    let spec = rt.manifest.model(&skey)?;
    let tkey = stages::teacher_key(size);
    let tspec = rt.manifest.model(&tkey)?;
    let mut rng = Rng::new(5);
    let sparams = ParamStore::init(spec, &mut rng);
    let tparams = ParamStore::init(tspec, &mut rng);

    let f32e = Engine::from_params(tspec, &tparams, false)?;
    let terne = Engine::from_params(spec, &sparams, true)?.with_kernel(kernel);

    let prompt: Vec<i32> = (5..21).collect();
    let measure = |e: &Engine| -> f64 {
        let mut cache = e.new_cache();
        let mut s = e.new_scratch();
        for &t in &prompt {
            e.decode_step(t, &mut cache, &mut s);
        }
        let t0 = Instant::now();
        let mut tok = 30i32;
        for _ in 0..tokens {
            if cache.len >= cache.max_t {
                cache.reset();
            }
            e.decode_step(tok, &mut cache, &mut s);
            tok = (tok + 7) % 900 + 30;
        }
        tokens as f64 / t0.elapsed().as_secs_f64()
    };

    let tps_f32 = measure(&f32e);
    let tps_tern = measure(&terne);
    let wb_f32 = f32e.weight_bytes();
    let wb_tern = terne.weight_bytes();
    // fp16-equivalent baseline (the paper's reference precision)
    let wb_fp16 = wb_f32 / 2;
    Ok(format!(
        "speed size={size} kernel={} f32_tok_s={tps_f32:.1} ternary_tok_s={tps_tern:.1} \
         speedup_vs_f32={:.2}x\nmemory f32={:.2}MB fp16_equiv={:.2}MB \
         ternary={:.2}MB reduction_vs_fp16={:.1}x reduction_vs_f32={:.1}x",
        kernel.name(),
        tps_tern / tps_f32,
        wb_f32 as f64 / 1e6,
        wb_fp16 as f64 / 1e6,
        wb_tern as f64 / 1e6,
        wb_fp16 as f64 / wb_tern as f64,
        wb_f32 as f64 / wb_tern as f64,
    ))
}

// -----------------------------------------------------------------------
// serving benchmark: continuous batching vs sequential decode
// -----------------------------------------------------------------------

/// One serving measurement (a row of BENCH_serve.json / results.jsonl).
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub engine: String,
    /// "batch" (continuous batching) or "seq" (one request at a time).
    pub mode: String,
    pub task: String,
    pub max_batch: usize,
    /// Engine worker threads ([`ServerCfg::threads`]); 1 = serial.
    pub threads: usize,
    /// Ternary kernel generation ([`KernelKind::name`]): "byte", "lut"
    /// or "simd". Rows written before the column existed default to
    /// "byte" in `bitdistill report`.
    pub kernel: String,
    /// Prompt tokens fed per lane per step
    /// ([`ServerCfg::prefill_chunk`]); sequential rows and rows written
    /// before the column existed back-fill to 1 in `bitdistill report`
    /// (mirroring the `threads`/`kernel` back-fills).
    pub prefill_chunk: usize,
    pub requests: usize,
    pub completed: usize,
    pub tok_s: f64,
    pub req_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Time-to-first-token (submission -> end of prefill), ms.
    pub prefill_p50_ms: f64,
    pub prefill_p95_ms: f64,
    pub mean_occupancy: f64,
}

impl ServeRow {
    pub fn render(&self) -> String {
        // empty-population percentiles are NaN and render as `-`
        // (ms_or_dash), never a fake 0.00ms
        format!(
            "serve engine={} mode={} task={} max_batch={} threads={} kernel={} \
             prefill_chunk={} reqs={} done={} tok_s={:.1} req_s={:.1} p50={} \
             p95={} p99={} ttft_p50={} ttft_p95={} occupancy={:.2}",
            self.engine,
            self.mode,
            self.task,
            self.max_batch,
            self.threads,
            self.kernel,
            self.prefill_chunk,
            self.requests,
            self.completed,
            self.tok_s,
            self.req_s,
            ms_or_dash(self.p50_ms),
            ms_or_dash(self.p95_ms),
            ms_or_dash(self.p99_ms),
            ms_or_dash(self.prefill_p50_ms),
            ms_or_dash(self.prefill_p95_ms),
            self.mean_occupancy,
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s("serve")),
            ("engine", json::s(&self.engine)),
            ("mode", json::s(&self.mode)),
            ("serve_task", json::s(&self.task)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("threads", json::num(self.threads as f64)),
            ("kernel", json::s(&self.kernel)),
            ("prefill_chunk", json::num(self.prefill_chunk as f64)),
            ("requests", json::num(self.requests as f64)),
            ("completed", json::num(self.completed as f64)),
            ("tok_s", json::num(self.tok_s)),
            ("req_s", json::num(self.req_s)),
            ("p50_ms", json::num_or_null(self.p50_ms)),
            ("p95_ms", json::num_or_null(self.p95_ms)),
            ("p99_ms", json::num_or_null(self.p99_ms)),
            ("prefill_p50_ms", json::num_or_null(self.prefill_p50_ms)),
            ("prefill_p95_ms", json::num_or_null(self.prefill_p95_ms)),
            ("mean_occupancy", json::num(self.mean_occupancy)),
        ])
    }
}

/// (f32, ternary) engines for serving over one spec: the manifest's
/// student spec when `artifacts_dir` has a manifest, else the synthetic
/// spec; a trained student checkpoint when one matches, else random
/// init (serving speed/memory do not depend on weight values).
pub fn serving_engines(size: &str, artifacts_dir: &str) -> Result<(Engine, Engine)> {
    let spec: ModelSpec = if Path::new(artifacts_dir).join("manifest.json").exists() {
        let rt = Runtime::open(artifacts_dir)?;
        rt.manifest
            .model(&stages::model_key(size, true, "absmean"))?
            .clone()
    } else {
        ModelSpec::synthetic(size)?
    };
    let params = [
        format!("runs/bitdistill_{size}_mnli_dl2.ckpt"),
        format!("runs/quickstart/bitdistill_{size}_mnli_dl2.ckpt"),
    ]
    .iter()
    .find(|p| Path::new(p.as_str()).exists())
    .map(ParamStore::load)
    .transpose()?
    .filter(|p| p.model_key == spec.key)
    .unwrap_or_else(|| {
        let mut rng = Rng::new(1);
        ParamStore::init(&spec, &mut rng)
    });
    Ok((
        Engine::from_params(&spec, &params, false)?,
        Engine::from_params(&spec, &params, true)?,
    ))
}

/// A deterministic serving workload from the task generators:
/// classification tasks yield classify() requests (prefill + verbalizer
/// argmax), generation tasks yield greedy generate() requests.
pub fn serve_workload(
    task: Task,
    tok: &Tokenizer,
    n: usize,
    seq: usize,
    max_new: usize,
    seed: u64,
) -> Vec<Request> {
    let gen = TaskGen::new(task, tok, seq);
    let label_ids: Vec<i32> = task.label_words().iter().map(|w| tok.id(w)).collect();
    gen.dataset(n, seed)
        .iter()
        .map(|ex| {
            let prompt = ex.tokens[..ex.prompt_len].to_vec();
            if task.is_generation() {
                Request::generate(prompt, max_new)
            } else {
                Request::classify(prompt, label_ids.clone())
            }
        })
        .collect()
}

/// Serve the workload through the continuous-batching [`Server`] with
/// `threads` engine workers, the given ternary `kernel`, and
/// `prefill_chunk` prompt tokens per lane per step (outputs are
/// invariant to all three — the kernels are bitwise identical, so are
/// the thread counts, and so is the chunked prefill; only the
/// throughput/latency/TTFT columns move).
#[allow(clippy::too_many_arguments)]
pub fn serve_batched(
    engine: &Engine,
    name: &str,
    task: &str,
    reqs: &[Request],
    max_batch: usize,
    max_queue: usize,
    threads: usize,
    kernel: KernelKind,
    prefill_chunk: usize,
) -> ServeRow {
    serve_batched_obs(
        engine,
        name,
        task,
        reqs,
        max_batch,
        max_queue,
        threads,
        kernel,
        prefill_chunk,
        &TraceRecorder::disabled(),
        &QuantScope::disabled(),
        0,
    )
    .0
}

/// [`serve_batched`] under observability recorders: request-lifecycle
/// and engine-phase spans land on `trace` (export via
/// [`TraceRecorder::write`]), per-layer int8 activation-range /
/// saturation accumulators land on `quant` (export via
/// [`QuantScope::take_rows`]), and when `metrics_every > 0` the server
/// emits a metrics snapshot every N steps, returned alongside the bench
/// row. The latency columns are computed **exactly** from the
/// per-response [`crate::serve::Timing`]s — the bench contract stays
/// exact-sorted-percentiles even though [`crate::serve::ServeStats`]
/// now aggregates into bounded histograms.
#[allow(clippy::too_many_arguments)]
pub fn serve_batched_obs(
    engine: &Engine,
    name: &str,
    task: &str,
    reqs: &[Request],
    max_batch: usize,
    max_queue: usize,
    threads: usize,
    kernel: KernelKind,
    prefill_chunk: usize,
    trace: &TraceRecorder,
    quant: &QuantScope,
    metrics_every: usize,
) -> (ServeRow, Vec<Json>) {
    let mut srv = Server::new(
        engine,
        ServerCfg { max_batch, max_queue, threads, kernel, prefill_chunk, metrics_every },
    );
    srv.set_trace(trace.clone());
    srv.set_quant_scope(quant.clone());
    let t0 = Instant::now();
    for r in reqs {
        srv.submit(r.clone());
    }
    let rs = srv.run_to_completion();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    // exact percentiles from the per-response timings — the same
    // population the old Vec-backed ServeStats held (completed
    // requests; rejected/expired never reached those Vecs either)
    let done = |r: &&crate::serve::Response| {
        !matches!(
            r.finish,
            FinishReason::Rejected | FinishReason::DeadlineExceeded | FinishReason::Canceled
        )
    };
    let lat: Vec<f64> = rs.iter().filter(done).map(|r| r.timing.total_ms).collect();
    let ttft: Vec<f64> = rs
        .iter()
        .filter(done)
        .map(|r| r.timing.queue_ms + r.timing.prefill_ms)
        .collect();
    let p = Percentiles::of(&lat);
    let (ttft_p50, ttft_p95) = ttft_percentiles(&ttft);
    let row = ServeRow {
        engine: name.to_string(),
        mode: "batch".to_string(),
        task: task.to_string(),
        max_batch,
        threads: threads.max(1),
        kernel: kernel.name().to_string(),
        prefill_chunk: prefill_chunk.max(1),
        requests: reqs.len(),
        completed: srv.stats.completed,
        tok_s: (srv.stats.prompt_tokens + srv.stats.new_tokens) as f64 / wall,
        req_s: srv.stats.completed as f64 / wall,
        p50_ms: p.p50,
        p95_ms: p.p95,
        p99_ms: p.p99,
        prefill_p50_ms: ttft_p50,
        prefill_p95_ms: ttft_p95,
        mean_occupancy: srv.stats.mean_occupancy(),
    };
    (row, srv.take_snapshots())
}

/// TTFT (p50, p95); NaN when no request recorded a prefill (e.g. a
/// fully rejected workload) — rendered as `-` / serialized as `null`,
/// never a fake 0.0ms ([`crate::serve::Percentiles`] owns the NaN-safe
/// sort and the empty-input contract).
fn ttft_percentiles(ttft_ms: &[f64]) -> (f64, f64) {
    let p = crate::serve::Percentiles::of(ttft_ms);
    (p.p50, p.p95)
}

/// The pre-serve baseline: one request at a time through the sequential
/// engine path with a single reset KV cache (the old serve_cpu loop),
/// on the given ternary `kernel`. The prompt phase is timed separately
/// so sequential rows carry honest TTFT columns, on the **same
/// definition the batch rows use** — time from workload start (all
/// requests arrive at once) to that request's end of prefill, i.e.
/// queue wait plus prefill; the decode loop is exactly
/// [`Engine::generate`]'s (shared `greedy_continue`).
pub fn serve_sequential(
    engine: &Engine,
    name: &str,
    task: &str,
    reqs: &[Request],
    kernel: KernelKind,
) -> ServeRow {
    use crate::engine::argmax;
    let ctx = ExecCtx::serial().with_kernel(kernel);
    let mut cache = engine.new_cache();
    let mut s = engine.new_scratch();
    let mut lat_ms = Vec::with_capacity(reqs.len());
    let mut prefill_ms = Vec::with_capacity(reqs.len());
    let mut prompt_tokens = 0usize;
    let mut new_tokens = 0usize;
    let t0 = Instant::now();
    for r in reqs {
        let t1 = Instant::now();
        cache.reset();
        for &t in &r.prompt {
            engine.decode_step_ctx(&ctx, t, &mut cache, &mut s);
        }
        // TTFT on the batch rows' definition (submission -> end of
        // prefill, all requests submitted up front): in a serial queue
        // that is time since workload start, not since this request's
        // turn began — without the queue term the seq column would
        // read lower than the batch server's even when the server
        // reaches first tokens strictly sooner
        prefill_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        if r.is_classification() {
            // same verbalizer argmax the server runs (one shared
            // definition: crate::engine::argmax_labels)
            std::hint::black_box(crate::engine::argmax_labels(&s.logits, &r.label_ids));
        } else {
            // Engine::generate's own decode loop (greedy_continue),
            // continuing from the prefilled cache — one source of
            // truth, so the baseline cannot drift from generate()
            let next = argmax(&s.logits);
            let out = engine.greedy_continue_ctx(&ctx, next, r.max_new, r.eos, &mut cache, &mut s);
            new_tokens += out.len();
        }
        prompt_tokens += r.prompt.len();
        lat_ms.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    lat_ms.sort_by(f64::total_cmp); // NaN-safe (panic-free stats path)
    let (ttft_p50, ttft_p95) = ttft_percentiles(&prefill_ms);
    ServeRow {
        engine: name.to_string(),
        mode: "seq".to_string(),
        task: task.to_string(),
        max_batch: 1,
        threads: 1,
        kernel: kernel.name().to_string(),
        prefill_chunk: 1,
        requests: reqs.len(),
        completed: reqs.len(),
        tok_s: (prompt_tokens + new_tokens) as f64 / wall,
        req_s: reqs.len() as f64 / wall,
        p50_ms: quantile(&lat_ms, 0.50),
        p95_ms: quantile(&lat_ms, 0.95),
        p99_ms: quantile(&lat_ms, 0.99),
        prefill_p50_ms: ttft_p50,
        prefill_p95_ms: ttft_p95,
        mean_occupancy: 1.0,
    }
}

/// One open-loop serving measurement: a `kind:"serve_open"` row of
/// BENCH_serve.json. Open-loop means arrivals follow a Poisson process
/// at `offered_req_s` regardless of how far behind the server falls —
/// the regime where overload actually happens (closed-loop benches
/// self-throttle and can never oversubscribe the queue). The saturation
/// story is in the columns: as `load_mult` crosses 1.0, `completed`
/// flattens at capacity while `rejected`/`expired` absorb the excess and
/// completed-request p99 stays bounded by the deadline — the shed curve
/// the overload-hardening contract promises.
#[derive(Debug, Clone)]
pub struct OpenLoopRow {
    pub engine: String,
    pub task: String,
    /// Offered load as a multiple of the measured closed-loop capacity.
    pub load_mult: f64,
    /// Poisson arrival rate actually offered (requests/s).
    pub offered_req_s: f64,
    pub max_batch: usize,
    pub threads: usize,
    pub kernel: String,
    pub requests: usize,
    pub completed: usize,
    pub rejected: usize,
    pub expired: usize,
    pub canceled: usize,
    pub completed_req_s: f64,
    /// Fraction of offered requests shed (rejected + expired).
    pub shed_rate: f64,
    /// Exact percentiles over *completed* requests only — the bounded-p99
    /// claim is about the requests the server chose to serve.
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl OpenLoopRow {
    pub fn render(&self) -> String {
        format!(
            "serve_open engine={} task={} mult={:.2} offered_req_s={:.1} reqs={} \
             done={} rejected={} expired={} canceled={} done_req_s={:.1} \
             shed={:.2} p50={} p99={}",
            self.engine,
            self.task,
            self.load_mult,
            self.offered_req_s,
            self.requests,
            self.completed,
            self.rejected,
            self.expired,
            self.canceled,
            self.completed_req_s,
            self.shed_rate,
            ms_or_dash(self.p50_ms),
            ms_or_dash(self.p99_ms),
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s("serve_open")),
            ("engine", json::s(&self.engine)),
            ("serve_task", json::s(&self.task)),
            ("load_mult", json::num(self.load_mult)),
            ("offered_req_s", json::num(self.offered_req_s)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("threads", json::num(self.threads as f64)),
            ("kernel", json::s(&self.kernel)),
            ("requests", json::num(self.requests as f64)),
            ("completed", json::num(self.completed as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("expired", json::num(self.expired as f64)),
            ("canceled", json::num(self.canceled as f64)),
            ("completed_req_s", json::num(self.completed_req_s)),
            ("shed_rate", json::num(self.shed_rate)),
            ("p50_ms", json::num_or_null(self.p50_ms)),
            ("p99_ms", json::num_or_null(self.p99_ms)),
        ])
    }
}

/// Drive the server open-loop: Poisson arrivals at `offered_req_s`
/// (seeded, deterministic in the *schedule* — wall-clock decides how far
/// behind the stepper falls), every request carrying `deadline` so the
/// scheduler sheds what it cannot serve in time instead of letting the
/// queue's sojourn time grow without bound. Steps the scheduler between
/// arrivals; never blocks waiting for capacity (that would close the
/// loop and hide the overload).
#[allow(clippy::too_many_arguments)]
pub fn serve_open_loop(
    engine: &Engine,
    name: &str,
    task: &str,
    reqs: &[Request],
    cfg: ServerCfg,
    offered_req_s: f64,
    load_mult: f64,
    deadline: Duration,
    seed: u64,
) -> OpenLoopRow {
    let mut srv = Server::new(engine, cfg);
    let mut rng = Rng::new(seed);
    let rate = offered_req_s.max(1e-9);
    let mut responses: Vec<crate::serve::Response> = Vec::with_capacity(reqs.len());
    let t0 = Instant::now();
    let mut next_arrival = 0.0f64; // seconds since t0
    let mut i = 0usize;
    while i < reqs.len() || srv.has_work() {
        let now = t0.elapsed().as_secs_f64();
        if i < reqs.len() && now >= next_arrival {
            srv.submit(reqs[i].clone().with_deadline(deadline));
            i += 1;
            // exponential inter-arrival gap (inverse-CDF on the seeded
            // uniform; 1-u keeps ln()'s argument in (0,1])
            next_arrival += -(1.0 - rng.f64()).ln() / rate;
            continue;
        }
        if srv.has_work() {
            srv.step();
            responses.extend(srv.take_completed());
        } else if i < reqs.len() {
            // idle until the next arrival, in small slices so a late
            // clock tick never overshoots the schedule by much
            let wait = (next_arrival - now).max(0.0).min(1e-3);
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
    }
    responses.extend(srv.take_completed());
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let lat: Vec<f64> = responses
        .iter()
        .filter(|r| {
            !matches!(
                r.finish,
                FinishReason::Rejected | FinishReason::DeadlineExceeded | FinishReason::Canceled
            )
        })
        .map(|r| r.timing.total_ms)
        .collect();
    let p = Percentiles::of(&lat);
    let shed = srv.stats.rejected + srv.stats.expired;
    OpenLoopRow {
        engine: name.to_string(),
        task: task.to_string(),
        load_mult,
        offered_req_s,
        max_batch: cfg.max_batch,
        threads: cfg.threads.max(1),
        kernel: cfg.kernel.name().to_string(),
        requests: reqs.len(),
        completed: srv.stats.completed,
        rejected: srv.stats.rejected,
        expired: srv.stats.expired,
        canceled: srv.stats.canceled,
        completed_req_s: srv.stats.completed as f64 / wall,
        shed_rate: shed as f64 / reqs.len().max(1) as f64,
        p50_ms: p.p50,
        p99_ms: p.p99,
    }
}

/// A pure-prefill workload for the TTFT benches: `n` greedy generate()
/// requests of `prompt_len` pseudo-random tokens with `max_new = 0`
/// (each retires on its first sampled token), isolating prompt
/// throughput and time-to-first-token.
pub fn long_prompt_workload(n: usize, prompt_len: usize, vocab: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n.max(1))
        .map(|_| {
            let prompt: Vec<i32> = (0..prompt_len.max(1))
                .map(|_| rng.below(vocab) as i32)
                .collect();
            Request::generate(prompt, 0)
        })
        .collect()
}

/// Shared writer for the per-bench trajectory files
/// (reports/BENCH_<name>.json): `{"bench": <name>, "rows": [...]}`.
fn write_bench_report(bench: &str, rows: Vec<Json>, path: impl AsRef<Path>) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let j = json::obj(vec![("bench", json::s(bench)), ("rows", Json::Arr(rows))]);
    std::fs::write(path.as_ref(), j.to_string())?;
    Ok(())
}

/// Shared appender for results.jsonl rows (one JSON object per line).
/// Append JSON rows to a JSONL file, creating parent directories as
/// needed (shared by the results log and `serve --metrics-out`).
pub fn append_jsonl_rows(rows: Vec<Json>, path: impl AsRef<Path>) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path.as_ref())?;
    for row in rows {
        writeln!(f, "{}", row.to_string())?;
    }
    Ok(())
}

/// Write the serving-throughput trajectory file (reports/BENCH_serve.json).
pub fn write_serve_report(rows: &[ServeRow], path: impl AsRef<Path>) -> Result<()> {
    write_serve_report_full(rows, &[], path)
}

/// [`write_serve_report`] with the open-loop saturation rows appended —
/// one file carries both the closed-loop throughput grid (`kind:"serve"`)
/// and the shed curves (`kind:"serve_open"`).
pub fn write_serve_report_full(
    rows: &[ServeRow],
    open: &[OpenLoopRow],
    path: impl AsRef<Path>,
) -> Result<()> {
    let mut all: Vec<Json> = rows.iter().map(ServeRow::to_json).collect();
    all.extend(open.iter().map(OpenLoopRow::to_json));
    write_bench_report("serve", all, path)
}

/// Append serve rows to reports/results.jsonl so `bitdistill report`
/// renders the serving table next to the paper tables.
pub fn append_serve_results(rows: &[ServeRow], path: impl AsRef<Path>) -> Result<()> {
    append_jsonl_rows(rows.iter().map(ServeRow::to_json).collect(), path)
}

// -----------------------------------------------------------------------
// kernel microbench + CI perf gate (`bitdistill bench --check`)
// -----------------------------------------------------------------------

/// One kernel measurement: a row of reports/BENCH_kernels.json.
#[derive(Debug, Clone)]
pub struct KernelRow {
    pub n_out: usize,
    pub k_in: usize,
    /// "f32" | "byte" | "lut" | "simd".
    pub kernel: String,
    /// Best (minimum) per-iteration mean over the `--repeats` timing
    /// runs — a noise-floor estimate, deliberately not an average, so
    /// rows are comparable across runs with different repeat counts.
    pub best_ns: f64,
    /// Effective multiply-add throughput, 2*n*k / best_ns (GOP/s).
    pub gops: f64,
    pub speedup_vs_f32: f64,
}

impl KernelRow {
    pub fn render(&self) -> String {
        format!(
            "kernel gemv n_out={} k_in={} kernel={} best_ns={:.0} gops={:.2} \
             speedup_vs_f32={:.2}x",
            self.n_out, self.k_in, self.kernel, self.best_ns, self.gops, self.speedup_vs_f32
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s("kernel")),
            ("n_out", json::num(self.n_out as f64)),
            ("k_in", json::num(self.k_in as f64)),
            ("kernel", json::s(&self.kernel)),
            ("best_ns", json::num(self.best_ns)),
            ("gops", json::num(self.gops)),
            ("speedup_vs_f32", json::num(self.speedup_vs_f32)),
        ])
    }
}

/// One chunked-prefill measurement: a `kind:"prefill"` row of
/// reports/BENCH_kernels.json. `tok_s` is prompt tokens per second
/// through [`crate::engine::prefill`] at the given chunk size;
/// `speedup_vs_chunk1` compares against the token-by-token baseline on
/// the same engine (the quantity the `bench --check` prefill gate
/// enforces).
#[derive(Debug, Clone)]
pub struct PrefillRow {
    pub prompt_len: usize,
    pub chunk: usize,
    /// "byte" | "lut" (the ternary kernel under the chunked GEMMs).
    pub kernel: String,
    pub best_ns: f64,
    pub tok_s: f64,
    pub speedup_vs_chunk1: f64,
}

impl PrefillRow {
    pub fn render(&self) -> String {
        format!(
            "prefill prompt_len={} chunk={} kernel={} best_ns={:.0} tok_s={:.1} \
             speedup_vs_chunk1={:.2}x",
            self.prompt_len, self.chunk, self.kernel, self.best_ns, self.tok_s,
            self.speedup_vs_chunk1
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s("prefill")),
            ("prompt_len", json::num(self.prompt_len as f64)),
            ("chunk", json::num(self.chunk as f64)),
            ("kernel", json::s(&self.kernel)),
            ("best_ns", json::num(self.best_ns)),
            ("tok_s", json::num(self.tok_s)),
            ("speedup_vs_chunk1", json::num(self.speedup_vs_chunk1)),
        ])
    }
}

/// `bitdistill bench --check` — the CI perf gate over the ternary GEMV
/// kernels. Needs no artifacts. Measures, at fixed synthetic shapes
/// spanning the attention-projection and FFN regimes (the `n_out >=
/// 1024` rows stand in for the widest ternary matmuls; the engine's LM
/// head itself is f32 and out of scope):
///
/// - `gemv_f32` (the FP baseline),
/// - `gemv_ternary` (byte-decode, on a pre-quantized activation —
///   activation quant is timed by neither ternary kernel, keeping the
///   byte-vs-LUT comparison about the kernels themselves),
/// - the activation-LUT kernel (same pre-quantized activation, plus
///   its per-call table build — the *unamortized* worst case; the
///   engine amortizes one build over Q/K/V or gate/up),
/// - the runtime-dispatched SIMD kernel ([`crate::engine::simd`], same
///   pre-quantized activation; on hosts without AVX2/NEON it times the
///   scalar fallback),
///
/// writes every row to reports/BENCH_kernels.json, and **fails** (so CI
/// goes red) when:
///
/// - byte-decode or LUT throughput drops below `--min-speedup` (default
///   1.0) times the f32 baseline, or
/// - the LUT kernel is slower than byte-decode at `n_out >= 1024`
///   (ratio below `--min-lut-ratio`, default 1.0) — the regime the LUT
///   rewrite exists for, or
/// - on hosts where [`crate::engine::simd::ternary_simd_available`]
///   reports support, the SIMD kernel is slower than the LUT kernel at
///   `n_out >= 1024` (ratio below `--min-simd-ratio`, default 1.0) —
///   the regime the in-register decode exists for. On hosts without
///   support the perf gate is skipped and the scalar fallback is
///   instead checked for **bitwise parity** with byte-decode, so the
///   gate never flakes on feature-poor runners but dispatch can never
///   silently change bits, or
/// - chunked prefill (chunk = `--prefill-chunk`, default 8) fails to
///   reach `--min-prefill-speedup` (default 1.5) times the unchunked
///   (chunk 1) prompt tok/s at `--prefill-prompt-len` (default 256)
///   tokens on the synthetic tiny ternary engine — the LM-head-skip +
///   time-batched-GEMM win the chunked prefill subsystem exists for
///   (`kind:"prefill"` rows land in BENCH_kernels.json too), or
/// - batched decode under an **enabled** span recorder drops below
///   `--min-obs-ratio` (default 0.98) times the uninstrumented decode
///   on the same engine — the [`crate::obs`] zero-cost-off /
///   low-cost-on contract, gated so instrumentation can never quietly
///   tax the hot path (`kind:"obs"` rows land in BENCH_kernels.json), or
/// - native QAT step throughput with a **live**
///   [`crate::obs::QuantScope`] at stride 10 drops below
///   `--min-quant-ratio` (default 0.95) times the uninstrumented
///   trainer on the synthetic tiny student — the quantization-telemetry
///   half of the same contract: a recorded step re-quantizes every
///   ternary matrix, so the stride must amortize it to noise
///   (`kind:"obs"` rows, modes `qat_off` / `qat_on`).
///
/// `--repeats N` (default 3) takes the best of N timing runs per kernel
/// to damp shared-runner noise.
pub fn bench_check(args: &Args) -> Result<()> {
    use crate::engine::gemv::{gemv_f32, gemv_ternary};
    use crate::engine::lut::{lut_gemv, LutScratch};
    use crate::engine::simd::{simd_gemv, ternary_simd_available};
    use crate::engine::{act_quant_i8, TernaryMatrix};
    use crate::substrate::bench::bench as microbench;

    let min_vs_f32 = args.f64("min-speedup", 1.0);
    let min_lut_vs_byte = args.f64("min-lut-ratio", 1.0);
    let min_simd_vs_lut = args.f64("min-simd-ratio", 1.0);
    let repeats = args.usize("repeats", 3).max(1);
    // validated up front so a bad flag fails before any timing runs
    let prefill_chunk_arg = args.usize("prefill-chunk", 8);
    if prefill_chunk_arg < 2 {
        bail!(
            "--prefill-chunk must be >= 2 for the prefill gate: chunk 1 IS the \
             token-by-token baseline the gate compares against"
        );
    }
    // (n_out, k_in): attention-projection and FFN-like shapes; the
    // >= 1024 rows are the LUT gate points
    let shapes = [(256usize, 256usize), (1024, 256), (1024, 1024), (2048, 1024)];

    let mut rows: Vec<KernelRow> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    for (n, k) in shapes {
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; n * k];
        rng.fill_normal(&mut w, 0.05);
        let mut x = vec![0.0f32; k];
        rng.fill_normal(&mut x, 1.0);
        let m = TernaryMatrix::from_xw_f32(&w, k, n); // [in,out] read; dims ok for timing
        let mut q = vec![0i8; k];
        let gamma = act_quant_i8(&x, &mut q);
        let flops = 2.0 * n as f64 * k as f64;

        let best = |name: &str, f: &mut dyn FnMut() -> f32| -> f64 {
            let mut best_ns = f64::INFINITY;
            for _ in 0..repeats {
                best_ns = best_ns.min(microbench(name, &mut *f).mean_ns);
            }
            best_ns
        };

        let mut yf = vec![0.0f32; n];
        let f32_ns = best(&format!("gemv_f32_{n}x{k}"), &mut || {
            gemv_f32(&w, n, k, &x, &mut yf);
            yf[0]
        });
        let mut yb = vec![0.0f32; m.rows];
        let byte_ns = best(&format!("gemv_byte_{n}x{k}"), &mut || {
            gemv_ternary(&m, &q, gamma, &mut yb);
            yb[0]
        });
        let mut yl = vec![0.0f32; m.rows];
        let mut lscratch = LutScratch::for_dims(k, 1);
        let lut_ns = best(&format!("gemv_lut_{n}x{k}"), &mut || {
            let table = lscratch.build(&q);
            lut_gemv(&m, table, gamma, &mut yl);
            yl[0]
        });
        let mut ys = vec![0.0f32; m.rows];
        let simd_ns = best(&format!("gemv_simd_{n}x{k}"), &mut || {
            simd_gemv(&m, &q, gamma, &mut ys);
            ys[0]
        });

        for (kernel, ns) in
            [("f32", f32_ns), ("byte", byte_ns), ("lut", lut_ns), ("simd", simd_ns)]
        {
            let row = KernelRow {
                n_out: n,
                k_in: k,
                kernel: kernel.to_string(),
                best_ns: ns,
                gops: flops / ns,
                speedup_vs_f32: f32_ns / ns,
            };
            println!("{}", row.render());
            rows.push(row);
        }

        let byte_speedup = f32_ns / byte_ns;
        let lut_speedup = f32_ns / lut_ns;
        let lut_vs_byte = byte_ns / lut_ns;
        if byte_speedup < min_vs_f32 {
            failures.push(format!(
                "gemv_ternary (byte) {n}x{k}: {byte_speedup:.2}x vs f32 < {min_vs_f32:.2}x"
            ));
        }
        if lut_speedup < min_vs_f32 {
            failures.push(format!(
                "lut_gemv {n}x{k}: {lut_speedup:.2}x vs f32 < {min_vs_f32:.2}x"
            ));
        }
        if n >= 1024 && lut_vs_byte < min_lut_vs_byte {
            failures.push(format!(
                "lut_gemv {n}x{k}: {lut_vs_byte:.2}x vs byte-decode < \
                 {min_lut_vs_byte:.2}x (LUT must win at n_out >= 1024)"
            ));
        }
        if ternary_simd_available() {
            // perf gate only where the host actually has the vector path
            let simd_speedup = f32_ns / simd_ns;
            let simd_vs_lut = lut_ns / simd_ns;
            if simd_speedup < min_vs_f32 {
                failures.push(format!(
                    "simd_gemv {n}x{k}: {simd_speedup:.2}x vs f32 < {min_vs_f32:.2}x"
                ));
            }
            if n >= 1024 && simd_vs_lut < min_simd_vs_lut {
                failures.push(format!(
                    "simd_gemv {n}x{k}: {simd_vs_lut:.2}x vs lut < \
                     {min_simd_vs_lut:.2}x (SIMD must win at n_out >= 1024)"
                ));
            }
        } else if let Some(i) = (0..yb.len()).find(|&i| yb[i].to_bits() != ys[i].to_bits()) {
            // feature-poor host: the dispatched kernel IS the scalar
            // fallback — hold it to the bitwise contract, not a perf bar
            failures.push(format!(
                "simd_gemv {n}x{k} scalar fallback: diverges from byte-decode at \
                 row {i} ({:?} vs {:?})",
                ys[i], yb[i]
            ));
        }
    }

    // --- chunked-prefill gate (the tentpole's perf contract) ---
    let min_prefill = args.f64("min-prefill-speedup", 1.5);
    let prompt_len = args.usize("prefill-prompt-len", 256);
    let chunk = prefill_chunk_arg;
    // The synthetic specs carry a toy 1024-token vocab, which
    // under-weights the `d_model x vocab` LM head by 1-2 orders of
    // magnitude vs real tokenizers (32k-150k entries) — and the head
    // skip is exactly what chunked prefill saves. Widen the gate
    // engine's vocab to `--prefill-vocab` (default 8192) so the bench
    // shape is head-proportioned like a real model; the bitwise
    // contract is vocab-independent (property-tested at engine level).
    let vocab = args.usize("prefill-vocab", 8192);
    let mut spec = ModelSpec::synthetic("tiny")?;
    let d_model = spec.config.d_model;
    spec.config.vocab = vocab;
    for p in spec.params.iter_mut() {
        if p.name == "embed" {
            p.shape = vec![vocab, d_model];
        }
    }
    let mut rng = Rng::new(9);
    let params = ParamStore::init(&spec, &mut rng);
    let engine = Engine::from_params(&spec, &params, true)?;
    // tiny's engine capacity is seq.max(256) = 256, so the default
    // prompt_len 256 is measured in full; larger requests clamp here
    let prompt_len = prompt_len.min(engine.max_seq());
    if chunk > prompt_len {
        bail!(
            "--prefill-chunk {chunk} exceeds the gate prompt length {prompt_len} \
             (engine capacity {})",
            engine.max_seq()
        );
    }
    let prompt: Vec<i32> = (0..prompt_len)
        .map(|i| (i * 13 + 7) as i32 % spec.config.vocab as i32)
        .collect();
    let mut prefill_rows: Vec<PrefillRow> = Vec::new();
    for kernel in [KernelKind::ByteDecode, KernelKind::Lut] {
        let ectx = ExecCtx::serial().with_kernel(kernel);
        // baseline (reported as chunk 1): the pre-chunking prompt path —
        // one decode_step per token, full LM head every step, exactly
        // what the serve scheduler runs with --prefill-chunk off
        let base_ns = {
            let mut cache = engine.new_cache();
            let mut s = engine.new_scratch();
            let mut run = || {
                cache.reset();
                for &t in &prompt {
                    engine.decode_step_ctx(&ectx, t, &mut cache, &mut s);
                }
                s.logits[0]
            };
            let name = format!("prefill_{}_{prompt_len}_c1", kernel.name());
            let mut best_ns = f64::INFINITY;
            for _ in 0..repeats {
                best_ns = best_ns.min(microbench(&name, &mut run).mean_ns);
            }
            best_ns
        };
        // chunked: time-batched GEMMs + interior-chunk LM-head skip
        let chunk_ns = {
            let mut cache = engine.new_cache();
            let mut ps = engine.new_prefill_scratch(chunk);
            let mut run = || {
                cache.reset();
                engine.prefill_prompt_ctx(&ectx, &prompt, chunk, &mut cache, &mut ps);
                ps.final_logits()[0]
            };
            let name = format!("prefill_{}_{prompt_len}_c{chunk}", kernel.name());
            let mut best_ns = f64::INFINITY;
            for _ in 0..repeats {
                best_ns = best_ns.min(microbench(&name, &mut run).mean_ns);
            }
            best_ns
        };
        let speedup = base_ns / chunk_ns;
        for (csize, ns) in [(1usize, base_ns), (chunk, chunk_ns)] {
            let row = PrefillRow {
                prompt_len,
                chunk: csize,
                kernel: kernel.name().to_string(),
                best_ns: ns,
                tok_s: prompt_len as f64 / (ns * 1e-9),
                speedup_vs_chunk1: base_ns / ns,
            };
            println!("{}", row.render());
            prefill_rows.push(row);
        }
        if speedup < min_prefill {
            failures.push(format!(
                "chunked prefill ({}, chunk {chunk}, prompt {prompt_len}): {speedup:.2}x \
                 vs token-by-token < {min_prefill:.2}x",
                kernel.name()
            ));
        }
    }

    // --- observability overhead gate (the obs zero-cost-off contract) ---
    // Batched decode on the same widened-vocab ternary engine, with the
    // span recorder disabled vs enabled. The recorder buffer is cleared
    // at the start of every timed run so the enabled path always pays
    // the full record cost (a capped-out buffer drops events, which is
    // *cheaper* and would flatter the measurement).
    let min_obs_ratio = args.f64("min-obs-ratio", 0.98);
    let obs_batch = 4usize;
    let obs_steps = 32usize.min(engine.max_seq().saturating_sub(1)).max(1);
    let mut pool = engine.new_cache_pool(obs_batch);
    let mut bs = engine.new_batch_scratch(obs_batch);
    let slots: Vec<usize> = (0..obs_batch).collect();
    let tokens: Vec<i32> = (0..obs_batch).map(|i| (i * 31 + 3) as i32 % vocab as i32).collect();
    let mut obs_rows: Vec<Json> = Vec::new();
    let mut obs_time = |name: &str, rec: &TraceRecorder| -> f64 {
        let octx = ExecCtx::serial().with_trace(rec.clone());
        let mut run = || {
            rec.clear();
            for s in &slots {
                pool.slots[*s].reset();
            }
            for _ in 0..obs_steps {
                engine.decode_step_batch_ctx(&octx, &tokens, &slots, &mut pool, &mut bs);
            }
            bs.logits_row(0)[0]
        };
        let mut best_ns = f64::INFINITY;
        for _ in 0..repeats {
            best_ns = best_ns.min(microbench(name, &mut run).mean_ns);
        }
        best_ns
    };
    let off_ns = obs_time("decode_obs_off", &TraceRecorder::disabled());
    let on_ns = obs_time("decode_obs_on", &TraceRecorder::enabled());
    let obs_ratio = off_ns / on_ns;
    for (mode, ns) in [("off", off_ns), ("on", on_ns)] {
        let row = json::obj(vec![
            ("kind", json::s("obs")),
            ("mode", json::s(mode)),
            ("batch", json::num(obs_batch as f64)),
            ("steps", json::num(obs_steps as f64)),
            ("best_ns", json::num(ns)),
            ("ratio_vs_off", json::num(off_ns / ns)),
        ]);
        println!(
            "obs decode mode={mode} batch={obs_batch} steps={obs_steps} best_ns={ns:.0} \
             ratio_vs_off={:.3}x",
            off_ns / ns
        );
        obs_rows.push(row);
    }
    if obs_ratio < min_obs_ratio {
        failures.push(format!(
            "obs overhead: traced decode at {obs_ratio:.3}x of untraced < \
             {min_obs_ratio:.3}x (span recording is taxing the hot path)"
        ));
    }

    // --- QAT telemetry overhead gate (QuantScope half of the contract) ---
    // Native train steps on the synthetic tiny student, QuantScope off
    // vs enabled at stride 10 (the CLI default). Each timed run covers
    // exactly one stride, so the enabled path always pays one full
    // record (re-quantizing all seven ternary matrices per layer);
    // clearing between runs keeps the row buffer from capping out and
    // silently cheapening later runs.
    let min_quant_ratio = args.f64("min-quant-ratio", 0.95);
    let qat_stride = 10usize;
    let qat_steps = qat_stride;
    let qspec = ModelSpec::synthetic("tiny")?;
    let (qb, qt) = (4usize, 32usize);
    let qvocab = qspec.config.vocab as i32;
    let mut qtoks = Vec::with_capacity(qb * qt);
    let mut qlabs = Vec::with_capacity(qb * qt);
    for r in 0..qb {
        for p in 0..qt {
            qtoks.push(((r * 5 + 3 * p) as i32) % qvocab);
            qlabs.push(((r * 5 + 3 * (p + 1)) as i32) % qvocab);
        }
    }
    let qbatch = crate::data::Batch {
        tokens: crate::tensor::TensorI32::from_vec(&[qb, qt], qtoks)?,
        labels: crate::tensor::TensorI32::from_vec(&[qb, qt], qlabs)?,
        idx: Vec::new(),
    };
    let mut qrng = Rng::new(11);
    let qparams = ParamStore::init(&qspec, &mut qrng);
    let mut qtr = crate::train::NativeTrainer::new(qspec, qparams);
    let mut qat_time = |name: &str, qs: &QuantScope| -> f64 {
        qtr.quant = qs.clone();
        let mut run = || {
            qs.clear();
            let mut last = 0.0f32;
            for _ in 0..qat_steps {
                last = qtr.train_step(&qbatch, 1e-3).expect("qat gate step");
            }
            last
        };
        let mut best_ns = f64::INFINITY;
        for _ in 0..repeats {
            best_ns = best_ns.min(microbench(name, &mut run).mean_ns);
        }
        best_ns
    };
    let qat_off_ns = qat_time("qat_obs_off", &QuantScope::disabled());
    let qat_on_ns = qat_time("qat_obs_on", &QuantScope::enabled(qat_stride));
    let qat_ratio = qat_off_ns / qat_on_ns;
    for (mode, ns) in [("qat_off", qat_off_ns), ("qat_on", qat_on_ns)] {
        let row = json::obj(vec![
            ("kind", json::s("obs")),
            ("mode", json::s(mode)),
            ("batch", json::num(qb as f64)),
            ("steps", json::num(qat_steps as f64)),
            ("best_ns", json::num(ns)),
            ("ratio_vs_off", json::num(qat_off_ns / ns)),
        ]);
        println!(
            "obs qat mode={mode} batch={qb} steps={qat_steps} best_ns={ns:.0} \
             ratio_vs_off={:.3}x",
            qat_off_ns / ns
        );
        obs_rows.push(row);
    }
    if qat_ratio < min_quant_ratio {
        failures.push(format!(
            "quant telemetry overhead: instrumented QAT (stride {qat_stride}) at \
             {qat_ratio:.3}x of uninstrumented < {min_quant_ratio:.3}x (QuantScope \
             is taxing the training step)"
        ));
    }

    let mut all_rows: Vec<Json> = rows.iter().map(KernelRow::to_json).collect();
    all_rows.extend(prefill_rows.iter().map(PrefillRow::to_json));
    all_rows.extend(obs_rows);
    let n_rows = all_rows.len();
    write_bench_report("kernels", all_rows, "reports/BENCH_kernels.json")?;
    println!("wrote reports/BENCH_kernels.json ({n_rows} rows)");
    if !failures.is_empty() {
        bail!("kernel perf gate FAILED:\n  {}", failures.join("\n  "));
    }
    println!(
        "kernel perf gate passed ({} shapes + prefill at prompt_len {prompt_len} + obs \
         overhead {obs_ratio:.3}x + qat telemetry {qat_ratio:.3}x)",
        shapes.len()
    );
    Ok(())
}

// -----------------------------------------------------------------------
// native-training benchmark rows (benches/train.rs)
// -----------------------------------------------------------------------

/// One native-training measurement: a row of reports/BENCH_train.json
/// and a `kind:"train"` line in results.jsonl (rendered by
/// `bitdistill report`).
#[derive(Debug, Clone)]
pub struct TrainRow {
    pub backend: String,
    pub size: String,
    /// "ce" (lm/bitnet step) or "distill" (stage-3 step).
    pub phase: String,
    pub steps: usize,
    pub tok_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl TrainRow {
    /// Summarize per-step wall times (ms) via the serve-layer quantile.
    pub fn from_step_times(
        backend: &str,
        size: &str,
        phase: &str,
        tokens_per_step: usize,
        step_ms: &[f64],
    ) -> TrainRow {
        let total_s: f64 = step_ms.iter().sum::<f64>() / 1e3;
        TrainRow {
            backend: backend.to_string(),
            size: size.to_string(),
            phase: phase.to_string(),
            steps: step_ms.len(),
            tok_s: tokens_per_step as f64 * step_ms.len() as f64 / total_s.max(1e-9),
            p50_ms: crate::serve::stats::quantile_unsorted(step_ms, 0.50),
            p95_ms: crate::serve::stats::quantile_unsorted(step_ms, 0.95),
        }
    }

    pub fn render(&self) -> String {
        format!(
            "train backend={} size={} phase={} steps={} tok_s={:.1} p50={:.2}ms p95={:.2}ms",
            self.backend, self.size, self.phase, self.steps, self.tok_s, self.p50_ms, self.p95_ms
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s("train")),
            ("backend", json::s(&self.backend)),
            ("size", json::s(&self.size)),
            ("phase", json::s(&self.phase)),
            ("steps", json::num(self.steps as f64)),
            ("tok_s", json::num(self.tok_s)),
            ("p50_ms", json::num(self.p50_ms)),
            ("p95_ms", json::num(self.p95_ms)),
        ])
    }
}

/// Write the training-throughput file (reports/BENCH_train.json).
pub fn write_train_report(rows: &[TrainRow], path: impl AsRef<Path>) -> Result<()> {
    write_bench_report("train", rows.iter().map(TrainRow::to_json).collect(), path)
}

/// Append train rows to results.jsonl so `bitdistill report` renders the
/// training table next to the paper tables.
pub fn append_train_results(rows: &[TrainRow], path: impl AsRef<Path>) -> Result<()> {
    append_jsonl_rows(rows.iter().map(TrainRow::to_json).collect(), path)
}

/// Engine-vs-HLO logits parity (the cross-layer integration check).
pub fn parity_check(rt: &Runtime, size: &str) -> Result<(f64, f64)> {
    let tok_n = rt.manifest.vocab as i32;
    let seq = rt.manifest.seq;
    let b = rt.manifest.batch;
    let mut rng = Rng::new(77);
    let tokens: Vec<i32> = (0..b * seq).map(|_| rng.below(tok_n as usize) as i32).collect();
    let tokens_t = crate::tensor::TensorI32::from_vec(&[b, seq], tokens.clone())?;

    let mut worst_t = 0.0f64;
    let mut worst_f = 0.0f64;
    for (key, fwd, ternary) in [
        (stages::model_key(size, true, "absmean"), format!("{size}_student_fwd"), true),
        (stages::teacher_key(size), format!("{size}_teacher_fwd"), false),
    ] {
        let spec = rt.manifest.model(&key)?;
        let params = ParamStore::init(spec, &mut rng);
        let mut inputs: Vec<xla::Literal> = params
            .flat()
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        inputs.push(tokens_t.to_literal()?);
        let outs = rt.run_f32(&fwd, &inputs)?;
        let hlo_logits = &outs[0]; // [b, seq, vocab]
        let engine = Engine::from_params(spec, &params, ternary)?;
        let vocab = rt.manifest.vocab;
        // compare rows 0 and 1 over all positions
        for row in 0..2usize {
            let seq_tokens = &tokens[row * seq..(row + 1) * seq];
            let got = engine.forward_logits(seq_tokens);
            for (pos, g) in got.iter().enumerate() {
                let base = (row * seq + pos) * vocab;
                for (v, &gv) in g.iter().enumerate() {
                    let hv = hlo_logits.data[base + v];
                    let err = ((gv - hv).abs() / (1.0 + hv.abs())) as f64;
                    if ternary {
                        worst_t = worst_t.max(err);
                    } else {
                        worst_f = worst_f.max(err);
                    }
                }
            }
        }
    }
    Ok((worst_t, worst_f))
}

// -----------------------------------------------------------------------
// experiment drivers
// -----------------------------------------------------------------------

pub fn run_experiment(ctx: &Ctx, exp: &str, args: &Args) -> Result<()> {
    match exp {
        "table1" => table1(ctx, args),
        "table2" => table2(ctx, args),
        "table3" => table3(ctx, args),
        "table4" => table4(ctx, args),
        "table5" => table5(ctx, args),
        "table6" => table6(ctx, args),
        "fig1" => fig1(ctx, args),
        "fig2" => fig2(ctx, args),
        "fig3a" => fig3a(ctx, args),
        "fig3b" => fig3b(ctx, args),
        "fig3c" => fig3c(ctx, args),
        "speed" => {
            let kernel = kernel_arg(args)?;
            for size in ["tiny", "small", "base"] {
                let r = speed_report(ctx.rt, size, args.usize("tokens", 256), kernel)?;
                report(ctx, &r, None)?;
            }
            Ok(())
        }
        "all" => {
            for e in ["table1", "table2", "table3", "table4", "table5",
                      "table6", "fig2", "fig3a", "fig3b", "fig3c", "speed", "fig1"] {
                run_experiment(ctx, e, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}"),
    }
}

/// Parse `--kernel byte|lut|simd` (default byte) for the speed
/// experiments; unknown names fail fast with the accepted list.
fn kernel_arg(args: &Args) -> Result<KernelKind> {
    KernelKind::parse_flag(&args.str("kernel", "byte"))
}

fn sizes_arg(args: &Args, default: &str) -> Vec<String> {
    args.str("sizes", default)
        .split(',')
        .map(str::to_string)
        .collect()
}

fn run_method(
    ctx: &Ctx,
    size: &str,
    task: Task,
    method: &str,
    opts: &StudentOpts,
) -> Result<Score> {
    let ckpt = match method {
        "fp16-sft" => pipeline::teacher_sft(ctx, size, task)?,
        "bitnet-sft" => pipeline::bitnet_sft(ctx, size, task, opts, false)?,
        "bitnet-sft+ct" => pipeline::bitnet_sft(ctx, size, task, opts, true)?,
        "bitdistill" => pipeline::bitdistill(ctx, size, task, opts, true)?.ckpt,
        "bitdistill-noct" => pipeline::bitdistill(ctx, size, task, opts, false)?.ckpt,
        m => bail!("unknown method {m:?}"),
    };
    evaluate_ckpt(ctx, &ckpt, task, size, method, opts)
}

fn n_layers_of(ctx: &Ctx, size: &str) -> usize {
    ctx.rt
        .manifest
        .model(&stages::teacher_key(size))
        .map(|m| m.config.n_layers)
        .unwrap_or(4)
}

/// Table 1: classification accuracy across sizes x methods + speed/memory.
fn table1(ctx: &Ctx, args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny");
    let tasks = [Task::Mnli, Task::Qnli, Task::Sst2];
    report(ctx, "=== Table 1: text classification (accuracy %) ===", None)?;
    for method in ["fp16-sft", "bitnet-sft", "bitdistill"] {
        for size in &sizes {
            for task in tasks {
                let opts = StudentOpts::defaults_for(task, n_layers_of(ctx, size));
                let s = run_method(ctx, size, task, method, &opts)?;
                report(ctx, &format!("table1 {}", s.render()), Some(&s))?;
            }
        }
    }
    for size in &sizes {
        let r = speed_report(ctx.rt, size, 256, kernel_arg(args)?)?;
        report(ctx, &format!("table1 {r}"), None)?;
    }
    Ok(())
}

/// Table 2: summarization (BLEU/ROUGE) x methods.
fn table2(ctx: &Ctx, args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny");
    report(ctx, "=== Table 2: summarization (CNNDM analog) ===", None)?;
    for method in ["fp16-sft", "bitnet-sft", "bitdistill"] {
        for size in &sizes {
            let opts = StudentOpts::defaults_for(Task::Cnndm, n_layers_of(ctx, size));
            let s = run_method(ctx, size, Task::Cnndm, method, &opts)?;
            report(ctx, &format!("table2 {}", s.render()), Some(&s))?;
        }
    }
    Ok(())
}

/// Table 3: alternative backbones on MNLI.
fn table3(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Table 3: backbone robustness (MNLI analog) ===", None)?;
    for size in ["gemmaish", "qwenish"] {
        for method in ["fp16-sft", "bitnet-sft", "bitdistill"] {
            let opts = StudentOpts::defaults_for(Task::Mnli, n_layers_of(ctx, size));
            let s = run_method(ctx, size, Task::Mnli, method, &opts)?;
            report(ctx, &format!("table3 {}", s.render()), Some(&s))?;
        }
    }
    Ok(())
}

/// Table 4: quantizer compatibility (BitDistill with 4 quantizers).
fn table4(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Table 4: quantizer compatibility (tiny) ===", None)?;
    for quant in ["absmean", "block", "gptq", "awq"] {
        for task in [Task::Mnli, Task::Qnli] {
            let mut opts = StudentOpts::defaults_for(task, n_layers_of(ctx, "tiny"));
            opts.quant = quant.into();
            let s = run_method(ctx, "tiny", task, "bitdistill", &opts)?;
            report(
                ctx,
                &format!("table4 quant={quant} {}", s.render()),
                Some(&s),
            )?;
        }
    }
    Ok(())
}

/// Table 5: stage ablation (M.D. / C.T. / D.F.) on MNLI + CNNDM.
fn table5(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Table 5: stage ablation (tiny) ===", None)?;
    // rows: (subln, ct, distill)
    let rows = [
        (false, false, false),
        (true, false, false),
        (true, true, false),
        (true, false, true),
        (true, true, true),
    ];
    for task in [Task::Mnli, Task::Cnndm] {
        for (subln, ct, distill) in rows {
            let mut opts = StudentOpts::defaults_for(task, n_layers_of(ctx, "tiny"));
            opts.subln = subln;
            let method = match (ct, distill) {
                (true, true) => "bitdistill",
                (false, true) => "bitdistill-noct",
                (true, false) => "bitnet-sft+ct",
                (false, false) => "bitnet-sft",
            };
            let s = run_method(ctx, "tiny", task, method, &opts)?;
            report(
                ctx,
                &format!(
                    "table5 md={} ct={} df={} {}",
                    subln as u8, ct as u8, distill as u8,
                    s.render()
                ),
                Some(&s),
            )?;
        }
    }
    Ok(())
}

/// Table 6: LD/AD ablation on MNLI (all rows include stages 1+2).
fn table6(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Table 6: distillation-loss ablation (tiny MNLI) ===", None)?;
    for (ld, ad) in [(false, false), (true, false), (false, true), (true, true)] {
        let mut opts = StudentOpts::defaults_for(Task::Mnli, n_layers_of(ctx, "tiny"));
        opts.use_ld = ld;
        opts.use_ad = ad;
        let s = if !ld && !ad {
            run_method(ctx, "tiny", Task::Mnli, "bitnet-sft+ct", &opts)?
        } else {
            run_method(ctx, "tiny", Task::Mnli, "bitdistill", &opts)?
        };
        report(
            ctx,
            &format!("table6 ld={} ad={} {}", ld as u8, ad as u8, s.render()),
            Some(&s),
        )?;
    }
    Ok(())
}

/// Fig. 1: scaling trend — composition of Table-1 rows over sizes.
fn fig1(ctx: &Ctx, args: &Args) -> Result<()> {
    report(ctx, "=== Fig. 1: accuracy vs model size (MNLI analog) ===", None)?;
    for size in sizes_arg(args, "tiny,small,base") {
        for method in ["fp16-sft", "bitnet-sft", "bitdistill"] {
            let opts = StudentOpts::defaults_for(Task::Mnli, n_layers_of(ctx, &size));
            let s = run_method(ctx, &size, Task::Mnli, method, &opts)?;
            report(ctx, &format!("fig1 {}", s.render()), Some(&s))?;
        }
        let r = speed_report(ctx.rt, &size, 256, kernel_arg(args)?)?;
        report(ctx, &format!("fig1 {r}"), None)?;
    }
    Ok(())
}

/// Fig. 2: weight-distribution histograms (base vs CT'd student vs
/// from-scratch BitNet). Emits reports/fig2_<name>.csv.
fn fig2(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Fig. 2: weight distributions -> reports/fig2_*.csv ===", None)?;
    let base = pipeline::pretrain_base(ctx, "tiny")?;
    // CT'd student (reuse/create the bitnet-sft+ct checkpoint on mnli)
    let opts = StudentOpts::defaults_for(Task::Mnli, n_layers_of(ctx, "tiny"));
    let ct_ckpt = pipeline::bitnet_sft(ctx, "tiny", Task::Mnli, &opts, true)?;
    // from-scratch BitNet: random init + corpus training only
    let scratch = from_scratch_bitnet(ctx)?;

    for (name, path) in [
        ("base_fp", base),
        ("student_after_ct", ct_ckpt),
        ("bitnet_from_scratch", scratch),
    ] {
        let p = ParamStore::load(&path)?;
        let t = p
            .tensors
            .get("blocks.w_gate")
            .ok_or_else(|| anyhow!("no w_gate"))?;
        let l = t.shape[0];
        let per = t.numel() / l;
        let slice = &t.data[..per]; // layer 0
        let delta = slice.iter().map(|v| v.abs()).sum::<f32>() / per as f32;
        let bins = 81;
        let mut hist = vec![0usize; bins];
        for &v in slice {
            let r = (v / (delta + 1e-6)).clamp(-2.0, 2.0);
            let b = (((r + 2.0) / 4.0) * (bins - 1) as f32).round() as usize;
            hist[b.min(bins - 1)] += 1;
        }
        let mut csv = String::from("bin_center,density\n");
        for (i, h) in hist.iter().enumerate() {
            let c = -2.0 + 4.0 * i as f32 / (bins - 1) as f32;
            csv.push_str(&format!("{c:.3},{}\n", *h as f64 / per as f64));
        }
        std::fs::create_dir_all("reports")?;
        std::fs::write(format!("reports/fig2_{name}.csv"), csv)?;
        // transition-boundary mass (paper §4.4: weights concentrated near
        // the 0 <-> +-1 rounding boundary |w/Delta| ~ 0.5)
        let near: usize = slice
            .iter()
            .filter(|v| {
                let r = (**v / (delta + 1e-6)).abs();
                (0.4..=0.6).contains(&r)
            })
            .count();
        report(
            ctx,
            &format!(
                "fig2 {name}: boundary_mass(|w/D| in [0.4,0.6]) = {:.3}",
                near as f64 / per as f64
            ),
            None,
        )?;
    }
    Ok(())
}

/// A BitNet trained from scratch on the corpus (Fig. 2 comparison row).
fn from_scratch_bitnet(ctx: &Ctx) -> Result<std::path::PathBuf> {
    let path = ctx.runs_dir.join("bitnet_scratch_tiny.ckpt");
    if path.exists() && !ctx.force {
        return Ok(path);
    }
    let key = stages::model_key("tiny", true, "absmean");
    let spec = ctx.rt.manifest.model(&key)?;
    let mut rng = Rng::new(4242);
    let params = ParamStore::init(spec, &mut rng);
    let mut tr = pipeline::Trainer::new(ctx.rt, "tiny_bitnet_train", params);
    let b = pipeline::budget("tiny");
    let steps = ((b.pretrain as f64 * ctx.steps_scale) as usize).max(2);
    let stream = crate::data::CorpusStream::new(&ctx.tok, ctx.rt.manifest.seq, 21);
    let mut batches =
        crate::data::CorpusBatcher::new(stream, ctx.rt.manifest.batch, ctx.rt.manifest.seq);
    let sched = pipeline::LrSchedule::new(b.pretrain_lr, steps / 20 + 1, steps);
    for s in 0..steps {
        let batch = batches.next_batch();
        let loss = tr.train_step(&batch, sched.at(s))?;
        if s % 100 == 0 {
            eprintln!("[fig2] scratch bitnet step {s}/{steps} loss {loss:.3}");
        }
    }
    tr.params.save(&path)?;
    Ok(path)
}

/// Fig. 3a: CT loss curves with vs without SubLN -> reports/fig3a.csv.
fn fig3a(ctx: &Ctx, args: &Args) -> Result<()> {
    let steps = args.usize("steps", ((100.0 * ctx.steps_scale) as usize).max(4));
    report(ctx, "=== Fig. 3a: SubLN stabilization -> reports/fig3a.csv ===", None)?;
    let mut curves: Vec<Vec<f32>> = Vec::new();
    for subln in [true, false] {
        let key = stages::model_key("tiny", subln, "absmean");
        let spec = ctx.rt.manifest.model(&key)?;
        // init from the pretrained base (the paper's setting)
        let base = pipeline::pretrain_base(ctx, "tiny")?;
        let base_params = ParamStore::load(&base)?;
        let mut rng = Rng::new(5);
        let mut params = ParamStore::init(spec, &mut rng);
        params.load_compatible(&base_params);
        let artifact = if subln {
            "tiny_bitnet_train"
        } else {
            "tiny_bitnet_train_nosubln"
        };
        let mut tr = pipeline::Trainer::new(ctx.rt, artifact, params);
        let stream = crate::data::CorpusStream::new(&ctx.tok, ctx.rt.manifest.seq, 31);
        let mut batches = crate::data::CorpusBatcher::new(
            stream,
            ctx.rt.manifest.batch,
            ctx.rt.manifest.seq,
        );
        let mut curve = Vec::new();
        for s in 0..steps {
            let batch = batches.next_batch();
            let loss = tr.train_step(&batch, 1e-3)?;
            curve.push(loss);
            if s % 25 == 0 {
                eprintln!("[fig3a] subln={subln} step {s}/{steps} loss {loss:.3}");
            }
        }
        curves.push(curve);
    }
    let mut csv = String::from("step,loss_subln,loss_nosubln\n");
    for s in 0..steps {
        csv.push_str(&format!("{s},{},{}\n", curves[0][s], curves[1][s]));
    }
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/fig3a.csv", csv)?;
    report(
        ctx,
        &format!(
            "fig3a final CT loss: subln={:.3} nosubln={:.3}",
            curves[0].last().unwrap(),
            curves[1].last().unwrap()
        ),
        None,
    )?;
    Ok(())
}

/// Fig. 3b: AD layer-selection sweep (no CT, matching the paper's setup).
fn fig3b(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Fig. 3b: distillation layer sweep (tiny MNLI, no CT) ===", None)?;
    let n_layers = n_layers_of(ctx, "tiny");
    for layer in 0..n_layers {
        let mut opts = StudentOpts::defaults_for(Task::Mnli, n_layers);
        opts.distill_layer = layer as i32;
        let s = run_method(ctx, "tiny", Task::Mnli, "bitdistill-noct", &opts)?;
        report(
            ctx,
            &format!("fig3b layer={layer} {}", s.render()),
            Some(&s),
        )?;
    }
    Ok(())
}

/// Fig. 3c: teacher-size sweep for the tiny student.
fn fig3c(ctx: &Ctx, args: &Args) -> Result<()> {
    let _ = args;
    report(ctx, "=== Fig. 3c: teacher-size sweep (tiny student, MNLI) ===", None)?;
    for tsize in ["tiny", "small", "base"] {
        let mut opts = StudentOpts::defaults_for(Task::Mnli, n_layers_of(ctx, "tiny"));
        if tsize != "tiny" {
            opts.teacher_size = Some(tsize.into());
        }
        let s = run_method(ctx, "tiny", Task::Mnli, "bitdistill", &opts)?;
        report(
            ctx,
            &format!("fig3c teacher={tsize} {}", s.render()),
            Some(&s),
        )?;
    }
    Ok(())
}
