//! Parameter store: manifest-driven initialization, flat ordering, and
//! checkpoint save/load.
//!
//! Checkpoint format (little-endian):
//!   magic "BDCKPT1\n" | u64 header_len | header JSON | raw f32 payload
//! The header records the model key, step, and every tensor's name/shape
//! in payload order, so checkpoints are self-describing and can be loaded
//! into a *different* (compatible) model spec — e.g. FP16 teacher weights
//! into the SubLN student, which is exactly Stage-1 of the pipeline.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{ModelSpec, ParamSpec};
use crate::substrate::{json, Json, Rng};
use crate::tensor::TensorF32;

const MAGIC: &[u8] = b"BDCKPT1\n";

/// A named set of tensors following a `ModelSpec`'s canonical order.
#[derive(Clone)]
pub struct ParamStore {
    pub model_key: String,
    pub specs: Vec<ParamSpec>,
    pub tensors: BTreeMap<String, TensorF32>,
    pub step: usize,
}

impl ParamStore {
    /// Initialize from the manifest spec: trunc-normal matrices, unit norm
    /// gains — mirroring `python/compile/model.py::param_specs`.
    pub fn init(spec: &ModelSpec, rng: &mut Rng) -> ParamStore {
        let mut tensors = BTreeMap::new();
        for p in &spec.params {
            let mut t = TensorF32::zeros(&p.shape);
            match p.init_kind.as_str() {
                "ones" => t.data.iter_mut().for_each(|v| *v = 1.0),
                _ => rng.fill_normal(&mut t.data, p.init_std),
            }
            tensors.insert(p.name.clone(), t);
        }
        ParamStore {
            model_key: spec.key.clone(),
            specs: spec.params.clone(),
            tensors,
            step: 0,
        }
    }

    /// All-zeros clone with the same shapes (optimizer m/v state).
    pub fn zeros_like(&self) -> ParamStore {
        let tensors = self
            .tensors
            .iter()
            .map(|(k, t)| (k.clone(), TensorF32::zeros(&t.shape)))
            .collect();
        ParamStore {
            model_key: self.model_key.clone(),
            specs: self.specs.clone(),
            tensors,
            step: 0,
        }
    }

    /// Tensors in canonical (manifest) order — the flat HLO input order.
    pub fn flat(&self) -> Vec<&TensorF32> {
        self.specs
            .iter()
            .map(|s| self.tensors.get(&s.name).expect("spec/tensor mismatch"))
            .collect()
    }

    /// Replace tensors from a flat list in canonical order (train-step
    /// outputs).
    pub fn set_flat(&mut self, flat: Vec<TensorF32>) -> Result<()> {
        if flat.len() != self.specs.len() {
            bail!("set_flat: {} tensors for {} specs", flat.len(), self.specs.len());
        }
        for (spec, t) in self.specs.iter().zip(flat) {
            if t.shape != spec.shape {
                bail!(
                    "set_flat: {} shape {:?} != spec {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            self.tensors.insert(spec.name.clone(), t);
        }
        Ok(())
    }

    pub fn n_params(&self) -> usize {
        self.tensors.values().map(TensorF32::numel).sum()
    }

    /// Copy overlapping tensors from `src` (by name; shapes must match).
    /// Returns the names that were NOT found in `src` (e.g. the freshly
    /// initialized SubLN gains when loading teacher weights — Stage-1).
    pub fn load_compatible(&mut self, src: &ParamStore) -> Vec<String> {
        let mut missing = Vec::new();
        for spec in &self.specs {
            match src.tensors.get(&spec.name) {
                Some(t) if t.shape == spec.shape => {
                    self.tensors.insert(spec.name.clone(), t.clone());
                }
                _ => missing.push(spec.name.clone()),
            }
        }
        missing
    }

    // ---------------------------------------------------------------
    // checkpoint io
    // ---------------------------------------------------------------

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut header_params = Vec::new();
        for spec in &self.specs {
            header_params.push(json::obj(vec![
                ("name", json::s(&spec.name)),
                (
                    "shape",
                    Json::Arr(spec.shape.iter().map(|&d| json::num(d as f64)).collect()),
                ),
            ]));
        }
        let header = json::obj(vec![
            ("model", json::s(&self.model_key)),
            ("step", json::num(self.step as f64)),
            ("params", Json::Arr(header_params)),
        ])
        .to_string();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for spec in &self.specs {
            let t = &self.tensors[&spec.name];
            // SAFETY: viewing a live Vec<f32> as raw little-endian
            // bytes — the pointer is valid for len*4 bytes, u8 has no
            // alignment requirement, every f32 bit pattern is a valid
            // [u8; 4], and the borrow of `t` outlives the slice's use.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if magic != MAGIC {
            bail!("{:?}: not a BDCKPT1 checkpoint", path.as_ref());
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)?;
        let model_key = header
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("checkpoint header: no model"))?
            .to_string();
        let step = header.get("step").and_then(Json::as_usize).unwrap_or(0);

        let mut specs = Vec::new();
        let mut tensors = BTreeMap::new();
        for pj in header
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint header: no params"))?
        {
            let name = pj
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param without name"))?
                .to_string();
            let shape: Vec<usize> = pj
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param without shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)
                .with_context(|| format!("reading payload of {name}"))?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            specs.push(ParamSpec {
                name: name.clone(),
                shape: shape.clone(),
                init_kind: "normal".into(),
                init_std: 0.0,
                weight_decay: shape.len() >= 2,
            });
            tensors.insert(name, TensorF32 { shape, data });
        }
        Ok(ParamStore { model_key, specs, tensors, step })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelCfg, ModelSpec};

    fn mini_spec() -> ModelSpec {
        ModelSpec {
            key: "mini".into(),
            config: ModelCfg {
                name: "mini".into(),
                vocab: 16,
                d_model: 4,
                n_layers: 1,
                n_heads: 1,
                n_kv_heads: 1,
                head_dim: 4,
                d_ff: 8,
                act: "silu".into(),
                tie_embeddings: true,
                use_subln: true,
                quant_method: "absmean".into(),
                rope_theta: 1e4,
                norm_eps: 1e-6,
                seq: 8,
            },
            n_params: 16 * 4 + 4,
            params: vec![
                ParamSpec {
                    name: "embed".into(),
                    shape: vec![16, 4],
                    init_kind: "normal".into(),
                    init_std: 0.02,
                    weight_decay: true,
                },
                ParamSpec {
                    name: "final_norm".into(),
                    shape: vec![4],
                    init_kind: "ones".into(),
                    init_std: 0.0,
                    weight_decay: false,
                },
            ],
        }
    }

    #[test]
    fn init_follows_spec() {
        let mut rng = Rng::new(0);
        let p = ParamStore::init(&mini_spec(), &mut rng);
        assert_eq!(p.n_params(), 16 * 4 + 4);
        assert!(p.tensors["final_norm"].data.iter().all(|&v| v == 1.0));
        let std = {
            let d = &p.tensors["embed"].data;
            let m: f32 = d.iter().sum::<f32>() / d.len() as f32;
            (d.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / d.len() as f32).sqrt()
        };
        assert!((std - 0.02).abs() < 0.01, "std={std}");
    }

    #[test]
    fn flat_order_matches_specs() {
        let mut rng = Rng::new(1);
        let p = ParamStore::init(&mini_spec(), &mut rng);
        let flat = p.flat();
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[0].shape, vec![16, 4]); // embed first
        assert_eq!(flat[1].shape, vec![4]);
    }

    #[test]
    fn checkpoint_round_trip() {
        let mut rng = Rng::new(2);
        let mut p = ParamStore::init(&mini_spec(), &mut rng);
        p.step = 123;
        let dir = std::env::temp_dir().join("bd_test_ckpt");
        let path = dir.join("mini.ckpt");
        p.save(&path).unwrap();
        let q = ParamStore::load(&path).unwrap();
        assert_eq!(q.model_key, "mini");
        assert_eq!(q.step, 123);
        assert_eq!(q.tensors["embed"], p.tensors["embed"]);
        assert_eq!(q.tensors["final_norm"], p.tensors["final_norm"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_compatible_reports_missing() {
        let mut rng = Rng::new(3);
        let teacher = ParamStore::init(&mini_spec(), &mut rng);
        let mut student_spec = mini_spec();
        student_spec.params.push(ParamSpec {
            name: "blocks.subln_attn".into(),
            shape: vec![1, 4],
            init_kind: "ones".into(),
            init_std: 0.0,
            weight_decay: false,
        });
        let mut student = ParamStore::init(&student_spec, &mut rng);
        let missing = student.load_compatible(&teacher);
        assert_eq!(missing, vec!["blocks.subln_attn".to_string()]);
        assert_eq!(student.tensors["embed"], teacher.tensors["embed"]);
    }
}
