//! Serving metrics: the canonical quantile implementation plus the
//! counters the server publishes (latency percentiles, queue depth,
//! tokens/s, batch occupancy).
//!
//! [`quantile`] is the one true percentile function of this crate — the
//! bench harness ([`crate::substrate::bench`]), the report renderer
//! ([`crate::bench::report`]) and the serving examples all route through
//! it. It linearly interpolates between order statistics, so small
//! samples behave: the median of `[1, 2, 3, 4]` is `2.5`, where the old
//! nearest-rank truncation `samples[(len * q) as usize]` mis-indexed
//! (median of 4 samples -> the 3rd, p99 of 100 samples -> past-the-end
//! but for the `min`-clamp).

use crate::obs::Histogram;
use crate::substrate::{json, Json};

/// Interpolated quantile of an **ascending-sorted** sample. `q` is
/// clamped to `[0, 1]`; an empty sample returns NaN.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Convenience for unsorted data: sorts a copy, then [`quantile`].
///
/// Sorts with [`f64::total_cmp`]: `partial_cmp(..).unwrap()` panicked on
/// the first NaN sample — and NaNs *do* occur in latency pipelines (e.g.
/// `quantile(&[], q)` is NaN by contract, so one empty sub-aggregation
/// feeding another's input was enough to kill a long-running server).
/// Under total order NaNs sort to the ends and the percentile of the
/// finite mass is still meaningful.
pub fn quantile_unsorted(samples: &[f64], q: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    quantile(&sorted, q)
}

/// p50/p95/p99 of a sample (ms by convention in this module).
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// Exact percentiles of a sample. An empty sample follows the
    /// [`quantile`] NaN contract — all-NaN percentiles, rendered as a
    /// dash — instead of the all-zero `Percentiles::default()` this
    /// used to return (an idle server reporting p50=0.0ms looked like
    /// a measurement).
    pub fn of(samples: &[f64]) -> Percentiles {
        // total_cmp: a NaN sample must not panic the stats path (see
        // [`quantile_unsorted`])
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Percentiles {
            p50: quantile(&sorted, 0.50),
            p95: quantile(&sorted, 0.95),
            p99: quantile(&sorted, 0.99),
        }
    }

    /// Approximate percentiles out of a bounded [`Histogram`] (within
    /// bucket error of the exact path; NaN when empty).
    pub fn of_hist(h: &Histogram) -> Percentiles {
        Percentiles {
            p50: h.quantile(0.50),
            p95: h.quantile(0.95),
            p99: h.quantile(0.99),
        }
    }
}

/// `"12.3ms"`, or `"-"` for the NaN an empty sample yields — never a
/// fake `0.0ms`.
pub fn ms_or_dash(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}ms")
    } else {
        "-".to_string()
    }
}

/// Counters and samples accumulated by one [`crate::serve::Server`].
///
/// Latency samples live in fixed-memory log-bucketed
/// [`Histogram`]s (~2 KB each), so a server's stats stay bounded no
/// matter how many requests flow through — the old unbounded
/// `Vec<f64>` sample lists could not survive a long-running process.
/// Quantiles read back within bucket error (~4.4%); benches that want
/// exact percentiles compute them from the per-response `Timing`s via
/// the canonical [`quantile`].
///
/// Overload is kept visible, not conflated: deadline-expired requests
/// record into the `expired_*` histograms and the `expired` counter
/// (never into the completed-latency picture), and every admission
/// rejection records the queue depth it bounced off.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub submitted: usize,
    pub rejected: usize,
    /// Requests that finished with a delivered result (incl. classified
    /// and EOS/budget-capped) — deadline expiries are **not** counted
    /// here, they land in [`ServeStats::expired`].
    pub completed: usize,
    /// Requests dropped by their deadline (in queue or mid-flight).
    pub expired: usize,
    /// Requests withdrawn via [`crate::serve::Server::cancel`] (queued
    /// or mid-flight) — the network front-end's client-disconnect path.
    /// Never counted in [`ServeStats::completed`].
    pub canceled: usize,
    /// Prompt tokens decoded for completed requests.
    pub prompt_tokens: usize,
    /// Newly generated tokens for completed requests.
    pub new_tokens: usize,
    /// Engine batch steps executed.
    pub steps: usize,
    /// Sum over steps of that step's batch size (occupancy integral).
    pub occupancy_sum: usize,
    /// Per-step decode batch size distribution (how full each engine
    /// step actually ran, vs `mean_occupancy`'s single average).
    pub batch_fill: Histogram,
    pub peak_queue_depth: usize,
    /// Per completed request, milliseconds.
    pub total_ms: Histogram,
    pub queue_ms: Histogram,
    /// Time from submission to the end of prefill (first usable logits).
    pub ttft_ms: Histogram,
    /// Deadline-expired requests, same units — separated so overload
    /// (exactly when observability matters) stays in the picture.
    pub expired_total_ms: Histogram,
    pub expired_queue_ms: Histogram,
    pub expired_ttft_ms: Histogram,
    /// Submission -> cancellation, for canceled requests (their own
    /// bucket for the same reason the expired ones get one).
    pub canceled_total_ms: Histogram,
    /// Queue depth observed by each rejected submission.
    pub rejected_queue_depth: Histogram,
}

impl ServeStats {
    pub fn record_step(&mut self, batch: usize) {
        self.steps += 1;
        self.occupancy_sum += batch;
        self.batch_fill.record(batch as f64);
    }

    /// Mean sequences per engine step — 1.0 means the batcher degenerated
    /// to sequential decode, `max_batch` means fully packed.
    pub fn mean_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.steps as f64
        }
    }

    pub fn latency(&self) -> Percentiles {
        Percentiles::of_hist(&self.total_ms)
    }

    /// Requests accounted for by a terminal outcome. The conservation
    /// invariant — every submission ends in exactly one of the four
    /// buckets, `submitted == completed + rejected + expired + canceled`
    /// — holds whenever the server is drained (no queued or active
    /// requests); test-enforced in `serve::scheduler` and the network
    /// chaos suite.
    pub fn accounted(&self) -> usize {
        self.completed + self.rejected + self.expired + self.canceled
    }

    /// One-line human summary given the serving wall-clock in seconds.
    pub fn render(&self, wall_s: f64) -> String {
        let p = self.latency();
        let tokens = self.prompt_tokens + self.new_tokens;
        format!(
            "reqs={} ok={} rejected={} expired={} canceled={} tok/s={:.1} req/s={:.1} \
             p50={} p95={} p99={} occupancy={:.2} peak_queue={}",
            self.submitted,
            self.completed,
            self.rejected,
            self.expired,
            self.canceled,
            tokens as f64 / wall_s.max(1e-9),
            self.completed as f64 / wall_s.max(1e-9),
            ms_or_dash(p.p50),
            ms_or_dash(p.p95),
            ms_or_dash(p.p99),
            self.mean_occupancy(),
            self.peak_queue_depth,
        )
    }

    pub fn to_json(&self, wall_s: f64) -> Json {
        let p = self.latency();
        let tokens = self.prompt_tokens + self.new_tokens;
        json::obj(vec![
            ("submitted", json::num(self.submitted as f64)),
            ("completed", json::num(self.completed as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("expired", json::num(self.expired as f64)),
            ("canceled", json::num(self.canceled as f64)),
            ("prompt_tokens", json::num(self.prompt_tokens as f64)),
            ("new_tokens", json::num(self.new_tokens as f64)),
            ("tok_s", json::num(tokens as f64 / wall_s.max(1e-9))),
            ("req_s", json::num(self.completed as f64 / wall_s.max(1e-9))),
            ("p50_ms", json::num_or_null(p.p50)),
            ("p95_ms", json::num_or_null(p.p95)),
            ("p99_ms", json::num_or_null(p.p99)),
            ("queue_p95_ms", json::num_or_null(self.queue_ms.quantile(0.95))),
            ("expired_p95_ms", json::num_or_null(self.expired_total_ms.quantile(0.95))),
            ("mean_occupancy", json::num(self.mean_occupancy())),
            ("peak_queue_depth", json::num(self.peak_queue_depth as f64)),
            ("steps", json::num(self.steps as f64)),
        ])
    }

    /// One `--metrics-every` snapshot row (`kind:"metrics"` JSONL),
    /// assembled through the [`crate::obs::Registry`]: cumulative
    /// counters, instantaneous gauges and bounded histogram summaries.
    ///
    /// **Snapshot semantics (the downstream-rate contract):** counters
    /// (`submitted`/`completed`/`rejected`/`expired`/`canceled`/`steps`/
    /// `prompt_tokens`/`new_tokens`) and histogram `count`s are
    /// **cumulative since server start and monotonic non-decreasing
    /// across consecutive snapshots** — a consumer computes rates as
    /// `(c_i - c_{i-1}) / (wall_s_i - wall_s_{i-1})`, never by treating
    /// a row as a delta. Gauges (`queue_depth`/`active`/
    /// `kv_resident_lanes`/`occupancy`/`tok_s`) are instantaneous and
    /// may move either way. Test-enforced over three consecutive
    /// snapshots in `serve::scheduler`.
    ///
    /// `kv_resident` is the number of memory-backed [`crate::serve::KvCachePool`]
    /// lanes at snapshot time (allocation high-water of the lazy pool).
    pub fn snapshot(&self, wall_s: f64, queue_depth: usize, active: usize, kv_resident: usize) -> Json {
        let tokens = self.prompt_tokens + self.new_tokens;
        let mut reg = crate::obs::Registry::new();
        reg.counter("submitted", self.submitted as u64)
            .counter("completed", self.completed as u64)
            .counter("rejected", self.rejected as u64)
            .counter("expired", self.expired as u64)
            .counter("canceled", self.canceled as u64)
            .counter("steps", self.steps as u64)
            .counter("prompt_tokens", self.prompt_tokens as u64)
            .counter("new_tokens", self.new_tokens as u64)
            .gauge("wall_s", wall_s)
            .gauge("tok_s", tokens as f64 / wall_s.max(1e-9))
            .gauge("occupancy", self.mean_occupancy())
            .gauge("queue_depth", queue_depth as f64)
            .gauge("active", active as f64)
            .gauge("kv_resident_lanes", kv_resident as f64)
            .hist("batch_fill", &self.batch_fill)
            .hist("total_ms", &self.total_ms)
            .hist("queue_ms", &self.queue_ms)
            .hist("ttft_ms", &self.ttft_ms)
            .hist("expired_total_ms", &self.expired_total_ms)
            .hist("canceled_total_ms", &self.canceled_total_ms)
            .hist("rejected_queue_depth", &self.rejected_queue_depth);
        let mut row = reg.to_json();
        if let Json::Obj(o) = &mut row {
            o.insert("kind".to_string(), json::s("metrics"));
        }
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_interpolates_small_samples() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&s, 0.0), 1.0);
        assert_eq!(quantile(&s, 1.0), 4.0);
        assert!((quantile(&s, 0.5) - 2.5).abs() < 1e-12);
        // the old nearest-rank truncation returned s[2] = 3.0 here
        assert!((quantile(&s, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn quantile_single_sample_and_clamp() {
        let s = [7.0];
        assert_eq!(quantile(&s, 0.0), 7.0);
        assert_eq!(quantile(&s, 0.5), 7.0);
        assert_eq!(quantile(&s, 0.99), 7.0);
        assert_eq!(quantile(&[1.0, 3.0], 2.0), 3.0); // q clamped to 1
        assert!(quantile(&[], 0.5).is_nan());
    }

    #[test]
    fn quantile_is_monotone_in_q() {
        let mut s: Vec<f64> = (0..17).map(|i| ((i * 7919) % 97) as f64).collect();
        s.sort_by(f64::total_cmp);
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=20 {
            let v = quantile(&s, i as f64 / 20.0);
            assert!(v >= prev, "q={} gave {v} < {prev}", i as f64 / 20.0);
            prev = v;
        }
    }

    #[test]
    fn unsorted_helper_sorts() {
        assert!((quantile_unsorted(&[4.0, 1.0, 3.0, 2.0], 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn nan_samples_do_not_panic_the_stats_path() {
        // regression: partial_cmp(..).unwrap() panicked on the first NaN
        // sample; a NaN enters naturally via quantile(&[], q) feeding a
        // downstream aggregation. total_cmp sorts NaNs to the ends.
        let with_nan = [4.0, f64::NAN, 1.0, 3.0, 2.0];
        let p50 = quantile_unsorted(&with_nan, 0.5);
        assert!(p50.is_finite(), "median over mostly-finite data: {p50}");
        assert_eq!(p50, 3.0, "positive NaN sorts last; median of 5 = 3rd");
        let p = Percentiles::of(&with_nan);
        assert!(p.p50.is_finite());
        // empty samples are NaN (rendered as a dash), not fake zeros
        let empty = Percentiles::of(&[]);
        assert!(empty.p50.is_nan() && empty.p95.is_nan() && empty.p99.is_nan());
        // p0 stays the finite minimum (negative NaN would sort first,
        // but f64::NAN is positive-sign)
        assert_eq!(quantile_unsorted(&with_nan, 0.0), 1.0);
        // all-NaN input: no panic, NaN out (nothing meaningful to report)
        assert!(quantile_unsorted(&[f64::NAN, f64::NAN], 0.5).is_nan());
        // the empty->NaN->aggregation chain that motivated the fix
        let empty_p95 = quantile(&[], 0.95);
        let chained = quantile_unsorted(&[12.0, empty_p95, 10.0], 0.5);
        assert_eq!(chained, 12.0);
    }

    #[test]
    fn stats_accumulate_and_render() {
        let mut s = ServeStats::default();
        s.submitted = 3;
        s.completed = 2;
        s.rejected = 1;
        s.prompt_tokens = 20;
        s.new_tokens = 10;
        s.record_step(2);
        s.record_step(1);
        s.total_ms.record(5.0);
        s.total_ms.record(15.0);
        assert!((s.mean_occupancy() - 1.5).abs() < 1e-12);
        let line = s.render(1.0);
        assert!(line.contains("tok/s=30.0"), "{line}");
        let j = s.to_json(1.0);
        assert_eq!(j.get("completed").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("tok_s").and_then(Json::as_f64), Some(30.0));
        // histogram-backed percentiles are within bucket error of exact
        let p50 = j.get("p50_ms").and_then(Json::as_f64).unwrap();
        assert!((p50 - 10.0).abs() / 10.0 < 0.05, "p50 {p50}");
    }

    #[test]
    fn canceled_requests_have_their_own_bucket_and_balance_the_books() {
        let mut s = ServeStats::default();
        s.submitted = 6;
        s.completed = 3;
        s.rejected = 1;
        s.expired = 1;
        s.canceled = 1;
        s.canceled_total_ms.record(4.0);
        assert_eq!(s.accounted(), s.submitted);
        let line = s.render(1.0);
        assert!(line.contains("canceled=1"), "{line}");
        let j = s.to_json(1.0);
        assert_eq!(j.get("canceled").and_then(Json::as_f64), Some(1.0));
        let row = s.snapshot(1.0, 0, 0, 0);
        assert_eq!(row.get("canceled").and_then(Json::as_f64), Some(1.0));
        assert_eq!(row.at(&["canceled_total_ms", "count"]).and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn idle_server_renders_dashes_and_nulls_not_zeros() {
        let s = ServeStats::default();
        let line = s.render(1.0);
        assert!(line.contains("p50=- p95=- p99=-"), "{line}");
        let j = s.to_json(1.0);
        assert_eq!(j.get("p50_ms"), Some(&Json::Null));
        assert_eq!(j.get("queue_p95_ms"), Some(&Json::Null));
        // and the JSON stays parseable (a bare NaN literal would not)
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn snapshot_row_carries_counters_gauges_and_hists() {
        let mut s = ServeStats::default();
        s.submitted = 5;
        s.completed = 4;
        s.expired = 1;
        s.new_tokens = 40;
        s.record_step(4);
        s.total_ms.record(8.0);
        s.expired_total_ms.record(50.0);
        let row = s.snapshot(2.0, 3, 4, 2);
        assert_eq!(row.get("kind").and_then(Json::as_str), Some("metrics"));
        assert_eq!(row.get("completed").and_then(Json::as_f64), Some(4.0));
        assert_eq!(row.get("expired").and_then(Json::as_f64), Some(1.0));
        assert_eq!(row.get("queue_depth").and_then(Json::as_f64), Some(3.0));
        assert_eq!(row.get("kv_resident_lanes").and_then(Json::as_f64), Some(2.0));
        // the per-step batch-size histogram rides the same row
        assert_eq!(row.at(&["batch_fill", "count"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(row.at(&["batch_fill", "max"]).and_then(Json::as_f64), Some(4.0));
        assert_eq!(row.at(&["total_ms", "count"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            row.at(&["expired_total_ms", "count"]).and_then(Json::as_f64),
            Some(1.0)
        );
        // ttft never recorded: null percentile, not zero
        assert_eq!(row.at(&["ttft_ms", "p50"]), Some(&Json::Null));
        assert_eq!(Json::parse(&row.to_string()).unwrap(), row);
    }
}
