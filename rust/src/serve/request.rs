//! The serving request/response API.
//!
//! A [`Request`] carries everything the scheduler needs to run one
//! sequence to completion: tokenized prompt, task shape (classification
//! via `label_ids` vs generation via `max_new`/`eos`), per-request
//! sampling parameters, and an optional deadline. A [`Response`] reports
//! the outcome plus a per-phase latency breakdown.

use std::time::Duration;

use crate::data::tokenizer::EOS;

/// Per-request decoding policy.
#[derive(Clone, Debug)]
pub enum Sampling {
    /// Deterministic argmax (matches [`crate::engine::Engine::generate`]).
    Greedy,
    /// Softmax sampling at `temp`, seeded per request for
    /// reproducibility. A request with `seed: None` or a non-finite /
    /// non-positive temperature is **rejected at submission**
    /// ([`FinishReason::Rejected`]) — it must never reach the decode
    /// loop, where the old code panicked the whole server mid-step.
    Temperature { temp: f32, seed: Option<u64> },
}

impl Sampling {
    /// Whether the scheduler can execute this policy. Checked in
    /// `Server::submit` so an invalid request bounces alone instead of
    /// panicking the shared decode step.
    pub fn is_valid(&self) -> bool {
        match self {
            Sampling::Greedy => true,
            Sampling::Temperature { temp, seed } => {
                temp.is_finite() && *temp > 0.0 && seed.is_some()
            }
        }
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
    /// Generation budget (ignored for classification requests).
    pub max_new: usize,
    pub eos: i32,
    pub sampling: Sampling,
    /// Non-empty marks a classification request: after prefill the server
    /// argmaxes the final logits over these token ids and retires the
    /// sequence without decoding.
    pub label_ids: Vec<i32>,
    /// Wall-clock budget measured from submission; exceeding it retires
    /// the request with [`FinishReason::DeadlineExceeded`].
    pub deadline: Option<Duration>,
}

impl Request {
    /// Greedy generation request decoding up to `max_new` tokens.
    pub fn generate(prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            prompt,
            max_new,
            eos: EOS,
            sampling: Sampling::Greedy,
            label_ids: Vec::new(),
            deadline: None,
        }
    }

    /// Classification request: one batched prefill, then argmax over
    /// `label_ids` (the verbalizer words).
    pub fn classify(prompt: Vec<i32>, label_ids: Vec<i32>) -> Request {
        Request {
            prompt,
            max_new: 0,
            eos: EOS,
            sampling: Sampling::Greedy,
            label_ids,
            deadline: None,
        }
    }

    pub fn with_sampling(mut self, sampling: Sampling) -> Request {
        self.sampling = sampling;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Request {
        self.deadline = Some(deadline);
        self
    }

    pub fn is_classification(&self) -> bool {
        !self.label_ids.is_empty()
    }
}

/// Why a request left the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generation hit the EOS token.
    Eos,
    /// Generation produced `max_new` tokens.
    MaxTokens,
    /// Classification request answered after prefill.
    Classified,
    /// Deadline expired while queued or decoding.
    DeadlineExceeded,
    /// Refused at submission (queue full, empty prompt, prompt longer
    /// than the KV capacity, an invalid sampling policy — e.g.
    /// temperature sampling without a seed — or any prompt token /
    /// classification label id outside the engine's vocab).
    Rejected,
    /// The KV slot filled up mid-generation.
    CacheExhausted,
    /// Withdrawn by the caller ([`crate::serve::Server::cancel`]) —
    /// typically the network front-end reacting to a client disconnect.
    /// A queued cancel never touches a lane; an in-flight cancel
    /// releases the lane's KV slot immediately. Any tokens generated
    /// before the cancel ride along in the response.
    Canceled,
}

impl FinishReason {
    /// Static label — used as a trace-span argument (span args are
    /// `&'static str` so recording allocates nothing) and in reports.
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Classified => "classified",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::Rejected => "rejected",
            FinishReason::CacheExhausted => "cache_exhausted",
            FinishReason::Canceled => "canceled",
        }
    }
}

/// Per-phase latency breakdown, milliseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    /// Submission -> admission into the running batch.
    pub queue_ms: f64,
    /// Admission -> last prompt token decoded.
    pub prefill_ms: f64,
    /// End of prefill -> retirement.
    pub decode_ms: f64,
    /// Submission -> retirement.
    pub total_ms: f64,
}

/// Outcome of one [`Request`].
#[derive(Clone, Debug)]
pub struct Response {
    /// Server-assigned id, in submission order.
    pub id: u64,
    /// Newly generated token ids (empty for classification).
    pub tokens: Vec<i32>,
    /// Classification answer: index into the request's `label_ids`.
    pub class: Option<usize>,
    pub finish: FinishReason,
    pub prompt_len: usize,
    pub timing: Timing,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_set_task_shape() {
        let g = Request::generate(vec![1, 2, 3], 8);
        assert!(!g.is_classification());
        assert_eq!(g.max_new, 8);
        assert_eq!(g.eos, EOS);

        let c = Request::classify(vec![1, 2], vec![9, 10, 11]);
        assert!(c.is_classification());
        assert_eq!(c.max_new, 0);

        let d = Request::generate(vec![1], 1).with_deadline(Duration::from_millis(5));
        assert_eq!(d.deadline, Some(Duration::from_millis(5)));
    }

    #[test]
    fn sampling_validity() {
        assert!(Sampling::Greedy.is_valid());
        assert!(Sampling::Temperature { temp: 0.7, seed: Some(1) }.is_valid());
        // the panic class this guards: no seed, or a degenerate temp
        assert!(!Sampling::Temperature { temp: 0.7, seed: None }.is_valid());
        assert!(!Sampling::Temperature { temp: f32::NAN, seed: Some(1) }.is_valid());
        assert!(!Sampling::Temperature { temp: f32::INFINITY, seed: Some(1) }.is_valid());
        assert!(!Sampling::Temperature { temp: 0.0, seed: Some(1) }.is_valid());
        assert!(!Sampling::Temperature { temp: -1.0, seed: Some(1) }.is_valid());
    }
}
