//! Continuous-batching CPU inference server over the packed-ternary
//! engine — the deployment layer behind the paper's "serve many users
//! from commodity CPUs" story (Fig. 1 right panels: ~10x weight memory,
//! faster CPU decode).
//!
//! Architecture (one request's life, left to right):
//!
//! ```text
//!  submit()          admit (join on arrival)        retire on finish
//!  Request ──► FIFO queue ──► scheduler lanes ──► Response + ServeStats
//!                               │       ▲
//!                               ▼       │ logits per lane
//!                      Engine::decode_step_batch
//!                      (gemm over the batch dim, KvCachePool slots)
//! ```
//!
//! - [`request`] — the API types: [`Request`] (prompt, task shape,
//!   sampling, deadline), [`Response`] (tokens/class, finish reason,
//!   per-phase latency).
//! - [`scheduler`] — [`Server`]: bounded admission queue, dynamic batch
//!   with per-step join/retire. Prompts run through **chunked
//!   prefill** ([`ServerCfg::prefill_chunk`] tokens per lane per step
//!   via [`crate::engine::prefill`]: time-batched GEMMs, one LM head
//!   per prompt — run by its final chunk), co-scheduled with single-token
//!   decode lanes. [`ServerCfg::threads`] sizes a
//!   [`crate::parallel::ThreadPool`] the engine step fans its GEMMs
//!   over, and [`ServerCfg::kernel`] picks the ternary kernel
//!   generation (byte-decode vs activation-LUT) — all three are pure
//!   throughput knobs, since the parallel kernels are bitwise identical
//!   to serial at every thread count, the LUT kernels to byte-decode on
//!   every input, and the chunked prefill to token-by-token decode at
//!   every chunk size.
//! - [`stats`] — [`ServeStats`] (p50/p95/p99 latency, queue depth,
//!   tokens/s, batch occupancy) and the crate-wide [`stats::quantile`].
//! - [`net`] — the std-only TCP front-end (`bitdistill serve --listen`):
//!   newline-delimited JSON frames with streamed tokens, bounded
//!   admission with socket-level backpressure, deadline shedding,
//!   cancel-on-disconnect ([`Server::cancel`]), per-connection
//!   timeouts, panic containment, and seeded deterministic fault
//!   injection ([`net::FaultPlan`]) for the chaos suite.
//!
//! The engine guarantees the scheduler leans on: a batch of one is
//! bitwise identical to [`crate::engine::Engine::decode_step`], and
//! co-scheduled lanes cannot influence each other (both test-enforced in
//! `engine::model` and re-checked end-to-end in `scheduler`).

pub mod net;
pub mod request;
pub mod scheduler;
pub mod stats;

pub use net::{FaultPlan, NetCfg, NetReport, NetServer, WireCaps};
pub use request::{FinishReason, Request, Response, Sampling, Timing};
pub use scheduler::{Server, ServerCfg};
pub use stats::{ms_or_dash, quantile, quantile_unsorted, Percentiles, ServeStats};
