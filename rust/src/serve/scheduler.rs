//! The continuous-batching scheduler.
//!
//! Requests enter a bounded FIFO queue; the scheduler admits them into
//! the running batch the moment a KV slot frees up (join on arrival) and
//! retires each sequence individually on EOS / budget / deadline (retire
//! on finish) — there is **no barrier**: a request submitted while others
//! are mid-generation starts decoding on the very next engine step, so
//! short and long requests mix freely.
//!
//! One [`Server::step`] feeds every active lane once: prefill lanes get
//! up to [`ServerCfg::prefill_chunk`] prompt tokens via the chunked
//! prefill forward ([`crate::engine::prefill`] — time-batched GEMMs;
//! the LM head runs once per prompt, in its final chunk), decode lanes
//! get one token each through one
//! [`crate::engine::Engine::decode_step_batch`].
//! Per-lane arithmetic is bitwise identical to the sequential engine
//! path at every chunk size, so scheduling decisions can never change a
//! request's output (test-enforced below).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::{
    argmax, BatchScratch, Engine, ExecCtx, KernelKind, KvCachePool, PrefillScratch,
};
use crate::obs::{request_tid, ArgV, QuantScope, TraceRecorder, TID_MAIN};
use crate::parallel::ThreadPool;
use crate::substrate::{Json, Rng};

use super::request::{FinishReason, Request, Response, Sampling, Timing};
use super::stats::ServeStats;

/// Admission-control and batching limits.
#[derive(Clone, Copy, Debug)]
pub struct ServerCfg {
    /// Max co-scheduled sequences (= KV slots = GEMM batch bound).
    pub max_batch: usize,
    /// Max requests waiting for a slot; submissions beyond are rejected.
    pub max_queue: usize,
    /// Worker threads for the engine step (1 = serial). The engine's
    /// row-partitioned kernels are bitwise identical at every thread
    /// count, so this knob changes throughput only, never outputs.
    pub threads: usize,
    /// Kernel generation for the engine step (byte-decode,
    /// activation-LUT, or runtime-dispatched SIMD). All three are
    /// bitwise identical on every input — SIMD falls back to the scalar
    /// reference on unsupported hosts, same bits — so, like `threads`,
    /// this changes throughput only, never responses (test-enforced).
    /// The server always runs this value, overriding the engine's own
    /// [`crate::engine::Engine::kernel`] default (which only governs
    /// the non-server entry points).
    pub kernel: KernelKind,
    /// Per-step prompt-token budget per lane (chunked prefill): a lane
    /// with more than one prompt token left feeds up to this many
    /// tokens per step through [`crate::engine::prefill`] — time-batched
    /// GEMMs, with the LM head run only by the chunk that ends the
    /// prompt — co-scheduled with the single-token decode lanes.
    /// 1 (the default) is the legacy unified prefill+decode. Like
    /// `threads` and `kernel` this is bitwise-output-invariant
    /// (test-enforced): it moves TTFT and prompt throughput only.
    pub prefill_chunk: usize,
    /// Emit one metrics snapshot row ([`ServeStats::snapshot`]) every
    /// this many engine steps into [`Server::take_snapshots`]; 0 (the
    /// default) disables the emitter. Like tracing, snapshots only
    /// *read* server state — they can never change a response.
    pub metrics_every: usize,
}

impl Default for ServerCfg {
    fn default() -> ServerCfg {
        ServerCfg {
            max_batch: 16,
            max_queue: 256,
            threads: 1,
            kernel: KernelKind::ByteDecode,
            prefill_chunk: 1,
            metrics_every: 0,
        }
    }
}

struct Queued {
    id: u64,
    req: Request,
    submitted: Instant,
}

struct Active {
    id: u64,
    req: Request,
    slot: usize,
    /// Prompt+generated tokens fed to the engine so far.
    fed: usize,
    /// Token to feed on the next step.
    next_token: i32,
    generated: Vec<i32>,
    class: Option<usize>,
    rng: Option<Rng>,
    submitted: Instant,
    admitted: Instant,
    prefill_done: Option<Instant>,
}

/// A continuous-batching inference server over one [`Engine`].
pub struct Server<'a> {
    engine: &'a Engine,
    cfg: ServerCfg,
    pool: KvCachePool,
    scratch: BatchScratch,
    /// Chunk-shaped scratch for the prefill lanes, sized to
    /// [`ServerCfg::prefill_chunk`].
    prefill: PrefillScratch,
    /// Worker pool for the engine step, sized by [`ServerCfg::threads`].
    tpool: ThreadPool,
    queue: VecDeque<Queued>,
    active: Vec<Active>,
    completed: Vec<Response>,
    /// Tokens generated since the last [`Server::take_streamed`] drain,
    /// as `(request id, token)` in production order — the network
    /// front-end's streaming feed. Drivers that only consume whole
    /// responses need not drain it: [`Server::take_completed`] clears it
    /// too, so the buffer stays bounded by the tokens of one
    /// take-to-take window either way.
    streamed: Vec<(u64, i32)>,
    pub stats: ServeStats,
    next_id: u64,
    /// Span recorder ([`Server::set_trace`]); disabled by default, in
    /// which case every recording call below is a single branch. The
    /// recorder only *reads* timestamps and metadata — trace-on vs
    /// trace-off responses are bitwise identical (test-enforced).
    trace: TraceRecorder,
    /// Quantization telemetry ([`Server::set_quant_scope`]): per-layer
    /// int8 activation-range/saturation accumulators fed by the decode
    /// batch ([`crate::engine::Engine::decode_step_batch_ctx`]).
    /// Disabled by default — one branch per act-quant site — and, like
    /// `trace`, recording only reads: instrumented responses are
    /// bitwise identical to uninstrumented (test-enforced below).
    quant: QuantScope,
    /// Wall-clock origin for metrics snapshots.
    started: Instant,
    snapshots: Vec<Json>,
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Draw the next token per the request's sampling policy. Greedy matches
/// [`crate::engine::Engine::generate`] exactly.
///
/// Total by construction: a temperature request without an rng used to
/// hit an `expect` here, killing every co-scheduled lane mid-step.
/// Submission now rejects such requests ([`Sampling::is_valid`]), and if
/// one ever slipped through anyway this degrades to greedy instead of
/// panicking the server.
fn sample_token(logits: &[f32], sampling: &Sampling, rng: &mut Option<Rng>) -> i32 {
    match sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature { temp, .. } => {
            let Some(r) = rng.as_mut() else {
                // unreachable post-validation; greedy beats killing the
                // whole batch if an invariant ever breaks
                return argmax(logits);
            };
            let t = temp.max(1e-4) as f64;
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let z: f64 = logits.iter().map(|&l| ((l as f64 - m) / t).exp()).sum();
            let mut u = r.f64() * z;
            for (i, &l) in logits.iter().enumerate() {
                u -= ((l as f64 - m) / t).exp();
                if u <= 0.0 {
                    return i as i32;
                }
            }
            logits.len() as i32 - 1
        }
    }
}

/// Shared post-feed bookkeeping for one lane — both phases of
/// [`Server::step`] (chunked prefill and the decode batch) route here
/// so the retirement rules live in exactly one place: advance through
/// the prompt (a mid-prompt lane only checks its deadline), stamp the
/// end of prefill, and consume the step's logits via [`lane_outcome`]
/// once the prompt is fully fed.
fn post_feed(
    a: &mut Active,
    logits: &[f32],
    slot_len: usize,
    max_seq: usize,
) -> Option<FinishReason> {
    let deadline_hit = a.req.deadline.is_some_and(|dl| a.submitted.elapsed() >= dl);
    if a.fed < a.req.prompt.len() {
        // lint: allow(no-panic-in-request-path): guarded by `a.fed < prompt.len()` above
        a.next_token = a.req.prompt[a.fed];
        return deadline_hit.then_some(FinishReason::DeadlineExceeded);
    }
    if a.prefill_done.is_none() {
        a.prefill_done = Some(Instant::now());
    }
    lane_outcome(a, logits, slot_len, max_seq, deadline_hit)
}

/// Bookkeeping for one lane whose prompt is fully fed: consume the
/// freshly computed logits **first** (classification answer or sampled
/// token), then apply the deadline. Work the engine already paid for is
/// always delivered — a deadline only prevents further steps, it never
/// drops a computed token or answer (the old code checked the deadline
/// before consuming, silently losing the final token of a just-finished
/// request). Precedence when several stop conditions coincide: budget,
/// EOS, cache capacity (mirroring
/// [`crate::engine::Engine::generate`]), then deadline.
///
/// Returns the finish reason, or None when the lane continues (in which
/// case `a.next_token` is set). Semantics pinned by the unit tests
/// below.
fn lane_outcome(
    a: &mut Active,
    logits: &[f32],
    slot_len: usize,
    max_seq: usize,
    deadline_hit: bool,
) -> Option<FinishReason> {
    if a.req.is_classification() {
        a.class = Some(crate::engine::argmax_labels(logits, &a.req.label_ids));
        return Some(FinishReason::Classified);
    }
    // generation: mirror Engine::generate's stop conditions in its
    // exact order (budget, then EOS, then cache capacity)
    let tok = sample_token(logits, &a.req.sampling, &mut a.rng);
    if a.generated.len() >= a.req.max_new {
        return Some(FinishReason::MaxTokens);
    }
    if tok == a.req.eos {
        return Some(FinishReason::Eos);
    }
    if slot_len >= max_seq {
        return Some(FinishReason::CacheExhausted);
    }
    a.generated.push(tok);
    if a.generated.len() >= a.req.max_new {
        return Some(FinishReason::MaxTokens);
    }
    if deadline_hit {
        return Some(FinishReason::DeadlineExceeded);
    }
    a.next_token = tok;
    None
}

impl<'a> Server<'a> {
    pub fn new(engine: &'a Engine, cfg: ServerCfg) -> Server<'a> {
        assert!(cfg.max_batch > 0);
        Server {
            pool: engine.new_cache_pool(cfg.max_batch),
            scratch: engine.new_batch_scratch(cfg.max_batch),
            // a chunk never exceeds a prompt, and prompts are capped at
            // max_seq — clamp so an absurd --prefill-chunk cannot
            // balloon the scratch
            prefill: engine.new_prefill_scratch(cfg.prefill_chunk.clamp(1, engine.max_seq())),
            tpool: ThreadPool::new(cfg.threads),
            engine,
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            completed: Vec::new(),
            streamed: Vec::new(),
            stats: ServeStats::default(),
            next_id: 0,
            trace: TraceRecorder::disabled(),
            quant: QuantScope::disabled(),
            started: Instant::now(),
            snapshots: Vec::new(),
        }
    }

    /// Attach a span recorder. Request lifecycle spans (queued /
    /// prefill / decode per request track) and engine step-phase spans
    /// land in it; pass [`TraceRecorder::disabled`] (the default) for
    /// the zero-cost-off path.
    pub fn set_trace(&mut self, trace: TraceRecorder) {
        trace.name_track(TID_MAIN, "scheduler");
        self.trace = trace;
    }

    /// Attach a quantization-telemetry scope (`bitdistill serve
    /// --quant-metrics`): every decode batch feeds its per-layer int8
    /// activation ranges and saturation counts into it; the driver
    /// drains `kind:"quant"` rows via [`QuantScope::take_rows`]. Pass
    /// [`QuantScope::disabled`] (the default) for the zero-cost-off
    /// path. Only meaningful on a ternary engine (the FP path has no
    /// activation-quant sites).
    pub fn set_quant_scope(&mut self, quant: QuantScope) {
        self.quant = quant;
    }

    /// Enqueue a request, returning its id. Invalid or over-capacity
    /// submissions complete immediately with [`FinishReason::Rejected`]
    /// (the response is still delivered through the normal channel).
    /// Validation includes the sampling policy ([`Sampling::is_valid`])
    /// and that every prompt token and verbalizer label id indexes the
    /// engine's vocab: an unseeded/degenerate-temperature request, an
    /// out-of-vocab prompt token (would slice the embedding table out
    /// of bounds mid-step) or an out-of-vocab label id (would index the
    /// logits out of bounds) bounces here, alone, instead of panicking
    /// the shared step and every co-scheduled lane.
    pub fn submit(&mut self, req: Request) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        let prompt_len = req.prompt.len();
        let vocab = self.engine.cfg.vocab;
        let in_vocab = |t: &i32| *t >= 0 && (*t as usize) < vocab;
        let invalid = prompt_len == 0
            || prompt_len > self.engine.max_seq()
            || !req.sampling.is_valid()
            || !req.prompt.iter().all(in_vocab)
            || !req.label_ids.iter().all(in_vocab);
        if invalid || self.queue.len() >= self.cfg.max_queue {
            self.stats.rejected += 1;
            // overload stays observable: record the queue depth this
            // submission bounced off (0 for validity rejections too —
            // the counter split is in the `invalid` flag's absence)
            self.stats.rejected_queue_depth.record(self.queue.len() as f64);
            self.trace.instant(
                TID_MAIN,
                "rejected",
                &[
                    ("id", ArgV::Num(id as f64)),
                    ("queue", ArgV::Num(self.queue.len() as f64)),
                ],
            );
            self.completed.push(Response {
                id,
                tokens: Vec::new(),
                class: None,
                finish: FinishReason::Rejected,
                prompt_len,
                timing: Timing::default(),
            });
            return id;
        }
        self.queue.push_back(Queued { id, req, submitted: Instant::now() });
        self.stats.peak_queue_depth = self.stats.peak_queue_depth.max(self.queue.len());
        id
    }

    /// Withdraw a request — the network front-end's client-disconnect
    /// path. A queued request is removed before ever touching a lane; an
    /// in-flight request is retired immediately, **releasing its KV slot
    /// for the next admit**, with any already-generated tokens riding
    /// along in the response. Either way the response completes with
    /// [`FinishReason::Canceled`] and lands in [`ServeStats::canceled`]
    /// (never `completed`), keeping the conservation invariant
    /// `submitted == completed + rejected + expired + canceled`.
    ///
    /// Returns `false` when `id` is unknown or already finished — a
    /// cancel racing a completion is a no-op, not an error.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|q| q.id == id) {
            if let Some(q) = self.queue.remove(pos) {
                let total = ms(q.submitted.elapsed());
                self.finish_unstarted(q, FinishReason::Canceled, total);
                return true;
            }
        }
        if let Some(pos) = self.active.iter().position(|a| a.id == id) {
            let a = self.active.swap_remove(pos);
            self.retire(a, FinishReason::Canceled);
            return true;
        }
        false
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// KV memory actually held by the slot pool: slots are backed
    /// lazily on first acquisition, so this starts at 0, grows with the
    /// peak concurrent batch, then stays constant.
    pub fn kv_memory_bytes(&self) -> usize {
        self.pool.memory_bytes()
    }

    /// Move queued requests into free slots (join on arrival).
    fn admit(&mut self) {
        while self.active.len() < self.cfg.max_batch {
            let Some(q) = self.queue.pop_front() else { break };
            if let Some(dl) = q.req.deadline {
                if q.submitted.elapsed() >= dl {
                    let total = ms(q.submitted.elapsed());
                    self.finish_unstarted(q, FinishReason::DeadlineExceeded, total);
                    continue;
                }
            }
            // the pool is sized to max_batch so a slot should exist
            // whenever active < max_batch — but a panic here would kill
            // every co-scheduled lane, so if the invariant ever breaks
            // the request goes back to the head of the queue and waits
            // for the next retire/admit cycle instead (no request lost)
            let Some(slot) = self.pool.acquire() else {
                self.queue.push_front(q);
                break;
            };
            let rng = match &q.req.sampling {
                Sampling::Greedy => None,
                // seed presence was validated at submit
                Sampling::Temperature { seed, .. } => seed.map(Rng::new),
            };
            // lint: allow(no-panic-in-request-path): prompt non-empty validated at submit()
            let first = q.req.prompt[0];
            self.active.push(Active {
                id: q.id,
                req: q.req,
                slot,
                fed: 0,
                next_token: first,
                generated: Vec::new(),
                class: None,
                rng,
                submitted: q.submitted,
                admitted: Instant::now(),
                prefill_done: None,
            });
        }
    }

    fn finish_unstarted(&mut self, q: Queued, finish: FinishReason, total_ms: f64) {
        // an in-queue deadline expiry is overload, not a completion:
        // its whole life was queue time, recorded into the expired
        // histograms so the latency picture keeps the worst cases
        if finish == FinishReason::DeadlineExceeded {
            self.stats.expired += 1;
            self.stats.expired_total_ms.record(total_ms);
            self.stats.expired_queue_ms.record(total_ms);
        } else if finish == FinishReason::Canceled {
            self.stats.canceled += 1;
            self.stats.canceled_total_ms.record(total_ms);
        } else {
            self.stats.completed += 1;
            self.stats.total_ms.record(total_ms);
            self.stats.queue_ms.record(total_ms);
        }
        let now = Instant::now();
        let rt = request_tid(q.id);
        self.trace.complete(
            rt,
            "request",
            q.submitted,
            now,
            &[("finish", ArgV::Str(finish.name()))],
        );
        self.trace.complete(rt, "queued", q.submitted, now, &[]);
        self.completed.push(Response {
            id: q.id,
            tokens: Vec::new(),
            class: None,
            finish,
            prompt_len: q.req.prompt.len(),
            timing: Timing {
                queue_ms: total_ms,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                total_ms,
            },
        });
    }

    /// One engine iteration over the current batch: admit joiners, feed
    /// each lane — up to [`ServerCfg::prefill_chunk`] prompt tokens for
    /// a prefill lane (time-batched chunk over its own KV slot; only a
    /// prompt-ending chunk runs the LM head), one token for everyone
    /// else (single decode batch) — then retire finished lanes. Returns the
    /// number of lanes processed (0 = idle).
    ///
    /// Deadline semantics (pinned by `lane_outcome` tests): a token or
    /// classification answer whose compute already happened this step is
    /// **always delivered** — the deadline only stops a lane from being
    /// scheduled further. (The old code checked the deadline before
    /// consuming the just-computed logits, silently dropping a finished
    /// request's final token.)
    pub fn step(&mut self) -> usize {
        self.admit();
        if self.active.is_empty() {
            return 0;
        }
        let max_seq = self.engine.max_seq();
        let chunk = self.cfg.prefill_chunk.clamp(1, max_seq);
        let b = self.active.len();
        let mut finished: Vec<(usize, FinishReason)> = Vec::new();
        // cheap Rc handle: span guards must not hold a borrow of self
        // across the &mut self calls below
        let trace = self.trace.clone();
        // one execution context for both engine phases: the scheduler's
        // pool, kernel and observability sinks, bundled once per step
        let ectx = ExecCtx {
            pool: self.tpool,
            kernel: self.cfg.kernel,
            trace: trace.clone(),
            quant: self.quant.clone(),
        };
        let _step_span = trace.span_args(
            TID_MAIN,
            "step",
            &[
                ("batch", ArgV::Num(b as f64)),
                ("queue", ArgV::Num(self.queue.len() as f64)),
            ],
        );

        // Phase 1: chunked prefill — each lane with more than one prompt
        // token left runs one time-batched chunk over its own slot.
        // Lanes are independent (disjoint slots), so running them before
        // the decode batch cannot change any output.
        let mut in_batch: Vec<usize> = Vec::with_capacity(b);
        for i in 0..b {
            let remaining = {
                // lint: allow(no-panic-in-request-path): i < b = active.len() by the loop bound
                let a = &self.active[i];
                a.req.prompt.len().saturating_sub(a.fed)
            };
            if chunk <= 1 || remaining <= 1 {
                in_batch.push(i);
                continue;
            }
            let k = remaining.min(chunk);
            // lint: allow(no-panic-in-request-path): i < b = active.len() by the loop bound
            let a = &mut self.active[i];
            // logits are only needed when this chunk ends the prompt;
            // interior chunks skip the vocab GEMV entirely, so a whole
            // prompt pays exactly one LM head
            let need_logits = k == remaining;
            // lint: allow(no-panic-in-request-path): a.fed + k <= prompt.len() since k = min(remaining, chunk)
            let chunk_tokens = &a.req.prompt[a.fed..a.fed + k];
            self.engine.prefill_chunk_slot_ctx(
                &ectx,
                chunk_tokens,
                a.slot,
                &mut self.pool,
                &mut self.prefill,
                need_logits,
            );
            a.fed += k;
            // lint: allow(no-panic-in-request-path): a.slot came from pool.acquire(), always in-range
            let slot_len = self.pool.slots[a.slot].len;
            let before = a.generated.len();
            if let Some(f) = post_feed(a, self.prefill.final_logits(), slot_len, max_seq) {
                finished.push((i, f));
            }
            for &t in a.generated.iter().skip(before) {
                self.streamed.push((a.id, t));
            }
        }

        // Phase 2: the single-token decode batch (decode lanes, lanes
        // feeding their final prompt token, and everything at chunk 1).
        if !in_batch.is_empty() {
            let mut tokens: Vec<i32> = Vec::with_capacity(in_batch.len());
            let mut slots: Vec<usize> = Vec::with_capacity(in_batch.len());
            for &i in &in_batch {
                // lint: allow(no-panic-in-request-path): in_batch holds indices from 0..active.len() above
                let a = &self.active[i];
                tokens.push(a.next_token);
                slots.push(a.slot);
            }
            self.engine.decode_step_batch_ctx(
                &ectx,
                &tokens,
                &slots,
                &mut self.pool,
                &mut self.scratch,
            );
            for (bi, &i) in in_batch.iter().enumerate() {
                // lint: allow(no-panic-in-request-path): in_batch holds indices from 0..active.len() above
                let a = &mut self.active[i];
                a.fed += 1;
                // logits_row(bi) holds the distribution after the last
                // fed token (end of prompt, or the latest generated one)
                // lint: allow(no-panic-in-request-path): a.slot came from pool.acquire(), always in-range
                let slot_len = self.pool.slots[a.slot].len;
                let before = a.generated.len();
                if let Some(f) = post_feed(a, self.scratch.logits_row(bi), slot_len, max_seq) {
                    finished.push((i, f));
                }
                for &t in a.generated.iter().skip(before) {
                    self.streamed.push((a.id, t));
                }
            }
        }
        self.stats.record_step(b);
        if self.cfg.metrics_every > 0 && self.stats.steps % self.cfg.metrics_every == 0 {
            let row = self.stats.snapshot(
                self.started.elapsed().as_secs_f64(),
                self.queue.len(),
                self.active.len(),
                self.pool.resident_lanes(),
            );
            self.snapshots.push(row);
        }

        // retire on finish: release slots for the next admit() to reuse.
        // `finished` mixes phase-1 and phase-2 indices, so sort before
        // the descending swap_remove sweep.
        finished.sort_by_key(|&(i, _)| i);
        for &(i, reason) in finished.iter().rev() {
            let a = self.active.swap_remove(i);
            self.retire(a, reason);
        }
        b
    }

    fn retire(&mut self, a: Active, finish: FinishReason) {
        let now = Instant::now();
        self.pool.release(a.slot);
        let prefill_end = a.prefill_done.unwrap_or(now);
        let timing = Timing {
            queue_ms: ms(a.admitted.duration_since(a.submitted)),
            prefill_ms: ms(prefill_end.duration_since(a.admitted)),
            decode_ms: ms(now.duration_since(prefill_end)),
            total_ms: ms(now.duration_since(a.submitted)),
        };
        // a mid-flight deadline expiry delivered whatever was computed,
        // but its latency belongs to the overload picture, not the
        // completed-request histograms
        if finish == FinishReason::DeadlineExceeded {
            self.stats.expired += 1;
            self.stats.expired_total_ms.record(timing.total_ms);
            self.stats.expired_queue_ms.record(timing.queue_ms);
            if a.prefill_done.is_some() {
                self.stats.expired_ttft_ms.record(timing.queue_ms + timing.prefill_ms);
            }
        } else if finish == FinishReason::Canceled {
            // a withdrawal, not a completion: its own bucket, same
            // doctrine as deadline expiries
            self.stats.canceled += 1;
            self.stats.canceled_total_ms.record(timing.total_ms);
        } else {
            self.stats.completed += 1;
            self.stats.total_ms.record(timing.total_ms);
            self.stats.queue_ms.record(timing.queue_ms);
            if a.prefill_done.is_some() {
                self.stats.ttft_ms.record(timing.queue_ms + timing.prefill_ms);
            }
        }
        self.stats.prompt_tokens += a.fed.min(a.req.prompt.len());
        self.stats.new_tokens += a.generated.len();
        // request-lifecycle spans, reconstructed from the timestamps
        // the scheduler keeps anyway: one track per request id
        if self.trace.is_enabled() {
            let rt = request_tid(a.id);
            self.trace.complete(
                rt,
                "request",
                a.submitted,
                now,
                &[
                    ("finish", ArgV::Str(finish.name())),
                    ("prompt", ArgV::Num(a.req.prompt.len() as f64)),
                    ("new_tokens", ArgV::Num(a.generated.len() as f64)),
                ],
            );
            self.trace.complete(rt, "queued", a.submitted, a.admitted, &[]);
            if let Some(pf) = a.prefill_done {
                self.trace.complete(rt, "prefill", a.admitted, pf, &[]);
                self.trace.complete(rt, "decode", pf, now, &[]);
            }
        }
        self.completed.push(Response {
            id: a.id,
            tokens: a.generated,
            class: a.class,
            finish,
            prompt_len: a.req.prompt.len(),
            timing,
        });
    }

    /// Responses finished since the last call (any order). Also clears
    /// the streamed-token buffer so whole-response consumers that never
    /// call [`Server::take_streamed`] don't accumulate it unboundedly.
    pub fn take_completed(&mut self) -> Vec<Response> {
        self.streamed.clear();
        std::mem::take(&mut self.completed)
    }

    /// `(request id, token)` pairs generated since the last drain, in
    /// production order — the streaming feed for the network front-end
    /// ([`crate::serve::net`]), which turns each pair into a `token`
    /// frame before the request's final `done` frame. Purely
    /// observational: draining (or never draining) cannot change any
    /// response.
    pub fn take_streamed(&mut self) -> Vec<(u64, i32)> {
        std::mem::take(&mut self.streamed)
    }

    /// Metrics snapshot rows accumulated since the last call
    /// ([`ServerCfg::metrics_every`]); the driver writes them as JSONL.
    pub fn take_snapshots(&mut self) -> Vec<Json> {
        std::mem::take(&mut self.snapshots)
    }

    /// Drive the batch until queue and active set are empty; returns
    /// every pending response.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        while self.has_work() {
            self.step();
        }
        self.take_completed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::model::mini_model;
    use crate::engine::Engine;

    fn engines() -> Vec<Engine> {
        [false, true]
            .into_iter()
            .map(|tern| {
                let (spec, store) = mini_model(true, true);
                Engine::from_params(&spec, &store, tern).unwrap()
            })
            .collect()
    }

    #[test]
    fn continuous_batching_matches_sequential_generate() {
        for e in engines() {
            let prompts: Vec<Vec<i32>> = vec![
                vec![1, 4, 6],
                vec![3, 9, 1, 7, 4],
                vec![5],
                vec![8, 8, 2, 1],
                vec![10, 11, 12, 13, 14, 15],
                vec![7, 3],
            ];
            let max_new = 6;
            let mut srv = Server::new(
                &e,
                ServerCfg { max_batch: 3, max_queue: 64, threads: 1, ..ServerCfg::default() },
            );
            let mut ids = Vec::new();
            for p in &prompts {
                ids.push(srv.submit(Request::generate(p.clone(), max_new)));
            }
            let mut responses = srv.run_to_completion();
            responses.sort_by_key(|r| r.id);
            assert_eq!(responses.len(), prompts.len());
            for (r, p) in responses.iter().zip(&prompts) {
                let want = e.generate(p, max_new, crate::data::tokenizer::EOS);
                assert_eq!(r.tokens, want, "request {} diverged from generate()", r.id);
                assert!(matches!(
                    r.finish,
                    FinishReason::Eos | FinishReason::MaxTokens
                ));
            }
            assert_eq!(ids, (0..prompts.len() as u64).collect::<Vec<_>>());
            // with 6 requests and max_batch 3, steps must overlap lanes
            assert!(srv.stats.mean_occupancy() > 1.0);
            assert_eq!(srv.stats.completed, prompts.len());
        }
    }

    #[test]
    fn admit_requeues_when_pool_has_no_free_slot() {
        for e in engines() {
            let mut srv = Server::new(
                &e,
                ServerCfg { max_batch: 2, max_queue: 8, threads: 1, ..ServerCfg::default() },
            );
            // steal both slots: admit() now sees an exhausted pool even
            // though active < max_batch. The old code panicked on this
            // invariant break, killing every co-scheduled lane; the
            // request-path contract is to requeue and retry instead.
            let s0 = srv.pool.acquire().unwrap();
            let s1 = srv.pool.acquire().unwrap();
            let prompt = vec![1i32, 2, 3];
            let id = srv.submit(Request::generate(prompt.clone(), 2));
            assert_eq!(srv.step(), 0, "nothing admissible, nothing computed");
            assert_eq!(srv.queue_depth(), 1, "request waits instead of being lost");
            srv.pool.release(s0);
            srv.pool.release(s1);
            let rs = srv.run_to_completion();
            assert_eq!(rs.len(), 1);
            assert_eq!(rs[0].id, id);
            let want = e.generate(&prompt, 2, crate::data::tokenizer::EOS);
            assert_eq!(rs[0].tokens, want, "the requeued request completes normally");
        }
    }

    #[test]
    fn classification_matches_forward_logits() {
        for e in engines() {
            let prompt = vec![1i32, 5, 9, 2, 8, 3];
            let label_ids = vec![6i32, 17, 28];
            let logits = e.forward_logits(&prompt);
            let last = logits.last().unwrap();
            let want = label_ids
                .iter()
                .enumerate()
                .max_by(|a, b| last[*a.1 as usize].total_cmp(&last[*b.1 as usize]))
                .map(|(c, _)| c)
                .unwrap();

            let mut srv = Server::new(
                &e,
                ServerCfg { max_batch: 2, max_queue: 8, threads: 1, ..ServerCfg::default() },
            );
            srv.submit(Request::classify(prompt.clone(), label_ids.clone()));
            // co-schedule a neighbour to prove isolation
            srv.submit(Request::generate(vec![7, 7, 3], 4));
            let mut rs = srv.run_to_completion();
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs[0].finish, FinishReason::Classified);
            assert_eq!(rs[0].class, Some(want));
            assert!(rs[0].tokens.is_empty());
        }
    }

    #[test]
    fn queue_overflow_and_invalid_prompts_reject() {
        let es = engines();
        let e = &es[1];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 1, max_queue: 2, threads: 1, ..ServerCfg::default() },
        );
        srv.submit(Request::generate(vec![], 4)); // empty prompt
        for _ in 0..4 {
            srv.submit(Request::generate(vec![1, 2, 3], 2));
        }
        // queue cap 2: submissions 3 and 4 of the valid ones bounce
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        let rejected: Vec<u64> = rs
            .iter()
            .filter(|r| r.finish == FinishReason::Rejected)
            .map(|r| r.id)
            .collect();
        assert_eq!(rejected, vec![0, 3, 4]);
        assert_eq!(srv.stats.rejected, 3);
        // the conservation invariant: every submission ends in exactly
        // one of completed / rejected / expired / canceled
        assert_eq!(srv.stats.accounted(), srv.stats.submitted);
        // every rejection records the queue depth it bounced off, so
        // overload is visible in the metrics instead of vanishing
        assert_eq!(srv.stats.rejected_queue_depth.count(), 3);
        assert_eq!(srv.stats.rejected_queue_depth.max(), 2.0, "full queue depth");
    }

    #[test]
    fn zero_deadline_expires_in_queue() {
        let es = engines();
        let e = &es[1];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 1, max_queue: 8, threads: 1, ..ServerCfg::default() },
        );
        let id = srv.submit(
            Request::generate(vec![1, 2, 3], 4).with_deadline(Duration::from_secs(0)),
        );
        let rs = srv.run_to_completion();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, id);
        assert_eq!(rs[0].finish, FinishReason::DeadlineExceeded);
        // the expiry is overload, not a completion: it lands in the
        // expired counter + histograms, never the completed-latency ones
        assert_eq!(srv.stats.expired, 1);
        assert_eq!(srv.stats.completed, 0);
        assert_eq!(srv.stats.expired_total_ms.count(), 1);
        assert_eq!(srv.stats.expired_queue_ms.count(), 1);
        assert_eq!(srv.stats.total_ms.count(), 0);
        assert_eq!(srv.stats.queue_ms.count(), 0);
    }

    #[test]
    fn midflight_deadline_expiry_records_into_expired_histograms() {
        // a deadline that trips after admission and prefill: the step's
        // computed token is still delivered (semantics pinned above),
        // and the request's latency goes to the expired picture, not
        // the completed one. Driven step-by-step so the expiry lands
        // deterministically mid-generation: the deadline is generous
        // next to the first steps (microseconds) and the sleep pushes
        // past it before the next step.
        let es = engines();
        let e = &es[1];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 2, max_queue: 8, ..ServerCfg::default() },
        );
        // eos = -1 is unreachable, so only the deadline can end lane 0
        let mut long = Request::generate(vec![1, 2, 3], 10_000)
            .with_deadline(Duration::from_millis(200));
        long.eos = -1;
        srv.submit(long);
        srv.submit(Request::generate(vec![4, 5], 3));
        // admit + fully prefill + start decoding, well inside the deadline
        for _ in 0..5 {
            srv.step();
        }
        assert_eq!(srv.stats.expired, 0, "deadline must not have tripped yet");
        std::thread::sleep(Duration::from_millis(250));
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs[0].finish, FinishReason::DeadlineExceeded);
        assert_eq!(srv.stats.expired, 1);
        assert_eq!(srv.stats.completed, 1);
        assert_eq!(srv.stats.expired_total_ms.count(), 1);
        // mid-flight: it was admitted and prefilled, so its TTFT is
        // recorded too — in the expired histogram
        assert_eq!(srv.stats.expired_ttft_ms.count(), 1);
        assert_eq!(srv.stats.total_ms.count(), 1, "the healthy lane");
        assert_eq!(srv.stats.ttft_ms.count(), 1);
    }

    #[test]
    fn tracing_records_request_and_phase_spans_without_changing_outputs() {
        use crate::substrate::Json;
        for e in engines() {
            let prompts: Vec<Vec<i32>> = vec![
                vec![1, 4, 6, 9, 3, 7, 2, 8],
                vec![3, 9, 1, 7, 4],
                vec![5],
            ];
            let run = |trace: Option<&TraceRecorder>| {
                let mut srv = Server::new(
                    &e,
                    ServerCfg {
                        max_batch: 2,
                        max_queue: 16,
                        prefill_chunk: 4,
                        metrics_every: 2,
                        ..ServerCfg::default()
                    },
                );
                if let Some(t) = trace {
                    srv.set_trace(t.clone());
                }
                for p in &prompts {
                    srv.submit(Request::generate(p.clone(), 5));
                }
                let mut rs = srv.run_to_completion();
                rs.sort_by_key(|r| r.id);
                let snaps = srv.take_snapshots();
                (
                    rs.iter()
                        .map(|r| (r.tokens.clone(), r.class, r.finish))
                        .collect::<Vec<_>>(),
                    snaps,
                )
            };
            let (plain, _) = run(None);
            let rec = TraceRecorder::enabled();
            let (traced, snaps) = run(Some(&rec));
            // the determinism contract: tracing may never change outputs
            assert_eq!(traced, plain);
            // per-request and per-phase spans landed
            assert!(!rec.is_empty());
            let j = rec.to_chrome_json();
            let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
            let names: Vec<&str> = evs
                .iter()
                .filter_map(|ev| ev.get("name").and_then(Json::as_str))
                .collect();
            for want in ["step", "request", "queued", "prefill", "decode", "prefill_chunk", "decode_batch", "lm_head"] {
                assert!(names.contains(&want), "missing span {want:?} in {names:?}");
            }
            // one request span per submitted request, on its own track
            let req_tids: Vec<u64> = evs
                .iter()
                .filter(|ev| ev.get("name").and_then(Json::as_str) == Some("request"))
                .map(|ev| ev.get("tid").unwrap().as_f64().unwrap() as u64)
                .collect();
            assert_eq!(req_tids.len(), prompts.len());
            for (i, _) in prompts.iter().enumerate() {
                assert!(req_tids.contains(&request_tid(i as u64)));
            }
            // the snapshot emitter fired (every 2 steps) with metric rows
            assert!(!snaps.is_empty());
            for row in &snaps {
                assert_eq!(row.get("kind").and_then(Json::as_str), Some("metrics"));
                assert!(row.at(&["total_ms", "count"]).is_some());
            }
        }
    }

    #[test]
    fn metrics_counters_are_cumulative_and_monotonic_across_snapshots() {
        // the snapshot contract pinned in ServeStats::snapshot docs:
        // counters and histogram counts are cumulative since server
        // start and never decrease from one snapshot to the next, so a
        // consumer may difference consecutive rows to get rates.
        let es = engines();
        let e = &es[1];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 2, max_queue: 16, metrics_every: 1, ..ServerCfg::default() },
        );
        for p in [vec![1i32, 4, 6, 9, 3], vec![3, 9, 1, 7], vec![5, 2], vec![8, 8, 2, 1]] {
            srv.submit(Request::generate(p, 6));
        }
        srv.run_to_completion();
        let snaps = srv.take_snapshots();
        assert!(snaps.len() >= 3, "regression needs >= 3 snapshots, got {}", snaps.len());
        let counters = [
            "submitted",
            "completed",
            "rejected",
            "expired",
            "canceled",
            "steps",
            "prompt_tokens",
            "new_tokens",
        ];
        for w in snaps.windows(2) {
            for c in counters {
                let a = w[0].get(c).and_then(Json::as_f64).unwrap();
                let b = w[1].get(c).and_then(Json::as_f64).unwrap();
                assert!(b >= a, "counter {c} regressed across snapshots: {a} -> {b}");
            }
            for h in ["total_ms", "batch_fill", "ttft_ms"] {
                let a = w[0].at(&[h, "count"]).and_then(Json::as_f64).unwrap();
                let b = w[1].at(&[h, "count"]).and_then(Json::as_f64).unwrap();
                assert!(b >= a, "histogram {h} count regressed: {a} -> {b}");
            }
        }
        // cumulative, not per-interval: the last row carries the totals
        let (first, last) = (&snaps[0], snaps.last().unwrap());
        assert!(
            last.get("steps").and_then(Json::as_f64).unwrap()
                > first.get("steps").and_then(Json::as_f64).unwrap(),
            "steps must accumulate"
        );
        assert_eq!(
            last.get("steps").and_then(Json::as_f64),
            Some(srv.stats.steps as f64),
            "metrics_every=1: final snapshot carries the full total"
        );
        // satellite fields: per-step batch-size histogram + KV occupancy
        assert_eq!(
            last.at(&["batch_fill", "count"]).and_then(Json::as_f64),
            Some(srv.stats.steps as f64)
        );
        assert!(last.at(&["batch_fill", "max"]).and_then(Json::as_f64).unwrap() <= 2.0);
        let resident = last.get("kv_resident_lanes").and_then(Json::as_f64).unwrap();
        assert!(
            (1.0..=2.0).contains(&resident),
            "lazy pool backs at most max_batch lanes: {resident}"
        );
    }

    #[test]
    fn quant_telemetry_on_vs_off_server_responses_are_identical() {
        // the serve half of the QuantScope zero-cost-off contract:
        // activation-range/saturation recording must not move a bit of
        // any response, across kernels x prefill_chunk.
        let es = engines();
        let e = &es[1]; // ternary engine: the act-quant sites exist
        let prompts: Vec<Vec<i32>> = vec![
            vec![1, 4, 6, 9, 3, 7, 2, 8, 5, 10, 11],
            vec![3, 9, 1, 7, 4],
            vec![5],
            vec![10, 11, 12, 13, 14, 15, 16, 17],
        ];
        let run = |kernel: KernelKind, chunk: usize, qs: Option<&QuantScope>| {
            let mut srv = Server::new(
                e,
                ServerCfg {
                    max_batch: 3,
                    max_queue: 16,
                    kernel,
                    prefill_chunk: chunk,
                    ..ServerCfg::default()
                },
            );
            if let Some(q) = qs {
                srv.set_quant_scope(q.clone());
            }
            for p in &prompts {
                srv.submit(Request::generate(p.clone(), 6));
            }
            srv.submit(Request::classify(vec![7, 3, 2, 9], vec![6, 17, 28]));
            let mut rs = srv.run_to_completion();
            rs.sort_by_key(|r| r.id);
            rs.iter()
                .map(|r| (r.tokens.clone(), r.class, r.finish))
                .collect::<Vec<_>>()
        };
        let n_layers = e.cfg.n_layers;
        for kernel in KernelKind::ALL {
            for chunk in [1usize, 8] {
                let plain = run(kernel, chunk, None);
                let scope = QuantScope::enabled(1);
                let instrumented = run(kernel, chunk, Some(&scope));
                assert_eq!(
                    instrumented,
                    plain,
                    "responses moved with telemetry on (kernel={} chunk={chunk})",
                    kernel.name()
                );
                let rows = scope.take_rows();
                // one phase:"serve" row per (layer, site) accumulator
                assert_eq!(rows.len(), n_layers * 2, "kernel={} chunk={chunk}", kernel.name());
                for site in ["attn_in", "ffn_in"] {
                    let site_rows: Vec<_> = rows
                        .iter()
                        .filter(|r| r.get("site").and_then(Json::as_str) == Some(site))
                        .collect();
                    assert_eq!(site_rows.len(), n_layers);
                    for r in site_rows {
                        assert_eq!(r.get("phase").and_then(Json::as_str), Some("serve"));
                        let sat = r.get("sat_frac").and_then(Json::as_f64).unwrap();
                        assert!((0.0..=1.0).contains(&sat), "sat_frac {sat}");
                        assert!(r.get("rows_q").and_then(Json::as_f64).unwrap() >= 1.0);
                        let gmax = r.get("gamma_max").and_then(Json::as_f64).unwrap();
                        let gmin = r.get("gamma_min").and_then(Json::as_f64).unwrap();
                        assert!(gmax >= gmin && gmin >= 0.0, "gamma range [{gmin}, {gmax}]");
                    }
                }
            }
        }
        // the FP engine has no activation-quant sites: nothing recorded
        let scope = QuantScope::enabled(1);
        let fp = &es[0];
        let mut srv = Server::new(fp, ServerCfg::default());
        srv.set_quant_scope(scope.clone());
        srv.submit(Request::generate(vec![1, 4, 6], 4));
        srv.run_to_completion();
        assert!(scope.take_rows().is_empty(), "FP engine must not emit quant rows");
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let es = engines();
        let e = &es[1];
        let req = Request::generate(vec![1, 4, 6, 2], 5)
            .with_sampling(Sampling::Temperature { temp: 0.8, seed: Some(99) });
        let run = |req: Request| {
            let mut srv = Server::new(
                e,
                ServerCfg { max_batch: 4, max_queue: 8, threads: 1, ..ServerCfg::default() },
            );
            srv.submit(req);
            // co-schedule greedy noise; must not perturb the sampled lane
            srv.submit(Request::generate(vec![9, 9], 3));
            let mut rs = srv.run_to_completion();
            rs.sort_by_key(|r| r.id);
            rs[0].tokens.clone()
        };
        let a = run(req.clone());
        let b = run(req);
        assert_eq!(a, b);
    }

    #[test]
    fn unseeded_temperature_rejects_without_killing_the_server() {
        // regression: this request used to reach sample_token, hit the
        // `expect("temperature sampling requires a seeded rng")`, and
        // panic the whole server mid-step. It must bounce at submit with
        // Rejected while every co-scheduled lane's output is unchanged.
        let es = engines();
        for e in &es {
            let good = [vec![1i32, 4, 6], vec![3i32, 9, 1, 7]];
            let solo: Vec<Vec<i32>> =
                good.iter().map(|p| e.generate(p, 5, crate::data::tokenizer::EOS)).collect();

            let mut srv = Server::new(
                e,
                ServerCfg { max_batch: 4, max_queue: 8, threads: 1, ..ServerCfg::default() },
            );
            let id0 = srv.submit(Request::generate(good[0].clone(), 5));
            let bad_id = srv.submit(
                Request::generate(vec![2, 5, 8], 5)
                    .with_sampling(Sampling::Temperature { temp: 0.8, seed: None }),
            );
            let nan_id = srv.submit(
                Request::generate(vec![2, 5], 5)
                    .with_sampling(Sampling::Temperature { temp: f32::NAN, seed: Some(7) }),
            );
            let id1 = srv.submit(Request::generate(good[1].clone(), 5));
            let mut rs = srv.run_to_completion();
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 4, "server must survive and answer everything");
            for (r, want_id) in [(&rs[1], bad_id), (&rs[2], nan_id)] {
                assert_eq!(r.id, want_id);
                assert_eq!(r.finish, FinishReason::Rejected);
                assert!(r.tokens.is_empty());
            }
            // the valid lanes are exactly what solo generation produces
            assert_eq!(rs[0].id, id0);
            assert_eq!(rs[0].tokens, solo[0]);
            assert_eq!(rs[3].id, id1);
            assert_eq!(rs[3].tokens, solo[1]);
            assert_eq!(srv.stats.rejected, 2);
        }
    }

    #[test]
    fn threaded_server_outputs_are_identical_to_serial() {
        // ServerCfg::threads is a throughput knob only: same workload,
        // same responses, bit for bit, at every thread count.
        for e in engines() {
            let prompts: Vec<Vec<i32>> = vec![
                vec![1, 4, 6],
                vec![3, 9, 1, 7, 4],
                vec![5],
                vec![10, 11, 12, 13],
            ];
            let run = |threads: usize| {
                let mut srv = Server::new(
                    &e,
                    ServerCfg { max_batch: 3, max_queue: 16, threads, ..ServerCfg::default() },
                );
                for p in &prompts {
                    srv.submit(Request::generate(p.clone(), 6));
                }
                srv.submit(Request::classify(vec![7, 3, 2], vec![6, 17, 28]));
                let mut rs = srv.run_to_completion();
                rs.sort_by_key(|r| r.id);
                rs.iter().map(|r| (r.tokens.clone(), r.class)).collect::<Vec<_>>()
            };
            let serial = run(1);
            for threads in [2usize, 4] {
                assert_eq!(run(threads), serial, "threads={threads}");
            }
        }
    }

    #[test]
    fn prefill_chunk_does_not_change_server_outputs() {
        // ServerCfg::prefill_chunk is — like threads and kernel — a
        // throughput knob only: the chunked prefill path is bitwise
        // identical to token-by-token decode, so the same workload
        // yields the same responses at every chunk size, co-scheduled
        // with decode lanes, under all three kernels.
        for e in engines() {
            let prompts: Vec<Vec<i32>> = vec![
                vec![1, 4, 6, 9, 3, 7, 2, 8, 5, 10, 11],
                vec![3, 9, 1, 7, 4],
                vec![5],
                vec![10, 11, 12, 13, 14, 15, 16, 17],
                vec![7, 3],
            ];
            let run = |prefill_chunk: usize, kernel: KernelKind| {
                let mut srv = Server::new(
                    &e,
                    ServerCfg {
                        max_batch: 3,
                        max_queue: 16,
                        prefill_chunk,
                        kernel,
                        ..ServerCfg::default()
                    },
                );
                for p in &prompts {
                    srv.submit(Request::generate(p.clone(), 6));
                }
                srv.submit(Request::classify(vec![7, 3, 2, 9, 1, 4, 6], vec![6, 17, 28]));
                let mut rs = srv.run_to_completion();
                rs.sort_by_key(|r| r.id);
                rs.iter()
                    .map(|r| (r.tokens.clone(), r.class, r.finish))
                    .collect::<Vec<_>>()
            };
            let want = run(1, KernelKind::ByteDecode);
            for kernel in KernelKind::ALL {
                for chunk in [1usize, 2, 3, 5, 8] {
                    assert_eq!(
                        run(chunk, kernel),
                        want,
                        "chunk={chunk} kernel={}",
                        kernel.name()
                    );
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_server_matches_sequential_generate() {
        // end-to-end: chunked-prefill responses equal Engine::generate
        // exactly, and long-prompt TTFT is recorded
        let es = engines();
        let e = &es[1];
        let prompts: Vec<Vec<i32>> = vec![
            (1..20).collect(),
            vec![3, 9, 1],
            (5..17).collect(),
        ];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 2, max_queue: 8, prefill_chunk: 8, ..ServerCfg::default() },
        );
        for p in &prompts {
            srv.submit(Request::generate(p.clone(), 5));
        }
        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        for (r, p) in rs.iter().zip(&prompts) {
            let want = e.generate(p, 5, crate::data::tokenizer::EOS);
            assert_eq!(r.tokens, want, "request {}", r.id);
        }
        assert_eq!(srv.stats.ttft_ms.count() as usize, prompts.len());
    }

    #[test]
    fn out_of_vocab_ids_reject_without_killing_the_server() {
        // same hardening doctrine as invalid sampling: a request whose
        // verbalizer id can't index the logits, or whose prompt token
        // can't index the embedding table, must bounce at submit,
        // alone — previously such requests were admitted and panicked
        // the shared step, killing every co-scheduled lane
        let es = engines();
        for e in &es {
            let good = vec![1i32, 4, 6];
            let solo = e.generate(&good, 5, crate::data::tokenizer::EOS);
            let mut srv = Server::new(
                e,
                ServerCfg { max_batch: 4, max_queue: 8, ..ServerCfg::default() },
            );
            let id0 = srv.submit(Request::generate(good.clone(), 5));
            // vocab is 32 in mini_model: 99 and -1 are both un-indexable
            let bad_hi = srv.submit(Request::classify(vec![2, 5, 8], vec![6, 99]));
            let bad_neg = srv.submit(Request::classify(vec![2, 5], vec![-1, 6]));
            // out-of-vocab *prompt* tokens would slice the embedding
            // table out of bounds mid-step — same rejection path
            let bad_prompt = srv.submit(Request::generate(vec![1, 5000], 4));
            let ok_cls = srv.submit(Request::classify(vec![7, 3, 2], vec![6, 17, 28]));
            let mut rs = srv.run_to_completion();
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), 5, "server must survive and answer everything");
            for (r, want_id) in [(&rs[1], bad_hi), (&rs[2], bad_neg), (&rs[3], bad_prompt)] {
                assert_eq!(r.id, want_id);
                assert_eq!(r.finish, FinishReason::Rejected);
            }
            assert_eq!(rs[0].id, id0);
            assert_eq!(rs[0].tokens, solo);
            assert_eq!(rs[4].id, ok_cls);
            assert_eq!(rs[4].finish, FinishReason::Classified);
            assert_eq!(srv.stats.rejected, 3);
        }
    }

    #[test]
    fn deadline_never_drops_a_computed_answer_or_token() {
        // satellite-5 semantics, pinned at the lane_outcome level: the
        // logits consumed this step were already paid for, so they are
        // delivered even when the deadline has passed (the old code
        // finished DeadlineExceeded *before* consuming, dropping them).
        let now = Instant::now();
        let mk = |req: Request, fed: usize| Active {
            id: 0,
            fed,
            next_token: 0,
            generated: Vec::new(),
            class: None,
            rng: None,
            slot: 0,
            submitted: now,
            admitted: now,
            prefill_done: Some(now),
            req,
        };

        // classification: answer delivered, not DeadlineExceeded
        let mut a = mk(Request::classify(vec![1, 2], vec![0, 1]), 2);
        let fin = lane_outcome(&mut a, &[0.1, 0.9], 2, 16, true);
        assert_eq!(fin, Some(FinishReason::Classified));
        assert_eq!(a.class, Some(1));

        // generation: the sampled token is pushed, THEN the deadline
        // retires the lane
        let mut req = Request::generate(vec![1], 5);
        req.eos = 99; // argmax below can never hit EOS
        let logits = vec![0.0, 0.0, 1.0, 0.0];
        let mut g = mk(req.clone(), 1);
        let fin = lane_outcome(&mut g, &logits, 1, 16, true);
        assert_eq!(fin, Some(FinishReason::DeadlineExceeded));
        assert_eq!(g.generated, vec![2], "computed token must be emitted");

        // same lane without the deadline continues
        let mut g2 = mk(req, 1);
        let fin = lane_outcome(&mut g2, &logits, 1, 16, false);
        assert_eq!(fin, None);
        assert_eq!(g2.generated, vec![2]);
        assert_eq!(g2.next_token, 2);

        // precedence: EOS beats the deadline (the answer is complete)
        let mut req_eos = Request::generate(vec![1], 5);
        req_eos.eos = 2;
        let mut ge = mk(req_eos, 1);
        let fin = lane_outcome(&mut ge, &logits, 1, 16, true);
        assert_eq!(fin, Some(FinishReason::Eos));
    }

    #[test]
    fn cancel_frees_the_slot_and_balances_the_invariant() {
        let es = engines();
        let e = &es[1];
        let mut srv = Server::new(
            e,
            ServerCfg { max_batch: 1, max_queue: 8, ..ServerCfg::default() },
        );
        // eos = -1 is unreachable: only cancel or budget can end lane 0
        let mut long = Request::generate(vec![1, 2, 3], 10_000);
        long.eos = -1;
        let id0 = srv.submit(long);
        let id1 = srv.submit(Request::generate(vec![4, 5], 3));
        let id2 = srv.submit(Request::generate(vec![6, 7, 8], 3));
        // admit lane 0 (max_batch 1 keeps id1/id2 queued) and decode a bit
        for _ in 0..6 {
            srv.step();
        }
        assert_eq!(srv.n_active(), 1);
        assert_eq!(srv.queue_depth(), 2);

        // cancel a *queued* request: it leaves before touching a lane
        assert!(srv.cancel(id1));
        assert_eq!(srv.queue_depth(), 1);

        // cancel the *active* request: the lane retires and its KV slot
        // frees immediately — the very next admit reuses it
        assert!(srv.cancel(id0));
        assert_eq!(srv.n_active(), 0);

        // unknown / already-finished ids are no-ops, not errors
        assert!(!srv.cancel(999));
        assert!(!srv.cancel(id0));

        let mut rs = srv.run_to_completion();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].id, id0);
        assert_eq!(rs[0].finish, FinishReason::Canceled);
        assert!(!rs[0].tokens.is_empty(), "generated-so-far tokens ride along");
        assert_eq!(rs[1].id, id1);
        assert_eq!(rs[1].finish, FinishReason::Canceled);
        assert!(rs[1].tokens.is_empty(), "a queued cancel never computed anything");
        assert_eq!(rs[2].id, id2);
        assert!(matches!(rs[2].finish, FinishReason::Eos | FinishReason::MaxTokens));

        assert_eq!(srv.stats.canceled, 2);
        assert_eq!(srv.stats.completed, 1);
        assert_eq!(srv.stats.canceled_total_ms.count(), 2);
        assert_eq!(srv.stats.total_ms.count(), 1);
        // the conservation invariant, canceled included
        assert_eq!(srv.stats.accounted(), srv.stats.submitted);
    }

    #[test]
    fn streamed_tokens_match_the_final_responses() {
        // take_streamed is the network front-end's token feed: drained
        // per step, the concatenation per request must equal the tokens
        // of its final response, in order — across prefill chunking.
        let es = engines();
        let e = &es[1];
        for chunk in [1usize, 4] {
            let prompts: Vec<Vec<i32>> =
                vec![vec![1, 4, 6, 9, 3], vec![3, 9, 1, 7], vec![5, 2]];
            let mut srv = Server::new(
                e,
                ServerCfg {
                    max_batch: 2,
                    max_queue: 8,
                    prefill_chunk: chunk,
                    ..ServerCfg::default()
                },
            );
            for p in &prompts {
                srv.submit(Request::generate(p.clone(), 5));
            }
            srv.submit(Request::classify(vec![7, 3, 2], vec![6, 17, 28]));
            let mut streamed: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
            let mut rs = Vec::new();
            while srv.has_work() {
                srv.step();
                for (id, t) in srv.take_streamed() {
                    streamed.entry(id).or_default().push(t);
                }
                rs.extend(srv.take_completed());
            }
            rs.sort_by_key(|r| r.id);
            for r in &rs {
                let got = streamed.get(&r.id).cloned().unwrap_or_default();
                assert_eq!(got, r.tokens, "request {} (chunk={chunk})", r.id);
            }
        }
    }

    #[test]
    fn alternate_kernel_server_outputs_are_identical_to_byte_decode() {
        // ServerCfg::kernel is — like threads — a throughput knob only:
        // all three kernel generations (byte-decode, LUT, SIMD) are
        // bitwise identical, so the same workload yields the same
        // responses under any of them, at any thread count.
        for e in engines() {
            let prompts: Vec<Vec<i32>> = vec![
                vec![1, 4, 6],
                vec![3, 9, 1, 7, 4],
                vec![5],
                vec![10, 11, 12, 13],
            ];
            let run = |kernel: KernelKind, threads: usize| {
                let mut srv = Server::new(
                    &e,
                    ServerCfg {
                        max_batch: 3,
                        max_queue: 16,
                        threads,
                        kernel,
                        ..ServerCfg::default()
                    },
                );
                for p in &prompts {
                    srv.submit(Request::generate(p.clone(), 6));
                }
                srv.submit(Request::classify(vec![7, 3, 2], vec![6, 17, 28]));
                let mut rs = srv.run_to_completion();
                rs.sort_by_key(|r| r.id);
                rs.iter().map(|r| (r.tokens.clone(), r.class)).collect::<Vec<_>>()
            };
            let byte = run(KernelKind::ByteDecode, 1);
            for kernel in [KernelKind::Lut, KernelKind::Simd] {
                for threads in [1usize, 4] {
                    assert_eq!(
                        run(kernel, threads),
                        byte,
                        "kernel={} threads={threads}",
                        kernel.name()
                    );
                }
            }
        }
    }
}
