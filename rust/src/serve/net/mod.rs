//! Overload-hardened TCP front-end for the continuous-batching server.
//!
//! Dependency-free (`std::net` only): newline-delimited JSON frames in,
//! streamed token / terminal / timing frames out (wire format in
//! [`frame`]). The design goal is a server that is *provably hard to
//! kill* — every resource a client can touch is bounded, every blocking
//! call has a timeout, and every failure mode degrades to one typed
//! reject frame instead of an unbounded buffer, a wedged thread, or a
//! dead process:
//!
//! - **Bounded admission with backpressure.** The scheduler's own
//!   `max_queue` is the only queue: a submission past it completes
//!   immediately as `Rejected` and flows back to the socket as a
//!   `reject` frame in the same bridge iteration. Nothing between
//!   socket and scheduler buffers without bound ([`conn::LineBuf`] caps
//!   inbound framing at [`frame::WireCaps::max_frame_bytes`]).
//! - **Deadline-aware shedding.** Deadlines ride the request frame;
//!   expiry in queue never touches a lane (scheduler semantics), and
//!   the expiry is delivered as a `done` frame with
//!   `finish:"deadline_exceeded"` so the client sees the shed.
//! - **Per-connection timeouts.** Readers tick on `set_read_timeout`
//!   (so the shutdown flag and the idle limit are always observable),
//!   writers on `set_write_timeout` (a client that stops draining is
//!   declared dead, not waited on). The
//!   `no-blocking-io-without-timeout` lint pins this file-by-file.
//! - **Cancellation on disconnect.** A connection that dies — error,
//!   idle timeout, injected fault, or panic — has its in-flight
//!   requests withdrawn via [`Server::cancel`], freeing their KV slots
//!   mid-flight ([`FinishReason::Canceled`]).
//! - **Panic containment.** Connection threads run under
//!   `catch_unwind`; a poisoned connection retires its own requests and
//!   dies alone. The scheduler itself never runs on a connection
//!   thread.
//!
//! The engine bridge runs on the thread that calls [`NetServer::run`]
//! (the `Server` holds `Rc`-based recorders and a borrow of the engine,
//! so it is deliberately not `Send`); the accept loop and per-connection
//! reader/writer pairs are scoped threads funneling [`conn::NetMsg`]s
//! into it over an mpsc channel.
//!
//! Fault injection ([`fault::FaultPlan`]) hooks four sites — slow
//! reads, corrupted frames, post-write disconnects, accept stalls — as
//! pure functions of a seed, for reproducible chaos tests. Disabled
//! (the default) it is one `Option` check per site.

pub mod conn;
pub mod fault;
pub mod frame;

use std::collections::BTreeMap;
use std::io::{self, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::obs::TraceRecorder;
use crate::substrate::Json;

use super::request::{FinishReason, Response};
use super::scheduler::{Server, ServerCfg};
use super::stats::ServeStats;

pub use conn::{LineBuf, LineEvent, NetMsg, OutMsg};
pub use fault::{FaultCfg, FaultPlan};
pub use frame::{
    parse_frame, terminal_frame, timing_frame, token_frame, wire_reject_frame, ClientFrame,
    WireCaps,
};

/// Network front-end limits and timeouts.
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` for an OS-assigned
    /// port, readable back via [`NetServer::local_addr`]).
    pub addr: String,
    /// Wire-size caps enforced at framing/parse time ([`WireCaps`]).
    pub caps: WireCaps,
    /// Per-`read` syscall timeout — the reader's wake-up tick, i.e. the
    /// latency bound on observing shutdown and idle expiry.
    pub read_timeout: Duration,
    /// Per-write timeout: a client that stops draining its socket is
    /// declared dead after this long, not waited on.
    pub write_timeout: Duration,
    /// A connection with no inbound bytes for this long is rejected
    /// (`idle_timeout`) and dropped.
    pub idle_timeout: Duration,
    /// Max concurrently open connections; accepts beyond get an
    /// immediate `server_busy` reject frame and are dropped.
    pub max_conns: usize,
}

impl Default for NetCfg {
    fn default() -> NetCfg {
        NetCfg {
            addr: "127.0.0.1:0".to_string(),
            caps: WireCaps::default(),
            read_timeout: Duration::from_millis(25),
            write_timeout: Duration::from_millis(1000),
            idle_timeout: Duration::from_secs(10),
            max_conns: 64,
        }
    }
}

/// What one [`NetServer::run`] lifetime amounted to.
#[derive(Debug)]
pub struct NetReport {
    /// Final scheduler stats; the conservation invariant
    /// `submitted == completed + rejected + expired + canceled` holds
    /// here because `run` returns only after a full drain.
    pub stats: ServeStats,
    /// `kind:"metrics"` snapshot rows ([`ServerCfg::metrics_every`]).
    pub snapshots: Vec<Json>,
    /// Frames that bounced at the wire (parse/cap/idle failures) —
    /// these never reached the scheduler, so they are *not* in
    /// `stats.rejected`.
    pub wire_rejects: u64,
    pub conns_accepted: u64,
    /// Connections rejected at accept because `max_conns` were open.
    pub conns_busy_rejected: u64,
    /// Serving wall-clock, seconds.
    pub wall_s: f64,
}

/// Track id for a connection's trace span: a high band that can never
/// collide with request tracks (`request_tid` = `1 + id`).
fn conn_tid(conn: u64) -> u64 {
    (1u64 << 32) + conn
}

/// Per-connection bridge-side state.
struct ConnState {
    tx: Sender<OutMsg>,
    /// Request ids submitted by this connection and not yet answered.
    outstanding: Vec<u64>,
    /// Client sent EOF (or the server is draining): close as soon as
    /// `outstanding` empties.
    half_closed: bool,
    opened: Instant,
}

/// The bridge's routing state, split from the `Server` so borrows stay
/// simple: every method takes the scheduler explicitly.
struct BridgeState {
    conns: BTreeMap<u64, ConnState>,
    /// request id -> conn id.
    route: BTreeMap<u64, u64>,
    wire_rejects: u64,
    shutting: bool,
}

impl BridgeState {
    fn new() -> BridgeState {
        BridgeState {
            conns: BTreeMap::new(),
            route: BTreeMap::new(),
            wire_rejects: 0,
            shutting: false,
        }
    }

    fn handle(
        &mut self,
        msg: NetMsg,
        srv: &mut Server<'_>,
        shutdown: &AtomicBool,
        trace: &TraceRecorder,
    ) {
        match msg {
            NetMsg::Open { conn, tx } => {
                if trace.is_enabled() {
                    trace.name_track(conn_tid(conn), &format!("conn-{conn}"));
                }
                self.conns.insert(
                    conn,
                    ConnState {
                        tx,
                        outstanding: Vec::new(),
                        half_closed: false,
                        opened: Instant::now(),
                    },
                );
            }
            NetMsg::Submit { conn, req } => {
                if self.shutting {
                    if let Some(cs) = self.conns.get(&conn) {
                        let _ = cs
                            .tx
                            .send(OutMsg::Frame(frame::wire_reject_frame("shutting_down")));
                    }
                    self.wire_rejects += 1;
                } else if let Some(cs) = self.conns.get_mut(&conn) {
                    // admission control happens in submit(): past
                    // max_queue this completes instantly as Rejected and
                    // the sweep below turns it into a reject frame — the
                    // backpressure path, one bridge iteration long
                    let id = srv.submit(req);
                    cs.outstanding.push(id);
                    self.route.insert(id, conn);
                }
                // a Submit for an already-Gone conn is dropped: its
                // client can't receive an answer anyway
            }
            NetMsg::HalfClosed { conn } => {
                let done = if let Some(cs) = self.conns.get_mut(&conn) {
                    cs.half_closed = true;
                    cs.outstanding.is_empty()
                } else {
                    false
                };
                if done {
                    self.close_conn(conn, trace);
                }
            }
            NetMsg::Gone { conn } => {
                // the cancel-on-disconnect path: whatever this client
                // still had in flight frees its lane now
                if let Some(cs) = self.conns.remove(&conn) {
                    for id in &cs.outstanding {
                        self.route.remove(id);
                        srv.cancel(*id);
                    }
                    trace.complete(conn_tid(conn), "connection", cs.opened, Instant::now(), &[]);
                }
            }
            NetMsg::WireReject { conn: _ } => self.wire_rejects += 1,
            NetMsg::Shutdown => {
                self.shutting = true;
                shutdown.store(true, Ordering::Relaxed);
            }
        }
    }

    /// Drain the scheduler's outputs onto sockets: streamed tokens
    /// first (their buffer is cleared by `take_completed`), then
    /// terminal + timing frames, then close any half-closed connection
    /// that just emptied.
    fn sweep(&mut self, srv: &mut Server<'_>, trace: &TraceRecorder) {
        for (id, tok) in srv.take_streamed() {
            if let Some(cs) = self.route.get(&id).and_then(|c| self.conns.get(c)) {
                let _ = cs.tx.send(OutMsg::Frame(frame::token_frame(id, tok)));
            }
        }
        let mut to_close: Vec<u64> = Vec::new();
        for r in srv.take_completed() {
            let Some(c) = self.route.remove(&r.id) else {
                continue; // canceled after its conn vanished
            };
            let Some(cs) = self.conns.get_mut(&c) else { continue };
            cs.outstanding.retain(|&x| x != r.id);
            deliver(&r, &cs.tx);
            if cs.half_closed && cs.outstanding.is_empty() {
                to_close.push(c);
            }
        }
        for c in to_close {
            self.close_conn(c, trace);
        }
    }

    fn close_conn(&mut self, conn: u64, trace: &TraceRecorder) {
        if let Some(cs) = self.conns.remove(&conn) {
            let _ = cs.tx.send(OutMsg::Close);
            trace.complete(conn_tid(conn), "connection", cs.opened, Instant::now(), &[]);
        }
    }
}

/// One response -> its frames. The terminal frame is byte-deterministic;
/// timing follows separately (and not for rejects/cancels, where no work
/// happened or no one is listening).
fn deliver(r: &Response, tx: &Sender<OutMsg>) {
    let _ = tx.send(OutMsg::Frame(frame::terminal_frame(r)));
    if !matches!(r.finish, FinishReason::Rejected | FinishReason::Canceled) {
        let _ = tx.send(OutMsg::Frame(frame::timing_frame(r)));
    }
}

/// A bound listener ready to serve. Construction and serving are split
/// so callers can read [`NetServer::local_addr`] (port 0 binds) and
/// print a "listening" line before entering the blocking [`NetServer::run`].
pub struct NetServer {
    listener: TcpListener,
    cfg: NetCfg,
    trace: TraceRecorder,
}

impl NetServer {
    pub fn bind(cfg: NetCfg) -> io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        Ok(NetServer { listener, cfg, trace: TraceRecorder::disabled() })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Attach a span recorder: per-connection spans land on high-band
    /// tracks, request/step spans on the scheduler's own ones.
    pub fn set_trace(&mut self, trace: TraceRecorder) {
        self.trace = trace;
    }

    /// Serve until a client sends `{"op":"shutdown"}`, then drain every
    /// in-flight request and return. The scheduler runs on *this*
    /// thread; accept and per-connection threads are scoped inside, so
    /// on return every thread has been joined — no detached state.
    pub fn run(self, engine: &Engine, scfg: ServerCfg, plan: FaultPlan) -> NetReport {
        let NetServer { listener, cfg, trace } = self;
        let started = Instant::now();
        let (tx, rx) = mpsc::channel::<NetMsg>();
        let shutdown = AtomicBool::new(false);
        let open_conns = AtomicUsize::new(0);
        let accepted = AtomicU64::new(0);
        let busy_rejected = AtomicU64::new(0);

        let (stats, snapshots, wire_rejects) = std::thread::scope(|s| {
            {
                let accept_tx = tx.clone();
                let accept_plan = plan.clone();
                let caps = cfg.caps;
                let (rt, wt, it) = (cfg.read_timeout, cfg.write_timeout, cfg.idle_timeout);
                let max_conns = cfg.max_conns;
                let (shutdown, open_conns) = (&shutdown, &open_conns);
                let (accepted, busy_rejected) = (&accepted, &busy_rejected);
                let listener = &listener;
                s.spawn(move || {
                    accept_loop(AcceptCtx {
                        scope: s,
                        listener,
                        caps,
                        read_timeout: rt,
                        write_timeout: wt,
                        idle_timeout: it,
                        max_conns,
                        plan: accept_plan,
                        to_bridge: accept_tx,
                        shutdown,
                        open_conns,
                        accepted,
                        busy_rejected,
                    });
                });
            }
            drop(tx); // the bridge must see disconnect once every conn thread exits

            // ---- the bridge: scheduler + routing, on this thread ----
            let mut srv = Server::new(engine, scfg);
            srv.set_trace(trace.clone());
            let mut st = BridgeState::new();
            loop {
                loop {
                    match rx.try_recv() {
                        Ok(msg) => st.handle(msg, &mut srv, &shutdown, &trace),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => break,
                    }
                }
                if srv.has_work() {
                    srv.step();
                }
                st.sweep(&mut srv, &trace);
                if st.shutting && st.conns.is_empty() && !srv.has_work() {
                    break;
                }
                if !srv.has_work() {
                    // idle: block briefly for the next message instead
                    // of spinning; the timeout keeps the exit condition
                    // above checked even if a sender dies silently
                    if let Ok(msg) = rx.recv_timeout(Duration::from_millis(5)) {
                        st.handle(msg, &mut srv, &shutdown, &trace);
                    }
                }
            }
            shutdown.store(true, Ordering::Relaxed);
            (std::mem::take(&mut srv.stats), srv.take_snapshots(), st.wire_rejects)
        });

        NetReport {
            stats,
            snapshots,
            wire_rejects,
            conns_accepted: accepted.load(Ordering::Relaxed),
            conns_busy_rejected: busy_rejected.load(Ordering::Relaxed),
            wall_s: started.elapsed().as_secs_f64(),
        }
    }
}

/// Everything the accept loop needs; bundled because it crosses a
/// thread boundary into the scope.
struct AcceptCtx<'scope, 'env> {
    scope: &'scope std::thread::Scope<'scope, 'env>,
    listener: &'scope TcpListener,
    caps: WireCaps,
    read_timeout: Duration,
    write_timeout: Duration,
    idle_timeout: Duration,
    max_conns: usize,
    plan: FaultPlan,
    to_bridge: Sender<NetMsg>,
    shutdown: &'scope AtomicBool,
    open_conns: &'scope AtomicUsize,
    accepted: &'scope AtomicU64,
    busy_rejected: &'scope AtomicU64,
}

/// Accept until shutdown. Nonblocking accept + short sleep rather than
/// a blocking accept: the shutdown flag must be observable without a
/// final wake-up connection.
fn accept_loop(ctx: AcceptCtx<'_, '_>) {
    if ctx.listener.set_nonblocking(true).is_err() {
        return;
    }
    let mut next_conn = 0u64;
    let mut accept_idx = 0u64;
    loop {
        if ctx.shutdown.load(Ordering::Relaxed) {
            return;
        }
        if let Some(d) = ctx.plan.accept_stall(accept_idx) {
            std::thread::sleep(d);
        }
        match ctx.listener.accept() {
            Ok((stream, _peer)) => {
                accept_idx += 1;
                ctx.accepted.fetch_add(1, Ordering::Relaxed);
                if ctx.open_conns.load(Ordering::Relaxed) >= ctx.max_conns {
                    // admission backpressure at the socket layer: a
                    // typed reject, then drop — never a buffered backlog
                    ctx.busy_rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.set_write_timeout(Some(ctx.write_timeout));
                    let mut w = &stream;
                    let _ = w.write_all(frame::wire_reject_frame("server_busy").as_bytes());
                    let _ = w.write_all(b"\n");
                    continue;
                }
                let Ok(w_stream) = stream.try_clone() else { continue };
                let conn = next_conn;
                next_conn += 1;
                ctx.open_conns.fetch_add(1, Ordering::Relaxed);
                let (out_tx, out_rx) = mpsc::channel::<OutMsg>();
                if ctx.to_bridge.send(NetMsg::Open { conn, tx: out_tx.clone() }).is_err() {
                    ctx.open_conns.fetch_sub(1, Ordering::Relaxed);
                    return; // bridge gone: nothing left to serve
                }
                spawn_conn_threads(&ctx, conn, stream, w_stream, out_tx, out_rx);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Reader + writer for one accepted connection, both panic-contained:
/// a poisoned thread reports `Gone` (retiring the connection's
/// requests) and dies alone instead of wedging the process.
fn spawn_conn_threads<'scope>(
    ctx: &AcceptCtx<'scope, '_>,
    conn: u64,
    r_stream: TcpStream,
    w_stream: TcpStream,
    out_tx: Sender<OutMsg>,
    out_rx: Receiver<OutMsg>,
) {
    let w_plan = ctx.plan.clone();
    let w_bridge = ctx.to_bridge.clone();
    let wt = ctx.write_timeout;
    ctx.scope.spawn(move || {
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            conn::run_writer(&w_stream, conn, wt, &w_plan, &out_rx, &w_bridge);
        }))
        .is_err();
        if panicked {
            let _ = w_bridge.send(NetMsg::Gone { conn });
        }
    });
    let rctx = conn::ReaderCtx {
        conn,
        caps: ctx.caps,
        read_timeout: ctx.read_timeout,
        idle_timeout: ctx.idle_timeout,
        plan: ctx.plan.clone(),
        to_bridge: ctx.to_bridge.clone(),
        to_writer: out_tx,
        shutdown: ctx.shutdown,
    };
    let open_conns = ctx.open_conns;
    ctx.scope.spawn(move || {
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            conn::run_reader(&r_stream, &rctx);
        }))
        .is_err();
        if panicked {
            let _ = rctx.to_bridge.send(NetMsg::Gone { conn });
        }
        // the reader is the connection's lifetime proxy for max_conns
        open_conns.fetch_sub(1, Ordering::Relaxed);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::model::mini_model;
    use std::io::{BufRead, BufReader, Write as _};

    fn engine() -> Engine {
        let (spec, store) = mini_model(true, true);
        Engine::from_params(&spec, &store, true).unwrap()
    }

    fn send_line(stream: &mut TcpStream, line: &str) {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
    }

    #[test]
    fn tcp_round_trip_generate_classify_and_clean_shutdown() {
        let e = engine();
        let net = NetServer::bind(NetCfg::default()).unwrap();
        let addr = net.local_addr().unwrap();
        let (report, client_lines) = std::thread::scope(|s| {
            let h = s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                send_line(&mut stream, r#"{"op":"generate","prompt":[1,4,6],"max_new":4}"#);
                send_line(&mut stream, r#"{"op":"classify","prompt":[7,3,2],"labels":[6,17,28]}"#);
                send_line(&mut stream, r#"{"op":"shutdown"}"#);
                let mut lines = Vec::new();
                for l in BufReader::new(stream).lines() {
                    let Ok(l) = l else { break };
                    lines.push(l);
                }
                lines
            });
            let report = net.run(&e, ServerCfg::default(), FaultPlan::off());
            (report, h.join().unwrap())
        });

        assert_eq!(report.stats.submitted, 2);
        assert_eq!(report.stats.completed, 2);
        assert_eq!(report.stats.accounted(), report.stats.submitted);
        assert_eq!(report.conns_accepted, 1);
        assert_eq!(report.wire_rejects, 0);

        let frames: Vec<Json> =
            client_lines.iter().map(|l| Json::parse(l).unwrap()).collect();
        let kind = |j: &Json| j.get("frame").and_then(Json::as_str).unwrap().to_string();
        // one done per request, each followed by its timing frame, and
        // token frames precede request 0's done frame
        let dones: Vec<&Json> = frames.iter().filter(|j| kind(j) == "done").collect();
        assert_eq!(dones.len(), 2);
        assert_eq!(frames.iter().filter(|j| kind(j) == "timing").count(), 2);
        let tokens: Vec<i32> = frames
            .iter()
            .filter(|j| kind(j) == "token")
            .map(|j| j.get("token").and_then(Json::as_i64).unwrap() as i32)
            .collect();
        // the in-process scheduler is the oracle: same engine, same
        // request, byte-deterministic
        let want = e.generate(&[1, 4, 6], 4, crate::data::tokenizer::EOS);
        let done0 = dones
            .iter()
            .find(|j| j.get("id").and_then(Json::as_usize) == Some(0))
            .unwrap();
        let got: Vec<i32> = done0
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|t| t.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(got, want);
        assert_eq!(tokens, want, "streamed tokens match the done frame");
        let done1 = dones
            .iter()
            .find(|j| j.get("id").and_then(Json::as_usize) == Some(1))
            .unwrap();
        assert_eq!(done1.get("finish").and_then(Json::as_str), Some("classified"));
        assert!(done1.get("class").and_then(Json::as_usize).is_some());
    }

    #[test]
    fn malformed_and_oversized_frames_get_typed_rejects_not_a_dead_server() {
        let e = engine();
        let cfg = NetCfg {
            caps: WireCaps { max_frame_bytes: 256, ..WireCaps::default() },
            ..NetCfg::default()
        };
        let net = NetServer::bind(cfg).unwrap();
        let addr = net.local_addr().unwrap();
        let (report, lines) = std::thread::scope(|s| {
            let h = s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                send_line(&mut stream, "this is not json");
                // an "attack" frame far past the cap: bounded buffering
                // means it costs the server 256 bytes, not 64k
                let big = format!(r#"{{"prompt":[{}]}}"#, "1,".repeat(30_000) + "1");
                send_line(&mut stream, &big);
                send_line(&mut stream, r#"{"prompt":[1],"sampling":{"kind":"temperature","temp":0.8}}"#);
                // the server must still serve real work afterwards
                send_line(&mut stream, r#"{"op":"generate","prompt":[1,4,6],"max_new":2}"#);
                send_line(&mut stream, r#"{"op":"shutdown"}"#);
                let mut lines = Vec::new();
                for l in BufReader::new(stream).lines() {
                    let Ok(l) = l else { break };
                    lines.push(l);
                }
                lines
            });
            let report = net.run(&e, ServerCfg::default(), FaultPlan::off());
            (report, h.join().unwrap())
        });

        let frames: Vec<Json> = lines.iter().map(|l| Json::parse(l).unwrap()).collect();
        let rejects: Vec<String> = frames
            .iter()
            .filter(|j| j.get("frame").and_then(Json::as_str) == Some("reject"))
            .map(|j| j.get("reason").and_then(Json::as_str).unwrap().to_string())
            .collect();
        assert_eq!(rejects.len(), 3, "{rejects:?}");
        assert!(rejects.iter().any(|r| r.starts_with("bad_json")), "{rejects:?}");
        assert!(rejects.iter().any(|r| r.starts_with("oversized_frame")), "{rejects:?}");
        assert!(rejects.iter().any(|r| r.starts_with("bad_request")), "{rejects:?}");
        assert_eq!(report.wire_rejects, 3);
        // the wire rejects never touched the scheduler
        assert_eq!(report.stats.submitted, 1);
        assert_eq!(report.stats.completed, 1);
        assert!(frames.iter().any(|j| j.get("frame").and_then(Json::as_str) == Some("done")));
    }

    #[test]
    fn client_disconnect_cancels_outstanding_requests() {
        // the mini model is so fast that a single request would race
        // the disconnect; a burst of 50 guarantees plenty are still
        // queued/active when the client vanishes — those must all end
        // Canceled (never delivered to nobody, never leaked)
        let n = 50usize;
        let e = engine();
        let net = NetServer::bind(NetCfg::default()).unwrap();
        let addr = net.local_addr().unwrap();
        let report = std::thread::scope(|s| {
            s.spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                for _ in 0..n {
                    // eos:-1: each request runs to its cache/budget cap
                    send_line(
                        &mut stream,
                        r#"{"op":"generate","prompt":[1,2,3],"max_new":100000,"eos":-1}"#,
                    );
                }
                // vanish without reading: the unread token frames make
                // the close an abortive disconnect as seen by the server
                drop(stream);
                // a second client shuts the server down cleanly
                let mut c2 = TcpStream::connect(addr).unwrap();
                send_line(&mut c2, r#"{"op":"shutdown"}"#);
            });
            net.run(&e, ServerCfg::default(), FaultPlan::off())
        });
        assert_eq!(report.stats.submitted, n);
        assert!(report.stats.canceled >= 1, "disconnect must cancel in-flight work");
        // conservation: completed-before-disconnect + canceled = all
        assert_eq!(report.stats.accounted(), report.stats.submitted);
        assert_eq!(
            report.stats.completed + report.stats.canceled,
            n,
            "no rejects or expiries in this workload"
        );
    }
}
