//! Per-connection machinery: bounded line framing and the reader /
//! writer loops.
//!
//! Each accepted socket gets two threads. The **reader** turns bytes
//! into frames under a hard byte cap ([`LineBuf`] never buffers past
//! `max_frame_bytes` — an oversized frame is discarded as it streams
//! in, not accumulated), parses them, and forwards admissible requests
//! to the bridge over an mpsc channel. The **writer** drains the
//! connection's outbound queue onto the socket. Both set socket
//! timeouts up front: every blocking call below wakes on its own, so a
//! stalled peer can never wedge a thread past its timeout tick (the
//! `no-blocking-io-without-timeout` lint pins this property).
//!
//! Neither loop touches the scheduler. All scheduler effects flow
//! through [`NetMsg`] to the bridge thread, which owns the `Server` —
//! so a connection thread that dies (error, injected fault, or
//! contained panic) can at worst lose its own socket; the bridge then
//! cancels that connection's in-flight requests and the lanes free up.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

use super::fault::FaultPlan;
use super::frame::{self, ClientFrame, WireCaps};
use crate::serve::request::Request;

/// Connection-thread -> bridge messages. The bridge is the only owner
/// of the `Server`, so these are the *entire* scheduler surface a
/// connection can reach.
#[derive(Debug)]
pub enum NetMsg {
    /// A new connection: `tx` is the handle the bridge uses to queue
    /// outbound frames for it.
    Open { conn: u64, tx: Sender<OutMsg> },
    /// A parsed, cap-checked request ready for admission.
    Submit { conn: u64, req: Request },
    /// Clean EOF: the client is done sending; deliver what remains
    /// outstanding, then close.
    HalfClosed { conn: u64 },
    /// The connection is dead (IO error, idle timeout, injected
    /// disconnect, or a contained panic): cancel its outstanding
    /// requests and forget it.
    Gone { conn: u64 },
    /// A frame bounced at the wire (parse/cap failure) — accounting
    /// only; the reject frame itself was already written by the reader.
    WireReject { conn: u64 },
    /// A client sent `{"op":"shutdown"}`: stop accepting, drain, exit.
    Shutdown,
}

/// Bridge -> writer messages.
#[derive(Debug)]
pub enum OutMsg {
    /// One frame line (newline appended on the wire).
    Frame(String),
    /// Flush and close the socket; the writer thread exits.
    Close,
}

/// What [`LineBuf::feed`] yields.
#[derive(Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// One complete frame line (newline stripped).
    Line(Vec<u8>),
    /// A frame exceeded the byte cap. Emitted once, at the moment the
    /// cap is crossed; the rest of the line streams into the void.
    Oversized,
}

/// Newline framing under a hard byte cap. The buffer never grows past
/// `cap`: the moment an unterminated line crosses it, the buffered
/// prefix is dropped, [`LineEvent::Oversized`] is emitted, and bytes
/// are discarded until the next newline — so a client streaming a
/// gigabyte "line" costs this server `cap` bytes, once.
#[derive(Debug)]
pub struct LineBuf {
    buf: Vec<u8>,
    cap: usize,
    discarding: bool,
}

impl LineBuf {
    pub fn new(cap: usize) -> LineBuf {
        LineBuf { buf: Vec::new(), cap, discarding: false }
    }

    /// Feed a chunk of socket bytes, appending completed events.
    pub fn feed(&mut self, bytes: &[u8], out: &mut Vec<LineEvent>) {
        for &b in bytes {
            if b == b'\n' {
                if self.discarding {
                    self.discarding = false;
                } else {
                    out.push(LineEvent::Line(std::mem::take(&mut self.buf)));
                }
            } else if self.discarding {
                // oversized line still streaming past; drop on the floor
            } else if self.buf.len() >= self.cap {
                self.buf.clear();
                self.discarding = true;
                out.push(LineEvent::Oversized);
            } else {
                self.buf.push(b);
            }
        }
    }
}

/// Everything a reader loop needs besides its socket.
pub struct ReaderCtx<'a> {
    pub conn: u64,
    pub caps: WireCaps,
    /// Per-`read` syscall timeout — the loop's wake-up tick.
    pub read_timeout: Duration,
    /// Whole-connection quiet limit; exceeded -> typed reject + `Gone`.
    pub idle_timeout: Duration,
    pub plan: FaultPlan,
    pub to_bridge: Sender<NetMsg>,
    /// The reader writes wire rejects itself (via the writer thread) so
    /// a malformed frame is answered even while the bridge is busy.
    pub to_writer: Sender<OutMsg>,
    pub shutdown: &'a AtomicBool,
}

/// Read frames until EOF, error, idle timeout, or server shutdown.
/// Every exit path tells the bridge what happened; this function never
/// returns without having sent a terminal [`NetMsg`] for its conn.
pub fn run_reader(stream: &TcpStream, ctx: &ReaderCtx<'_>) {
    // the tick that makes every exit condition (shutdown flag, idle
    // limit) observable: reads wake at least this often
    if stream.set_read_timeout(Some(ctx.read_timeout)).is_err() {
        let _ = ctx.to_bridge.send(NetMsg::Gone { conn: ctx.conn });
        return;
    }
    let mut lines = LineBuf::new(ctx.caps.max_frame_bytes);
    let mut events = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut last_data = Instant::now();
    let mut read_idx = 0u64;
    let mut frame_idx = 0u64;
    let mut stream = stream;
    loop {
        if ctx.shutdown.load(Ordering::Relaxed) {
            // server-side drain: treat like client EOF so outstanding
            // results still go out before the bridge closes the conn
            let _ = ctx.to_bridge.send(NetMsg::HalfClosed { conn: ctx.conn });
            return;
        }
        if let Some(delay) = ctx.plan.read_delay(ctx.conn, read_idx) {
            std::thread::sleep(delay);
        }
        read_idx += 1;
        match stream.read(&mut chunk) {
            Ok(0) => {
                let _ = ctx.to_bridge.send(NetMsg::HalfClosed { conn: ctx.conn });
                return;
            }
            Ok(n) => {
                last_data = Instant::now();
                let Some(got) = chunk.get(..n) else {
                    let _ = ctx.to_bridge.send(NetMsg::Gone { conn: ctx.conn });
                    return;
                };
                lines.feed(got, &mut events);
                for ev in events.drain(..) {
                    if !handle_event(ev, &mut frame_idx, ctx) {
                        // bridge or writer hung up: the server is gone
                        // from this connection's point of view
                        return;
                    }
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if last_data.elapsed() > ctx.idle_timeout {
                    let _ = ctx
                        .to_writer
                        .send(OutMsg::Frame(frame::wire_reject_frame("idle_timeout")));
                    let _ = ctx.to_writer.send(OutMsg::Close);
                    let _ = ctx.to_bridge.send(NetMsg::Gone { conn: ctx.conn });
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => {
                let _ = ctx.to_bridge.send(NetMsg::Gone { conn: ctx.conn });
                return;
            }
        }
    }
}

/// Process one framing event; `false` means a channel peer hung up and
/// the reader should exit (its terminal message has already been sent
/// implicitly by the disconnect).
fn handle_event(ev: LineEvent, frame_idx: &mut u64, ctx: &ReaderCtx<'_>) -> bool {
    let mut raw = match ev {
        LineEvent::Oversized => {
            let reject = frame::wire_reject_frame("oversized_frame");
            let ok = ctx.to_writer.send(OutMsg::Frame(reject)).is_ok()
                && ctx.to_bridge.send(NetMsg::WireReject { conn: ctx.conn }).is_ok();
            return ok;
        }
        LineEvent::Line(raw) => raw,
    };
    let idx = *frame_idx;
    *frame_idx += 1;
    ctx.plan.corrupt_frame(ctx.conn, idx, &mut raw);
    let parsed = match std::str::from_utf8(&raw) {
        Ok(text) if text.trim().is_empty() => return true, // blank line: ignore
        Ok(text) => frame::parse_frame(text.trim(), &ctx.caps),
        Err(_) => Err("bad_utf8".to_string()),
    };
    match parsed {
        Ok(ClientFrame::Request(req)) => {
            ctx.to_bridge.send(NetMsg::Submit { conn: ctx.conn, req }).is_ok()
        }
        Ok(ClientFrame::Shutdown) => ctx.to_bridge.send(NetMsg::Shutdown).is_ok(),
        Err(reason) => {
            ctx.to_writer.send(OutMsg::Frame(frame::wire_reject_frame(&reason))).is_ok()
                && ctx.to_bridge.send(NetMsg::WireReject { conn: ctx.conn }).is_ok()
        }
    }
}

/// Drain outbound frames onto the socket until `Close`, an IO error, or
/// an injected disconnect. A slow client hits the write timeout and is
/// treated as dead — backpressure never travels past this thread into
/// the bridge, whose send into this writer's unbounded-but-short queue
/// stays non-blocking (queue depth is bounded in practice by the
/// scheduler's own admission cap).
pub fn run_writer(
    stream: &TcpStream,
    conn: u64,
    write_timeout: Duration,
    plan: &FaultPlan,
    rx: &Receiver<OutMsg>,
    to_bridge: &Sender<NetMsg>,
) {
    if stream.set_write_timeout(Some(write_timeout)).is_err() {
        let _ = to_bridge.send(NetMsg::Gone { conn });
        return;
    }
    let mut stream = stream;
    let mut write_idx = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            OutMsg::Frame(line) => {
                let idx = write_idx;
                write_idx += 1;
                if stream.write_all(line.as_bytes()).is_err()
                    || stream.write_all(b"\n").is_err()
                {
                    let _ = to_bridge.send(NetMsg::Gone { conn });
                    return;
                }
                if plan.drop_after_write(conn, idx) {
                    // injected mid-stream disconnect: the client
                    // vanishes from the server's point of view
                    let _ = stream.shutdown(Shutdown::Both);
                    let _ = to_bridge.send(NetMsg::Gone { conn });
                    return;
                }
            }
            OutMsg::Close => {
                let _ = stream.flush();
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        }
    }
    // all senders dropped (bridge exited): nothing left to deliver
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(events: &[LineEvent]) -> Vec<Option<&[u8]>> {
        events
            .iter()
            .map(|e| match e {
                LineEvent::Line(l) => Some(l.as_slice()),
                LineEvent::Oversized => None,
            })
            .collect()
    }

    #[test]
    fn linebuf_reassembles_across_chunk_boundaries() {
        let mut lb = LineBuf::new(64);
        let mut out = Vec::new();
        lb.feed(b"{\"a\":1}\n{\"b\"", &mut out);
        lb.feed(b":2}\n", &mut out);
        lb.feed(b"tail-no-newline", &mut out);
        assert_eq!(
            lines(&out),
            vec![Some(b"{\"a\":1}".as_slice()), Some(b"{\"b\":2}".as_slice())]
        );
        // the tail stays buffered until its newline arrives
        lb.feed(b"\n", &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(lines(&out)[2], Some(b"tail-no-newline".as_slice()));
    }

    #[test]
    fn linebuf_caps_memory_and_resynchronizes() {
        let mut lb = LineBuf::new(8);
        let mut out = Vec::new();
        // a "gigabyte line", fed in small chunks: one Oversized event,
        // bounded buffering, and clean resync at the next newline
        for _ in 0..1000 {
            lb.feed(b"xxxxxxxxxx", &mut out);
            assert!(lb.buf.len() <= 8, "buffer grew past the cap");
        }
        assert_eq!(out, vec![LineEvent::Oversized]);
        lb.feed(b"\n{\"ok\":1}\n", &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(lines(&out)[1], Some(b"{\"ok\":1}".as_slice()));
    }

    #[test]
    fn linebuf_exact_cap_line_still_passes() {
        let mut lb = LineBuf::new(4);
        let mut out = Vec::new();
        lb.feed(b"abcd\nabcde\nok\n", &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(lines(&out)[0], Some(b"abcd".as_slice()));
        assert_eq!(out[1], LineEvent::Oversized);
        assert_eq!(lines(&out)[2], Some(b"ok".as_slice()));
    }

    #[test]
    fn linebuf_handles_empty_and_consecutive_newlines() {
        let mut lb = LineBuf::new(16);
        let mut out = Vec::new();
        lb.feed(b"\n\na\n", &mut out);
        assert_eq!(
            lines(&out),
            vec![Some(b"".as_slice()), Some(b"".as_slice()), Some(b"a".as_slice())]
        );
    }
}
