//! Newline-delimited JSON wire codec for the network front-end.
//!
//! One frame = one JSON object on one line. Client frames:
//!
//! ```text
//! {"op":"generate","prompt":[1,2,3],"max_new":8}
//! {"op":"generate","prompt":[..],"sampling":{"kind":"temperature","temp":0.8,"seed":7},
//!  "deadline_ms":250}
//! {"op":"classify","prompt":[..],"labels":[5,6,7]}
//! {"op":"shutdown"}
//! ```
//!
//! Server frames (also one JSON object per line):
//!
//! ```text
//! {"frame":"token","id":0,"token":42}            per generated token
//! {"frame":"done","id":0,"finish":"eos","tokens":[..],"class":null,"prompt_len":3}
//! {"frame":"reject","id":0,"reason":"rejected"}  scheduler-level reject
//! {"frame":"reject","reason":"bad_json: .."}     wire-level reject (no id)
//! {"frame":"canceled","id":0}
//! {"frame":"timing","id":0,"queue_ms":..,"prefill_ms":..,"decode_ms":..,"total_ms":..}
//! ```
//!
//! **Wire robustness is enforced at parse time.** The connection reader
//! caps a frame's byte length *while reading* ([`super::conn::LineBuf`]
//! never buffers past [`WireCaps::max_frame_bytes`]), so by the time a
//! line reaches [`parse_frame`] every allocation is already bounded by
//! the cap — an attacker-sized prompt costs the attacker bandwidth, not
//! the server memory. On top of that, [`parse_frame`] rejects prompts
//! longer than [`WireCaps::max_prompt_tokens`] and validates the
//! sampling policy with the **same** [`Sampling::is_valid`] the
//! scheduler's `submit` uses — a NaN/absent temperature or a missing
//! seed bounces at the frame boundary with a typed reject instead of
//! burning a queue slot.
//!
//! The `done` frame carries only deterministic payload (tokens, class,
//! finish, prompt_len) — timing rides in a separate `timing` frame — so
//! a TCP response is **byte-identical** to [`terminal_frame`] of the
//! in-process [`Response`] for the same request and seed (test-pinned
//! in `tests/net.rs`).

use std::time::Duration;

use crate::serve::request::{FinishReason, Request, Response, Sampling};
use crate::substrate::{json, Json};

/// Parse-time size limits (the read loop enforces `max_frame_bytes`
/// during buffering; see module docs).
#[derive(Clone, Copy, Debug)]
pub struct WireCaps {
    /// Max bytes in one frame line, newline excluded.
    pub max_frame_bytes: usize,
    /// Max prompt tokens accepted in one request frame.
    pub max_prompt_tokens: usize,
}

impl Default for WireCaps {
    fn default() -> WireCaps {
        WireCaps { max_frame_bytes: 64 * 1024, max_prompt_tokens: 4096 }
    }
}

/// A parsed client frame.
#[derive(Debug)]
pub enum ClientFrame {
    Request(Request),
    /// `{"op":"shutdown"}` — drain in-flight work, then exit the serve
    /// loop (the clean-shutdown path the CI smoke test drives).
    Shutdown,
}

fn int_array(j: &Json, field: &'static str, cap: usize) -> Result<Vec<i32>, String> {
    let arr = j
        .get(field)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("bad_request: {field} must be an array of token ids"))?;
    if arr.len() > cap {
        return Err(format!("{field}_too_long: {} > cap {cap}", arr.len()));
    }
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v
            .as_f64()
            .ok_or_else(|| format!("bad_request: {field} holds a non-number"))?;
        if n.fract() != 0.0 || !(i32::MIN as f64..=i32::MAX as f64).contains(&n) {
            return Err(format!("bad_request: {field} holds a non-token value"));
        }
        out.push(n as i32);
    }
    Ok(out)
}

fn parse_sampling(j: &Json) -> Result<Sampling, String> {
    let Some(s) = j.get("sampling") else {
        return Ok(Sampling::Greedy);
    };
    let kind = s.get("kind").and_then(Json::as_str).unwrap_or("");
    let sampling = match kind {
        "greedy" => Sampling::Greedy,
        "temperature" => Sampling::Temperature {
            // absent -> NaN -> is_valid() rejects below: the missing
            // field fails the same check a degenerate value does
            temp: s.get("temp").and_then(Json::as_f64).unwrap_or(f64::NAN) as f32,
            seed: s.get("seed").and_then(Json::as_f64).and_then(|v| {
                (v.fract() == 0.0 && v >= 0.0).then_some(v as u64)
            }),
        },
        other => return Err(format!("bad_request: unknown sampling kind {other:?}")),
    };
    // the same validity gate Server::submit applies — enforced here so
    // an invalid policy never costs a queue slot
    if !sampling.is_valid() {
        return Err("bad_request: invalid sampling (need finite temp > 0 and a seed)".to_string());
    }
    Ok(sampling)
}

/// Parse one frame line. Errors are typed reject reasons for the
/// `{"frame":"reject","reason":..}` wire frame; nothing about a failed
/// parse escapes to the scheduler.
pub fn parse_frame(line: &str, caps: &WireCaps) -> Result<ClientFrame, String> {
    // redundant with the reader's streaming cap; kept so the codec is
    // safe standalone (benches and tests call it directly)
    if line.len() > caps.max_frame_bytes {
        return Err(format!("oversized_frame: {} > cap {}", line.len(), caps.max_frame_bytes));
    }
    let j = Json::parse(line).map_err(|e| format!("bad_json: {e}"))?;
    let op = j.get("op").and_then(Json::as_str).unwrap_or("generate");
    match op {
        "shutdown" => Ok(ClientFrame::Shutdown),
        "generate" | "classify" => {
            let prompt = int_array(&j, "prompt", caps.max_prompt_tokens)?;
            let mut req = if op == "classify" {
                // labels index the logits row, so the prompt cap is a
                // safe bound for them too
                let labels = int_array(&j, "labels", caps.max_prompt_tokens)?;
                if labels.is_empty() {
                    return Err("bad_request: classify needs non-empty labels".to_string());
                }
                Request::classify(prompt, labels)
            } else {
                let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
                Request::generate(prompt, max_new)
            };
            if let Some(eos) = j.get("eos").and_then(Json::as_i64) {
                if !(i32::MIN as i64..=i32::MAX as i64).contains(&eos) {
                    return Err("bad_request: eos out of range".to_string());
                }
                req.eos = eos as i32;
            }
            req.sampling = parse_sampling(&j)?;
            if let Some(dl) = j.get("deadline_ms") {
                let ms = dl
                    .as_f64()
                    .filter(|v| v.is_finite() && *v >= 0.0)
                    .ok_or("bad_request: deadline_ms must be a non-negative number")?;
                req.deadline = Some(Duration::from_secs_f64(ms / 1e3));
            }
            Ok(ClientFrame::Request(req))
        }
        other => Err(format!("bad_request: unknown op {other:?}")),
    }
}

/// One streamed token.
pub fn token_frame(id: u64, token: i32) -> String {
    json::obj(vec![
        ("frame", json::s("token")),
        ("id", json::num(id as f64)),
        ("token", json::num(token as f64)),
    ])
    .to_string()
}

/// The terminal frame for a response: `done` for delivered results
/// (deadline expiries included — the shed is visible in `finish`),
/// `reject` for admission rejections, `canceled` for withdrawn
/// requests. Deterministic payload only — no timing, no wall-clock —
/// so TCP bytes can be pinned against in-process responses.
pub fn terminal_frame(r: &Response) -> String {
    match r.finish {
        FinishReason::Rejected => json::obj(vec![
            ("frame", json::s("reject")),
            ("id", json::num(r.id as f64)),
            ("reason", json::s("rejected")),
        ])
        .to_string(),
        FinishReason::Canceled => json::obj(vec![
            ("frame", json::s("canceled")),
            ("id", json::num(r.id as f64)),
        ])
        .to_string(),
        _ => {
            let class = match r.class {
                Some(c) => json::num(c as f64),
                None => Json::Null,
            };
            json::obj(vec![
                ("frame", json::s("done")),
                ("id", json::num(r.id as f64)),
                ("finish", json::s(r.finish.name())),
                ("tokens", Json::Arr(r.tokens.iter().map(|&t| json::num(t as f64)).collect())),
                ("class", class),
                ("prompt_len", json::num(r.prompt_len as f64)),
            ])
            .to_string()
        }
    }
}

/// The informational timing frame that follows a `done` frame
/// (separate so the terminal frame stays byte-deterministic).
pub fn timing_frame(r: &Response) -> String {
    json::obj(vec![
        ("frame", json::s("timing")),
        ("id", json::num(r.id as f64)),
        ("queue_ms", json::num_or_null(r.timing.queue_ms)),
        ("prefill_ms", json::num_or_null(r.timing.prefill_ms)),
        ("decode_ms", json::num_or_null(r.timing.decode_ms)),
        ("total_ms", json::num_or_null(r.timing.total_ms)),
    ])
    .to_string()
}

/// A wire-level reject (parse/cap failure): no request id exists yet.
pub fn wire_reject_frame(reason: &str) -> String {
    json::obj(vec![("frame", json::s("reject")), ("reason", json::s(reason))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Timing;

    fn caps() -> WireCaps {
        WireCaps::default()
    }

    #[test]
    fn parses_generate_classify_and_shutdown() {
        let f = parse_frame(r#"{"op":"generate","prompt":[1,2,3],"max_new":8}"#, &caps());
        let ClientFrame::Request(r) = f.unwrap() else { panic!("want request") };
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 8);
        assert!(!r.is_classification());
        assert!(matches!(r.sampling, Sampling::Greedy));
        assert_eq!(r.deadline, None);

        let f = parse_frame(r#"{"op":"classify","prompt":[4,5],"labels":[7,8,9]}"#, &caps());
        let ClientFrame::Request(r) = f.unwrap() else { panic!("want request") };
        assert_eq!(r.label_ids, vec![7, 8, 9]);
        assert!(r.is_classification());

        assert!(matches!(
            parse_frame(r#"{"op":"shutdown"}"#, &caps()).unwrap(),
            ClientFrame::Shutdown
        ));
    }

    #[test]
    fn parses_sampling_deadline_and_eos() {
        let line = r#"{"prompt":[1],"max_new":4,"eos":2,
            "sampling":{"kind":"temperature","temp":0.8,"seed":7},"deadline_ms":250}"#;
        // one frame per line on the wire; the codec itself tolerates
        // embedded whitespace, so collapse for the test
        let line = line.replace('\n', " ");
        let ClientFrame::Request(r) = parse_frame(&line, &caps()).unwrap() else {
            panic!("want request")
        };
        assert_eq!(r.eos, 2);
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        match r.sampling {
            Sampling::Temperature { temp, seed } => {
                assert!((temp - 0.8).abs() < 1e-6);
                assert_eq!(seed, Some(7));
            }
            s => panic!("want temperature sampling, got {s:?}"),
        }
    }

    #[test]
    fn invalid_sampling_bounces_at_the_frame_boundary() {
        // the satellite contract: the same Sampling::is_valid gate as
        // submit(), applied before a queue slot is ever considered
        for bad in [
            r#"{"prompt":[1],"sampling":{"kind":"temperature","seed":7}}"#, // temp absent
            r#"{"prompt":[1],"sampling":{"kind":"temperature","temp":0.8}}"#, // seed absent
            r#"{"prompt":[1],"sampling":{"kind":"temperature","temp":0,"seed":7}}"#,
            r#"{"prompt":[1],"sampling":{"kind":"temperature","temp":-1,"seed":7}}"#,
            r#"{"prompt":[1],"sampling":{"kind":"temperature","temp":1e999,"seed":7}}"#, // inf
            r#"{"prompt":[1],"sampling":{"kind":"nucleus","temp":1,"seed":7}}"#,
        ] {
            let err = parse_frame(bad, &caps()).unwrap_err();
            assert!(err.starts_with("bad_request"), "{bad} -> {err}");
        }
    }

    #[test]
    fn caps_reject_before_scheduler_involvement() {
        let caps = WireCaps { max_frame_bytes: 64 * 1024, max_prompt_tokens: 4 };
        let err = parse_frame(r#"{"prompt":[1,2,3,4,5]}"#, &caps).unwrap_err();
        assert!(err.starts_with("prompt_too_long"), "{err}");
        let tiny = WireCaps { max_frame_bytes: 8, max_prompt_tokens: 4 };
        let err = parse_frame(r#"{"prompt":[1]}"#, &tiny).unwrap_err();
        assert!(err.starts_with("oversized_frame"), "{err}");
    }

    #[test]
    fn malformed_frames_are_typed_rejects() {
        for (line, prefix) in [
            ("{", "bad_json"),
            ("not json at all", "bad_json"),
            (r#"{"op":"generate"}"#, "bad_request"),              // prompt missing
            (r#"{"prompt":[1.5]}"#, "bad_request"),               // non-token value
            (r#"{"prompt":["a"]}"#, "bad_request"),               // non-number
            (r#"{"op":"classify","prompt":[1]}"#, "bad_request"), // labels missing
            (r#"{"op":"classify","prompt":[1],"labels":[]}"#, "bad_request"),
            (r#"{"op":"frobnicate","prompt":[1]}"#, "bad_request"),
            (r#"{"prompt":[1],"deadline_ms":-5}"#, "bad_request"),
        ] {
            let err = parse_frame(line, &caps()).unwrap_err();
            assert!(err.starts_with(prefix), "{line} -> {err}");
        }
    }

    #[test]
    fn frames_serialize_deterministically_and_round_trip() {
        let r = Response {
            id: 3,
            tokens: vec![5, 9, 2],
            class: None,
            finish: FinishReason::Eos,
            prompt_len: 4,
            timing: Timing { queue_ms: 1.0, prefill_ms: 2.0, decode_ms: 3.0, total_ms: 6.0 },
        };
        let done = terminal_frame(&r);
        assert_eq!(
            done,
            r#"{"class":null,"finish":"eos","frame":"done","id":3,"prompt_len":4,"tokens":[5,9,2]}"#
        );
        assert!(!done.contains("ms"), "done frames must stay wall-clock-free");
        let t = Json::parse(&timing_frame(&r)).unwrap();
        assert_eq!(t.get("total_ms").and_then(Json::as_f64), Some(6.0));

        let mut rej = r.clone();
        rej.finish = FinishReason::Rejected;
        assert_eq!(
            terminal_frame(&rej),
            r#"{"frame":"reject","id":3,"reason":"rejected"}"#
        );
        let mut can = r.clone();
        can.finish = FinishReason::Canceled;
        assert_eq!(terminal_frame(&can), r#"{"frame":"canceled","id":3}"#);

        assert_eq!(
            token_frame(3, 42),
            r#"{"frame":"token","id":3,"token":42}"#
        );
        let w = Json::parse(&wire_reject_frame("bad_json: x")).unwrap();
        assert_eq!(w.get("reason").and_then(Json::as_str), Some("bad_json: x"));
        assert_eq!(w.get("id"), None, "wire rejects predate any request id");
    }

    #[test]
    fn classification_done_frame_carries_the_class() {
        let r = Response {
            id: 0,
            tokens: Vec::new(),
            class: Some(2),
            finish: FinishReason::Classified,
            prompt_len: 3,
            timing: Timing::default(),
        };
        let j = Json::parse(&terminal_frame(&r)).unwrap();
        assert_eq!(j.get("class").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("finish").and_then(Json::as_str), Some("classified"));
    }
}
