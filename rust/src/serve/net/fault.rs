//! Seeded deterministic fault injection for the network front-end.
//!
//! A [`FaultPlan`] decides, as a **pure function of (seed, site, conn,
//! event index)**, whether a given IO event gets a fault injected: a
//! slow read (the socket sits idle long enough to exercise the
//! read-timeout path), a corrupted inbound frame (truncated or
//! malformed — the parse-reject path), a forced disconnect after a
//! written frame (the cancel-on-disconnect path), or an accept stall
//! (the backlog/backpressure path). Because the decision is a hash, not
//! mutable state, the same plan replays the same fault schedule for the
//! same connection/event sequence — chaos tests are reproducible from
//! the seed alone — and the plan can be shared across connection
//! threads without locks.
//!
//! **Zero-cost-off contract** (the PR-6 observability doctrine): a
//! disabled plan is `inner: None` and every query below is a single
//! `Option` check — the production server pays one branch per IO site.
//! Like the trace recorder, an *enabled* plan never reads or writes
//! request payloads outside the faults it injects, so responses that do
//! complete under chaos are bitwise identical to fault-free responses
//! (the scheduler underneath is deterministic).

use std::sync::Arc;
use std::time::Duration;

/// Fault rates and magnitudes. A rate of `every = n` means roughly one
/// event in `n` is hit (decided per event by the seeded hash); `0`
/// disables that fault class.
#[derive(Clone, Debug)]
pub struct FaultCfg {
    pub seed: u64,
    /// Inject a pause before roughly one in this many socket reads.
    pub slow_read_every: u64,
    /// Length of an injected read pause, milliseconds.
    pub slow_read_ms: u64,
    /// Corrupt roughly one in this many inbound frames before parse
    /// (alternating truncation and byte-mangling, per the hash).
    pub corrupt_every: u64,
    /// Hard-drop the connection after roughly one in this many written
    /// frames (a mid-stream client disconnect, as seen by the server).
    pub disconnect_every: u64,
    /// Stall the accept loop before roughly one in this many accepts.
    pub accept_stall_every: u64,
    /// Length of an injected accept stall, milliseconds.
    pub accept_stall_ms: u64,
}

impl FaultCfg {
    /// The default chaos mix used by the tests and `--fault-seed`:
    /// every fault class on, at rates high enough that a few hundred
    /// frames hit each class at least once.
    pub fn chaos(seed: u64) -> FaultCfg {
        FaultCfg {
            seed,
            slow_read_every: 13,
            slow_read_ms: 30,
            corrupt_every: 11,
            disconnect_every: 17,
            accept_stall_every: 7,
            accept_stall_ms: 20,
        }
    }
}

/// Site tags: distinct fault classes must not correlate just because
/// they share a (conn, idx) pair.
const SITE_SLOW_READ: u64 = 0x51;
const SITE_CORRUPT: u64 = 0x52;
const SITE_DISCONNECT: u64 = 0x53;
const SITE_ACCEPT: u64 = 0x54;

/// splitmix64 finalizer — the same mixer `substrate::Rng` seeds with;
/// good avalanche, no state.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Shareable handle to a fault schedule; `off()` is the zero-cost
/// disabled state.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    inner: Option<Arc<FaultCfg>>,
}

impl FaultPlan {
    /// No faults, no cost: every query below is one `Option` check.
    pub fn off() -> FaultPlan {
        FaultPlan { inner: None }
    }

    pub fn seeded(cfg: FaultCfg) -> FaultPlan {
        FaultPlan { inner: Some(Arc::new(cfg)) }
    }

    /// [`FaultCfg::chaos`] shorthand.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan::seeded(FaultCfg::chaos(seed))
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn hit(cfg: &FaultCfg, site: u64, conn: u64, idx: u64, every: u64) -> bool {
        if every == 0 {
            return false;
        }
        let h = mix(
            cfg.seed
                ^ site.wrapping_mul(0x9e3779b97f4a7c15)
                ^ conn.rotate_left(32)
                ^ idx.wrapping_mul(0x2545f4914f6cdd1d),
        );
        h % every == 0
    }

    /// Pause to inject before read number `idx` on connection `conn`,
    /// if this read is scheduled for a slow-client fault.
    pub fn read_delay(&self, conn: u64, idx: u64) -> Option<Duration> {
        let cfg = self.inner.as_deref()?;
        Self::hit(cfg, SITE_SLOW_READ, conn, idx, cfg.slow_read_every)
            .then(|| Duration::from_millis(cfg.slow_read_ms))
    }

    /// Corrupt inbound frame `idx` in place, returning `true` when a
    /// fault fired. Alternates (by hash bit) between truncating the
    /// frame mid-way and mangling a byte into structural garbage — the
    /// two malformed-input shapes a real misbehaving client produces.
    pub fn corrupt_frame(&self, conn: u64, idx: u64, line: &mut Vec<u8>) -> bool {
        let Some(cfg) = self.inner.as_deref() else {
            return false;
        };
        if !Self::hit(cfg, SITE_CORRUPT, conn, idx, cfg.corrupt_every) || line.is_empty() {
            return false;
        }
        let h = mix(cfg.seed ^ SITE_CORRUPT ^ conn ^ idx);
        if h & 1 == 0 {
            line.truncate(line.len() / 2);
        } else {
            let pos = (h as usize >> 1) % line.len();
            if let Some(b) = line.get_mut(pos) {
                *b = b'\x01';
            }
        }
        true
    }

    /// Whether to hard-drop the connection after written frame `idx`
    /// (the mid-stream disconnect fault).
    pub fn drop_after_write(&self, conn: u64, idx: u64) -> bool {
        let Some(cfg) = self.inner.as_deref() else {
            return false;
        };
        Self::hit(cfg, SITE_DISCONNECT, conn, idx, cfg.disconnect_every)
    }

    /// Pause to inject before accept number `idx`, if scheduled.
    pub fn accept_stall(&self, idx: u64) -> Option<Duration> {
        let cfg = self.inner.as_deref()?;
        Self::hit(cfg, SITE_ACCEPT, 0, idx, cfg.accept_stall_every)
            .then(|| Duration::from_millis(cfg.accept_stall_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_injects_nothing() {
        let p = FaultPlan::off();
        assert!(!p.is_enabled());
        let mut line = b"{\"op\":\"generate\"}".to_vec();
        let orig = line.clone();
        for i in 0..1000 {
            assert!(p.read_delay(0, i).is_none());
            assert!(!p.corrupt_frame(0, i, &mut line));
            assert!(!p.drop_after_write(0, i));
            assert!(p.accept_stall(i).is_none());
        }
        assert_eq!(line, orig, "a disabled plan must never touch a frame");
    }

    #[test]
    fn schedule_is_deterministic_in_the_seed() {
        let a = FaultPlan::chaos(42);
        let b = FaultPlan::chaos(42);
        let c = FaultPlan::chaos(43);
        let sched = |p: &FaultPlan| -> Vec<(bool, bool, bool)> {
            (0..512)
                .map(|i| {
                    (
                        p.read_delay(3, i).is_some(),
                        p.drop_after_write(3, i),
                        p.accept_stall(i).is_some(),
                    )
                })
                .collect()
        };
        assert_eq!(sched(&a), sched(&b), "same seed, same schedule");
        assert_ne!(sched(&a), sched(&c), "different seed, different schedule");
    }

    #[test]
    fn chaos_mix_fires_every_class_at_plausible_rates() {
        let p = FaultPlan::chaos(7);
        let n = 4096u64;
        let mut slow = 0;
        let mut corrupt = 0;
        let mut drop = 0;
        let mut stall = 0;
        for conn in 0..4u64 {
            for i in 0..n / 4 {
                slow += usize::from(p.read_delay(conn, i).is_some());
                let mut line = b"{\"op\":\"generate\",\"prompt\":[1,2,3]}".to_vec();
                corrupt += usize::from(p.corrupt_frame(conn, i, &mut line));
                drop += usize::from(p.drop_after_write(conn, i));
            }
        }
        for i in 0..n {
            stall += usize::from(p.accept_stall(i).is_some());
        }
        // rate 1/k with n draws: expect n/k, allow a wide band — this
        // checks "fires, and not constantly", not exact statistics
        for (name, count, every) in
            [("slow", slow, 13u64), ("corrupt", corrupt, 11), ("drop", drop, 17)]
        {
            let expect = (n / every) as f64;
            assert!(
                (count as f64) > expect * 0.3 && (count as f64) < expect * 3.0,
                "{name}: {count} hits for rate 1/{every} over {n}"
            );
        }
        assert!(stall > 100, "accept stalls too rare: {stall}");
    }

    #[test]
    fn corruption_produces_unparseable_or_shorter_frames() {
        let p = FaultPlan::chaos(5);
        let mut truncated = 0;
        let mut mangled = 0;
        for i in 0..256 {
            let orig = b"{\"op\":\"generate\",\"prompt\":[1,2,3],\"max_new\":4}".to_vec();
            let mut line = orig.clone();
            if p.corrupt_frame(9, i, &mut line) {
                assert_ne!(line, orig);
                if line.len() < orig.len() {
                    truncated += 1;
                } else {
                    mangled += 1;
                }
            }
        }
        assert!(truncated > 0, "truncation arm never fired");
        assert!(mangled > 0, "mangling arm never fired");
    }
}
