//! Export-side weight quantizers — the rust mirrors of
//! python/compile/quantizers.py (Table 4 family). Used by the Fig. 2
//! weight-distribution analysis, the engine export path, the native QAT
//! fake-quant forward ([`crate::train::qat`]), and as fixtures asserting
//! rust/python agreement on the ternary lattice.

use anyhow::{bail, Result};

/// Ternary codes (-1/0/1 as i8) + the scale grid that dequantizes them.
pub struct QuantResult {
    pub codes: Vec<i8>,
    /// One scale per code (expanded; callers that want compact scales can
    /// use the accessors below).
    pub scales: Vec<f32>,
}

const EPS: f32 = 1e-6;

/// NaN-safe ternary rounding: NaN maps to 0 explicitly (a NaN weight —
/// e.g. from a diverged training run — must not poison the lattice;
/// the previous `as i8` cast happened to saturate to 0, but only as an
/// implementation detail of the cast). Shared with the engine-side
/// packer ([`crate::engine::ternary::TernaryMatrix::from_xw_f32`]) so
/// deployment packing and training-side quantization agree on the
/// lattice, non-finite entries included.
pub fn round_clip(v: f32) -> i8 {
    if v.is_nan() {
        return 0;
    }
    v.round().clamp(-1.0, 1.0) as i8
}

/// Mean |w| over the *finite* entries (0.0 if none): one NaN/inf weight
/// must not turn delta — and with it every scale and dequantized value —
/// into NaN. Codes for the non-finite entries themselves land on 0 via
/// [`round_clip`]. Shared with the engine-side packer
/// ([`crate::engine::ternary::TernaryMatrix::from_xw_f32`]) so both
/// sides compute the same delta from the same weights.
pub fn finite_absmean(w: impl Iterator<Item = f32>) -> f32 {
    let (mut sum, mut n) = (0.0f32, 0usize);
    for v in w {
        if v.is_finite() {
            sum += v.abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f32
    }
}

/// Paper eq. (1)-(2): per-tensor absmean.
pub fn absmean(w: &[f32]) -> QuantResult {
    let delta = finite_absmean(w.iter().copied());
    let codes = w.iter().map(|&v| round_clip(v / (delta + EPS))).collect();
    QuantResult { codes, scales: vec![delta; w.len()] }
}

/// Block-Quant analog: per `block`-row blocks of a [k, n] matrix.
/// Errors (instead of panicking) when the shape does not tile into
/// blocks; callers that want a graceful path fall back to the
/// per-tensor [`absmean`] (see `crate::train::qat::quantize_weight_value`).
pub fn block(w: &[f32], k: usize, n: usize, block_rows: usize) -> Result<QuantResult> {
    if w.len() != k * n {
        bail!("block: {} weights for a [{k}, {n}] matrix", w.len());
    }
    if block_rows == 0 || k % block_rows != 0 {
        bail!("block: k={k} does not divide into blocks of {block_rows} rows");
    }
    let mut codes = vec![0i8; w.len()];
    let mut scales = vec![0f32; w.len()];
    for b in 0..k / block_rows {
        let rows = b * block_rows..(b + 1) * block_rows;
        let delta = finite_absmean(
            rows.clone().flat_map(|r| (0..n).map(move |c| w[r * n + c])),
        );
        for r in rows {
            for c in 0..n {
                let i = r * n + c;
                codes[i] = round_clip(w[i] / (delta + EPS));
                scales[i] = delta;
            }
        }
    }
    Ok(QuantResult { codes, scales })
}

/// GPTQ analog: per-output-channel (column of [k, n]).
pub fn gptq(w: &[f32], k: usize, n: usize) -> QuantResult {
    assert_eq!(w.len(), k * n);
    let mut codes = vec![0i8; w.len()];
    let mut scales = vec![0f32; w.len()];
    for c in 0..n {
        let delta = finite_absmean((0..k).map(|r| w[r * n + c]));
        for r in 0..k {
            let i = r * n + c;
            codes[i] = round_clip(w[i] / (delta + EPS));
            scales[i] = delta;
        }
    }
    QuantResult { codes, scales }
}

/// AWQ analog: activation-aware per-input-channel rescale before absmean.
/// `act_mag[k]`: mean |activation| per input channel.
pub fn awq(w: &[f32], k: usize, n: usize, act_mag: &[f32]) -> QuantResult {
    assert_eq!(w.len(), k * n);
    assert_eq!(act_mag.len(), k);
    let s: Vec<f32> = act_mag
        .iter()
        .map(|&m| (m + EPS).sqrt().max(1e-3))
        .collect();
    let scaled: Vec<f32> = (0..w.len())
        .map(|i| w[i] * s[i / n])
        .collect();
    let mut r = absmean(&scaled);
    // dequantized value = codes * delta / s[row]: fold 1/s into scales
    for i in 0..r.scales.len() {
        r.scales[i] /= s[i / n];
    }
    QuantResult { codes: r.codes, scales: r.scales }
}

impl QuantResult {
    pub fn dequant(&self) -> Vec<f32> {
        self.codes
            .iter()
            .zip(&self.scales)
            .map(|(&c, &s)| c as f32 * s)
            .collect()
    }

    /// Fractions of (-1, 0, +1) codes — the Fig. 2 sparsity statistic.
    pub fn code_fractions(&self) -> (f64, f64, f64) {
        let n = self.codes.len().max(1) as f64;
        let neg = self.codes.iter().filter(|&&c| c == -1).count() as f64 / n;
        let zero = self.codes.iter().filter(|&&c| c == 0).count() as f64 / n;
        let pos = self.codes.iter().filter(|&&c| c == 1).count() as f64 / n;
        (neg, zero, pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::{prop, Rng};

    #[test]
    fn absmean_matches_manual() {
        let w = vec![0.3, -0.05, 0.0, -0.4];
        let r = absmean(&w);
        let delta = (0.3 + 0.05 + 0.0 + 0.4) / 4.0;
        assert_eq!(r.codes, vec![
            ((0.3 / (delta + EPS)) as f32).round().clamp(-1.0, 1.0) as i8,
            0,
            0,
            -1
        ]);
        assert!((r.scales[0] - delta).abs() < 1e-7);
    }

    #[test]
    fn prop_codes_are_ternary_and_error_bounded() {
        prop::check("quant-ternary", 40, |g| {
            let k = 32;
            let n = 16;
            let w = g.normal_vec(k * n, 0.05);
            let act = g.normal_vec(k, 1.0).iter().map(|v| v.abs()).collect::<Vec<_>>();
            for r in [
                absmean(&w),
                block(&w, k, n, 8).unwrap(),
                gptq(&w, k, n),
                awq(&w, k, n, &act),
            ] {
                assert!(r.codes.iter().all(|c| (-1..=1).contains(c)));
                let dq = r.dequant();
                // dequantization error: half the local scale inside the
                // grid, |w| - scale for clipped outliers (|w| > 1.5*scale)
                for i in 0..w.len() {
                    let bound = (r.scales[i] * 0.5).max(w[i].abs() - r.scales[i]);
                    assert!(
                        (dq[i] - w[i]).abs() <= bound + 1e-4,
                        "i={i} w={} dq={} scale={}",
                        w[i],
                        dq[i],
                        r.scales[i]
                    );
                }
            }
        });
    }

    #[test]
    fn round_clip_is_nan_safe() {
        // one NaN weight must poison neither the codes nor the scales:
        // delta is computed over the finite entries, the NaN entry
        // lands on the 0 code, and the dequantization stays finite
        let w = vec![0.3, f32::NAN, -0.4, 0.1];
        let r = absmean(&w);
        assert!(r.codes.iter().all(|c| (-1..=1).contains(c)), "{:?}", r.codes);
        assert_eq!(r.codes[1], 0, "NaN maps to the 0 code");
        let want_delta = (0.3 + 0.4 + 0.1) / 3.0;
        assert!((r.scales[0] - want_delta).abs() < 1e-6, "finite-only delta");
        assert!(r.dequant().iter().all(|v| v.is_finite()), "dequant unpoisoned");
        // per-column variant: only the NaN entry's code becomes 0
        let w2 = vec![1.0, f32::NAN, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0];
        let r2 = gptq(&w2, 4, 2);
        assert_eq!(r2.codes[1], 0);
        assert_eq!(r2.codes[0], 1);
        assert_eq!(r2.codes[2], -1);
        assert!(r2.dequant().iter().all(|v| v.is_finite()));
        // block variant with a NaN in one block
        let mut w3 = vec![0.1f32; 8 * 2];
        w3[3] = f32::NAN;
        let r3 = block(&w3, 8, 2, 4).unwrap();
        assert!(r3.dequant().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn block_rejects_non_divisible_shapes() {
        let w = vec![0.1f32; 10 * 4];
        assert!(block(&w, 10, 4, 3).is_err(), "10 rows / blocks of 3");
        assert!(block(&w, 10, 4, 0).is_err(), "zero block size");
        assert!(block(&w[..39], 10, 4, 2).is_err(), "length/shape mismatch");
        assert!(block(&w, 10, 4, 5).is_ok());
    }

    #[test]
    fn block_scales_are_blockwise_constant() {
        let mut rng = Rng::new(2);
        let mut w = vec![0.0; 64 * 8];
        rng.fill_normal(&mut w, 0.1);
        let r = block(&w, 64, 8, 16).unwrap();
        for b in 0..4 {
            let s0 = r.scales[b * 16 * 8];
            for i in 0..16 * 8 {
                assert_eq!(r.scales[b * 16 * 8 + i], s0);
            }
        }
    }

    #[test]
    fn gptq_scales_are_columnwise_constant() {
        let mut rng = Rng::new(3);
        let mut w = vec![0.0; 32 * 4];
        rng.fill_normal(&mut w, 0.1);
        let r = gptq(&w, 32, 4);
        for c in 0..4 {
            let s0 = r.scales[c];
            for row in 0..32 {
                assert_eq!(r.scales[row * 4 + c], s0);
            }
        }
    }

    #[test]
    fn awq_high_activation_channels_get_finer_effective_grid() {
        // with a large activation on channel 0, its weights are scaled up
        // before ternarization -> their dequantized error shrinks
        let w = vec![0.02f32; 2 * 4]; // k=2 channels, n=4
        let act = vec![100.0, 0.01];
        let r = awq(&w, 2, 4, &act);
        let dq = r.dequant();
        let err0: f32 = (0..4).map(|c| (dq[c] - w[c]).abs()).sum();
        let err1: f32 = (4..8).map(|c| (dq[c] - w[c]).abs()).sum();
        assert!(err0 <= err1 + 1e-6, "err0={err0} err1={err1}");
    }
}
