//! Ternary (1.58-bit) weight packing + int8 activation quantization —
//! the deployment-side mirror of paper eq. (1)-(3).
//!
//! Weights are stored transposed ([out, in], row-major) and packed 4 trits
//! per byte (2 bits each: 00 -> 0, 01 -> +1, 10 -> -1), giving the 16x
//! weight-memory reduction over f32 (the paper's "10x vs fp16" claim
//! counts fp16 embeddings; see EXPERIMENTS.md). Decoding goes through a
//! 256-entry lookup table that expands one packed byte into 4 i8 trits.

pub const EPS: f32 = 1e-6;

/// 256 x 4 LUT: packed byte -> 4 trits. Built once, used by every GEMV.
pub fn trit_lut() -> &'static [[i8; 4]; 256] {
    use std::sync::OnceLock;
    static LUT: OnceLock<[[i8; 4]; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut lut = [[0i8; 4]; 256];
        for b in 0..256usize {
            for s in 0..4 {
                lut[b][s] = match (b >> (2 * s)) & 0b11 {
                    0b01 => 1,
                    0b10 => -1,
                    _ => 0,
                };
            }
        }
        lut
    })
}

fn encode_trit(t: i8) -> u8 {
    match t {
        1 => 0b01,
        -1 => 0b10,
        _ => 0b00,
    }
}

/// A ternary-quantized matrix in [out, in] orientation.
#[derive(Clone)]
pub struct TernaryMatrix {
    pub rows: usize,
    pub cols: usize,
    /// ceil(cols/4) bytes per row, row-major.
    pub packed: Vec<u8>,
    /// Per-tensor absmean scale (paper eq. (2)).
    pub delta: f32,
}

impl TernaryMatrix {
    pub fn bytes_per_row(&self) -> usize {
        (self.cols + 3) / 4
    }

    pub fn memory_bytes(&self) -> usize {
        self.packed.len() + 4
    }

    /// Quantize a [in, out] (x @ W orientation, as stored in checkpoints)
    /// f32 matrix: absmean ternary, transposed to [out, in], packed.
    ///
    /// NaN/Inf-safe, on the exact lattice of the training-side quantizer
    /// [`crate::quant::absmean`]: delta is the **finite-only** absmean
    /// ([`crate::quant::finite_absmean`] — previously one NaN weight
    /// poisoned delta and with it every dequantized value), and codes go
    /// through [`crate::quant::round_clip`] (NaN packs as the 0 trit,
    /// ±Inf saturates to ±1, exactly as QAT trained it).
    pub fn from_xw_f32(w: &[f32], k_in: usize, n_out: usize) -> TernaryMatrix {
        assert_eq!(w.len(), k_in * n_out);
        let delta = crate::quant::finite_absmean(w.iter().copied());
        let bpr = (k_in + 3) / 4;
        let mut packed = vec![0u8; n_out * bpr];
        for n in 0..n_out {
            for k in 0..k_in {
                let t = crate::quant::round_clip(w[k * n_out + n] / (delta + EPS));
                packed[n * bpr + k / 4] |= encode_trit(t) << (2 * (k % 4));
            }
        }
        TernaryMatrix { rows: n_out, cols: k_in, packed, delta }
    }

    /// Dequantized row (testing / debugging).
    pub fn row_f32(&self, n: usize) -> Vec<f32> {
        let lut = trit_lut();
        let bpr = self.bytes_per_row();
        let mut out = Vec::with_capacity(self.cols);
        for b in &self.packed[n * bpr..(n + 1) * bpr] {
            for &t in &lut[*b as usize] {
                if out.len() < self.cols {
                    out.push(t as f32 * self.delta);
                }
            }
        }
        out
    }
}

/// Per-token int8 absmax activation quantization (paper eq. (3)).
/// Returns gamma; `q` receives RoundClip(127 x/(gamma+eps), -128, 127).
pub fn act_quant_i8(x: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), q.len());
    let gamma = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let scale = 127.0 / (gamma + EPS);
    for (qi, &v) in q.iter_mut().zip(x) {
        *qi = (v * scale).round().clamp(-128.0, 127.0) as i8;
    }
    gamma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::{prop, Rng};

    #[test]
    fn lut_decodes_all_codes() {
        let lut = trit_lut();
        assert_eq!(lut[0b01], [1, 0, 0, 0]);
        assert_eq!(lut[0b10], [-1, 0, 0, 0]);
        assert_eq!(lut[0b01 << 2], [0, 1, 0, 0]);
        assert_eq!(lut[0xAA], [-1, -1, -1, -1]);
        assert_eq!(lut[0x55], [1, 1, 1, 1]);
    }

    #[test]
    fn prop_pack_round_trip_matches_absmean() {
        prop::check("ternary-pack-round-trip", 40, |g| {
            let k = g.usize(1, 65);
            let n = g.usize(1, 33);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            // reference: eq. (1)-(2) directly on the [in, out] layout
            let delta = w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32;
            assert!((m.delta - delta).abs() < 1e-7);
            for row in 0..n {
                let got = m.row_f32(row);
                for kk in 0..k {
                    let v = w[kk * n + row] / (delta + EPS);
                    let want = v.round().clamp(-1.0, 1.0) * delta;
                    assert!(
                        (got[kk] - want).abs() < 1e-6,
                        "row {row} col {kk}: {} vs {want}",
                        got[kk]
                    );
                }
            }
        });
    }

    #[test]
    fn act_quant_matches_reference() {
        let mut rng = Rng::new(3);
        let mut x = vec![0.0f32; 37];
        rng.fill_normal(&mut x, 2.0);
        let mut q = vec![0i8; 37];
        let gamma = act_quant_i8(&x, &mut q);
        let gmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert_eq!(gamma, gmax);
        for (&qi, &v) in q.iter().zip(&x) {
            let want = (v * 127.0 / (gamma + EPS)).round().clamp(-128.0, 127.0);
            assert_eq!(qi as f32, want);
        }
    }

    #[test]
    fn act_quant_zero_vector() {
        let x = vec![0.0f32; 8];
        let mut q = vec![0i8; 8];
        let gamma = act_quant_i8(&x, &mut q);
        assert_eq!(gamma, 0.0);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn non_finite_weights_do_not_poison_packing() {
        // regression: absmean delta used to include NaN/Inf, turning the
        // per-tensor scale — and with it every dequantized weight and
        // every gemv output — into NaN. The packer must use the same
        // finite-only statistics as the training-side quant::absmean.
        let mut w = vec![0.3f32, -0.4, 0.1, 0.2, -0.3, 0.25];
        w[1] = f32::NAN;
        w[4] = f32::INFINITY;
        let (k, n) = (3, 2); // [in, out] layout
        let m = TernaryMatrix::from_xw_f32(&w, k, n);
        // delta = finite-only absmean, matching quant::absmean bit for bit
        let q = crate::quant::absmean(&w);
        assert!(m.delta.is_finite());
        assert_eq!(m.delta.to_bits(), q.scales[0].to_bits());
        // codes agree with the training-side lattice at every position:
        // NaN -> 0, +Inf saturates to +1, finite entries round normally
        for row in 0..n {
            let got = m.row_f32(row);
            for kk in 0..k {
                let want = q.codes[kk * n + row] as f32 * m.delta;
                assert!(
                    (got[kk] - want).abs() < 1e-7,
                    "row {row} col {kk}: {} vs {want}",
                    got[kk]
                );
            }
        }
        // and the kernel output stays finite
        let x = vec![1.0f32, -2.0, 0.5];
        let mut qact = vec![0i8; k];
        let gamma = act_quant_i8(&x, &mut qact);
        let mut y = vec![0.0f32; n];
        crate::engine::gemv::gemv_ternary(&m, &qact, gamma, &mut y);
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");

        // all-non-finite matrix: delta 0, every code 0, output all-zero
        let bad = vec![f32::NAN; 4];
        let mb = TernaryMatrix::from_xw_f32(&bad, 2, 2);
        assert_eq!(mb.delta, 0.0);
        assert!(mb.packed.iter().all(|&b| b == 0));
    }

    #[test]
    fn memory_is_quarter_byte_per_weight() {
        let w = vec![0.01f32; 256 * 128];
        let m = TernaryMatrix::from_xw_f32(&w, 256, 128);
        assert_eq!(m.packed.len(), 128 * 64); // 256/4 bytes per row
        assert!(m.memory_bytes() * 16 <= 256 * 128 * 4 + 64);
    }
}
