//! Deployment-side CPU inference engine: f32 baseline + packed-ternary
//! W1.58A8 path. Reproduces the paper's Speed / Memory columns
//! (Tables 1-2, Fig. 1) and serves generation for the CNNDM analog.
//!
//! Two decode paths share the same arithmetic:
//! - [`Engine::decode_step`] — one token, one sequence (the original).
//! - [`Engine::decode_step_batch`] — one token for each of `b`
//!   co-scheduled sequences over a [`KvCachePool`], with the hot matvecs
//!   lifted to batch GEMMs. Batch size 1 is bitwise identical to
//!   `decode_step` (test-enforced); the [`crate::serve`] layer builds
//!   continuous batching on top.
//!
//! Every operation has one canonical `_ctx` entry point taking an
//! [`ExecCtx`] — the execution context bundling thread pool, kernel
//! generation, tracing and quant telemetry ([`ctx`]). The context fans
//! each projection/FFN matmul and the LM head across workers via the
//! row-partitioned kernels in [`crate::parallel`] — bitwise identical
//! to serial at every thread count (test-enforced), so threading
//! composes with every parity guarantee above. The plain methods
//! (`decode_step`, `generate`, ...) are serial-unobserved shims over
//! the `_ctx` forms.
//!
//! Prompts run through the **chunked prefill** path ([`prefill`]):
//! up to C consecutive prompt tokens stack as rows of one time-batched
//! GEMM per matrix, attention stays causal within the chunk, and the
//! LM head runs only for the chunk's final position — bitwise
//! identical to a decode_step loop over the same tokens (test-enforced
//! at chunk {1,2,3,5,8} x threads {1,4} x every kernel), so chunking
//! is, like threads and kernels, a pure throughput knob.
//!
//! Three interchangeable ternary kernel generations sit underneath
//! ([`KernelKind`] on [`Engine`] / `--kernel` on the CLI): the
//! byte-decode kernels in [`gemv`], the activation-LUT kernels in
//! [`lut`] (TL-style, one table load + add per packed byte), and the
//! runtime-dispatched SIMD kernels in [`simd`] (AVX2/NEON in-register
//! nibble decode, plus the SIMD f32 GEMV the LM head rides on). They
//! are **bitwise identical** on every input — SIMD falls back to the
//! scalar reference on hosts without the features, same bits — so the
//! selector is purely a throughput knob; `bitdistill bench --check`
//! gates their relative speed in CI.

pub mod ctx;
pub mod gemv;
pub mod lut;
pub mod model;
pub mod prefill;
pub mod simd;
pub mod ternary;

pub use ctx::ExecCtx;
pub use gemv::TernGemmScratch;
pub use lut::{KernelKind, LutScratch};
pub use model::{argmax, argmax_labels, BatchScratch, Engine, KvCache, KvCachePool, Scratch};
pub use prefill::{PrefillScratch, DEFAULT_PREFILL_CHUNK};
pub use ternary::{act_quant_i8, TernaryMatrix};
