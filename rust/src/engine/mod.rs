//! Deployment-side CPU inference engine: f32 baseline + packed-ternary
//! W1.58A8 path. Reproduces the paper's Speed / Memory columns
//! (Tables 1-2, Fig. 1) and serves generation for the CNNDM analog.

pub mod gemv;
pub mod model;
pub mod ternary;

pub use model::{argmax, Engine, KvCache, Scratch};
pub use ternary::{act_quant_i8, TernaryMatrix};
