//! The Engine's unified execution context.
//!
//! Every knob that used to pick an `Engine` method variant — thread
//! pool, kernel generation, tracing, quant telemetry — now rides in one
//! [`ExecCtx`] value, and each operation has exactly **one** canonical
//! entry point taking `&ExecCtx` (`decode_step_ctx`,
//! `prefill_prompt_ctx`, `generate_ctx`, ...). Adding a kernel
//! generation or an observability sink extends this struct, not the
//! method matrix: the third (SIMD) generation added zero new Engine
//! methods, and a fourth would too.
//!
//! The plain convenience methods (`decode_step`, `generate`, ...) stay
//! for callers that want serial execution with the engine's default
//! kernel; they are thin shims over the `_ctx` forms. The legacy
//! `_with` / `_kernel` / `_traced` / `_obs` variants are gone, and the
//! in-tree lint rule `no-legacy-engine-variants` keeps call sites
//! outside `engine/` from growing them back.
//!
//! `ExecCtx` is cheap to build and to clone: [`ThreadPool`] is a
//! two-word `Copy` policy value (workers spawn per call, not per pool),
//! and disabled [`TraceRecorder`] / [`QuantScope`] handles carry
//! nothing. Observability stays zero-cost-off through this layer — a
//! default context traces nothing and observes nothing.

use super::lut::KernelKind;
use crate::obs::{QuantScope, TraceRecorder};
use crate::parallel::ThreadPool;

/// How one Engine call executes: where it fans out, which kernel
/// generation it runs, and what it reports while doing so. Results are
/// bitwise independent of all of it — threads, kernel, tracing and
/// telemetry may never move an output bit (test-enforced across the
/// engine, server and generate levels).
#[derive(Clone, Debug)]
pub struct ExecCtx {
    /// Row-partitioning policy for the parallel kernels.
    pub pool: ThreadPool,
    /// Kernel generation (byte-decode, LUT, or runtime-dispatched SIMD).
    pub kernel: KernelKind,
    /// Span recorder; disabled by default (zero-cost-off).
    pub trace: TraceRecorder,
    /// Quantization telemetry scope; disabled by default.
    pub quant: QuantScope,
}

impl ExecCtx {
    /// Serial, byte-decode, unobserved — the conservative default the
    /// plain Engine wrappers use (with the engine's own default kernel
    /// swapped in).
    pub fn serial() -> ExecCtx {
        ExecCtx {
            pool: ThreadPool::serial(),
            kernel: KernelKind::ByteDecode,
            trace: TraceRecorder::disabled(),
            quant: QuantScope::disabled(),
        }
    }

    /// Same context, different kernel generation.
    pub fn with_kernel(mut self, kernel: KernelKind) -> ExecCtx {
        self.kernel = kernel;
        self
    }

    /// Same context, fanning out over `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> ExecCtx {
        self.pool = ThreadPool::new(threads);
        self
    }

    /// Same context, custom partitioning policy.
    pub fn with_pool(mut self, pool: ThreadPool) -> ExecCtx {
        self.pool = pool;
        self
    }

    /// Same context, recording spans into `trace`.
    pub fn with_trace(mut self, trace: TraceRecorder) -> ExecCtx {
        self.trace = trace;
        self
    }

    /// Same context, emitting quant telemetry into `quant`.
    pub fn with_quant(mut self, quant: QuantScope) -> ExecCtx {
        self.quant = quant;
        self
    }
}

impl Default for ExecCtx {
    fn default() -> ExecCtx {
        ExecCtx::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_default_is_unobserved_single_threaded_byte_decode() {
        let ctx = ExecCtx::serial();
        assert_eq!(ctx.pool.threads(), 1);
        assert_eq!(ctx.kernel, KernelKind::ByteDecode);
        assert!(!ctx.trace.is_enabled());
        assert!(!ctx.quant.is_enabled());
    }

    #[test]
    fn builders_compose() {
        let ctx = ExecCtx::serial().with_kernel(KernelKind::Simd).with_threads(4);
        assert_eq!(ctx.kernel, KernelKind::Simd);
        assert_eq!(ctx.pool.threads(), 4);
        let ctx2 = ctx.clone().with_pool(ThreadPool::with_granularity(2, 1));
        assert_eq!(ctx2.pool.threads(), 2);
        assert_eq!(ctx2.kernel, KernelKind::Simd);
    }
}
