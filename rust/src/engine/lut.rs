//! Activation-LUT ternary kernels (TL-style, after bitnet.cpp's lookup
//! tables) — the second-generation W1.58A8 GEMV/GEMM path.
//!
//! The byte-decode kernels in [`super::gemv`] pay, per packed weight
//! byte, a 4-trit LUT decode plus 4 multiply-adds — and they pay it
//! again for every one of the `n_out` rows that consume the same
//! quantized activation. This module inverts the lookup: for each
//! 4-activation group `g` it precomputes, over all 256 possible weight
//! bytes,
//!
//! ```text
//!   table[g][byte] = Σ_s trit(byte, s) · q[g*4 + s]      (i16 exact)
//! ```
//!
//! so that inside the row loop one packed byte costs **one table load
//! and one i32 add** instead of a decode and 4 multiply-adds. Tables
//! are built once per quantized activation — about four builds per
//! layer per decode step (Q/K/V share one, gate/up another, and the
//! `wo`/`w_down` inputs get their own) — and each build is amortized
//! over every output row of every matrix consuming that activation;
//! the batched server additionally shares each lane's tables across
//! all rows of the batch GEMM.
//!
//! ## Exactness
//!
//! Each table entry is the exact integer sum of the same products the
//! byte-decode kernel accumulates for that byte: trits are in
//! {-1, 0, 1} and `q` in [-128, 127], so |entry| <= 4*128 = 512, well
//! inside i16. Both kernels then add one value per packed byte into an
//! i32 accumulator in the same byte order, so the final dot — and with
//! it the dequantized f32 output — is **bitwise identical** to
//! [`super::gemv::ternary_row_dot`] / [`super::gemv::gemv_ternary`] /
//! [`super::gemv::gemm_ternary`]. The property tests below and the
//! thread-fanned twins in [`crate::parallel::gemm`] pin this.
//!
//! ## Cost model (see EXPERIMENTS.md §Perf for measured numbers)
//!
//! Building one group's 256-entry table via two 16-entry half tables
//! costs ~288 i16 adds; the byte-decode kernel spends ~8 ops per byte
//! per row. The LUT path therefore breaks even once a table is reused
//! by roughly `288 / 7 ≈ 40` rows and wins decisively at the wide
//! ternary matmuls — the FFN projections (`n_out = d_ff`) above all.
//! (The LM head stays full-precision f32 and never runs a ternary
//! kernel, so it gets no LUT benefit.) The CI `bitdistill bench
//! --check` gate enforces the `n_out >= 1024` win on synthetic GEMV
//! shapes of that scale.

use super::gemv::TernGemmScratch;
use super::ternary::TernaryMatrix;

/// Which ternary GEMV/GEMM implementation the engine runs.
///
/// Both kernels are bitwise identical on every input (test-enforced),
/// so this is purely a performance selector — flipping it can never
/// change a logit, a generated token, or a served response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Per-byte trit decode + 4 multiply-adds ([`super::gemv`]).
    ByteDecode,
    /// Per-4-activation-group lookup tables (this module).
    Lut,
    /// Third generation ([`super::simd`]): runtime-dispatched AVX2/NEON
    /// nibble-decode kernels over the same packed bytes, with a bitwise
    /// fallback to the scalar LUT path on hosts without the features.
    Simd,
}

impl KernelKind {
    /// Every kernel generation, oldest first — the sweep order of
    /// `--kernel both` and the test matrices.
    pub const ALL: [KernelKind; 3] = [KernelKind::ByteDecode, KernelKind::Lut, KernelKind::Simd];

    /// Parse a CLI spelling (`byte` | `lut` | `simd`).
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "byte" | "byte-decode" | "bytedecode" => Some(KernelKind::ByteDecode),
            "lut" => Some(KernelKind::Lut),
            "simd" => Some(KernelKind::Simd),
            _ => None,
        }
    }

    /// The canonical name used in CLI flags, bench rows and reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::ByteDecode => "byte",
            KernelKind::Lut => "lut",
            KernelKind::Simd => "simd",
        }
    }

    /// [`KernelKind::parse`] with the canonical CLI error, for flags
    /// that take exactly one kernel (`--kernel byte|lut|simd`). An
    /// unknown spelling errors here, at arg-parse time, with the
    /// accepted list — it never silently defaults.
    pub fn parse_flag(s: &str) -> anyhow::Result<KernelKind> {
        KernelKind::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --kernel {s:?} (byte|lut|simd)"))
    }

    /// Parse a sweep-capable `--kernel` value (`byte`, `lut`, `simd`,
    /// or `both`/`all` = every generation) into the list of kernels to
    /// run. Shares the accepted spellings with [`KernelKind::parse`],
    /// so a new kernel name is added in one place.
    pub fn parse_sweep(s: &str) -> anyhow::Result<Vec<KernelKind>> {
        match s {
            "both" | "all" => Ok(KernelKind::ALL.to_vec()),
            k => KernelKind::parse(k)
                .map(|kk| vec![kk])
                .ok_or_else(|| anyhow::anyhow!("unknown --kernel {k:?} (byte|lut|simd|both)")),
        }
    }
}

/// Table entries per 4-activation group (one per possible packed byte).
pub const GROUP_TABLE: usize = 256;

/// Groups (packed bytes) covering `cols` activations.
#[inline]
pub fn n_groups(cols: usize) -> usize {
    (cols + 3) / 4
}

/// Signed value of trit code `c` (2 bits of a packed byte) applied to
/// `q` — the i16 mirror of the packing in [`super::ternary::trit_lut`]:
/// 01 -> +q, 10 -> -q, otherwise 0.
#[inline]
fn trit_apply(q: i16, c: usize) -> i16 {
    match c {
        0b01 => q,
        0b10 => -q,
        _ => 0,
    }
}

/// Fill `table[..n_groups(q.len()) * 256]` with the per-group byte sums
/// for one quantized activation `q`. A trailing group with fewer than 4
/// activations is zero-padded, which matches the byte-decode tail loop
/// exactly (missing slots contribute 0, and the packed tail bits are 0
/// trits anyway).
///
/// Each group's 256 entries are assembled from two 16-entry half tables
/// (low two trit slots x high two trit slots), ~288 i16 adds per group
/// instead of the naive 1024 multiply-adds.
pub fn build_tables(q: &[i8], table: &mut [i16]) {
    let groups = n_groups(q.len());
    debug_assert!(table.len() >= groups * GROUP_TABLE);
    for g in 0..groups {
        let base = g * 4;
        let qv = |s: usize| -> i16 {
            if base + s < q.len() {
                q[base + s] as i16
            } else {
                0
            }
        };
        let (q0, q1, q2, q3) = (qv(0), qv(1), qv(2), qv(3));
        // low half: trit slots 0-1 (byte bits 0..4)
        let mut lo = [0i16; 16];
        for c1 in 0..4 {
            for c0 in 0..4 {
                lo[(c1 << 2) | c0] = trit_apply(q0, c0) + trit_apply(q1, c1);
            }
        }
        // high half: trit slots 2-3 (byte bits 4..8)
        let mut hi = [0i16; 16];
        for c3 in 0..4 {
            for c2 in 0..4 {
                hi[(c3 << 2) | c2] = trit_apply(q2, c2) + trit_apply(q3, c3);
            }
        }
        let t = &mut table[g * GROUP_TABLE..(g + 1) * GROUP_TABLE];
        for h in 0..16 {
            let hv = hi[h];
            let row = &mut t[h * 16..(h + 1) * 16];
            for (entry, &lv) in row.iter_mut().zip(lo.iter()) {
                *entry = hv + lv;
            }
        }
    }
}

/// Reusable, growable table scratch. One per [`crate::engine::Scratch`]
/// / [`crate::engine::BatchScratch`]: the buffer grows on the first
/// build at a new width and is reused afterwards, so the steady-state
/// decode loop allocates nothing and byte-decode runs never pay the
/// table memory at all.
pub struct LutScratch {
    buf: Vec<i16>,
}

impl LutScratch {
    /// An empty scratch; the buffer grows on first use.
    pub fn new() -> LutScratch {
        LutScratch { buf: Vec::new() }
    }

    /// Preallocated for activations up to `max_cols` wide and batches up
    /// to `max_b` — the decode loop then never allocates.
    pub fn for_dims(max_cols: usize, max_b: usize) -> LutScratch {
        LutScratch { buf: vec![0i16; max_b * n_groups(max_cols) * GROUP_TABLE] }
    }

    fn ensure(&mut self, need: usize) {
        if self.buf.len() < need {
            self.buf.resize(need, 0);
        }
    }

    /// Build the tables for one quantized activation and return them
    /// (`n_groups(q.len()) * 256` entries).
    pub fn build(&mut self, q: &[i8]) -> &[i16] {
        let need = n_groups(q.len()) * GROUP_TABLE;
        self.ensure(need);
        build_tables(q, &mut self.buf[..need]);
        &self.buf[..need]
    }

    /// Build tables for `b` quantized activations stored at stride
    /// `cols` in `qs`; item `bi`'s tables live at
    /// `[bi * n_groups(cols) * 256 ..][.. n_groups(cols) * 256]` of the
    /// returned slice.
    pub fn build_batch(&mut self, qs: &[i8], cols: usize, b: usize) -> &[i16] {
        let per = n_groups(cols) * GROUP_TABLE;
        let need = b * per;
        self.ensure(need);
        for bi in 0..b {
            build_tables(&qs[bi * cols..(bi + 1) * cols], &mut self.buf[bi * per..(bi + 1) * per]);
        }
        &self.buf[..need]
    }
}

impl Default for LutScratch {
    fn default() -> LutScratch {
        LutScratch::new()
    }
}

/// i32 dot of one packed row against one activation's tables: one load
/// + one add per packed byte. Adds, per byte, exactly the value
/// [`super::gemv::ternary_row_dot`] accumulates for that byte, in the
/// same byte order — bitwise-identical result.
#[inline]
pub(crate) fn lut_row_dot(row: &[u8], table: &[i16]) -> i32 {
    let mut acc: i32 = 0;
    for (g, &byte) in row.iter().enumerate() {
        acc += table[g * GROUP_TABLE + byte as usize] as i32;
    }
    acc
}

/// Batched twin of [`lut_row_dot`]: one packed row against `b`
/// activations' tables (stride `groups * 256`), byte-major so each
/// packed byte is loaded once per lane. Results land in `acc[..b]`
/// (reset here), matching [`super::gemv::ternary_row_dot_batch`] bit
/// for bit per lane.
#[inline]
pub(crate) fn lut_row_dot_batch(
    row: &[u8],
    tables: &[i16],
    groups: usize,
    b: usize,
    acc: &mut [i32],
) {
    let stride = groups * GROUP_TABLE;
    acc[..b].iter_mut().for_each(|a| *a = 0);
    for (g, &byte) in row.iter().enumerate() {
        let off = g * GROUP_TABLE + byte as usize;
        for (bi, a) in acc[..b].iter_mut().enumerate() {
            *a += tables[bi * stride + off] as i32;
        }
    }
}

/// LUT twin of [`super::gemv::gemv_ternary`]: y = scale * (trits . q)
/// with the per-byte products pre-summed into `table`
/// ([`LutScratch::build`] over the same `q`). Bitwise identical to the
/// byte-decode kernel (property-test-enforced).
pub fn lut_gemv(m: &TernaryMatrix, table: &[i16], gamma: f32, y: &mut [f32]) {
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    debug_assert!(table.len() >= bpr * GROUP_TABLE);
    let scale = (gamma / 127.0) * m.delta;
    for (n, yn) in y.iter_mut().enumerate() {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        *yn = lut_row_dot(row, table) as f32 * scale;
    }
}

/// LUT twin of [`super::gemv::gemm_ternary`]: `b` lanes' tables
/// ([`LutScratch::build_batch`]), one `gamma` per lane, caller-owned
/// [`TernGemmScratch`] for the dequant scales and i32 accumulators.
/// Bitwise identical to the byte-decode kernel per lane.
pub fn lut_gemm(
    m: &TernaryMatrix,
    tables: &[i16],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    let bpr = m.bytes_per_row();
    debug_assert!(tables.len() >= b * bpr * GROUP_TABLE);
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    for n in 0..m.rows {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        lut_row_dot_batch(row, tables, bpr, b, &mut scratch.acc);
        for bi in 0..b {
            ys[bi * m.rows + n] = scratch.acc[bi] as f32 * scratch.scales[bi];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gemv::{gemm_ternary, gemv_ternary, ternary_row_dot};
    use crate::engine::ternary::act_quant_i8;
    use crate::substrate::prop;

    #[test]
    fn kernel_kind_parse_and_name_round_trip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("byte-decode"), Some(KernelKind::ByteDecode));
        assert_eq!(KernelKind::parse("simd"), Some(KernelKind::Simd));
        assert_eq!(KernelKind::parse_sweep("both").unwrap(), KernelKind::ALL.to_vec());
        assert_eq!(KernelKind::parse_sweep("all").unwrap(), KernelKind::ALL.to_vec());
        assert_eq!(KernelKind::parse_sweep("lut").unwrap(), vec![KernelKind::Lut]);
        assert_eq!(KernelKind::parse_sweep("simd").unwrap(), vec![KernelKind::Simd]);
        assert_eq!(KernelKind::parse_flag("simd").unwrap(), KernelKind::Simd);
    }

    #[test]
    fn kernel_kind_unknown_names_error_with_accepted_list() {
        // the bugfix contract: an unknown --kernel spelling fails at
        // arg-parse time and the message names every accepted value
        assert_eq!(KernelKind::parse("sse"), None);
        let flag_err = KernelKind::parse_flag("sse").unwrap_err().to_string();
        assert!(flag_err.contains("unknown --kernel \"sse\""), "{flag_err}");
        assert!(flag_err.contains("(byte|lut|simd)"), "{flag_err}");
        let sweep_err = KernelKind::parse_sweep("neither").unwrap_err().to_string();
        assert!(sweep_err.contains("unknown --kernel \"neither\""), "{sweep_err}");
        assert!(sweep_err.contains("(byte|lut|simd|both)"), "{sweep_err}");
    }

    #[test]
    fn table_entries_match_trit_lut_products() {
        // every (byte, group) entry equals the byte-decode product sum,
        // including a tail group with q = [-128] (the i8 extreme whose
        // negation only exists in i16)
        let q: Vec<i8> = vec![3, -7, 127, -128, 5];
        let groups = n_groups(q.len());
        let mut table = vec![0i16; groups * GROUP_TABLE];
        build_tables(&q, &mut table);
        let lut = crate::engine::ternary::trit_lut();
        for g in 0..groups {
            for byte in 0..256usize {
                let mut want: i32 = 0;
                for (s, &t) in lut[byte].iter().enumerate() {
                    if g * 4 + s < q.len() {
                        want += t as i32 * q[g * 4 + s] as i32;
                    }
                }
                assert_eq!(
                    table[g * GROUP_TABLE + byte] as i32,
                    want,
                    "group {g} byte {byte:#04x}"
                );
            }
        }
    }

    #[test]
    fn prop_lut_row_dot_is_bitwise_ternary_row_dot() {
        prop::check("lut-row-dot", 40, |g| {
            let k = g.usize(1, 70); // includes non-multiple-of-4 tails
            let w = g.normal_vec(k, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, 1);
            let x = g.normal_vec(k, 1.0);
            let mut q = vec![0i8; k];
            act_quant_i8(&x, &mut q);
            let mut scratch = LutScratch::new();
            let table = scratch.build(&q);
            let row = &m.packed[..m.bytes_per_row()];
            assert_eq!(lut_row_dot(row, table), ternary_row_dot(row, &q, k / 4));
        });
    }

    #[test]
    fn prop_lut_gemv_is_bitwise_gemv_ternary() {
        prop::check("lut-gemv", 40, |g| {
            let k = g.usize(4, 96);
            let n = g.usize(1, 48);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.5);
            let mut q = vec![0i8; k];
            let gamma = act_quant_i8(&x, &mut q);
            let mut want = vec![0.0f32; n];
            gemv_ternary(&m, &q, gamma, &mut want);
            let mut scratch = LutScratch::new();
            let table = scratch.build(&q);
            let mut y = vec![0.0f32; n];
            lut_gemv(&m, table, gamma, &mut y);
            let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "k={k} n={n}");
        });
    }

    #[test]
    fn prop_lut_gemm_is_bitwise_gemm_ternary() {
        prop::check("lut-gemm", 40, |g| {
            let b = g.usize(1, 5);
            let k = g.usize(4, 70); // includes tail columns
            let n = g.usize(1, 30);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] = act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut want = vec![0.0f32; b * n];
            let mut ws = TernGemmScratch::new();
            gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut ws);
            let mut lscratch = LutScratch::new();
            let tables = lscratch.build_batch(&qs, k, b);
            let mut ys = vec![0.0f32; b * n];
            let mut gs = TernGemmScratch::new();
            lut_gemm(&m, tables, &gammas, b, &mut ys, &mut gs);
            let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
            assert!(same, "b={b} k={k} n={n}");
        });
    }

    #[test]
    fn scratch_reuse_across_widths_is_exact() {
        // a LutScratch carried across matrices of different widths (the
        // decode loop's usage: d -> q_dim -> d_ff -> d ...) must produce
        // the same tables as a fresh one each time
        let mut g = crate::substrate::Rng::new(11);
        let mut scratch = LutScratch::for_dims(24, 1);
        for &k in &[24usize, 7, 16, 24, 3] {
            let mut x = vec![0.0f32; k];
            g.fill_normal(&mut x, 1.0);
            let mut q = vec![0i8; k];
            act_quant_i8(&x, &mut q);
            let got = scratch.build(&q).to_vec();
            let mut fresh = vec![0i16; n_groups(k) * GROUP_TABLE];
            build_tables(&q, &mut fresh);
            assert_eq!(got, fresh, "k={k}");
        }
    }
}
