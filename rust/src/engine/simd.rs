//! Third-generation kernels: runtime-dispatched SIMD over the packed
//! ternary format, plus a SIMD f32 GEMV for the LM head / FP path.
//!
//! ## Ternary path
//!
//! The scalar generations decode packed bytes through a 256-entry trit
//! LUT ([`super::gemv`]) or pre-expand per-activation-group tables
//! ([`super::lut`]). The SIMD generation decodes **in registers**: two
//! fixed 16-entry nibble->trit tables are applied with a byte shuffle
//! (`pshufb` on x86, `tbl` on aarch64), the four trit streams are
//! interleaved back into activation order, and products accumulate in
//! i32 lanes. Integer addition is exact and order-free, so the result
//! is **bitwise identical** to [`super::gemv::ternary_row_dot`] — and
//! therefore to the LUT kernel, which is pinned against the same
//! reference — for every input, including `q = -128` (products are
//! widened to i16 before summing; nothing saturates).
//!
//! The vector loop covers whole 16-byte blocks (64 activations) of the
//! fully-covered prefix; the remainder and the ragged tail byte run
//! through the scalar reference itself, so tail bits match by
//! construction. On hosts without the required features the block count
//! is zero and the whole row runs scalar: the fallback is the reference,
//! not an approximation of it.
//!
//! ## f32 path
//!
//! [`dot4_f32`] evaluates exactly the fixed-width blocked reduction of
//! [`super::gemv::dot4`]: four independent lane accumulators over
//! chunks of 4, one multiply and one add per element (never an FMA — a
//! fused multiply-add rounds once, not twice, and would move bits), a
//! left-associated horizontal sum `((l0 + l1) + l2) + l3`, then the
//! scalar tail. Each lane performs the same IEEE-754 single operations
//! in the same order as its scalar twin, so the SIMD dot is bitwise
//! identical to `dot4` at every length — the crate-wide determinism
//! contract extends over this generation unchanged.
//!
//! Dispatch: AVX2 via `is_x86_feature_detected!` on x86_64 (cached by
//! std), NEON unconditionally on aarch64 (a baseline feature of the
//! architecture), scalar everywhere else. [`ternary_simd_available`]
//! reports which of these the ternary path took; `bench --check` uses
//! it to decide between the perf gate and the parity-only gate.

use super::gemv::{dot4, ternary_row_dot, TernGemmScratch};
use super::ternary::TernaryMatrix;

/// Packed bytes consumed per vector block (64 activations).
const BLOCK_BYTES: usize = 16;
/// Activations consumed per vector block.
const BLOCK_ACTS: usize = 4 * BLOCK_BYTES;

/// Nibble -> trit of the low 2-bit field. Applied to a byte's low
/// nibble this decodes trit slot 0, to its high nibble slot 2
/// (encoding: `0b01` -> +1, `0b10` -> -1, else 0; see
/// [`super::ternary::trit_lut`]).
#[allow(dead_code)] // scalar-only hosts never reference the tables
const NIBBLE_TRIT_EVEN: [i8; 16] = [0, 1, -1, 0, 0, 1, -1, 0, 0, 1, -1, 0, 0, 1, -1, 0];
/// Nibble -> trit of the high 2-bit field (slot 1 from the low nibble,
/// slot 3 from the high nibble).
#[allow(dead_code)]
const NIBBLE_TRIT_ODD: [i8; 16] = [0, 0, 0, 0, 1, 1, 1, 1, -1, -1, -1, -1, 0, 0, 0, 0];

/// `true` when the vector ternary path is active on this host: AVX2 on
/// x86_64 (runtime-detected), NEON on aarch64 (baseline). `false` means
/// [`KernelKind::Simd`](super::KernelKind) runs the scalar reference —
/// same bits, no speedup.
#[cfg(target_arch = "x86_64")]
pub fn ternary_simd_available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// NEON is a baseline aarch64 feature: the vector path is always on.
#[cfg(target_arch = "aarch64")]
pub fn ternary_simd_available() -> bool {
    true
}

/// No vector ternary kernel for this architecture: always the scalar
/// reference (bitwise-identical by construction).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn ternary_simd_available() -> bool {
    false
}

/// i32 dot of one packed ternary row against one quantized activation —
/// the SIMD twin of [`ternary_row_dot`], bitwise identical to it on
/// every host. `full` = `cols / 4`, exactly as for the scalar kernel.
#[inline]
pub(crate) fn simd_row_dot(row: &[u8], q: &[i8], full: usize) -> i32 {
    let blocks = if ternary_simd_available() { full / BLOCK_BYTES } else { 0 };
    let head = dot_blocks(row, q, blocks);
    head + ternary_row_dot(&row[blocks * BLOCK_BYTES..], &q[blocks * BLOCK_ACTS..], full - blocks * BLOCK_BYTES)
}

/// Vector-accumulate `blocks` whole 16-byte blocks of `row` against
/// `q`; the caller adds the scalar remainder. Returns 0 when there is
/// nothing to do (or, defensively, when the host lacks the features —
/// the caller computes `blocks = 0` in that case anyway).
#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_blocks(row: &[u8], q: &[i8], blocks: usize) -> i32 {
    if blocks == 0 || !ternary_simd_available() {
        return 0;
    }
    // SAFETY: AVX2 presence was checked at runtime on the line above,
    // and `simd_row_dot` derives `blocks` from `full <= row.len()`, so
    // every 16-byte row load and 64-byte activation load below stays in
    // bounds.
    unsafe { dot_blocks_avx2(row, q, blocks) }
}

/// # Safety
/// Caller must ensure AVX2 is available, `row.len() >= blocks * 16`,
/// and `q.len() >= blocks * 64`. All loads are unaligned.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_blocks_avx2(row: &[u8], q: &[i8], blocks: usize) -> i32 {
    use std::arch::x86_64::*;
    debug_assert!(row.len() >= blocks * BLOCK_BYTES);
    debug_assert!(q.len() >= blocks * BLOCK_ACTS);
    let mask0f = _mm_set1_epi8(0x0F);
    let tab_even = _mm_loadu_si128(NIBBLE_TRIT_EVEN.as_ptr() as *const __m128i);
    let tab_odd = _mm_loadu_si128(NIBBLE_TRIT_ODD.as_ptr() as *const __m128i);
    let zero = _mm_setzero_si128();
    let mut acc = _mm_setzero_si128();
    for blk in 0..blocks {
        let bytes = _mm_loadu_si128(row.as_ptr().add(blk * BLOCK_BYTES) as *const __m128i);
        let lo = _mm_and_si128(bytes, mask0f);
        // 16-bit shift leaks the neighbour byte's low bits into the
        // high nibble positions; the mask removes them.
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(bytes), mask0f);
        // per-byte trits for slots 0..3 (shuffle indices are 0..15, so
        // the pshufb zeroing-MSB rule never triggers)
        let t0 = _mm_shuffle_epi8(tab_even, lo);
        let t1 = _mm_shuffle_epi8(tab_odd, lo);
        let t2 = _mm_shuffle_epi8(tab_even, hi);
        let t3 = _mm_shuffle_epi8(tab_odd, hi);
        // interleave the four slot streams back into activation order:
        // u0 covers q[0..16] (bytes 0..3), u1 q[16..32], ...
        let ab_lo = _mm_unpacklo_epi8(t0, t1);
        let ab_hi = _mm_unpackhi_epi8(t0, t1);
        let cd_lo = _mm_unpacklo_epi8(t2, t3);
        let cd_hi = _mm_unpackhi_epi8(t2, t3);
        let us = [
            _mm_unpacklo_epi16(ab_lo, cd_lo),
            _mm_unpackhi_epi16(ab_lo, cd_lo),
            _mm_unpacklo_epi16(ab_hi, cd_hi),
            _mm_unpackhi_epi16(ab_hi, cd_hi),
        ];
        for (j, &u) in us.iter().enumerate() {
            let qv = _mm_loadu_si128(q.as_ptr().add(blk * BLOCK_ACTS + j * 16) as *const __m128i);
            // widen both operands to i16 before multiplying: |t*q| <= 128
            // is exact in i16 (the sign-trick alternative saturates at
            // q = -128), and pmaddwd's pairwise i32 sums are exact too
            let q_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(zero, qv));
            let q_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(zero, qv));
            let u_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(zero, u));
            let u_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(zero, u));
            acc = _mm_add_epi32(acc, _mm_madd_epi16(q_lo, u_lo));
            acc = _mm_add_epi32(acc, _mm_madd_epi16(q_hi, u_hi));
        }
    }
    let mut lanes = [0i32; 4];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc);
    // i32 addition is exact: lane order cannot move a bit
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

/// Vector-accumulate `blocks` whole 16-byte blocks (NEON twin).
#[cfg(target_arch = "aarch64")]
#[inline]
fn dot_blocks(row: &[u8], q: &[i8], blocks: usize) -> i32 {
    if blocks == 0 {
        return 0;
    }
    // SAFETY: NEON is a baseline aarch64 target feature, and
    // `simd_row_dot` derives `blocks` from `full <= row.len()`, so every
    // 16-byte row load and 64-byte activation load below stays in bounds.
    unsafe { dot_blocks_neon(row, q, blocks) }
}

/// # Safety
/// Caller must ensure `row.len() >= blocks * 16` and
/// `q.len() >= blocks * 64`. All loads are unaligned.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_blocks_neon(row: &[u8], q: &[i8], blocks: usize) -> i32 {
    use std::arch::aarch64::*;
    debug_assert!(row.len() >= blocks * BLOCK_BYTES);
    debug_assert!(q.len() >= blocks * BLOCK_ACTS);
    let tab_even = vld1q_s8(NIBBLE_TRIT_EVEN.as_ptr());
    let tab_odd = vld1q_s8(NIBBLE_TRIT_ODD.as_ptr());
    let mask0f = vdupq_n_u8(0x0F);
    let mut acc = vdupq_n_s32(0);
    for blk in 0..blocks {
        let bytes = vld1q_u8(row.as_ptr().add(blk * BLOCK_BYTES));
        let lo = vandq_u8(bytes, mask0f);
        let hi = vshrq_n_u8::<4>(bytes);
        let t0 = vqtbl1q_s8(tab_even, lo);
        let t1 = vqtbl1q_s8(tab_odd, lo);
        let t2 = vqtbl1q_s8(tab_even, hi);
        let t3 = vqtbl1q_s8(tab_odd, hi);
        // interleave the four slot streams back into activation order
        let ab_lo = vzip1q_s8(t0, t1);
        let ab_hi = vzip2q_s8(t0, t1);
        let cd_lo = vzip1q_s8(t2, t3);
        let cd_hi = vzip2q_s8(t2, t3);
        let us = [
            vreinterpretq_s8_s16(vzip1q_s16(vreinterpretq_s16_s8(ab_lo), vreinterpretq_s16_s8(cd_lo))),
            vreinterpretq_s8_s16(vzip2q_s16(vreinterpretq_s16_s8(ab_lo), vreinterpretq_s16_s8(cd_lo))),
            vreinterpretq_s8_s16(vzip1q_s16(vreinterpretq_s16_s8(ab_hi), vreinterpretq_s16_s8(cd_hi))),
            vreinterpretq_s8_s16(vzip2q_s16(vreinterpretq_s16_s8(ab_hi), vreinterpretq_s16_s8(cd_hi))),
        ];
        for (j, &u) in us.iter().enumerate() {
            let qv = vld1q_s8(q.as_ptr().add(blk * BLOCK_ACTS + j * 16));
            // widening i8 x i8 -> i16 multiplies are exact (|t*q| <= 128),
            // and the pairwise add-accumulate into i32 lanes is exact
            let p_lo = vmull_s8(vget_low_s8(u), vget_low_s8(qv));
            let p_hi = vmull_s8(vget_high_s8(u), vget_high_s8(qv));
            acc = vpadalq_s16(acc, p_lo);
            acc = vpadalq_s16(acc, p_hi);
        }
    }
    // i32 addition is exact: lane order cannot move a bit
    vaddvq_s32(acc)
}

/// Scalar-only architectures: no vector blocks, ever.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
#[inline]
fn dot_blocks(_row: &[u8], _q: &[i8], blocks: usize) -> i32 {
    debug_assert_eq!(blocks, 0);
    0
}

/// SIMD twin of [`super::gemv::gemv_ternary`] — identical signature,
/// identical dequant expression, bitwise-identical output on every host.
pub fn simd_gemv(m: &TernaryMatrix, q: &[i8], gamma: f32, y: &mut [f32]) {
    debug_assert_eq!(q.len(), m.cols);
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    let scale = (gamma / 127.0) * m.delta;
    let full = m.cols / 4;
    for (n, yn) in y.iter_mut().enumerate() {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        *yn = simd_row_dot(row, q, full) as f32 * scale;
    }
}

/// SIMD twin of [`super::gemv::gemm_ternary`]: `b` pre-quantized
/// activations (rows of `qs` at stride `m.cols`, one `gamma` each).
/// Per item this computes exactly [`simd_gemv`]'s bits — the in-register
/// decode is cheap enough that re-decoding per lane beats the scalar
/// kernels' decode-once-per-batch bookkeeping. `scratch` holds the
/// per-lane dequant scales (same discipline as the other generations).
pub fn simd_gemm(
    m: &TernaryMatrix,
    qs: &[i8],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(qs.len() >= b * m.cols);
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    for n in 0..m.rows {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        for bi in 0..b {
            let d = simd_row_dot(row, &qs[bi * m.cols..(bi + 1) * m.cols], full);
            ys[bi * m.rows + n] = d as f32 * scratch.scales[bi];
        }
    }
}

/// SIMD twin of [`dot4`], bitwise identical to it at every length: the
/// four vector lanes *are* `dot4`'s four accumulators, the horizontal
/// sum is the same left-associated `((l0 + l1) + l2) + l3`, and the tail
/// is the same scalar loop. SSE2 on x86_64 and NEON on aarch64 are
/// baseline features, so this needs no runtime dispatch.
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn dot4_f32(row: &[f32], x: &[f32]) -> f32 {
    // SAFETY: SSE2 is a baseline x86_64 target feature; the callee only
    // performs unaligned loads inside the slices' bounds.
    unsafe { dot4_sse2(row, x) }
}

/// # Safety
/// `row` and `x` must be the same length (debug-asserted); requires
/// SSE2, which is baseline on x86_64. All loads are unaligned.
#[cfg(target_arch = "x86_64")]
unsafe fn dot4_sse2(row: &[f32], x: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let k = x.len();
    debug_assert_eq!(row.len(), k);
    let chunks = k / 4;
    let mut accv = _mm_setzero_ps();
    for c in 0..chunks {
        let r = _mm_loadu_ps(row.as_ptr().add(c * 4));
        let v = _mm_loadu_ps(x.as_ptr().add(c * 4));
        // mul then add, never FMA: two roundings, exactly like the
        // scalar `acc_j += row[i] * x[i]`
        accv = _mm_add_ps(accv, _mm_mul_ps(r, v));
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), accv);
    let mut acc = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
    for i in chunks * 4..k {
        acc += row[i] * x[i];
    }
    acc
}

/// NEON twin of [`dot4`] (see the x86_64 variant for the contract).
#[cfg(target_arch = "aarch64")]
#[inline]
pub(crate) fn dot4_f32(row: &[f32], x: &[f32]) -> f32 {
    // SAFETY: NEON is a baseline aarch64 target feature; the callee only
    // performs unaligned loads inside the slices' bounds.
    unsafe { dot4_neon(row, x) }
}

/// # Safety
/// `row` and `x` must be the same length (debug-asserted); requires
/// NEON, which is baseline on aarch64. All loads are unaligned.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot4_neon(row: &[f32], x: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let k = x.len();
    debug_assert_eq!(row.len(), k);
    let chunks = k / 4;
    let mut accv = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let r = vld1q_f32(row.as_ptr().add(c * 4));
        let v = vld1q_f32(x.as_ptr().add(c * 4));
        // mul then add, never FMA (two roundings, like the scalar twin)
        accv = vaddq_f32(accv, vmulq_f32(r, v));
    }
    let l0 = vgetq_lane_f32::<0>(accv);
    let l1 = vgetq_lane_f32::<1>(accv);
    let l2 = vgetq_lane_f32::<2>(accv);
    let l3 = vgetq_lane_f32::<3>(accv);
    let mut acc = ((l0 + l1) + l2) + l3;
    for i in chunks * 4..k {
        acc += row[i] * x[i];
    }
    acc
}

/// Scalar-only architectures: the reference reduction *is* the kernel.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
#[inline]
pub(crate) fn dot4_f32(row: &[f32], x: &[f32]) -> f32 {
    dot4(row, x)
}

/// SIMD twin of [`super::gemv::gemv_f32`] (LM head, FP fallback path).
pub fn simd_gemv_f32(w: &[f32], n_out: usize, k_in: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(x.len(), k_in);
    debug_assert_eq!(y.len(), n_out);
    for (n, yn) in y.iter_mut().enumerate() {
        *yn = dot4_f32(&w[n * k_in..(n + 1) * k_in], x);
    }
}

/// SIMD twin of [`super::gemv::gemm_f32_shared`]: each weight row is
/// streamed once for the whole batch, each dot through [`dot4_f32`].
pub fn simd_gemm_f32_shared(
    w: &[f32],
    n_out: usize,
    k_in: usize,
    xs: &[f32],
    b: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert!(xs.len() >= b * k_in);
    debug_assert!(ys.len() >= b * n_out);
    for (n, rowv) in w.chunks_exact(k_in).enumerate() {
        for bi in 0..b {
            ys[bi * n_out + n] = dot4_f32(rowv, &xs[bi * k_in..(bi + 1) * k_in]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gemv::{gemm_f32_shared, gemm_ternary, gemv_f32, gemv_ternary};
    use crate::engine::lut::{lut_gemv, LutScratch};
    use crate::engine::ternary::act_quant_i8;
    use crate::substrate::prop;

    #[test]
    fn availability_probe_is_stable_and_matches_the_host() {
        let a = ternary_simd_available();
        assert_eq!(a, ternary_simd_available());
        #[cfg(target_arch = "x86_64")]
        assert_eq!(a, is_x86_feature_detected!("avx2"));
        #[cfg(target_arch = "aarch64")]
        assert!(a);
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(!a);
    }

    #[test]
    fn nibble_tables_match_the_byte_lut() {
        let lut = crate::engine::ternary::trit_lut();
        for byte in 0..256usize {
            let lo = byte & 0x0F;
            let hi = byte >> 4;
            let want = lut[byte];
            assert_eq!(NIBBLE_TRIT_EVEN[lo], want[0], "byte {byte:#04x} slot 0");
            assert_eq!(NIBBLE_TRIT_ODD[lo], want[1], "byte {byte:#04x} slot 1");
            assert_eq!(NIBBLE_TRIT_EVEN[hi], want[2], "byte {byte:#04x} slot 2");
            assert_eq!(NIBBLE_TRIT_ODD[hi], want[3], "byte {byte:#04x} slot 3");
        }
    }

    #[test]
    fn prop_simd_row_dot_is_bitwise_ternary_row_dot() {
        // k spans multiple 64-activation blocks plus every tail shape:
        // k % 64 != 0 (partial block), k % 4 != 0 (ragged byte), k < 64
        // (no vector block at all — the forced-fallback geometry)
        prop::check("simd-row-dot", 60, |g| {
            let k = g.usize(1, 300);
            let w = g.normal_vec(k, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, 1);
            let x = g.normal_vec(k, 1.0);
            let mut q = vec![0i8; k];
            act_quant_i8(&x, &mut q);
            let row = &m.packed[..m.bytes_per_row()];
            assert_eq!(simd_row_dot(row, &q, k / 4), ternary_row_dot(row, &q, k / 4), "k={k}");
        });
    }

    #[test]
    fn simd_row_dot_survives_q_extremes() {
        // -128 has no i8 negation — the vector path must widen before
        // multiplying (a sign-flip shortcut would saturate and drift)
        for k in [64usize, 65, 96, 127, 128, 193] {
            let w: Vec<f32> =
                (0..k).map(|i| [0.5f32, -0.5, 0.0, 0.5][i % 4] * [1.0f32, -1.0][i % 2]).collect();
            let m = TernaryMatrix::from_xw_f32(&w, k, 1);
            let q: Vec<i8> = (0..k).map(|i| [-128i8, 127, -128, 7][i % 4]).collect();
            let row = &m.packed[..m.bytes_per_row()];
            assert_eq!(simd_row_dot(row, &q, k / 4), ternary_row_dot(row, &q, k / 4), "k={k}");
        }
    }

    #[test]
    fn prop_simd_gemv_is_bitwise_lut_and_byte_decode() {
        prop::check("simd-gemv", 40, |g| {
            let k = g.usize(4, 200);
            let n = g.usize(1, 48); // includes rows < vector lanes
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.5);
            let mut q = vec![0i8; k];
            let gamma = act_quant_i8(&x, &mut q);
            let mut want = vec![0.0f32; n];
            gemv_ternary(&m, &q, gamma, &mut want);
            let mut scratch = LutScratch::new();
            let table = scratch.build(&q);
            let mut want_lut = vec![0.0f32; n];
            lut_gemv(&m, table, gamma, &mut want_lut);
            let mut y = vec![0.0f32; n];
            simd_gemv(&m, &q, gamma, &mut y);
            let same_byte = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
            let same_lut = y.iter().zip(&want_lut).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same_byte && same_lut, "k={k} n={n}");
        });
    }

    #[test]
    fn prop_simd_gemm_is_bitwise_gemm_ternary() {
        prop::check("simd-gemm", 30, |g| {
            let b = g.usize(1, 5);
            let k = g.usize(4, 150);
            let n = g.usize(1, 30);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] = act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut want = vec![0.0f32; b * n];
            gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut TernGemmScratch::new());
            let mut ys = vec![0.0f32; b * n];
            simd_gemm(&m, &qs, &gammas, b, &mut ys, &mut TernGemmScratch::new());
            let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
            assert!(same, "b={b} k={k} n={n}");
        });
    }

    #[test]
    fn forced_scalar_fallback_is_the_dispatched_result() {
        // What an unsupported host computes is blocks = 0, i.e. the pure
        // scalar reference. Pin the dispatched result (vector path on
        // supporting hosts) to exactly those bits, so flipping a host's
        // detection can never flip an output bit.
        let mut g = crate::substrate::Rng::new(23);
        let k = 200;
        let mut w = vec![0.0f32; k];
        g.fill_normal(&mut w, 0.05);
        let m = TernaryMatrix::from_xw_f32(&w, k, 1);
        let mut x = vec![0.0f32; k];
        g.fill_normal(&mut x, 1.0);
        let mut q = vec![0i8; k];
        act_quant_i8(&x, &mut q);
        let row = &m.packed[..m.bytes_per_row()];
        let fallback = ternary_row_dot(row, &q, k / 4);
        assert_eq!(simd_row_dot(row, &q, k / 4), fallback);
    }

    #[test]
    fn prop_dot4_f32_is_bitwise_dot4() {
        prop::check("simd-dot4-f32", 60, |g| {
            let k = g.usize(1, 200); // covers % 4 tails and sub-chunk sizes
            let r = g.normal_vec(k, 1.0);
            let x = g.normal_vec(k, 1.0);
            assert_eq!(dot4_f32(&r, &x).to_bits(), dot4(&r, &x).to_bits(), "k={k}");
        });
    }

    #[test]
    fn dot4_f32_empty_is_zero() {
        assert_eq!(dot4_f32(&[], &[]).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn prop_simd_gemv_f32_is_bitwise_gemv_f32() {
        prop::check("simd-gemv-f32", 30, |g| {
            let n = g.usize(1, 40);
            let k = g.usize(1, 130);
            let w = g.normal_vec(n * k, 1.0);
            let x = g.normal_vec(k, 1.0);
            let mut want = vec![0.0f32; n];
            gemv_f32(&w, n, k, &x, &mut want);
            let mut y = vec![0.0f32; n];
            simd_gemv_f32(&w, n, k, &x, &mut y);
            let same = y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "n={n} k={k}");
        });
    }

    #[test]
    fn prop_simd_gemm_f32_shared_is_bitwise_gemm_f32_shared() {
        prop::check("simd-gemm-f32-shared", 30, |g| {
            let b = g.usize(1, 6);
            let n = g.usize(1, 40);
            let k = g.usize(1, 70);
            let w = g.normal_vec(n * k, 1.0);
            let xs = g.normal_vec(b * k, 1.0);
            let mut want = vec![0.0f32; b * n];
            gemm_f32_shared(&w, n, k, &xs, b, &mut want);
            let mut ys = vec![0.0f32; b * n];
            simd_gemm_f32_shared(&w, n, k, &xs, b, &mut ys);
            let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
            assert!(same, "b={b} n={n} k={k}");
        });
    }
}
