//! GEMV kernels — the inference hot path.
//!
//! `gemv_f32` is the FP baseline (the paper's "FP16" row; f32 here — this
//! testbed's x86 core has no fp16 ALU, see DESIGN.md). `gemv_ternary` is
//! the W1.58A8 kernel: int8 activations x LUT-decoded trits with i32
//! accumulation (exact), one dequant multiply per output row.

use super::ternary::{trit_lut, TernaryMatrix};

/// y[n] = sum_k w[n, k] * x[k]; `w` row-major [n_out, k_in].
pub fn gemv_f32(w: &[f32], n_out: usize, k_in: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(x.len(), k_in);
    debug_assert_eq!(y.len(), n_out);
    for (n, yn) in y.iter_mut().enumerate() {
        let row = &w[n * k_in..(n + 1) * k_in];
        // 4-way unrolled dot product: the compiler auto-vectorizes this
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = k_in / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc0 += row[i] * x[i];
            acc1 += row[i + 1] * x[i + 1];
            acc2 += row[i + 2] * x[i + 2];
            acc3 += row[i + 3] * x[i + 3];
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for i in chunks * 4..k_in {
            acc += row[i] * x[i];
        }
        *yn = acc;
    }
}

/// y = (gamma/127) * delta * (trits . q); `q` is the int8-quantized token.
pub fn gemv_ternary(m: &TernaryMatrix, q: &[i8], gamma: f32, y: &mut [f32]) {
    debug_assert_eq!(q.len(), m.cols);
    debug_assert_eq!(y.len(), m.rows);
    let lut = trit_lut();
    let bpr = m.bytes_per_row();
    let scale = (gamma / 127.0) * m.delta;
    let full = m.cols / 4; // bytes fully covered by q
    for (n, yn) in y.iter_mut().enumerate() {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        // NOTE(perf): a dual-accumulator 2-byte unroll was tried here and
        // measured *slower* uncontended (1.2-1.6x vs 1.8-2.2x over f32) —
        // the single-accumulator form lets LLVM vectorize the LUT gather
        // better; see EXPERIMENTS.md §Perf.
        let mut acc: i32 = 0;
        for (b, qq) in row[..full].iter().zip(q.chunks_exact(4)) {
            let t = &lut[*b as usize];
            acc += t[0] as i32 * qq[0] as i32
                + t[1] as i32 * qq[1] as i32
                + t[2] as i32 * qq[2] as i32
                + t[3] as i32 * qq[3] as i32;
        }
        // tail (cols not divisible by 4)
        if full < bpr {
            let t = &lut[row[full] as usize];
            for (s, &qv) in q[full * 4..].iter().enumerate() {
                acc += t[s] as i32 * qv as i32;
            }
        }
        *yn = acc as f32 * scale;
    }
}

/// Multi-token f32 matmul for prefill: x [t, k] row-major -> y [t, n].
pub fn gemm_f32(w: &[f32], n_out: usize, k_in: usize, x: &[f32], t: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(y.len(), t * n_out);
    for ti in 0..t {
        gemv_f32(w, n_out, k_in, &x[ti * k_in..(ti + 1) * k_in], &mut y[ti * n_out..(ti + 1) * n_out]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    fn naive_f32(w: &[f32], n: usize, k: usize, x: &[f32]) -> Vec<f32> {
        (0..n)
            .map(|r| (0..k).map(|c| w[r * k + c] * x[c]).sum())
            .collect()
    }

    #[test]
    fn prop_gemv_f32_matches_naive() {
        prop::check("gemv-f32", 50, |g| {
            let n = g.usize(1, 64);
            let k = g.usize(1, 130);
            let w = g.normal_vec(n * k, 1.0);
            let x = g.normal_vec(k, 1.0);
            let mut y = vec![0.0; n];
            gemv_f32(&w, n, k, &x, &mut y);
            let want = naive_f32(&w, n, k, &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn prop_gemv_ternary_matches_dequantized_f32() {
        prop::check("gemv-ternary", 40, |g| {
            let k = g.usize(4, 96);
            let n = g.usize(1, 48);
            let w = g.normal_vec(k * n, 0.05); // [in, out] layout
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.5);
            let mut q = vec![0i8; k];
            let gamma = super::super::ternary::act_quant_i8(&x, &mut q);
            let mut y = vec![0.0; n];
            gemv_ternary(&m, &q, gamma, &mut y);
            // reference: dequantized trits x dequantized acts in f64
            for row in 0..n {
                let wrow = m.row_f32(row);
                let want: f64 = wrow
                    .iter()
                    .zip(&q)
                    .map(|(&wv, &qv)| wv as f64 * (qv as f64 * gamma as f64 / 127.0))
                    .sum();
                assert!(
                    (y[row] as f64 - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "row {row}: {} vs {want}",
                    y[row]
                );
            }
        });
    }

    #[test]
    fn gemm_matches_per_token_gemv() {
        let mut g = crate::substrate::Rng::new(8);
        let (t, k, n) = (3, 16, 8);
        let mut w = vec![0.0; n * k];
        let mut x = vec![0.0; t * k];
        g.fill_normal(&mut w, 1.0);
        g.fill_normal(&mut x, 1.0);
        let mut y = vec![0.0; t * n];
        gemm_f32(&w, n, k, &x, t, &mut y);
        for ti in 0..t {
            let mut yt = vec![0.0; n];
            gemv_f32(&w, n, k, &x[ti * k..(ti + 1) * k], &mut yt);
            assert_eq!(&y[ti * n..(ti + 1) * n], &yt[..]);
        }
    }
}
