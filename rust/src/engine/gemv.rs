//! GEMV kernels — the inference hot path.
//!
//! `gemv_f32` is the FP baseline (the paper's "FP16" row; f32 here — this
//! testbed's x86 core has no fp16 ALU, see DESIGN.md). `gemv_ternary` is
//! the W1.58A8 kernel: int8 activations x LUT-decoded trits with i32
//! accumulation (exact), one dequant multiply per output row.

use super::ternary::{trit_lut, TernaryMatrix};

/// 4-way unrolled dot product (the compiler auto-vectorizes this).
/// The one accumulation order of every f32 matvec in this crate: the
/// serial kernels below and the [`crate::parallel`] row-partitioned
/// kernels all go through it, so serial/batched/parallel results are
/// bitwise identical per output element by construction.
#[inline]
pub(crate) fn dot4(row: &[f32], x: &[f32]) -> f32 {
    let k = x.len();
    debug_assert_eq!(row.len(), k);
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = k / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += row[i] * x[i];
        acc1 += row[i + 1] * x[i + 1];
        acc2 += row[i + 2] * x[i + 2];
        acc3 += row[i + 3] * x[i + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks * 4..k {
        acc += row[i] * x[i];
    }
    acc
}

/// i32 dot of one packed ternary row against one quantized activation.
/// `full` = `cols / 4` (bytes fully covered by `q`); the trailing byte,
/// if any, handles cols not divisible by 4. Integer accumulation is
/// order-exact, so every caller — serial, batched, or parallel — gets
/// identical bits from identical inputs.
///
/// NOTE(perf): a dual-accumulator 2-byte unroll was tried here and
/// measured *slower* uncontended (1.2-1.6x vs 1.8-2.2x over f32) —
/// the single-accumulator form lets LLVM vectorize the LUT gather
/// better; see EXPERIMENTS.md §Perf.
#[inline]
pub(crate) fn ternary_row_dot(row: &[u8], q: &[i8], full: usize) -> i32 {
    let lut = trit_lut();
    let mut acc: i32 = 0;
    for (b, qq) in row[..full].iter().zip(q.chunks_exact(4)) {
        let t = &lut[*b as usize];
        acc += t[0] as i32 * qq[0] as i32
            + t[1] as i32 * qq[1] as i32
            + t[2] as i32 * qq[2] as i32
            + t[3] as i32 * qq[3] as i32;
    }
    if full < row.len() {
        let t = &lut[row[full] as usize];
        for (s, &qv) in q[full * 4..].iter().enumerate() {
            acc += t[s] as i32 * qv as i32;
        }
    }
    acc
}

/// Batched twin of [`ternary_row_dot`]: one packed row against `b`
/// quantized activations (rows of `qs` at stride `cols`), byte-major so
/// each packed byte is LUT-decoded **once** for the whole batch.
/// Per item this adds exactly the products of [`ternary_row_dot`]
/// (i32 math is order-exact), so the two are interchangeable bit for
/// bit. Results land in `acc[..b]` (reset here).
#[inline]
pub(crate) fn ternary_row_dot_batch(
    row: &[u8],
    qs: &[i8],
    cols: usize,
    b: usize,
    full: usize,
    acc: &mut [i32],
) {
    let lut = trit_lut();
    acc[..b].iter_mut().for_each(|a| *a = 0);
    for (ci, byte) in row[..full].iter().enumerate() {
        let t = &lut[*byte as usize];
        let base = ci * 4;
        for (bi, a) in acc[..b].iter_mut().enumerate() {
            let q = &qs[bi * cols + base..bi * cols + base + 4];
            *a += t[0] as i32 * q[0] as i32
                + t[1] as i32 * q[1] as i32
                + t[2] as i32 * q[2] as i32
                + t[3] as i32 * q[3] as i32;
        }
    }
    if full < row.len() {
        let t = &lut[row[full] as usize];
        for (bi, a) in acc[..b].iter_mut().enumerate() {
            let tail = &qs[bi * cols + full * 4..bi * cols + cols];
            for (s, &qv) in tail.iter().enumerate() {
                *a += t[s] as i32 * qv as i32;
            }
        }
    }
}

/// Caller-owned scratch for the batched ternary kernels
/// ([`gemm_ternary`], [`crate::parallel::par_gemm_ternary`],
/// [`super::lut::lut_gemm`]): the per-lane dequant scales and i32
/// accumulators. These used to be two `Vec` allocations **per matrix
/// per decode step** inside `gemm_ternary`; hoisting them here makes
/// the serve decode loop allocation-free (the scratch lives in
/// [`crate::engine::BatchScratch`] and grows only on the first call at
/// a new batch size).
pub struct TernGemmScratch {
    pub(crate) scales: Vec<f32>,
    pub(crate) acc: Vec<i32>,
}

impl TernGemmScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> TernGemmScratch {
        TernGemmScratch { scales: Vec::new(), acc: Vec::new() }
    }

    /// Preallocated for batches up to `max_b`.
    pub fn for_batch(max_b: usize) -> TernGemmScratch {
        TernGemmScratch { scales: vec![0.0; max_b], acc: vec![0; max_b] }
    }

    pub(crate) fn ensure(&mut self, b: usize) {
        if self.scales.len() < b {
            self.scales.resize(b, 0.0);
        }
        if self.acc.len() < b {
            self.acc.resize(b, 0);
        }
    }
}

impl Default for TernGemmScratch {
    fn default() -> TernGemmScratch {
        TernGemmScratch::new()
    }
}

/// y[n] = sum_k w[n, k] * x[k]; `w` row-major [n_out, k_in].
pub fn gemv_f32(w: &[f32], n_out: usize, k_in: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert_eq!(x.len(), k_in);
    debug_assert_eq!(y.len(), n_out);
    for (n, yn) in y.iter_mut().enumerate() {
        *yn = dot4(&w[n * k_in..(n + 1) * k_in], x);
    }
}

/// y = (gamma/127) * delta * (trits . q); `q` is the int8-quantized token.
pub fn gemv_ternary(m: &TernaryMatrix, q: &[i8], gamma: f32, y: &mut [f32]) {
    debug_assert_eq!(q.len(), m.cols);
    debug_assert_eq!(y.len(), m.rows);
    let bpr = m.bytes_per_row();
    let scale = (gamma / 127.0) * m.delta;
    let full = m.cols / 4; // bytes fully covered by q
    for (n, yn) in y.iter_mut().enumerate() {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        *yn = ternary_row_dot(row, q, full) as f32 * scale;
    }
}

/// Multi-token f32 matmul for prefill: x [t, k] row-major -> y [t, n].
pub fn gemm_f32(w: &[f32], n_out: usize, k_in: usize, x: &[f32], t: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), t * k_in);
    debug_assert_eq!(y.len(), t * n_out);
    for ti in 0..t {
        gemv_f32(w, n_out, k_in, &x[ti * k_in..(ti + 1) * k_in], &mut y[ti * n_out..(ti + 1) * n_out]);
    }
}

/// Batched decode GEMM: y[bi] = W x[bi] for `b` independent activations,
/// streaming each weight row once for the whole batch (the row stays in
/// L1 across the `b` dot products, so weight memory traffic is amortized
/// b-fold vs per-request `gemv_f32`). Per item the accumulation is the
/// exact code of [`gemv_f32`], so batch=1 results are bitwise identical —
/// the serve-layer parity test relies on this.
pub fn gemm_f32_shared(w: &[f32], n_out: usize, k_in: usize, xs: &[f32], b: usize, ys: &mut [f32]) {
    debug_assert_eq!(w.len(), n_out * k_in);
    debug_assert!(xs.len() >= b * k_in);
    debug_assert!(ys.len() >= b * n_out);
    for (n, row) in w.chunks_exact(k_in).enumerate() {
        for bi in 0..b {
            ys[bi * n_out + n] = dot4(row, &xs[bi * k_in..(bi + 1) * k_in]);
        }
    }
}

/// Batched ternary GEMM over `b` pre-quantized activations (`qs` rows at
/// stride `m.cols`, one `gamma` per row). Each packed weight byte is
/// LUT-decoded **once** per output row and applied to every batch item,
/// amortizing both the packed-weight traffic and the trit decode b-fold —
/// this is where continuous batching beats sequential decode on CPU.
/// The i32 accumulation per item adds exactly the same products as
/// [`gemv_ternary`] (integer math is order-exact), and the dequant scale
/// uses the same expression, so batch=1 is bitwise identical.
/// `scratch` holds the per-lane scales/accumulators (caller-owned, see
/// [`TernGemmScratch`]) — reusing one scratch across calls changes no
/// bits (regression-tested below).
pub fn gemm_ternary(
    m: &TernaryMatrix,
    qs: &[i8],
    gammas: &[f32],
    b: usize,
    ys: &mut [f32],
    scratch: &mut TernGemmScratch,
) {
    debug_assert!(qs.len() >= b * m.cols);
    debug_assert!(gammas.len() >= b);
    debug_assert!(ys.len() >= b * m.rows);
    let bpr = m.bytes_per_row();
    let full = m.cols / 4;
    scratch.ensure(b);
    for bi in 0..b {
        scratch.scales[bi] = (gammas[bi] / 127.0) * m.delta;
    }
    for n in 0..m.rows {
        let row = &m.packed[n * bpr..(n + 1) * bpr];
        ternary_row_dot_batch(row, qs, m.cols, b, full, &mut scratch.acc);
        for bi in 0..b {
            ys[bi * m.rows + n] = scratch.acc[bi] as f32 * scratch.scales[bi];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop;

    fn naive_f32(w: &[f32], n: usize, k: usize, x: &[f32]) -> Vec<f32> {
        (0..n)
            .map(|r| (0..k).map(|c| w[r * k + c] * x[c]).sum())
            .collect()
    }

    #[test]
    fn prop_gemv_f32_matches_naive() {
        prop::check("gemv-f32", 50, |g| {
            let n = g.usize(1, 64);
            let k = g.usize(1, 130);
            let w = g.normal_vec(n * k, 1.0);
            let x = g.normal_vec(k, 1.0);
            let mut y = vec![0.0; n];
            gemv_f32(&w, n, k, &x, &mut y);
            let want = naive_f32(&w, n, k, &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn prop_gemv_ternary_matches_dequantized_f32() {
        prop::check("gemv-ternary", 40, |g| {
            let k = g.usize(4, 96);
            let n = g.usize(1, 48);
            let w = g.normal_vec(k * n, 0.05); // [in, out] layout
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let x = g.normal_vec(k, 1.5);
            let mut q = vec![0i8; k];
            let gamma = super::super::ternary::act_quant_i8(&x, &mut q);
            let mut y = vec![0.0; n];
            gemv_ternary(&m, &q, gamma, &mut y);
            // reference: dequantized trits x dequantized acts in f64
            for row in 0..n {
                let wrow = m.row_f32(row);
                let want: f64 = wrow
                    .iter()
                    .zip(&q)
                    .map(|(&wv, &qv)| wv as f64 * (qv as f64 * gamma as f64 / 127.0))
                    .sum();
                assert!(
                    (y[row] as f64 - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "row {row}: {} vs {want}",
                    y[row]
                );
            }
        });
    }

    #[test]
    fn prop_gemm_f32_shared_is_bitwise_gemv() {
        prop::check("gemm-f32-shared", 40, |g| {
            let b = g.usize(1, 6);
            let n = g.usize(1, 40);
            let k = g.usize(1, 70);
            let w = g.normal_vec(n * k, 1.0);
            let xs = g.normal_vec(b * k, 1.0);
            let mut ys = vec![0.0; b * n];
            gemm_f32_shared(&w, n, k, &xs, b, &mut ys);
            for bi in 0..b {
                let mut want = vec![0.0; n];
                gemv_f32(&w, n, k, &xs[bi * k..(bi + 1) * k], &mut want);
                assert_eq!(&ys[bi * n..(bi + 1) * n], &want[..], "item {bi}");
            }
        });
    }

    #[test]
    fn prop_gemm_ternary_is_bitwise_gemv() {
        prop::check("gemm-ternary-batch", 40, |g| {
            let b = g.usize(1, 5);
            let k = g.usize(4, 70); // includes non-multiple-of-4 tails
            let n = g.usize(1, 30);
            let w = g.normal_vec(k * n, 0.05);
            let m = TernaryMatrix::from_xw_f32(&w, k, n);
            let mut qs = vec![0i8; b * k];
            let mut gammas = vec![0.0f32; b];
            for bi in 0..b {
                let x = g.normal_vec(k, 1.0);
                gammas[bi] =
                    super::super::ternary::act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
            }
            let mut ys = vec![0.0; b * n];
            gemm_ternary(&m, &qs, &gammas, b, &mut ys, &mut TernGemmScratch::new());
            for bi in 0..b {
                let mut want = vec![0.0; n];
                gemv_ternary(&m, &qs[bi * k..(bi + 1) * k], gammas[bi], &mut want);
                assert_eq!(&ys[bi * n..(bi + 1) * n], &want[..], "item {bi}");
            }
        });
    }

    #[test]
    fn prop_gemm_ternary_scratch_reuse_is_bitwise_stable() {
        // regression for the alloc hoist: one TernGemmScratch reused
        // across calls of varying batch size (the serve decode loop's
        // usage) must produce exactly the bits a fresh scratch produces
        prop::check("gemm-ternary-scratch-reuse", 20, |g| {
            let mut reused = TernGemmScratch::for_batch(2);
            for _ in 0..4 {
                let b = g.usize(1, 5);
                let k = g.usize(4, 50);
                let n = g.usize(1, 20);
                let w = g.normal_vec(k * n, 0.05);
                let m = TernaryMatrix::from_xw_f32(&w, k, n);
                let mut qs = vec![0i8; b * k];
                let mut gammas = vec![0.0f32; b];
                for bi in 0..b {
                    let x = g.normal_vec(k, 1.0);
                    gammas[bi] =
                        super::super::ternary::act_quant_i8(&x, &mut qs[bi * k..(bi + 1) * k]);
                }
                let mut want = vec![0.0; b * n];
                gemm_ternary(&m, &qs, &gammas, b, &mut want, &mut TernGemmScratch::new());
                let mut ys = vec![0.0; b * n];
                gemm_ternary(&m, &qs, &gammas, b, &mut ys, &mut reused);
                let same = ys.iter().zip(&want).all(|(a, c)| a.to_bits() == c.to_bits());
                assert!(same, "b={b} k={k} n={n}");
            }
        });
    }

    #[test]
    fn gemm_matches_per_token_gemv() {
        let mut g = crate::substrate::Rng::new(8);
        let (t, k, n) = (3, 16, 8);
        let mut w = vec![0.0; n * k];
        let mut x = vec![0.0; t * k];
        g.fill_normal(&mut w, 1.0);
        g.fill_normal(&mut x, 1.0);
        let mut y = vec![0.0; t * n];
        gemm_f32(&w, n, k, &x, t, &mut y);
        for ti in 0..t {
            let mut yt = vec![0.0; n];
            gemv_f32(&w, n, k, &x[ti * k..(ti + 1) * k], &mut yt);
            assert_eq!(&y[ti * n..(ti + 1) * n], &yt[..]);
        }
    }
}
