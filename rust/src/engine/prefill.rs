//! Chunked multi-token prefill — time-batched GEMMs over the prompt
//! axis, bitwise-pinned to the sequential decode path.
//!
//! The serve scheduler used to feed prompts **one token per engine
//! step**: a 256-token prompt cost 256 sequential GEMV sweeps, each one
//! ending in a full `d_model x vocab` LM-head matvec whose logits were
//! thrown away (only the final prompt position's logits are ever
//! consumed). Prefill is compute-bound and embarrassingly batchable
//! along the time axis, so this module stacks up to C prompt tokens as
//! rows of one activation matrix and drives the *existing* batch GEMM
//! kernels over them ([`crate::engine::gemv::gemm_f32_shared`] /
//! [`crate::engine::gemv::gemm_ternary`] / [`crate::engine::lut::lut_gemm`],
//! thread-fanned by [`crate::parallel::gemm`]) — so every weight row is
//! streamed once per chunk instead of once per token, LUT tables are
//! built once per chunk per activation width, and the LM head runs
//! **once per prompt** — only the chunk holding the final prompt token
//! computes it, for that position alone ([`HeadMode`]); interior
//! chunks skip the vocab GEMV outright, saving `(P-1) * d * vocab` f32
//! MACs over a P-token prompt.
//!
//! ## Determinism contract (non-negotiable, property-test-enforced)
//!
//! Chunking is a pure throughput knob: after prefilling through chunks
//! of any size, the KV-cache contents and the final-position logits are
//! **bitwise identical** to feeding the same tokens one at a time
//! through [`Engine::decode_step`]. This holds by construction:
//!
//! - every per-position op (rmsnorm, RoPE, attention, SiLU/GeLU,
//!   residual adds, activation quantization) applies exactly the
//!   arithmetic of the sequential path, row by row;
//! - the batch GEMMs are bitwise identical per row to their GEMV twins
//!   (pinned in [`crate::engine::gemv`] / [`crate::engine::lut`] /
//!   [`crate::parallel::gemm`]), with the serial accumulation order
//!   preserved per output element via the shared `dot4` /
//!   `ternary_row_dot` cores;
//! - attention is causal *within* the chunk: all C K/V rows are
//!   appended to the lane's slot first, then position `pos0 + i`
//!   attends over cache entries `0..=pos0+i` only — reading exactly
//!   the values the sequential path would have seen.
//!
//! The tests below pin this at chunk sizes {1, 2, 3, 5, 8} x threads
//! {1, 4} x all three kernel generations x both engine modes, for the
//! KV cache and the logits; `serve::scheduler` re-pins it end-to-end
//! (server responses with `--prefill-chunk` on vs off are equal).
//!
//! ## Known trade-offs (deliberate, candidates for a later PR)
//!
//! - This is a third hand-written transformer forward next to
//!   [`Engine::decode_step`] and [`Engine::decode_step_batch`]. The
//!   bitwise property tests pin all three to each other, so drift is
//!   caught — and since chunk 1 equals `decode_step` exactly, the
//!   single-token bodies could later collapse onto this one.
//! - The scheduler runs one chunk GEMM sweep **per prefill lane** per
//!   step; concatenating all prefill lanes' chunk rows into one GEMM
//!   (as the decode batch does across lanes) would stream the weights
//!   once per step and is the natural next optimization.

use super::ctx::ExecCtx;
use super::gemv::TernGemmScratch;
use super::lut::{KernelKind, LutScratch};
use super::model::{rmsnorm, rmsnorm_inplace, Engine, KvCache, KvCachePool};
use crate::obs::{ArgV, TID_MAIN};
use super::ternary::act_quant_i8;
use crate::parallel::{
    par_gemm_f32_shared, par_gemv_f32, par_simd_gemm_f32_shared, par_simd_gemv_f32,
};

/// Default chunk size for the engine-internal prefill loops
/// ([`Engine::generate`], [`Engine::forward_logits`], the eval paths).
/// The LM-head skip is chunk-independent (interior chunks never run
/// the head at all); the chunk size governs how far the weight-stream
/// and LUT-table costs are amortized per GEMM, and the scratch
/// footprint grows linearly with it — ~8 captures most of the
/// amortization (see EXPERIMENTS.md §Perf). Purely a throughput knob
/// (see the module docs), so callers may pick anything >= 1.
pub const DEFAULT_PREFILL_CHUNK: usize = 8;

/// Which positions of a chunk get the `d_model x vocab` LM head.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum HeadMode {
    /// No logits needed (interior prompt chunks): the vocab GEMV is
    /// skipped entirely — across a whole prompt only the chunk holding
    /// the final token pays the head at all.
    Skip,
    /// Final position only (a chunk that ends a prompt).
    Last,
    /// Every position (`forward_logits`).
    All,
}

/// Preallocated scratch for the chunked prefill forward: every
/// activation buffer holds `max_chunk` time rows (the chunk analog of
/// [`crate::engine::BatchScratch`]'s lane rows), reusing the same
/// [`LutScratch`] / [`TernGemmScratch`] kernel scratch so LUT tables
/// are built once per chunk per activation width and the steady-state
/// prefill loop allocates nothing.
pub struct PrefillScratch {
    pub(crate) max_chunk: usize,
    vocab: usize,
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    scores: Vec<f32>,
    qact: Vec<i8>,
    gammas: Vec<f32>,
    lut: LutScratch,
    gemm: TernGemmScratch,
    /// `[max_chunk, vocab]` row-major. After a prefill call (LM head on
    /// the final position only) row 0 holds the chunk's final logits;
    /// after an all-heads call (`forward_logits`) row `i` holds
    /// position `i`'s logits.
    pub logits: Vec<f32>,
}

impl PrefillScratch {
    /// The final-position logits of the last `prefill_chunk*` /
    /// `prefill_prompt*` call.
    pub fn final_logits(&self) -> &[f32] {
        &self.logits[..self.vocab]
    }

    /// Logits row `i` of the last all-heads chunk (internal:
    /// `forward_logits`).
    pub(crate) fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }
}

impl Engine {
    /// Scratch for prefill chunks of up to `max_chunk` tokens.
    pub fn new_prefill_scratch(&self, max_chunk: usize) -> PrefillScratch {
        let c = &self.cfg;
        let m = max_chunk.max(1);
        let max_dim = c.d_model.max(c.q_dim()).max(c.d_ff);
        PrefillScratch {
            max_chunk: m,
            vocab: c.vocab,
            x: vec![0.0; m * c.d_model],
            normed: vec![0.0; m * c.d_model],
            q: vec![0.0; m * c.q_dim()],
            k: vec![0.0; m * c.kv_dim()],
            v: vec![0.0; m * c.kv_dim()],
            attn_out: vec![0.0; m * c.q_dim()],
            proj: vec![0.0; m * c.d_model],
            gate: vec![0.0; m * c.d_ff],
            up: vec![0.0; m * c.d_ff],
            scores: vec![0.0; self.max_seq()],
            qact: vec![0i8; m * max_dim],
            gammas: vec![0.0; m],
            // grows on the first LUT-kernel chunk; byte-decode runs
            // never pay the table memory
            lut: LutScratch::new(),
            gemm: TernGemmScratch::for_batch(m),
            logits: vec![0.0; m * c.vocab],
        }
    }

    /// Process one chunk of consecutive tokens for the sequence held in
    /// `cache` (starting at `cache.len`), appending all of them to the
    /// cache and leaving **only the final position's** logits in
    /// `ps` ([`PrefillScratch::final_logits`]) — the interior vocab
    /// GEMVs are skipped entirely. Serial-unobserved shim over
    /// [`Engine::prefill_chunk_ctx`], engine-default kernel.
    pub fn prefill_chunk(&self, tokens: &[i32], cache: &mut KvCache, ps: &mut PrefillScratch) {
        self.prefill_chunk_ctx(&self.serial_ctx(), tokens, cache, ps);
    }

    /// The canonical chunk prefill: the chunk GEMMs row-fan across
    /// `ctx.pool` workers and run the `ctx.kernel` generation. Bitwise
    /// identical to a [`Engine::decode_step`] loop over the same tokens
    /// — KV cache and final logits — for every chunk size, thread count
    /// and kernel (test-enforced).
    pub fn prefill_chunk_ctx(
        &self,
        ctx: &ExecCtx,
        tokens: &[i32],
        cache: &mut KvCache,
        ps: &mut PrefillScratch,
    ) {
        self.forward_chunk_ctx(ctx, tokens, cache, ps, HeadMode::Last);
    }

    /// [`Engine::prefill_chunk_ctx`] addressing a [`KvCachePool`] slot
    /// — the serve scheduler's entry point for chunked-prefill lanes
    /// co-scheduled with single-token decode lanes. `need_logits` says
    /// whether this chunk ends the lane's prompt: when false the LM
    /// head is skipped outright (an interior chunk's logits are never
    /// consumed), so a whole prompt pays exactly **one** vocab GEMV no
    /// matter how many chunks it spans. `ctx.trace` records the chunk
    /// forward as one `prefill_chunk` span (tagged rows / kernel /
    /// threads), with the end-of-prompt LM head — when this chunk runs
    /// it — as a nested `lm_head` span; tracing never touches an
    /// activation, so traced and untraced outputs are bitwise identical
    /// (test-enforced).
    pub fn prefill_chunk_slot_ctx(
        &self,
        ctx: &ExecCtx,
        tokens: &[i32],
        slot: usize,
        pool: &mut KvCachePool,
        ps: &mut PrefillScratch,
        need_logits: bool,
    ) {
        let heads = if need_logits { HeadMode::Last } else { HeadMode::Skip };
        self.forward_chunk_ctx(ctx, tokens, &mut pool.slots[slot], ps, heads);
    }

    /// Prefill an entire prompt in chunks of `chunk` (clamped to the
    /// scratch capacity), leaving the end-of-prompt logits in `ps`
    /// ([`PrefillScratch::final_logits`]). Only the final chunk runs
    /// the LM head (interior chunks skip it entirely), so the whole
    /// prompt costs one vocab GEMV. Panics on an empty prompt.
    pub fn prefill_prompt_ctx(
        &self,
        ctx: &ExecCtx,
        prompt: &[i32],
        chunk: usize,
        cache: &mut KvCache,
        ps: &mut PrefillScratch,
    ) {
        assert!(!prompt.is_empty(), "prefill_prompt on an empty prompt");
        let step = chunk.max(1).min(ps.max_chunk);
        let n_chunks = (prompt.len() + step - 1) / step;
        for (ci, ch) in prompt.chunks(step).enumerate() {
            let heads = if ci + 1 == n_chunks { HeadMode::Last } else { HeadMode::Skip };
            self.forward_chunk_ctx(ctx, ch, cache, ps, heads);
        }
    }

    /// [`Engine::prefill_prompt_ctx`] serial, engine-default kernel,
    /// chunked at the scratch capacity — the one-line prompt scorer the
    /// eval paths use.
    pub fn prefill_prompt(&self, prompt: &[i32], cache: &mut KvCache, ps: &mut PrefillScratch) {
        self.prefill_prompt_ctx(&self.serial_ctx(), prompt, ps.max_chunk, cache, ps);
    }

    /// The chunk forward shared by prefill ([`HeadMode::Last`] for a
    /// chunk that ends a prompt, [`HeadMode::Skip`] for interior
    /// chunks) and `forward_logits` ([`HeadMode::All`]). Mirrors
    /// [`Engine::decode_step_batch_ctx`] with lanes replaced by time
    /// rows of one sequence: per-row arithmetic is exactly the
    /// sequential path's, the GEMMs are the bitwise-identical batch
    /// twins, and attention is causal within the chunk (all K/V rows
    /// appended before any row attends, each row reading only positions
    /// `0..=its own`). The head mode only decides which logits get
    /// computed — it can never change the KV cache or any computed
    /// logit's bits.
    pub(crate) fn forward_chunk_ctx(
        &self,
        ctx: &ExecCtx,
        tokens: &[i32],
        cache: &mut KvCache,
        ps: &mut PrefillScratch,
        heads: HeadMode,
    ) {
        let tp = &ctx.pool;
        let kernel = ctx.kernel;
        let trace = &ctx.trace;
        let cn = tokens.len();
        let _chunk_span = trace.span_args(
            TID_MAIN,
            "prefill_chunk",
            &[
                ("rows", ArgV::Num(cn as f64)),
                ("kernel", ArgV::Str(kernel.name())),
                ("threads", ArgV::Num(tp.threads() as f64)),
            ],
        );
        assert!(
            cn > 0 && cn <= ps.max_chunk,
            "chunk {cn} vs scratch capacity {}",
            ps.max_chunk
        );
        let c = &self.cfg;
        let (d, hd, nh, nkv) = (c.d_model, c.head_dim, c.n_heads, c.n_kv_heads);
        let (qd, kvd) = (c.q_dim(), c.kv_dim());
        let rep = nh / nkv;
        let eps = c.norm_eps as f32;
        let pos0 = cache.len;
        assert!(
            pos0 + cn <= cache.max_t,
            "kv cache exhausted: chunk of {cn} at {pos0} vs capacity {}",
            cache.max_t
        );
        cache.ensure_allocated();

        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            ps.x[i * d..(i + 1) * d].copy_from_slice(&self.embed[t * d..(t + 1) * d]);
        }

        for (li, layer) in self.layers.iter().enumerate() {
            // ---- attention ----
            for i in 0..cn {
                rmsnorm(
                    &ps.x[i * d..(i + 1) * d],
                    &layer.attn_norm,
                    eps,
                    &mut ps.normed[i * d..(i + 1) * d],
                );
            }
            if self.ternary {
                for i in 0..cn {
                    ps.gammas[i] = act_quant_i8(
                        &ps.normed[i * d..(i + 1) * d],
                        &mut ps.qact[i * d..(i + 1) * d],
                    );
                }
                let tables = match kernel {
                    KernelKind::Lut => Some(ps.lut.build_batch(&ps.qact, d, cn)),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer.wq.apply_quantized_batch(
                    tp,
                    &ps.normed,
                    &ps.qact,
                    &ps.gammas,
                    cn,
                    kernel,
                    tables,
                    &mut ps.q,
                    &mut ps.gemm,
                );
                layer.wk.apply_quantized_batch(
                    tp,
                    &ps.normed,
                    &ps.qact,
                    &ps.gammas,
                    cn,
                    kernel,
                    tables,
                    &mut ps.k,
                    &mut ps.gemm,
                );
                layer.wv.apply_quantized_batch(
                    tp,
                    &ps.normed,
                    &ps.qact,
                    &ps.gammas,
                    cn,
                    kernel,
                    tables,
                    &mut ps.v,
                    &mut ps.gemm,
                );
            } else {
                layer.wq.apply_batch(
                    tp,
                    &ps.normed,
                    cn,
                    &mut ps.qact,
                    &mut ps.gammas,
                    &mut ps.q,
                    kernel,
                    &mut ps.lut,
                    &mut ps.gemm,
                );
                layer.wk.apply_batch(
                    tp,
                    &ps.normed,
                    cn,
                    &mut ps.qact,
                    &mut ps.gammas,
                    &mut ps.k,
                    kernel,
                    &mut ps.lut,
                    &mut ps.gemm,
                );
                layer.wv.apply_batch(
                    tp,
                    &ps.normed,
                    cn,
                    &mut ps.qact,
                    &mut ps.gammas,
                    &mut ps.v,
                    kernel,
                    &mut ps.lut,
                    &mut ps.gemm,
                );
            }
            for i in 0..cn {
                self.rope(&mut ps.q[i * qd..(i + 1) * qd], nh, pos0 + i);
                self.rope(&mut ps.k[i * kvd..(i + 1) * kvd], nkv, pos0 + i);
            }

            // append every chunk row to the cache BEFORE any attention:
            // row i then attends over 0..=pos0+i only, so causality (and
            // bitwise parity with the sequential path) is preserved
            for i in 0..cn {
                let pos = pos0 + i;
                for kh in 0..nkv {
                    let dst = kh * cache.max_t * hd + pos * hd;
                    cache.k[li][dst..dst + hd]
                        .copy_from_slice(&ps.k[i * kvd + kh * hd..i * kvd + (kh + 1) * hd]);
                    cache.v[li][dst..dst + hd]
                        .copy_from_slice(&ps.v[i * kvd + kh * hd..i * kvd + (kh + 1) * hd]);
                }
            }

            let scale = 1.0 / (hd as f32).sqrt();
            for i in 0..cn {
                let t_len = pos0 + i + 1;
                for h in 0..nh {
                    let kh = h / rep;
                    let qv = &ps.q[i * qd + h * hd..i * qd + (h + 1) * hd];
                    let kbase = kh * cache.max_t * hd;
                    for t in 0..t_len {
                        let kr = &cache.k[li][kbase + t * hd..kbase + t * hd + hd];
                        let mut dot = 0.0f32;
                        for e in 0..hd {
                            dot += qv[e] * kr[e];
                        }
                        ps.scores[t] = dot * scale;
                    }
                    let m = ps.scores[..t_len]
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for t in 0..t_len {
                        ps.scores[t] = (ps.scores[t] - m).exp();
                        z += ps.scores[t];
                    }
                    let inv_z = 1.0 / z;
                    let out = &mut ps.attn_out[i * qd + h * hd..i * qd + (h + 1) * hd];
                    out.iter_mut().for_each(|o| *o = 0.0);
                    let vbase = kh * cache.max_t * hd;
                    for t in 0..t_len {
                        let wgt = ps.scores[t] * inv_z;
                        let vr = &cache.v[li][vbase + t * hd..vbase + t * hd + hd];
                        for e in 0..hd {
                            out[e] += wgt * vr[e];
                        }
                    }
                }
            }
            if let Some(g) = &layer.subln_attn {
                for i in 0..cn {
                    rmsnorm_inplace(&mut ps.attn_out[i * qd..(i + 1) * qd], g, eps);
                }
            }
            layer.wo.apply_batch(
                tp,
                &ps.attn_out,
                cn,
                &mut ps.qact,
                &mut ps.gammas,
                &mut ps.proj,
                kernel,
                &mut ps.lut,
                &mut ps.gemm,
            );
            for i in 0..cn {
                for j in 0..d {
                    ps.x[i * d + j] += ps.proj[i * d + j];
                }
            }

            // ---- FFN ----
            for i in 0..cn {
                rmsnorm(
                    &ps.x[i * d..(i + 1) * d],
                    &layer.ffn_norm,
                    eps,
                    &mut ps.normed[i * d..(i + 1) * d],
                );
            }
            if self.ternary {
                for i in 0..cn {
                    ps.gammas[i] = act_quant_i8(
                        &ps.normed[i * d..(i + 1) * d],
                        &mut ps.qact[i * d..(i + 1) * d],
                    );
                }
                let tables = match kernel {
                    KernelKind::Lut => Some(ps.lut.build_batch(&ps.qact, d, cn)),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer.w_gate.apply_quantized_batch(
                    tp,
                    &ps.normed,
                    &ps.qact,
                    &ps.gammas,
                    cn,
                    kernel,
                    tables,
                    &mut ps.gate,
                    &mut ps.gemm,
                );
                layer.w_up.apply_quantized_batch(
                    tp,
                    &ps.normed,
                    &ps.qact,
                    &ps.gammas,
                    cn,
                    kernel,
                    tables,
                    &mut ps.up,
                    &mut ps.gemm,
                );
            } else {
                layer.w_gate.apply_batch(
                    tp,
                    &ps.normed,
                    cn,
                    &mut ps.qact,
                    &mut ps.gammas,
                    &mut ps.gate,
                    kernel,
                    &mut ps.lut,
                    &mut ps.gemm,
                );
                layer.w_up.apply_batch(
                    tp,
                    &ps.normed,
                    cn,
                    &mut ps.qact,
                    &mut ps.gammas,
                    &mut ps.up,
                    kernel,
                    &mut ps.lut,
                    &mut ps.gemm,
                );
            }
            let use_silu = c.act == "silu";
            for i in 0..cn {
                for j in 0..c.d_ff {
                    let g = ps.gate[i * c.d_ff + j];
                    let a = if use_silu {
                        super::model::silu(g)
                    } else {
                        super::model::gelu(g)
                    };
                    ps.gate[i * c.d_ff + j] = ps.up[i * c.d_ff + j] * a;
                }
            }
            if let Some(g) = &layer.subln_ffn {
                for i in 0..cn {
                    rmsnorm_inplace(&mut ps.gate[i * c.d_ff..(i + 1) * c.d_ff], g, eps);
                }
            }
            layer.w_down.apply_batch(
                tp,
                &ps.gate,
                cn,
                &mut ps.qact,
                &mut ps.gammas,
                &mut ps.proj,
                kernel,
                &mut ps.lut,
                &mut ps.gemm,
            );
            for i in 0..cn {
                for j in 0..d {
                    ps.x[i * d + j] += ps.proj[i * d + j];
                }
            }
        }

        cache.len = pos0 + cn;

        // ---- LM head (full precision, as in the sequential path) ----
        let head: &[f32] = self.lm_head.as_deref().unwrap_or(&self.embed);
        match heads {
            // the LM-head skip: an interior chunk's logits are never
            // consumed, so the vocab GEMV (and the final norm — `x` is
            // re-embedded next chunk) is skipped outright
            HeadMode::Skip => {}
            HeadMode::Last => {
                let _lm_span = trace.span(TID_MAIN, "lm_head");
                let last = cn - 1;
                rmsnorm_inplace(&mut ps.x[last * d..(last + 1) * d], &self.final_norm, eps);
                let x_last = &ps.x[last * d..(last + 1) * d];
                match kernel {
                    KernelKind::Simd => {
                        par_simd_gemv_f32(tp, head, c.vocab, d, x_last, &mut ps.logits[..c.vocab])
                    }
                    _ => par_gemv_f32(tp, head, c.vocab, d, x_last, &mut ps.logits[..c.vocab]),
                }
            }
            HeadMode::All => {
                let _lm_span = trace.span(TID_MAIN, "lm_head");
                for i in 0..cn {
                    rmsnorm_inplace(&mut ps.x[i * d..(i + 1) * d], &self.final_norm, eps);
                }
                match kernel {
                    KernelKind::Simd => par_simd_gemm_f32_shared(
                        tp,
                        head,
                        c.vocab,
                        d,
                        &ps.x[..cn * d],
                        cn,
                        &mut ps.logits[..cn * c.vocab],
                    ),
                    _ => par_gemm_f32_shared(
                        tp,
                        head,
                        c.vocab,
                        d,
                        &ps.x[..cn * d],
                        cn,
                        &mut ps.logits[..cn * c.vocab],
                    ),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::model::mini_model;
    use crate::engine::Scratch;
    use crate::parallel::ThreadPool;
    use crate::params::ParamStore;
    use crate::runtime::ModelSpec;

    /// The determinism contract's coverage grid (the ISSUE acceptance
    /// matrix): chunk {1,2,3,5,8} x threads {1,4} x kernels x modes.
    const CHUNKS: [usize; 5] = [1, 2, 3, 5, 8];
    const THREADS: [usize; 2] = [1, 4];

    fn sequential_reference(
        e: &Engine,
        tokens: &[i32],
    ) -> (KvCache, Vec<f32>) {
        let mut cache = e.new_cache();
        let mut s: Scratch = e.new_scratch();
        for &t in tokens {
            e.decode_step(t, &mut cache, &mut s);
        }
        (cache, s.logits.clone())
    }

    /// Compare only the populated region `[kvh][0..len][hd]` of two
    /// caches bitwise; the tail beyond `len` is never read (and lazily
    /// reused pool slots keep stale data there on purpose).
    fn assert_cache_bitwise_eq(e: &Engine, a: &KvCache, b: &KvCache, ctx: &str) {
        assert_eq!(a.len, b.len, "{ctx}: cache len");
        assert_eq!(a.max_t, b.max_t, "{ctx}: cache max_t");
        let (hd, nkv) = (e.cfg.head_dim, e.cfg.n_kv_heads);
        for (li, ka) in a.k.iter().enumerate() {
            for kh in 0..nkv {
                for t in 0..a.len {
                    let lo = kh * a.max_t * hd + t * hd;
                    let sa = &ka[lo..lo + hd];
                    let sb = &b.k[li][lo..lo + hd];
                    let same = sa.iter().zip(sb).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "{ctx}: K layer {li} head {kh} t {t}");
                    let va = &a.v[li][lo..lo + hd];
                    let vb = &b.v[li][lo..lo + hd];
                    let same = va.iter().zip(vb).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "{ctx}: V layer {li} head {kh} t {t}");
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_is_bitwise_identical_to_decode_steps() {
        // the tentpole contract: KV cache + final logits bitwise-equal
        // to the sequential decode path at chunk {1,2,3,5,8} x threads
        // {1,4} x kernels {byte, lut, simd} x modes {f32, ternary}
        for ternary in [false, true] {
            for tie in [true, false] {
                let (spec, store) = mini_model(true, tie);
                let e = Engine::from_params(&spec, &store, ternary).unwrap();
                let tokens = [3i32, 9, 1, 7, 4, 2, 11, 5, 6, 8, 10, 12, 13];
                let (want_cache, want_logits) = sequential_reference(&e, &tokens);
                for kernel in KernelKind::ALL {
                    for chunk in CHUNKS {
                        for threads in THREADS {
                            let tp = ThreadPool::with_granularity(threads, 1);
                            let ectx = ExecCtx::serial().with_pool(tp).with_kernel(kernel);
                            let mut cache = e.new_cache();
                            let mut ps = e.new_prefill_scratch(chunk);
                            e.prefill_prompt_ctx(&ectx, &tokens, chunk, &mut cache, &mut ps);
                            let ctx = format!(
                                "ternary={ternary} tie={tie} kernel={} chunk={chunk} \
                                 threads={threads}",
                                kernel.name()
                            );
                            let same = ps
                                .final_logits()
                                .iter()
                                .zip(&want_logits)
                                .all(|(x, y)| x.to_bits() == y.to_bits());
                            assert!(same, "{ctx}: final logits diverged");
                            assert_cache_bitwise_eq(&e, &cache, &want_cache, &ctx);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prefill_resumes_mid_sequence() {
        // a chunk starting at a non-zero cache position (the scheduler's
        // steady state) must continue exactly where decode left off
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let tokens = [3i32, 9, 1, 7, 4, 2, 11];
        let (_, want_logits) = sequential_reference(&e, &tokens);

        let mut cache = e.new_cache();
        let mut s = e.new_scratch();
        // first two tokens via decode_step, rest via one chunk
        for &t in &tokens[..2] {
            e.decode_step(t, &mut cache, &mut s);
        }
        let mut ps = e.new_prefill_scratch(8);
        e.prefill_chunk(&tokens[2..], &mut cache, &mut ps);
        assert_eq!(cache.len, tokens.len());
        let same = ps
            .final_logits()
            .iter()
            .zip(&want_logits)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "mid-sequence chunk diverged");
    }

    #[test]
    fn pool_slot_prefill_matches_plain_cache_prefill() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let tokens = [5i32, 1, 9, 2, 7];
        let tp = ThreadPool::serial();

        let ctx = ExecCtx::serial().with_pool(tp);
        let mut cache = e.new_cache();
        let mut ps = e.new_prefill_scratch(4);
        e.prefill_prompt_ctx(&ctx, &tokens, 4, &mut cache, &mut ps);
        let want = ps.final_logits().to_vec();

        let mut pool = e.new_cache_pool(2);
        let slot = pool.acquire().unwrap();
        let mut ps2 = e.new_prefill_scratch(4);
        let mut fed = 0;
        for ch in tokens.chunks(4) {
            fed += ch.len();
            // the scheduler's usage: logits only for the prompt-ending
            // chunk (interior chunks skip the LM head)
            let need_logits = fed == tokens.len();
            e.prefill_chunk_slot_ctx(&ctx, ch, slot, &mut pool, &mut ps2, need_logits);
        }
        assert_eq!(pool.slots[slot].len, tokens.len());
        let same = ps2
            .final_logits()
            .iter()
            .zip(&want)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same);
    }

    #[test]
    fn scratch_reuse_across_chunk_sizes_is_bitwise_stable() {
        // one PrefillScratch reused across varying chunk sizes (the
        // prompt loop's usage: full chunks then a tail) must produce the
        // bits a fresh scratch produces
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let tokens = [3i32, 9, 1, 7, 4, 2, 11, 5, 6];
        let tp = ThreadPool::serial();

        let ctx = ExecCtx::serial().with_pool(tp).with_kernel(KernelKind::Lut);
        let mut reused = e.new_prefill_scratch(4);
        let mut cache = e.new_cache();
        e.prefill_prompt_ctx(&ctx, &tokens, 4, &mut cache, &mut reused);

        let mut fresh_cache = e.new_cache();
        let mut last = Vec::new();
        for ch in tokens.chunks(4) {
            let mut fresh = e.new_prefill_scratch(4);
            e.prefill_chunk_ctx(&ctx, ch, &mut fresh_cache, &mut fresh);
            last = fresh.final_logits().to_vec();
        }
        let same = reused
            .final_logits()
            .iter()
            .zip(&last)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same);
    }

    #[test]
    fn synthetic_long_prompt_prefill_matches_sequential() {
        // the bench/gate shape: a synthetic-spec ternary engine over a
        // long prompt, chunked vs token-by-token
        let spec = ModelSpec::synthetic("micro").unwrap();
        let mut rng = crate::substrate::Rng::new(3);
        let params = ParamStore::init(&spec, &mut rng);
        let e = Engine::from_params(&spec, &params, true).unwrap();
        let prompt: Vec<i32> = (0..65).map(|i| (i * 13 + 7) % spec.config.vocab as i32).collect();
        let (want_cache, want_logits) = sequential_reference(&e, &prompt);
        let mut cache = e.new_cache();
        let mut ps = e.new_prefill_scratch(DEFAULT_PREFILL_CHUNK);
        e.prefill_prompt_ctx(
            &ExecCtx::serial(),
            &prompt,
            DEFAULT_PREFILL_CHUNK,
            &mut cache,
            &mut ps,
        );
        assert_eq!(cache.len, want_cache.len);
        let same = ps
            .final_logits()
            .iter()
            .zip(&want_logits)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "long-prompt chunked prefill diverged");
        assert_cache_bitwise_eq(&e, &cache, &want_cache, "synthetic long prompt");
    }
}
