//! The deployment-side transformer: pure-rust forward that mirrors the
//! Layer-2 JAX model numerics (python/compile/model.py) in both
//! full-precision (f32) and ternary (W1.58A8) modes.
//!
//! Integer-exact design: in ternary mode the quantized matmuls accumulate
//! in i32 over exactly the same integer grids as the JAX QAT forward
//! (which does f32 matmuls over integer-valued floats — exact below 2^24),
//! so engine logits match `*_student_fwd` HLO logits to float tolerance.
//! The parity test in rust/tests enforces this.

use anyhow::{anyhow, bail, Result};

use super::ctx::ExecCtx;
use super::gemv::TernGemmScratch;
use super::lut::{KernelKind, LutScratch};
use super::ternary::{act_quant_i8, TernaryMatrix};
use crate::obs::{ArgV, TID_MAIN};
use crate::parallel::{
    par_gemm_f32_shared, par_gemm_ternary, par_gemv_f32, par_gemv_ternary, par_lut_gemm,
    par_lut_gemv, par_simd_gemm, par_simd_gemm_f32_shared, par_simd_gemv, par_simd_gemv_f32,
    ThreadPool,
};
use crate::params::ParamStore;
use crate::runtime::{ModelCfg, ModelSpec};

/// One linear operator in [out, in] orientation.
pub enum LinOp {
    F32 { w: Vec<f32>, out: usize, inp: usize },
    Tern(TernaryMatrix),
}

impl LinOp {
    pub fn out_dim(&self) -> usize {
        match self {
            LinOp::F32 { out, .. } => *out,
            LinOp::Tern(m) => m.rows,
        }
    }
    pub fn in_dim(&self) -> usize {
        match self {
            LinOp::F32 { inp, .. } => *inp,
            LinOp::Tern(m) => m.cols,
        }
    }
    pub fn weight_bytes(&self) -> usize {
        match self {
            LinOp::F32 { w, .. } => w.len() * 4,
            LinOp::Tern(m) => m.memory_bytes(),
        }
    }

    /// y = W x, quantizing the activation on the fly in ternary mode
    /// (and, under [`KernelKind::Lut`], building the activation tables
    /// into `lut`). Output rows fan across `tp` workers; results are
    /// bitwise identical for every thread count **and every kernel**
    /// (see [`crate::parallel`] / [`super::lut`]).
    pub fn apply(
        &self,
        tp: &ThreadPool,
        x: &[f32],
        y: &mut [f32],
        qbuf: &mut [i8],
        kernel: KernelKind,
        lut: &mut LutScratch,
    ) {
        match self {
            LinOp::F32 { w, out, inp } => match kernel {
                KernelKind::Simd => par_simd_gemv_f32(tp, w, *out, *inp, x, y),
                _ => par_gemv_f32(tp, w, *out, *inp, x, y),
            },
            LinOp::Tern(m) => {
                let gamma = act_quant_i8(x, &mut qbuf[..m.cols]);
                match kernel {
                    KernelKind::Lut => {
                        let table = lut.build(&qbuf[..m.cols]);
                        par_lut_gemv(tp, m, table, gamma, y);
                    }
                    KernelKind::ByteDecode => {
                        par_gemv_ternary(tp, m, &qbuf[..m.cols], gamma, y)
                    }
                    KernelKind::Simd => par_simd_gemv(tp, m, &qbuf[..m.cols], gamma, y),
                }
            }
        }
    }

    /// y = W x with a pre-quantized activation (shared across Q/K/V and
    /// gate/up, which consume the same normed input). `table` is the
    /// activation's LUT ([`LutScratch::build`] over the same `q`) when
    /// the LUT kernel is selected — built once, shared by every matrix
    /// of equal `in_dim` — or `None` for the byte-decode and SIMD
    /// kernels, which consume `q` directly.
    pub fn apply_quantized(
        &self,
        tp: &ThreadPool,
        x: &[f32],
        q: &[i8],
        gamma: f32,
        kernel: KernelKind,
        table: Option<&[i16]>,
        y: &mut [f32],
    ) {
        match self {
            LinOp::F32 { w, out, inp } => match kernel {
                KernelKind::Simd => par_simd_gemv_f32(tp, w, *out, *inp, x, y),
                _ => par_gemv_f32(tp, w, *out, *inp, x, y),
            },
            LinOp::Tern(m) => match kernel {
                KernelKind::Lut => {
                    let t = table.expect("LUT kernel requires prebuilt activation tables");
                    par_lut_gemv(tp, m, t, gamma, y);
                }
                KernelKind::ByteDecode => par_gemv_ternary(tp, m, &q[..m.cols], gamma, y),
                KernelKind::Simd => par_simd_gemv(tp, m, &q[..m.cols], gamma, y),
            },
        }
    }

    /// Batched [`LinOp::apply`]: `b` activations at stride `in_dim`,
    /// quantized on the fly in ternary mode (`qbuf`/`gammas` are per-item
    /// scratch; `lut`/`gemm` the kernel scratch). Streams each weight
    /// row once for the whole batch.
    pub fn apply_batch(
        &self,
        tp: &ThreadPool,
        xs: &[f32],
        b: usize,
        qbuf: &mut [i8],
        gammas: &mut [f32],
        ys: &mut [f32],
        kernel: KernelKind,
        lut: &mut LutScratch,
        gemm: &mut TernGemmScratch,
    ) {
        match self {
            LinOp::F32 { w, out, inp } => match kernel {
                KernelKind::Simd => par_simd_gemm_f32_shared(tp, w, *out, *inp, xs, b, ys),
                _ => par_gemm_f32_shared(tp, w, *out, *inp, xs, b, ys),
            },
            LinOp::Tern(m) => {
                let k = m.cols;
                for bi in 0..b {
                    gammas[bi] =
                        act_quant_i8(&xs[bi * k..(bi + 1) * k], &mut qbuf[bi * k..(bi + 1) * k]);
                }
                match kernel {
                    KernelKind::Lut => {
                        let tables = lut.build_batch(qbuf, k, b);
                        par_lut_gemm(tp, m, tables, gammas, b, ys, gemm);
                    }
                    KernelKind::ByteDecode => {
                        par_gemm_ternary(tp, m, qbuf, gammas, b, ys, gemm)
                    }
                    KernelKind::Simd => par_simd_gemm(tp, m, qbuf, gammas, b, ys, gemm),
                }
            }
        }
    }

    /// Batched [`LinOp::apply_quantized`]: pre-quantized rows in `q`
    /// (stride = in_dim), one `gamma` per row, shared across Q/K/V and
    /// gate/up. `tables` is the batch's LUT ([`LutScratch::build_batch`]
    /// over the same rows) under the LUT kernel, `None` for the
    /// byte-decode and SIMD kernels, which consume `q` directly.
    pub fn apply_quantized_batch(
        &self,
        tp: &ThreadPool,
        xs: &[f32],
        q: &[i8],
        gammas: &[f32],
        b: usize,
        kernel: KernelKind,
        tables: Option<&[i16]>,
        ys: &mut [f32],
        gemm: &mut TernGemmScratch,
    ) {
        match self {
            LinOp::F32 { w, out, inp } => match kernel {
                KernelKind::Simd => par_simd_gemm_f32_shared(tp, w, *out, *inp, xs, b, ys),
                _ => par_gemm_f32_shared(tp, w, *out, *inp, xs, b, ys),
            },
            LinOp::Tern(m) => match kernel {
                KernelKind::Lut => {
                    let t = tables.expect("LUT kernel requires prebuilt activation tables");
                    par_lut_gemm(tp, m, t, gammas, b, ys, gemm);
                }
                KernelKind::ByteDecode => par_gemm_ternary(tp, m, q, gammas, b, ys, gemm),
                KernelKind::Simd => par_simd_gemm(tp, m, q, gammas, b, ys, gemm),
            },
        }
    }
}

/// Build a LinOp from a checkpoint tensor stored in x@W ([in, out]) layout.
fn lin_from_xw(w: &[f32], k_in: usize, n_out: usize, ternary: bool) -> LinOp {
    if ternary {
        LinOp::Tern(TernaryMatrix::from_xw_f32(w, k_in, n_out))
    } else {
        // transpose to [out, in]
        let mut t = vec![0.0f32; w.len()];
        for k in 0..k_in {
            for n in 0..n_out {
                t[n * k_in + k] = w[k * n_out + n];
            }
        }
        LinOp::F32 { w: t, out: n_out, inp: k_in }
    }
}

pub struct EngineLayer {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub subln_attn: Option<Vec<f32>>,
    pub subln_ffn: Option<Vec<f32>>,
    pub wq: LinOp,
    pub wk: LinOp,
    pub wv: LinOp,
    pub wo: LinOp,
    pub w_gate: LinOp,
    pub w_up: LinOp,
    pub w_down: LinOp,
}

/// KV cache: per layer, [kv_head][t][head_dim] f32.
///
/// A cache can exist **unallocated** (see [`KvCache::unallocated`]):
/// it remembers its geometry but holds no buffers until
/// [`KvCache::ensure_allocated`] backs it. [`KvCachePool`] uses this to
/// defer each slot's memory to its first acquisition, so a server sized
/// for a worst-case batch doesn't zero-fill
/// `n_slots x n_layers x 2 x n_kv x max_t x head_dim` floats up front.
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub len: usize,
    pub max_t: usize,
    n_layers: usize,
    n_kv: usize,
    head_dim: usize,
}

impl KvCache {
    /// An eagerly allocated cache (the single-sequence paths).
    pub fn new(n_layers: usize, n_kv: usize, head_dim: usize, max_t: usize) -> Self {
        let mut c = KvCache::unallocated(n_layers, n_kv, head_dim, max_t);
        c.ensure_allocated();
        c
    }

    /// A cache holding only its geometry — zero bytes of K/V storage
    /// until [`KvCache::ensure_allocated`]. Crate-internal: the decode
    /// entry points assume a backed cache (only the pool's `acquire`
    /// and the chunk forward guard), so handing an unallocated cache to
    /// external callers would be a panic footgun.
    pub(crate) fn unallocated(n_layers: usize, n_kv: usize, head_dim: usize, max_t: usize) -> Self {
        KvCache {
            k: Vec::new(),
            v: Vec::new(),
            len: 0,
            max_t,
            n_layers,
            n_kv,
            head_dim,
        }
    }

    pub fn is_allocated(&self) -> bool {
        !self.k.is_empty()
    }

    /// Back the cache with (zeroed) K/V buffers if it has none yet;
    /// a no-op on an already-backed cache — in particular it does NOT
    /// re-zero a reused buffer (stale data beyond `len` is never read:
    /// attention scans `0..len` and appends overwrite, so skipping the
    /// wipe changes no bits — regression-tested in the pool tests).
    pub fn ensure_allocated(&mut self) {
        if self.is_allocated() {
            return;
        }
        let per = self.n_kv * self.max_t * self.head_dim;
        self.k = (0..self.n_layers).map(|_| vec![0.0; per]).collect();
        self.v = (0..self.n_layers).map(|_| vec![0.0; per]).collect();
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes actually held (0 for an unallocated cache — the honest
    /// number [`KvCachePool::memory_bytes`] sums).
    pub fn memory_bytes(&self) -> usize {
        self.k.iter().map(|v| v.len() * 4).sum::<usize>() * 2
    }
}

/// A fixed pool of KV-cache slots for continuous batching: requests
/// acquire a slot on admission and release it on retirement. Slots are
/// created **unallocated** and backed lazily on their first
/// acquisition, so a pool sized for a worst-case batch costs nothing
/// until the load actually arrives; released slots are reused without
/// re-zeroing (reset on the next acquire — bitwise-equivalent, since
/// data beyond `len` is never read; regression-tested).
pub struct KvCachePool {
    pub slots: Vec<KvCache>,
    free: Vec<usize>,
}

impl KvCachePool {
    pub fn new(engine: &Engine, n_slots: usize) -> KvCachePool {
        let c = &engine.cfg;
        KvCachePool {
            slots: (0..n_slots)
                .map(|_| {
                    KvCache::unallocated(c.n_layers, c.n_kv_heads, c.head_dim, engine.max_seq())
                })
                .collect(),
            // reversed so acquire() hands out slot 0 first (determinism)
            free: (0..n_slots).rev().collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Take a (reset) slot, or None when every slot is in use. A slot's
    /// K/V buffers are allocated here on its first acquisition; a
    /// reused slot is reset without re-zeroing its dead region.
    pub fn acquire(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        self.slots[id].ensure_allocated();
        self.slots[id].reset();
        Some(id)
    }

    /// Return a slot to the pool. Must not be called twice for one id.
    pub fn release(&mut self, id: usize) {
        debug_assert!(id < self.slots.len());
        debug_assert!(!self.free.contains(&id), "double release of slot {id}");
        self.free.push(id);
    }

    /// Bytes actually held by the slot buffers: 0 at construction,
    /// growing as slots are first acquired, then constant (honest
    /// accounting under lazy allocation).
    pub fn memory_bytes(&self) -> usize {
        self.slots.iter().map(KvCache::memory_bytes).sum()
    }

    /// Memory-backed lanes: slots whose K/V buffers have been allocated.
    /// Under the lazy pool this is the high-water mark of concurrent
    /// occupancy — 0 on an idle server, never exceeding
    /// [`KvCachePool::capacity`] (`kv_resident_lanes` in the serve
    /// metrics snapshots).
    pub fn resident_lanes(&self) -> usize {
        self.slots.iter().filter(|s| s.memory_bytes() > 0).count()
    }
}

/// Preallocated per-token scratch (the decode hot loop is allocation-free).
pub struct Scratch {
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    scores: Vec<f32>,
    qi8: Vec<i8>,
    /// Activation tables for the LUT kernel, rebuilt per quantized
    /// activation and shared across all matrices of equal `in_dim`
    /// (Q/K/V; gate/up). Grows to the widest activation on the first
    /// LUT-kernel step (one allocation), then is reused — byte-decode
    /// runs never pay its memory.
    lut: LutScratch,
    pub logits: Vec<f32>,
}

/// Preallocated scratch for [`Engine::decode_step_batch`]: every
/// activation buffer holds `max_b` rows, so the batched step allocates
/// nothing proportional to model size — the batch GEMM kernels' O(b)
/// temporaries (dequant scales / i32 accumulators) live in `gemm`
/// rather than being reallocated per matrix per step, and the LUT
/// kernel's activation tables live in `lut`, built once per step per
/// activation width and shared across all matrices of equal `in_dim`.
pub struct BatchScratch {
    pub max_b: usize,
    vocab: usize,
    pos: Vec<usize>,
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    scores: Vec<f32>,
    qact: Vec<i8>,
    gammas: Vec<f32>,
    lut: LutScratch,
    gemm: TernGemmScratch,
    /// [max_b, vocab] row-major; rows beyond the last step's batch size
    /// are stale.
    pub logits: Vec<f32>,
}

impl BatchScratch {
    /// Logits row for batch lane `i` of the last `decode_step_batch`.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }
}

pub struct Engine {
    pub cfg: ModelCfg,
    pub ternary: bool,
    /// Which kernel generation the plain convenience entry points
    /// (decode_step*, forward_logits, generate) run. All three
    /// generations are bitwise identical on every input (test-enforced),
    /// so this is a pure throughput knob. Defaults to
    /// [`KernelKind::ByteDecode`]. The canonical `_ctx` entry points
    /// take their kernel from the [`ExecCtx`] instead.
    pub kernel: KernelKind,
    pub embed: Vec<f32>,       // [V, d] row-major
    pub final_norm: Vec<f32>,  // [d]
    pub lm_head: Option<Vec<f32>>, // [V, d] (transposed from the [d, V] ckpt)
    pub layers: Vec<EngineLayer>,
    cos: Vec<f32>, // [max_t, hd/2]
    sin: Vec<f32>,
    max_t: usize,
}

pub(crate) fn rmsnorm(x: &[f32], g: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * r * gv;
    }
}

pub(crate) fn rmsnorm_inplace(x: &mut [f32], g: &[f32], eps: f32) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for (v, &gv) in x.iter_mut().zip(g) {
        *v = *v * r * gv;
    }
}

pub(crate) fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// tanh-approximate GeLU, matching jax.nn.gelu's default.
pub(crate) fn gelu(v: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh())
}

impl Engine {
    /// Assemble from a checkpointed ParamStore following `spec`. `ternary`
    /// selects the packed W1.58A8 path (absmean; Table-4 variants are
    /// evaluated through their HLO fwd artifacts instead — see DESIGN.md).
    pub fn from_params(spec: &ModelSpec, store: &ParamStore, ternary: bool) -> Result<Engine> {
        let cfg = spec.config.clone();
        let (d, l) = (cfg.d_model, cfg.n_layers);
        let get = |name: &str| -> Result<&crate::tensor::TensorF32> {
            store
                .tensors
                .get(name)
                .ok_or_else(|| anyhow!("checkpoint missing tensor {name:?}"))
        };

        let embed = get("embed")?;
        if embed.shape != vec![cfg.vocab, d] {
            bail!("embed shape {:?}", embed.shape);
        }

        let layer_slice = |t: &crate::tensor::TensorF32, li: usize| -> Vec<f32> {
            let per = t.numel() / l;
            t.data[li * per..(li + 1) * per].to_vec()
        };

        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let wq = layer_slice(get("blocks.wq")?, li);
            let wk = layer_slice(get("blocks.wk")?, li);
            let wv = layer_slice(get("blocks.wv")?, li);
            let wo = layer_slice(get("blocks.wo")?, li);
            let wg = layer_slice(get("blocks.w_gate")?, li);
            let wu = layer_slice(get("blocks.w_up")?, li);
            let wd = layer_slice(get("blocks.w_down")?, li);
            layers.push(EngineLayer {
                attn_norm: layer_slice(get("blocks.attn_norm")?, li),
                ffn_norm: layer_slice(get("blocks.ffn_norm")?, li),
                subln_attn: if cfg.use_subln {
                    Some(layer_slice(get("blocks.subln_attn")?, li))
                } else {
                    None
                },
                subln_ffn: if cfg.use_subln {
                    Some(layer_slice(get("blocks.subln_ffn")?, li))
                } else {
                    None
                },
                wq: lin_from_xw(&wq, d, cfg.q_dim(), ternary),
                wk: lin_from_xw(&wk, d, cfg.kv_dim(), ternary),
                wv: lin_from_xw(&wv, d, cfg.kv_dim(), ternary),
                wo: lin_from_xw(&wo, cfg.q_dim(), d, ternary),
                w_gate: lin_from_xw(&wg, d, cfg.d_ff, ternary),
                w_up: lin_from_xw(&wu, d, cfg.d_ff, ternary),
                w_down: lin_from_xw(&wd, cfg.d_ff, d, ternary),
            });
        }

        let lm_head = if cfg.tie_embeddings {
            None
        } else {
            let h = get("lm_head")?; // [d, V]
            let mut t = vec![0.0f32; h.numel()];
            for k in 0..d {
                for v in 0..cfg.vocab {
                    t[v * d + k] = h.data[k * cfg.vocab + v];
                }
            }
            Some(t)
        };

        // RoPE tables
        let max_t = cfg.seq.max(256);
        let half = cfg.head_dim / 2;
        let mut cos = vec![0.0f32; max_t * half];
        let mut sin = vec![0.0f32; max_t * half];
        for t in 0..max_t {
            for i in 0..half {
                let freq = 1.0 / (cfg.rope_theta as f32).powf(i as f32 / half as f32);
                let ang = t as f32 * freq;
                cos[t * half + i] = ang.cos();
                sin[t * half + i] = ang.sin();
            }
        }

        Ok(Engine {
            ternary,
            kernel: KernelKind::ByteDecode,
            embed: embed.data.clone(),
            final_norm: get("final_norm")?.data.clone(),
            lm_head,
            layers,
            cos,
            sin,
            max_t,
            cfg,
        })
    }

    /// Builder-style kernel selection:
    /// `Engine::from_params(..)?.with_kernel(KernelKind::Lut)`.
    pub fn with_kernel(mut self, kernel: KernelKind) -> Engine {
        self.kernel = kernel;
        self
    }

    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim, self.max_t)
    }

    pub fn new_scratch(&self) -> Scratch {
        let c = &self.cfg;
        let max_dim = c.d_model.max(c.q_dim()).max(c.d_ff);
        Scratch {
            x: vec![0.0; c.d_model],
            normed: vec![0.0; c.d_model],
            q: vec![0.0; c.q_dim()],
            k: vec![0.0; c.kv_dim()],
            v: vec![0.0; c.kv_dim()],
            attn_out: vec![0.0; c.q_dim()],
            proj: vec![0.0; c.d_model.max(c.d_ff)],
            gate: vec![0.0; c.d_ff],
            up: vec![0.0; c.d_ff],
            scores: vec![0.0; self.max_t],
            qi8: vec![0i8; max_dim],
            lut: LutScratch::new(),
            logits: vec![0.0; c.vocab],
        }
    }

    /// Weight memory in bytes (the Tables 1-2 "Memory" column, modulo the
    /// unit — see EXPERIMENTS.md for the fp16-equivalent accounting).
    pub fn weight_bytes(&self) -> usize {
        let mut total = self.embed.len() * 4 + self.final_norm.len() * 4;
        if let Some(h) = &self.lm_head {
            total += h.len() * 4;
        }
        for l in &self.layers {
            total += l.attn_norm.len() * 4 + l.ffn_norm.len() * 4;
            if let Some(s) = &l.subln_attn {
                total += s.len() * 4;
            }
            if let Some(s) = &l.subln_ffn {
                total += s.len() * 4;
            }
            for op in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                total += op.weight_bytes();
            }
        }
        total
    }

    pub(crate) fn rope(&self, vec: &mut [f32], n_heads: usize, pos: usize) {
        let hd = self.cfg.head_dim;
        let half = hd / 2;
        let (cos, sin) = (
            &self.cos[pos * half..(pos + 1) * half],
            &self.sin[pos * half..(pos + 1) * half],
        );
        for h in 0..n_heads {
            let v = &mut vec[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (a, b) = (v[i], v[half + i]);
                v[i] = a * cos[i] - b * sin[i];
                v[half + i] = a * sin[i] + b * cos[i];
            }
        }
    }

    /// The context the plain convenience methods run under: serial,
    /// unobserved, with the engine's default [`Engine::kernel`].
    pub(crate) fn serial_ctx(&self) -> ExecCtx {
        ExecCtx::serial().with_kernel(self.kernel)
    }

    /// One decode step: process `token` at position `cache.len`, append to
    /// the cache, return a reference to the logits in `scratch.logits`.
    /// Serial-unobserved shim over [`Engine::decode_step_ctx`], running
    /// the engine's default [`Engine::kernel`].
    pub fn decode_step(&self, token: i32, cache: &mut KvCache, s: &mut Scratch) {
        self.decode_step_ctx(&self.serial_ctx(), token, cache, s);
    }

    /// The canonical single-sequence decode step: every projection/FFN
    /// matmul and the LM head fan across `ctx.pool` workers and run the
    /// `ctx.kernel` generation. Bitwise identical for every thread
    /// count and every kernel — the parallel kernels share the serial
    /// kernels' per-element accumulation order (test-enforced in
    /// [`crate::parallel::gemm`]) and the generations are pinned to
    /// each other in [`super::lut`] / [`super::simd`]. Under
    /// [`KernelKind::Lut`] each quantized activation's per-group tables
    /// are built once (into `s.lut`) and shared across every matrix of
    /// equal `in_dim` (Q/K/V; gate/up); the byte-decode and SIMD
    /// generations consume the quantized codes directly.
    pub fn decode_step_ctx(
        &self,
        ctx: &ExecCtx,
        token: i32,
        cache: &mut KvCache,
        s: &mut Scratch,
    ) {
        let tp = &ctx.pool;
        let kernel = ctx.kernel;
        let c = &self.cfg;
        let (d, hd, nh, nkv) = (c.d_model, c.head_dim, c.n_heads, c.n_kv_heads);
        let rep = nh / nkv;
        let pos = cache.len;
        assert!(pos < cache.max_t, "kv cache exhausted at {pos}");
        // a directly indexed lazy pool slot must keep working, as it
        // did under eager allocation (no-op for acquired/eager caches)
        cache.ensure_allocated();
        let eps = c.norm_eps as f32;

        s.x.copy_from_slice(&self.embed[token as usize * d..(token as usize + 1) * d]);

        for (li, layer) in self.layers.iter().enumerate() {
            // ---- attention ----
            rmsnorm(&s.x, &layer.attn_norm, eps, &mut s.normed);
            if self.ternary {
                let gamma = act_quant_i8(&s.normed, &mut s.qi8[..d]);
                let table = match kernel {
                    KernelKind::Lut => Some(s.lut.build(&s.qi8[..d])),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer.wq.apply_quantized(tp, &s.normed, &s.qi8, gamma, kernel, table, &mut s.q);
                layer.wk.apply_quantized(tp, &s.normed, &s.qi8, gamma, kernel, table, &mut s.k);
                layer.wv.apply_quantized(tp, &s.normed, &s.qi8, gamma, kernel, table, &mut s.v);
            } else {
                layer.wq.apply(tp, &s.normed, &mut s.q, &mut s.qi8, kernel, &mut s.lut);
                layer.wk.apply(tp, &s.normed, &mut s.k, &mut s.qi8, kernel, &mut s.lut);
                layer.wv.apply(tp, &s.normed, &mut s.v, &mut s.qi8, kernel, &mut s.lut);
            }
            self.rope(&mut s.q, nh, pos);
            self.rope(&mut s.k, nkv, pos);

            // append k/v to cache: layout [kvh][t][hd]
            for kh in 0..nkv {
                let dst = kh * cache.max_t * hd + pos * hd;
                cache.k[li][dst..dst + hd].copy_from_slice(&s.k[kh * hd..(kh + 1) * hd]);
                cache.v[li][dst..dst + hd].copy_from_slice(&s.v[kh * hd..(kh + 1) * hd]);
            }

            let scale = 1.0 / (hd as f32).sqrt();
            let t_len = pos + 1;
            for h in 0..nh {
                let kh = h / rep;
                let qv = &s.q[h * hd..(h + 1) * hd];
                let kbase = kh * cache.max_t * hd;
                // scores
                for t in 0..t_len {
                    let kr = &cache.k[li][kbase + t * hd..kbase + t * hd + hd];
                    let mut dot = 0.0f32;
                    for i in 0..hd {
                        dot += qv[i] * kr[i];
                    }
                    s.scores[t] = dot * scale;
                }
                // softmax
                let m = s.scores[..t_len].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for t in 0..t_len {
                    s.scores[t] = (s.scores[t] - m).exp();
                    z += s.scores[t];
                }
                let inv_z = 1.0 / z;
                // weighted value sum
                let out = &mut s.attn_out[h * hd..(h + 1) * hd];
                out.iter_mut().for_each(|o| *o = 0.0);
                let vbase = kh * cache.max_t * hd;
                for t in 0..t_len {
                    let wgt = s.scores[t] * inv_z;
                    let vr = &cache.v[li][vbase + t * hd..vbase + t * hd + hd];
                    for i in 0..hd {
                        out[i] += wgt * vr[i];
                    }
                }
            }
            if let Some(g) = &layer.subln_attn {
                rmsnorm_inplace(&mut s.attn_out, g, eps);
            }
            layer.wo.apply(tp, &s.attn_out, &mut s.proj[..d], &mut s.qi8, kernel, &mut s.lut);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }

            // ---- FFN ----
            rmsnorm(&s.x, &layer.ffn_norm, eps, &mut s.normed);
            if self.ternary {
                let gamma = act_quant_i8(&s.normed, &mut s.qi8[..d]);
                let table = match kernel {
                    KernelKind::Lut => Some(s.lut.build(&s.qi8[..d])),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer
                    .w_gate
                    .apply_quantized(tp, &s.normed, &s.qi8, gamma, kernel, table, &mut s.gate);
                layer.w_up.apply_quantized(tp, &s.normed, &s.qi8, gamma, kernel, table, &mut s.up);
            } else {
                layer.w_gate.apply(tp, &s.normed, &mut s.gate, &mut s.qi8, kernel, &mut s.lut);
                layer.w_up.apply(tp, &s.normed, &mut s.up, &mut s.qi8, kernel, &mut s.lut);
            }
            let use_silu = c.act == "silu";
            for i in 0..c.d_ff {
                let a = if use_silu { silu(s.gate[i]) } else { gelu(s.gate[i]) };
                s.gate[i] = s.up[i] * a;
            }
            if let Some(g) = &layer.subln_ffn {
                rmsnorm_inplace(&mut s.gate, g, eps);
            }
            layer.w_down.apply(tp, &s.gate, &mut s.proj[..d], &mut s.qi8, kernel, &mut s.lut);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
        }

        cache.len = pos + 1;

        // ---- LM head (full precision, as in L2) ----
        rmsnorm_inplace(&mut s.x, &self.final_norm, eps);
        let head: &[f32] = self.lm_head.as_deref().unwrap_or(&self.embed);
        match kernel {
            KernelKind::Simd => par_simd_gemv_f32(tp, head, c.vocab, d, &s.x, &mut s.logits),
            _ => par_gemv_f32(tp, head, c.vocab, d, &s.x, &mut s.logits),
        }
    }

    pub fn new_cache_pool(&self, n_slots: usize) -> KvCachePool {
        KvCachePool::new(self, n_slots)
    }

    pub fn new_batch_scratch(&self, max_b: usize) -> BatchScratch {
        let c = &self.cfg;
        let max_dim = c.d_model.max(c.q_dim()).max(c.d_ff);
        BatchScratch {
            max_b,
            vocab: c.vocab,
            pos: vec![0; max_b],
            x: vec![0.0; max_b * c.d_model],
            normed: vec![0.0; max_b * c.d_model],
            q: vec![0.0; max_b * c.q_dim()],
            k: vec![0.0; max_b * c.kv_dim()],
            v: vec![0.0; max_b * c.kv_dim()],
            attn_out: vec![0.0; max_b * c.q_dim()],
            proj: vec![0.0; max_b * c.d_model],
            gate: vec![0.0; max_b * c.d_ff],
            up: vec![0.0; max_b * c.d_ff],
            scores: vec![0.0; self.max_t],
            qact: vec![0i8; max_b * max_dim],
            gammas: vec![0.0; max_b],
            // grows on the first LUT-kernel step; byte-decode servers
            // (the default) never pay the table memory
            lut: LutScratch::new(),
            gemm: TernGemmScratch::for_batch(max_b),
            logits: vec![0.0; max_b * c.vocab],
        }
    }

    /// Max sequence length a cache slot can hold.
    pub fn max_seq(&self) -> usize {
        self.max_t
    }

    /// One decode step over a dynamic batch: feed `tokens[i]` to the
    /// sequence held in pool slot `slot_ids[i]` (slots must be distinct;
    /// sequences may sit at different positions). Logits for lane `i`
    /// land in `bs.logits_row(i)`.
    ///
    /// The hot matvecs run as batch GEMMs ([`super::gemv::gemm_f32_shared`] /
    /// [`super::gemv::gemm_ternary`]) that stream each weight row once for the whole
    /// batch; everything per-item (norms, RoPE, attention over the lane's
    /// own KV slot, activation quantization) applies exactly the same
    /// arithmetic as [`Engine::decode_step`], so a batch of one is
    /// bitwise identical to the sequential path and co-scheduled lanes
    /// cannot influence each other — both are test-enforced.
    pub fn decode_step_batch(
        &self,
        tokens: &[i32],
        slot_ids: &[usize],
        pool: &mut KvCachePool,
        bs: &mut BatchScratch,
    ) {
        self.decode_step_batch_ctx(&self.serial_ctx(), tokens, slot_ids, pool, bs);
    }

    /// The canonical batched decode step ([`crate::serve::Server`]
    /// drives this with its scheduler-built [`ExecCtx`]): the batch
    /// GEMMs are row-fanned across `ctx.pool` workers and run the
    /// `ctx.kernel` generation — bitwise identical to the serial
    /// batched path, and therefore to [`Engine::decode_step`] at batch
    /// 1, for every thread count and kernel. Under [`KernelKind::Lut`]
    /// each batch of quantized activations gets its tables built once
    /// (into `bs.lut`) and shared across every matrix consuming it
    /// (Q/K/V; gate/up) and all lanes' output rows; byte-decode and
    /// SIMD consume the quantized codes directly.
    ///
    /// Observability rides the context too. `ctx.trace` records the
    /// whole step as one `decode_batch` span (tagged with the batch
    /// size, kernel and thread count) with the final-norm + vocab GEMM
    /// tail as a nested `lm_head` span; `ctx.quant`
    /// (`bitdistill serve --quant-metrics`) observes the two int8
    /// activation-quant sites of the ternary path (`attn_in`, `ffn_in`)
    /// into its per-layer range/saturation accumulators, on the
    /// coordinating thread only. Neither touches an activation, so
    /// observed and unobserved outputs are bitwise identical
    /// (test-enforced in `serve::scheduler` and `tests/serve.rs`); when
    /// disabled each site is one `Option` check.
    pub fn decode_step_batch_ctx(
        &self,
        ctx: &ExecCtx,
        tokens: &[i32],
        slot_ids: &[usize],
        pool: &mut KvCachePool,
        bs: &mut BatchScratch,
    ) {
        let tp = &ctx.pool;
        let kernel = ctx.kernel;
        let trace = &ctx.trace;
        let qs = &ctx.quant;
        let b = tokens.len();
        assert_eq!(b, slot_ids.len());
        let _batch_span = trace.span_args(
            TID_MAIN,
            "decode_batch",
            &[
                ("batch", ArgV::Num(b as f64)),
                ("kernel", ArgV::Str(kernel.name())),
                ("threads", ArgV::Num(tp.threads() as f64)),
            ],
        );
        assert!(b > 0 && b <= bs.max_b, "batch {b} vs scratch capacity {}", bs.max_b);
        // pool slots are lazily backed; acquire() normally does this,
        // but guard here too so a directly indexed slot keeps working
        // (as it did under eager allocation) instead of panicking
        for &slot in slot_ids {
            pool.slots[slot].ensure_allocated();
        }
        let c = &self.cfg;
        let (d, hd, nh, nkv) = (c.d_model, c.head_dim, c.n_heads, c.n_kv_heads);
        let (qd, kvd) = (c.q_dim(), c.kv_dim());
        let rep = nh / nkv;
        let eps = c.norm_eps as f32;

        for i in 0..b {
            let cache = &pool.slots[slot_ids[i]];
            let pos = cache.len;
            assert!(pos < cache.max_t, "kv slot {} exhausted at {pos}", slot_ids[i]);
            bs.pos[i] = pos;
            let t = tokens[i] as usize;
            bs.x[i * d..(i + 1) * d].copy_from_slice(&self.embed[t * d..(t + 1) * d]);
        }

        for (li, layer) in self.layers.iter().enumerate() {
            // ---- attention ----
            for i in 0..b {
                rmsnorm(
                    &bs.x[i * d..(i + 1) * d],
                    &layer.attn_norm,
                    eps,
                    &mut bs.normed[i * d..(i + 1) * d],
                );
            }
            if self.ternary {
                for i in 0..b {
                    bs.gammas[i] = act_quant_i8(
                        &bs.normed[i * d..(i + 1) * d],
                        &mut bs.qact[i * d..(i + 1) * d],
                    );
                }
                if qs.is_enabled() {
                    for i in 0..b {
                        qs.observe_act(li, "attn_in", bs.gammas[i], &bs.qact[i * d..(i + 1) * d]);
                    }
                }
                let tables = match kernel {
                    KernelKind::Lut => Some(bs.lut.build_batch(&bs.qact, d, b)),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer.wq.apply_quantized_batch(
                    tp,
                    &bs.normed,
                    &bs.qact,
                    &bs.gammas,
                    b,
                    kernel,
                    tables,
                    &mut bs.q,
                    &mut bs.gemm,
                );
                layer.wk.apply_quantized_batch(
                    tp,
                    &bs.normed,
                    &bs.qact,
                    &bs.gammas,
                    b,
                    kernel,
                    tables,
                    &mut bs.k,
                    &mut bs.gemm,
                );
                layer.wv.apply_quantized_batch(
                    tp,
                    &bs.normed,
                    &bs.qact,
                    &bs.gammas,
                    b,
                    kernel,
                    tables,
                    &mut bs.v,
                    &mut bs.gemm,
                );
            } else {
                layer.wq.apply_batch(
                    tp,
                    &bs.normed,
                    b,
                    &mut bs.qact,
                    &mut bs.gammas,
                    &mut bs.q,
                    kernel,
                    &mut bs.lut,
                    &mut bs.gemm,
                );
                layer.wk.apply_batch(
                    tp,
                    &bs.normed,
                    b,
                    &mut bs.qact,
                    &mut bs.gammas,
                    &mut bs.k,
                    kernel,
                    &mut bs.lut,
                    &mut bs.gemm,
                );
                layer.wv.apply_batch(
                    tp,
                    &bs.normed,
                    b,
                    &mut bs.qact,
                    &mut bs.gammas,
                    &mut bs.v,
                    kernel,
                    &mut bs.lut,
                    &mut bs.gemm,
                );
            }
            for i in 0..b {
                self.rope(&mut bs.q[i * qd..(i + 1) * qd], nh, bs.pos[i]);
                self.rope(&mut bs.k[i * kvd..(i + 1) * kvd], nkv, bs.pos[i]);
            }

            // append each lane's k/v to its own slot: layout [kvh][t][hd]
            for i in 0..b {
                let cache = &mut pool.slots[slot_ids[i]];
                let pos = bs.pos[i];
                for kh in 0..nkv {
                    let dst = kh * cache.max_t * hd + pos * hd;
                    cache.k[li][dst..dst + hd]
                        .copy_from_slice(&bs.k[i * kvd + kh * hd..i * kvd + (kh + 1) * hd]);
                    cache.v[li][dst..dst + hd]
                        .copy_from_slice(&bs.v[i * kvd + kh * hd..i * kvd + (kh + 1) * hd]);
                }
            }

            let scale = 1.0 / (hd as f32).sqrt();
            for i in 0..b {
                let cache = &pool.slots[slot_ids[i]];
                let t_len = bs.pos[i] + 1;
                for h in 0..nh {
                    let kh = h / rep;
                    let qv = &bs.q[i * qd + h * hd..i * qd + (h + 1) * hd];
                    let kbase = kh * cache.max_t * hd;
                    for t in 0..t_len {
                        let kr = &cache.k[li][kbase + t * hd..kbase + t * hd + hd];
                        let mut dot = 0.0f32;
                        for e in 0..hd {
                            dot += qv[e] * kr[e];
                        }
                        bs.scores[t] = dot * scale;
                    }
                    let m = bs.scores[..t_len]
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for t in 0..t_len {
                        bs.scores[t] = (bs.scores[t] - m).exp();
                        z += bs.scores[t];
                    }
                    let inv_z = 1.0 / z;
                    let out = &mut bs.attn_out[i * qd + h * hd..i * qd + (h + 1) * hd];
                    out.iter_mut().for_each(|o| *o = 0.0);
                    let vbase = kh * cache.max_t * hd;
                    for t in 0..t_len {
                        let wgt = bs.scores[t] * inv_z;
                        let vr = &cache.v[li][vbase + t * hd..vbase + t * hd + hd];
                        for e in 0..hd {
                            out[e] += wgt * vr[e];
                        }
                    }
                }
            }
            if let Some(g) = &layer.subln_attn {
                for i in 0..b {
                    rmsnorm_inplace(&mut bs.attn_out[i * qd..(i + 1) * qd], g, eps);
                }
            }
            layer.wo.apply_batch(
                tp,
                &bs.attn_out,
                b,
                &mut bs.qact,
                &mut bs.gammas,
                &mut bs.proj,
                kernel,
                &mut bs.lut,
                &mut bs.gemm,
            );
            for i in 0..b {
                for j in 0..d {
                    bs.x[i * d + j] += bs.proj[i * d + j];
                }
            }

            // ---- FFN ----
            for i in 0..b {
                rmsnorm(
                    &bs.x[i * d..(i + 1) * d],
                    &layer.ffn_norm,
                    eps,
                    &mut bs.normed[i * d..(i + 1) * d],
                );
            }
            if self.ternary {
                for i in 0..b {
                    bs.gammas[i] = act_quant_i8(
                        &bs.normed[i * d..(i + 1) * d],
                        &mut bs.qact[i * d..(i + 1) * d],
                    );
                }
                if qs.is_enabled() {
                    for i in 0..b {
                        qs.observe_act(li, "ffn_in", bs.gammas[i], &bs.qact[i * d..(i + 1) * d]);
                    }
                }
                let tables = match kernel {
                    KernelKind::Lut => Some(bs.lut.build_batch(&bs.qact, d, b)),
                    KernelKind::ByteDecode | KernelKind::Simd => None,
                };
                layer.w_gate.apply_quantized_batch(
                    tp,
                    &bs.normed,
                    &bs.qact,
                    &bs.gammas,
                    b,
                    kernel,
                    tables,
                    &mut bs.gate,
                    &mut bs.gemm,
                );
                layer.w_up.apply_quantized_batch(
                    tp,
                    &bs.normed,
                    &bs.qact,
                    &bs.gammas,
                    b,
                    kernel,
                    tables,
                    &mut bs.up,
                    &mut bs.gemm,
                );
            } else {
                layer.w_gate.apply_batch(
                    tp,
                    &bs.normed,
                    b,
                    &mut bs.qact,
                    &mut bs.gammas,
                    &mut bs.gate,
                    kernel,
                    &mut bs.lut,
                    &mut bs.gemm,
                );
                layer.w_up.apply_batch(
                    tp,
                    &bs.normed,
                    b,
                    &mut bs.qact,
                    &mut bs.gammas,
                    &mut bs.up,
                    kernel,
                    &mut bs.lut,
                    &mut bs.gemm,
                );
            }
            let use_silu = c.act == "silu";
            for i in 0..b {
                for j in 0..c.d_ff {
                    let g = bs.gate[i * c.d_ff + j];
                    let a = if use_silu { silu(g) } else { gelu(g) };
                    bs.gate[i * c.d_ff + j] = bs.up[i * c.d_ff + j] * a;
                }
            }
            if let Some(g) = &layer.subln_ffn {
                for i in 0..b {
                    rmsnorm_inplace(&mut bs.gate[i * c.d_ff..(i + 1) * c.d_ff], g, eps);
                }
            }
            layer.w_down.apply_batch(
                tp,
                &bs.gate,
                b,
                &mut bs.qact,
                &mut bs.gammas,
                &mut bs.proj,
                kernel,
                &mut bs.lut,
                &mut bs.gemm,
            );
            for i in 0..b {
                for j in 0..d {
                    bs.x[i * d + j] += bs.proj[i * d + j];
                }
            }
        }

        for i in 0..b {
            pool.slots[slot_ids[i]].len = bs.pos[i] + 1;
        }

        // ---- LM head (full precision, as in the sequential path) ----
        let _lm_span = trace.span(TID_MAIN, "lm_head");
        for i in 0..b {
            rmsnorm_inplace(&mut bs.x[i * d..(i + 1) * d], &self.final_norm, eps);
        }
        let head: &[f32] = self.lm_head.as_deref().unwrap_or(&self.embed);
        match kernel {
            KernelKind::Simd => {
                par_simd_gemm_f32_shared(tp, head, c.vocab, d, &bs.x, b, &mut bs.logits)
            }
            _ => par_gemm_f32_shared(tp, head, c.vocab, d, &bs.x, b, &mut bs.logits),
        }
    }

    /// Full-sequence logits (parity tests + classification scoring).
    /// Serial-unobserved shim over [`Engine::forward_logits_ctx`].
    pub fn forward_logits(&self, tokens: &[i32]) -> Vec<Vec<f32>> {
        self.forward_logits_ctx(&self.serial_ctx(), tokens)
    }

    /// The canonical full-sequence scorer: the matmuls fan across
    /// `ctx.pool` workers under `ctx.kernel`; bitwise identical to
    /// serial byte-decode either way. Runs the chunked forward
    /// ([`crate::engine::prefill`]) in all-heads mode — every position's
    /// logits are requested here, so the LM head runs per position, but
    /// the projection/FFN GEMMs are still time-batched; bitwise
    /// identical to the decode_step loop it replaced (the
    /// `forward_logits_equals_repeated_decode_steps` test pins this).
    pub fn forward_logits_ctx(&self, ctx: &ExecCtx, tokens: &[i32]) -> Vec<Vec<f32>> {
        let mut cache = self.new_cache();
        let chunk = super::prefill::DEFAULT_PREFILL_CHUNK.min(tokens.len().max(1));
        let mut ps = self.new_prefill_scratch(chunk);
        let mut out = Vec::with_capacity(tokens.len());
        for ch in tokens.chunks(chunk) {
            self.forward_chunk_ctx(ctx, ch, &mut cache, &mut ps, super::prefill::HeadMode::All);
            for i in 0..ch.len() {
                out.push(ps.logits_row(i).to_vec());
            }
        }
        out
    }

    /// Greedy generation. Returns only the newly generated ids.
    /// Serial-unobserved shim over [`Engine::generate_ctx`], running
    /// the engine's default [`Engine::kernel`].
    pub fn generate(&self, prompt: &[i32], max_new: usize, eos: i32) -> Vec<i32> {
        self.generate_ctx(&self.serial_ctx(), prompt, max_new, eos)
    }

    /// The canonical greedy generator: runs under `ctx`'s pool and
    /// kernel; the kernels are bitwise identical and threading never
    /// moves a bit, so generated ids cannot depend on either
    /// (test-enforced). The prompt runs through the chunked prefill
    /// ([`crate::engine::prefill`]: time-batched GEMMs, LM head only at
    /// the prompt's final token) — bitwise identical to the decode_step
    /// loop it replaced, so generated ids are unchanged.
    pub fn generate_ctx(
        &self,
        ctx: &ExecCtx,
        prompt: &[i32],
        max_new: usize,
        eos: i32,
    ) -> Vec<i32> {
        let mut cache = self.new_cache();
        let mut s = self.new_scratch();
        let chunk = super::prefill::DEFAULT_PREFILL_CHUNK.min(prompt.len().max(1));
        let mut ps = self.new_prefill_scratch(chunk);
        let next = if prompt.is_empty() {
            // degenerate legacy behavior: no prompt, argmax of zeroed
            // logits (token 0)
            argmax(&s.logits)
        } else {
            self.prefill_prompt_ctx(ctx, prompt, chunk, &mut cache, &mut ps);
            argmax(ps.final_logits())
        };
        self.greedy_continue_ctx(ctx, next, max_new, eos, &mut cache, &mut s)
    }

    /// Greedy decode continuing from a prefilled sequence: `next` is
    /// the argmax of the end-of-prompt logits, subsequent tokens decode
    /// through `cache`/`s`. This IS [`Engine::generate`]'s decode loop
    /// (stop order: EOS, then cache capacity, checked before each
    /// emit; `max_new` bounds the count) — the serve bench's sequential
    /// baseline shares it, so the two can never drift apart.
    pub fn greedy_continue_ctx(
        &self,
        ctx: &ExecCtx,
        mut next: i32,
        max_new: usize,
        eos: i32,
        cache: &mut KvCache,
        s: &mut Scratch,
    ) -> Vec<i32> {
        let mut out = Vec::new();
        for _ in 0..max_new {
            if next == eos || cache.len >= cache.max_t {
                break;
            }
            out.push(next);
            self.decode_step_ctx(ctx, next, cache, s);
            next = argmax(&s.logits);
        }
        out
    }

    /// [`Engine::greedy_continue_ctx`] serial, engine-default kernel.
    pub fn greedy_continue(
        &self,
        next: i32,
        max_new: usize,
        eos: i32,
        cache: &mut KvCache,
        s: &mut Scratch,
    ) -> Vec<i32> {
        self.greedy_continue_ctx(&self.serial_ctx(), next, max_new, eos, cache, s)
    }
}

pub fn argmax(v: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i32
}

/// Argmax over a subset of logit indices (the classification
/// verbalizer): returns the index *into `label_ids`* of the winning
/// label. First of equal maxima wins and a NaN logit can never win
/// (strict `>`), matching [`argmax`]'s tie/NaN discipline — the serve
/// scheduler, the bench sequential baseline and the engine eval all
/// share this one definition, so "deployment parity" accuracy can
/// never diverge from served responses on ties.
pub fn argmax_labels(logits: &[f32], label_ids: &[i32]) -> usize {
    let mut best = 0usize;
    for (c, &tid) in label_ids.iter().enumerate() {
        if logits[tid as usize] > logits[label_ids[best] as usize] {
            best = c;
        }
    }
    best
}

#[cfg(test)]
pub(crate) use tests::mini_model;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;
    use crate::substrate::Rng;

    /// Hand-build a miniature ModelSpec + random ParamStore.
    pub(crate) fn mini_model(use_subln: bool, tie: bool) -> (ModelSpec, ParamStore) {
        let cfg = ModelCfg {
            name: "mini".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 8,
            d_ff: 24,
            act: "silu".into(),
            tie_embeddings: tie,
            use_subln,
            quant_method: "absmean".into(),
            rope_theta: 1e4,
            norm_eps: 1e-6,
            seq: 16,
        };
        let l = cfg.n_layers;
        let mut params = vec![("embed".to_string(), vec![cfg.vocab, cfg.d_model], "normal")];
        let block = |name: &str, shape: Vec<usize>, kind: &'static str| {
            (format!("blocks.{name}"), shape, kind)
        };
        let mut blocks = vec![
            block("attn_norm", vec![l, cfg.d_model], "ones"),
            block("wq", vec![l, cfg.d_model, cfg.q_dim()], "normal"),
            block("wk", vec![l, cfg.d_model, cfg.kv_dim()], "normal"),
            block("wv", vec![l, cfg.d_model, cfg.kv_dim()], "normal"),
            block("wo", vec![l, cfg.q_dim(), cfg.d_model], "normal"),
            block("ffn_norm", vec![l, cfg.d_model], "ones"),
            block("w_gate", vec![l, cfg.d_model, cfg.d_ff], "normal"),
            block("w_up", vec![l, cfg.d_model, cfg.d_ff], "normal"),
            block("w_down", vec![l, cfg.d_ff, cfg.d_model], "normal"),
        ];
        if use_subln {
            blocks.insert(5, block("subln_attn", vec![l, cfg.q_dim()], "ones"));
            blocks.push(block("subln_ffn", vec![l, cfg.d_ff], "ones"));
        }
        params.extend(blocks.into_iter().map(|(n, s, k)| (n, s, k)));
        params.push(("final_norm".to_string(), vec![cfg.d_model], "ones"));
        if !tie {
            params.push(("lm_head".to_string(), vec![cfg.d_model, cfg.vocab], "normal"));
        }
        let spec = ModelSpec {
            key: "mini".into(),
            config: cfg,
            n_params: 0,
            params: params
                .iter()
                .map(|(n, s, k)| ParamSpec {
                    name: n.clone(),
                    shape: s.clone(),
                    init_kind: k.to_string(),
                    init_std: 0.05,
                    weight_decay: s.len() >= 2,
                })
                .collect(),
        };
        let mut rng = Rng::new(17);
        let store = ParamStore::init(&spec, &mut rng);
        (spec, store)
    }

    #[test]
    fn decode_produces_finite_logits() {
        for ternary in [false, true] {
            let (spec, store) = mini_model(true, true);
            let e = Engine::from_params(&spec, &store, ternary).unwrap();
            let logits = e.forward_logits(&[1, 5, 9, 2]);
            assert_eq!(logits.len(), 4);
            for l in &logits {
                assert_eq!(l.len(), 32);
                assert!(l.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn incremental_equals_fresh_prefix() {
        // logits at position t must not depend on how many future tokens
        // will be fed — i.e. the cache implements causal attention.
        let (spec, store) = mini_model(true, false);
        let e = Engine::from_params(&spec, &store, false).unwrap();
        let full = e.forward_logits(&[3, 7, 11, 13, 2]);
        let prefix = e.forward_logits(&[3, 7, 11]);
        for (a, b) in full[..3].iter().zip(&prefix) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ternary_memory_much_smaller() {
        let (spec, store) = mini_model(true, true);
        let f = Engine::from_params(&spec, &store, false).unwrap();
        let t = Engine::from_params(&spec, &store, true).unwrap();
        assert!(t.weight_bytes() < f.weight_bytes());
        // linear weights dominate at real sizes; at mini size just check
        // the packed ops individually
        for (lf, lt) in f.layers.iter().zip(&t.layers) {
            assert!(lt.wq.weight_bytes() * 10 < lf.wq.weight_bytes() * 11 / 4 * 4);
            assert!(lt.w_down.weight_bytes() < lf.w_down.weight_bytes() / 8);
        }
    }

    #[test]
    fn rope_matches_complex_rotation() {
        // rotate-half RoPE == multiplication by e^{i * pos * freq} on the
        // (x_j, x_{j+half}) pairs.
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, false).unwrap();
        let hd = e.cfg.head_dim;
        let half = hd / 2;
        let mut v: Vec<f32> = (0..hd).map(|i| (i as f32 * 0.37).sin()).collect();
        let orig = v.clone();
        let pos = 5;
        e.rope(&mut v, 1, pos);
        for i in 0..half {
            let freq = 1.0 / (e.cfg.rope_theta as f32).powf(i as f32 / half as f32);
            let ang = pos as f32 * freq;
            let (re, im) = (orig[i], orig[half + i]);
            let want_re = re * ang.cos() - im * ang.sin();
            let want_im = re * ang.sin() + im * ang.cos();
            assert!((v[i] - want_re).abs() < 1e-5, "re {i}");
            assert!((v[half + i] - want_im).abs() < 1e-5, "im {i}");
        }
    }

    #[test]
    fn argmax_ties_break_to_first_max() {
        // deterministic tie-breaking anchors greedy decode and the serve
        // layer's classification argmax across refactors
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1, "first of equal maxima wins");
        assert_eq!(argmax(&[5.0, 5.0, 5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -1.0]), 1);
        assert_eq!(argmax(&[0.0]), 0);
        // strict `>` also makes trailing NaNs lose (NaN comparisons are
        // false), so a stray NaN cannot hijack the prediction
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
    }

    #[test]
    fn argmax_labels_shares_argmax_tie_and_nan_discipline() {
        // one definition serves the scheduler, the bench baseline and
        // the engine eval: first of equal maxima wins, and (as with
        // argmax) a trailing NaN logit cannot displace a real one
        let logits = [0.5f32, 2.0, 2.0, f32::NAN, -1.0];
        assert_eq!(argmax_labels(&logits, &[1, 2]), 0, "first of equal maxima");
        assert_eq!(argmax_labels(&logits, &[2, 1]), 0);
        assert_eq!(argmax_labels(&logits, &[4, 3]), 0, "NaN cannot displace");
        assert_eq!(argmax_labels(&logits, &[0, 3, 1]), 2);
        // the result indexes label_ids, not the vocab
        assert_eq!(argmax_labels(&logits, &[4, 0, 1]), 2);
    }

    #[test]
    fn forward_logits_equals_repeated_decode_steps() {
        // the contract the train->export path leans on: full-sequence
        // scoring (forward_logits, a decode_step loop) must be bitwise
        // identical to feeding the sequence one token at a time through
        // the *independently implemented* batched decode path — so this
        // also pins any future forward_logits rewrite (batched prefill
        // etc.) to the per-token reference.
        for ternary in [false, true] {
            let (spec, store) = mini_model(true, true);
            let e = Engine::from_params(&spec, &store, ternary).unwrap();
            let tokens = [3i32, 9, 1, 7, 4, 2, 11, 5];
            let full = e.forward_logits(&tokens);
            assert_eq!(full.len(), tokens.len());
            let mut pool = e.new_cache_pool(1);
            let mut bs = e.new_batch_scratch(1);
            let slot = pool.acquire().unwrap();
            for (pos, &tok) in tokens.iter().enumerate() {
                e.decode_step_batch(&[tok], &[slot], &mut pool, &mut bs);
                assert_eq!(
                    bs.logits_row(0),
                    full[pos].as_slice(),
                    "ternary={ternary} pos={pos}"
                );
            }
            assert_eq!(pool.slots[slot].len, tokens.len());
        }
    }

    #[test]
    fn cache_reset_reproduces_first_pass() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let mut cache = e.new_cache();
        let mut s = e.new_scratch();
        let toks = [3, 9, 1, 7];
        let mut first = Vec::new();
        for &t in &toks {
            e.decode_step(t, &mut cache, &mut s);
            first.push(s.logits.clone());
        }
        cache.reset();
        for (i, &t) in toks.iter().enumerate() {
            e.decode_step(t, &mut cache, &mut s);
            for (a, b) in s.logits.iter().zip(&first[i]) {
                assert_eq!(a, b, "reset cache diverged at pos {i}");
            }
        }
    }

    #[test]
    fn generate_terminates_and_is_deterministic() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let a = e.generate(&[1, 4, 6], 8, 2);
        let b = e.generate(&[1, 4, 6], 8, 2);
        assert_eq!(a, b);
        assert!(a.len() <= 8);
    }

    #[test]
    fn batch_of_one_is_bitwise_identical_to_decode_step() {
        // The serve-layer contract: lifting the matvecs to batch GEMMs
        // must not change a single bit of the logits at batch 1.
        for ternary in [false, true] {
            for tie in [true, false] {
                let (spec, store) = mini_model(true, tie);
                let e = Engine::from_params(&spec, &store, ternary).unwrap();
                let mut cache = e.new_cache();
                let mut s = e.new_scratch();
                let mut pool = e.new_cache_pool(1);
                let mut bs = e.new_batch_scratch(1);
                let slot = pool.acquire().unwrap();
                for &t in &[3i32, 9, 1, 7, 4, 2] {
                    e.decode_step(t, &mut cache, &mut s);
                    e.decode_step_batch(&[t], &[slot], &mut pool, &mut bs);
                    assert_eq!(
                        s.logits.as_slice(),
                        bs.logits_row(0),
                        "ternary={ternary} tie={tie}"
                    );
                }
                assert_eq!(pool.slots[slot].len, cache.len);
            }
        }
    }

    #[test]
    fn threaded_decode_is_bitwise_identical_to_serial() {
        // the tentpole contract end to end at engine level: fanning the
        // projections/FFN/head across workers must not move one bit of
        // the logits, single-sequence or batched, for any thread count.
        for ternary in [false, true] {
            let (spec, store) = mini_model(true, true);
            let e = Engine::from_params(&spec, &store, ternary).unwrap();
            let tokens = [3i32, 9, 1, 7, 4, 2];
            let want = e.forward_logits(&tokens);
            for threads in [2usize, 3, 8] {
                let tp = ThreadPool::with_granularity(threads, 1);
                let ctx = ExecCtx::serial().with_pool(tp);
                let got = e.forward_logits_ctx(&ctx, &tokens);
                for (pos, (a, b)) in got.iter().zip(&want).enumerate() {
                    let same = a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "ternary={ternary} threads={threads} pos={pos}");
                }
                // batched path, two co-scheduled lanes
                let mut pool = e.new_cache_pool(2);
                let mut bs = e.new_batch_scratch(2);
                let (sa, sb) = (pool.acquire().unwrap(), pool.acquire().unwrap());
                let mut serial_pool = e.new_cache_pool(2);
                let mut serial_bs = e.new_batch_scratch(2);
                let (ca, cb) = (
                    serial_pool.acquire().unwrap(),
                    serial_pool.acquire().unwrap(),
                );
                for (i, &t) in tokens.iter().enumerate() {
                    let u = tokens[(i + 1) % tokens.len()];
                    e.decode_step_batch_ctx(&ctx, &[t, u], &[sa, sb], &mut pool, &mut bs);
                    e.decode_step_batch(&[t, u], &[ca, cb], &mut serial_pool, &mut serial_bs);
                    for lane in 0..2 {
                        let same = bs
                            .logits_row(lane)
                            .iter()
                            .zip(serial_bs.logits_row(lane))
                            .all(|(x, y)| x.to_bits() == y.to_bits());
                        assert!(same, "ternary={ternary} threads={threads} step={i} lane={lane}");
                    }
                }
            }
        }
    }

    #[test]
    fn alternate_kernel_logits_are_bitwise_identical_to_byte_decode() {
        // the tentpole contract at engine level: flipping KernelKind
        // must not move one bit of the logits — single-sequence or
        // batched, serial or thread-fanned, for every kernel
        // generation (LUT and runtime-dispatched SIMD).
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        for kernel in [KernelKind::Lut, KernelKind::Simd] {
            let alt = Engine::from_params(&spec, &store, true).unwrap().with_kernel(kernel);
            assert_eq!(alt.kernel, kernel);
            let tokens = [3i32, 9, 1, 7, 4, 2];
            let want = e.forward_logits(&tokens);
            for threads in [1usize, 3] {
                let tp = ThreadPool::with_granularity(threads, 1);
                let ctx = ExecCtx::serial().with_pool(tp).with_kernel(kernel);
                let got = alt.forward_logits_ctx(&ctx, &tokens);
                for (pos, (a, b)) in got.iter().zip(&want).enumerate() {
                    let same = a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "kernel={} threads={threads} pos={pos}", kernel.name());
                }
                // batched path, two co-scheduled lanes, explicit ctx kernel
                let mut pool = alt.new_cache_pool(2);
                let mut bs = alt.new_batch_scratch(2);
                let (sa, sb) = (pool.acquire().unwrap(), pool.acquire().unwrap());
                let mut byte_pool = e.new_cache_pool(2);
                let mut byte_bs = e.new_batch_scratch(2);
                let (ca, cb) = (byte_pool.acquire().unwrap(), byte_pool.acquire().unwrap());
                for (i, &t) in tokens.iter().enumerate() {
                    let u = tokens[(i + 1) % tokens.len()];
                    alt.decode_step_batch_ctx(&ctx, &[t, u], &[sa, sb], &mut pool, &mut bs);
                    e.decode_step_batch(&[t, u], &[ca, cb], &mut byte_pool, &mut byte_bs);
                    for lane in 0..2 {
                        let same = bs
                            .logits_row(lane)
                            .iter()
                            .zip(byte_bs.logits_row(lane))
                            .all(|(x, y)| x.to_bits() == y.to_bits());
                        assert!(
                            same,
                            "kernel={} threads={threads} step={i} lane={lane}",
                            kernel.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn generate_is_byte_identical_under_every_kernel() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let want = e.generate(&[1, 4, 6], 8, 2);
        for kernel in [KernelKind::Lut, KernelKind::Simd] {
            let alt = Engine::from_params(&spec, &store, true).unwrap().with_kernel(kernel);
            assert_eq!(alt.generate(&[1, 4, 6], 8, 2), want, "kernel={}", kernel.name());
            // explicit-ctx entry point agrees too, threaded
            let ctx = ExecCtx::serial()
                .with_pool(ThreadPool::with_granularity(3, 1))
                .with_kernel(kernel);
            assert_eq!(e.generate_ctx(&ctx, &[1, 4, 6], 8, 2), want, "kernel={}", kernel.name());
        }
    }

    #[test]
    fn cobatched_sequences_do_not_interact() {
        // A sequence decoded alone must produce exactly the same logits
        // as the same sequence co-scheduled with arbitrary neighbours
        // that join late and retire early.
        for ternary in [false, true] {
            let (spec, store) = mini_model(true, true);
            let e = Engine::from_params(&spec, &store, ternary).unwrap();
            let seq_a = [1i32, 5, 9, 2, 8];
            let seq_b = [7i32, 7, 3];

            let mut pool = e.new_cache_pool(2);
            let mut bs = e.new_batch_scratch(2);

            // solo pass of `a`
            let sa = pool.acquire().unwrap();
            let mut solo = Vec::new();
            for &t in &seq_a {
                e.decode_step_batch(&[t], &[sa], &mut pool, &mut bs);
                solo.push(bs.logits_row(0).to_vec());
            }
            pool.release(sa);

            // co-scheduled: `b` joins at step 1 and retires after 3 steps
            let sa = pool.acquire().unwrap();
            let sb = pool.acquire().unwrap();
            e.decode_step_batch(&[seq_a[0]], &[sa], &mut pool, &mut bs);
            assert_eq!(bs.logits_row(0), &solo[0][..], "step 0 ternary={ternary}");
            for i in 1..=3 {
                e.decode_step_batch(&[seq_a[i], seq_b[i - 1]], &[sa, sb], &mut pool, &mut bs);
                assert_eq!(bs.logits_row(0), &solo[i][..], "step {i} ternary={ternary}");
            }
            pool.release(sb);
            e.decode_step_batch(&[seq_a[4]], &[sa], &mut pool, &mut bs);
            assert_eq!(bs.logits_row(0), &solo[4][..], "step 4 ternary={ternary}");
        }
    }

    #[test]
    fn cache_pool_reuses_released_slots() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let mut pool = e.new_cache_pool(2);
        assert_eq!(pool.capacity(), 2);
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        assert_ne!(a, b);
        assert_eq!(pool.n_free(), 0);
        assert!(pool.acquire().is_none());

        // dirty slot `a`, release, re-acquire: must come back reset
        let mut bs = e.new_batch_scratch(1);
        e.decode_step_batch(&[3], &[a], &mut pool, &mut bs);
        assert_eq!(pool.slots[a].len, 1);
        pool.release(a);
        let a2 = pool.acquire().unwrap();
        assert_eq!(a2, a);
        assert_eq!(pool.slots[a2].len, 0);
        assert!(pool.memory_bytes() > 0);
    }

    #[test]
    fn cache_pool_allocates_slots_lazily_with_honest_memory() {
        let (spec, store) = mini_model(true, true);
        let e = Engine::from_params(&spec, &store, true).unwrap();
        let mut pool = e.new_cache_pool(4);
        // nothing is backed at construction
        assert_eq!(pool.memory_bytes(), 0);
        assert!(pool.slots.iter().all(|s| !s.is_allocated()));

        let a = pool.acquire().unwrap();
        let after_one = pool.memory_bytes();
        assert!(after_one > 0, "first acquire must back the slot");
        assert!(pool.slots[a].is_allocated());
        // untouched slots stay unallocated
        assert_eq!(pool.slots.iter().filter(|s| s.is_allocated()).count(), 1);

        let b = pool.acquire().unwrap();
        assert_eq!(pool.memory_bytes(), 2 * after_one);
        // release + re-acquire reuses the backing without growth
        pool.release(a);
        pool.release(b);
        let _ = pool.acquire().unwrap();
        let _ = pool.acquire().unwrap();
        assert_eq!(pool.memory_bytes(), 2 * after_one);
        // a fully-eager single cache matches one slot's footprint
        assert_eq!(e.new_cache().memory_bytes(), after_one);
    }

    #[test]
    fn reused_pool_slot_is_bitwise_identical_to_fresh() {
        // the lazy-pool regression: decoding into a dirty, re-acquired
        // slot (reset without re-zeroing) must produce exactly the bits
        // a fresh pool produces — stale K/V beyond `len` is never read
        for ternary in [false, true] {
            let (spec, store) = mini_model(true, true);
            let e = Engine::from_params(&spec, &store, ternary).unwrap();
            let mut bs = e.new_batch_scratch(1);

            // fresh pool reference for sequence B
            let seq_b = [7i32, 2, 9, 4];
            let mut fresh = e.new_cache_pool(1);
            let fs = fresh.acquire().unwrap();
            let mut want = Vec::new();
            for &t in &seq_b {
                e.decode_step_batch(&[t], &[fs], &mut fresh, &mut bs);
                want.push(bs.logits_row(0).to_vec());
            }

            // dirty the slot with a longer sequence A, release, reuse
            let mut pool = e.new_cache_pool(1);
            let s0 = pool.acquire().unwrap();
            for &t in &[1i32, 5, 3, 8, 6, 2, 4, 9] {
                e.decode_step_batch(&[t], &[s0], &mut pool, &mut bs);
            }
            pool.release(s0);
            let s1 = pool.acquire().unwrap();
            assert_eq!(s1, s0);
            assert_eq!(pool.slots[s1].len, 0);
            for (pos, &t) in seq_b.iter().enumerate() {
                e.decode_step_batch(&[t], &[s1], &mut pool, &mut bs);
                let same = bs
                    .logits_row(0)
                    .iter()
                    .zip(&want[pos])
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "ternary={ternary} pos={pos}: reused slot diverged");
            }
        }
    }

    #[test]
    fn ternary_and_f32_agree_on_easy_inputs() {
        // ternary is a coarse approximation; just require the same top
        // token often enough on a tiny model to catch orientation bugs.
        let (spec, store) = mini_model(true, true);
        let f = Engine::from_params(&spec, &store, false).unwrap();
        let t = Engine::from_params(&spec, &store, true).unwrap();
        let lf = f.forward_logits(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let lt = t.forward_logits(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let mut corr_sum = 0.0;
        for (a, b) in lf.iter().zip(&lt) {
            let ma = a.iter().sum::<f32>() / a.len() as f32;
            let mb = b.iter().sum::<f32>() / b.len() as f32;
            let (mut num, mut da, mut db) = (0.0, 0.0, 0.0);
            for (x, y) in a.iter().zip(b) {
                num += (x - ma) * (y - mb);
                da += (x - ma) * (x - ma);
                db += (y - mb) * (y - mb);
            }
            corr_sum += num / (da.sqrt() * db.sqrt() + 1e-9);
        }
        // Random weights at d=16 are heavily distorted by per-tensor
        // ternarization compounding over 2 layers, so only require weak
        // positive correlation here; the *exact* numerics check is the
        // integration test against the `*_student_fwd` HLO executable
        // (rust/tests/parity.rs), which quantizes identically.
        let corr = corr_sum / lf.len() as f32;
        assert!(corr > 0.1, "f32/ternary logits decorrelated: {corr}");
    }
}
