//! End-to-end decode benchmark: tokens/s + memory for the f32 vs ternary
//! engines at every model size — the Speed/Memory columns of Tables 1-2
//! and the right panels of Fig. 1.

// Bench/example crate roots sit outside src/lib.rs, so the Cargo.toml
// clippy deny-list (unwrap_used & co.) is re-allowed here: panicking on
// bad setup is the right behavior for a demo or harness, as in tests.
#![allow(clippy::unwrap_used, clippy::indexing_slicing, clippy::float_cmp)]

use bitnet_distill::bench::speed_report;
use bitnet_distill::engine::KernelKind;
use bitnet_distill::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP engine bench: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::open("artifacts")?;
    for size in ["tiny", "small", "base"] {
        for kernel in KernelKind::ALL {
            println!("{}", speed_report(&rt, size, 384, kernel)?);
        }
    }
    Ok(())
}
